"""Tensor-Train embedding-table shape planning (shared by kernels & model).

A plain embedding table ``W ∈ R^{M×N}`` is factored (paper Eq. 2) as a
3-core tensor train:

    D1 ∈ R^{m1, n1, R1}          (boundary rank R0 = 1)
    D2 ∈ R^{R1, m2, n2, R2}
    D3 ∈ R^{R2, m3, n3}          (boundary rank R3 = 1)

with ``M = m1·m2·m3`` and ``N = n1·n2·n3``.  Row ``i`` decomposes into TT
indices (paper Eq. 5, row-major):

    i1 = i // (m2·m3)
    i2 = (i // m3) % m2
    i3 = i % m3

and the *reuse prefix* of the paper's Algorithm 1 is ``i // m3`` — two rows
sharing it read the same slices of D1 and D2, so the partial product
``D1[i1] @ D2[:, i2]`` can be computed once per distinct prefix and kept in
the Reuse Buffer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple


def factorize3(x: int) -> Tuple[int, int, int]:
    """Split ``x`` into three factors as close to x^(1/3) as possible.

    Mirrors ``rust/src/tt/shapes.rs::factorize3`` — the two sides must agree
    so that artifacts and the native engine index cores identically.
    """
    if x <= 0:
        raise ValueError(f"cannot factorize non-positive {x}")
    best = (1, 1, x)
    best_cost = _spread((1, 1, x))
    for a in range(1, int(round(x ** (1.0 / 3.0))) + 2):
        if x % a:
            continue
        rem = x // a
        for b in range(a, int(math.isqrt(rem)) + 1):
            if rem % b:
                continue
            cand = tuple(sorted((a, b, rem // b)))
            cost = _spread(cand)
            if cost < best_cost:
                best, best_cost = cand, cost
    return best  # ascending: m1 <= m2 <= m3


def _spread(f: Sequence[int]) -> int:
    return max(f) - min(f)


def padded_rows(rows: int) -> int:
    """Smallest M >= rows whose factorize3 is 'balanced enough'.

    Embedding tables rarely have smooth cardinalities; like TT-Rec we pad
    the virtual row count so it factors into three near-cubic terms (excess
    rows are simply never addressed).
    """
    m = rows
    while True:
        f = factorize3(m)
        if max(f) <= 4 * min(f) or max(f) <= 64:
            return m
        m += 1


@dataclasses.dataclass(frozen=True)
class TtSpec:
    """Complete shape plan for one Eff-TT table."""

    rows: int           # logical row count M' (pre-padding)
    dim: int            # embedding dim N
    m: Tuple[int, int, int]
    n: Tuple[int, int, int]
    rank: int           # R1 == R2 == R (R0 = R3 = 1)

    @staticmethod
    def plan(rows: int, dim: int, rank: int = 16) -> "TtSpec":
        m = factorize3(padded_rows(rows))
        n = factorize3(dim)
        if n[0] * n[1] * n[2] != dim:
            raise ValueError(f"dim {dim} not factorable into 3 terms")
        return TtSpec(rows=rows, dim=dim, m=m, n=n, rank=rank)

    # -- core shapes ------------------------------------------------------
    @property
    def core_shapes(self) -> List[Tuple[int, ...]]:
        m1, m2, m3 = self.m
        n1, n2, n3 = self.n
        r = self.rank
        return [(m1, n1, r), (r, m2, n2, r), (r, m3, n3)]

    @property
    def padded_m(self) -> int:
        return self.m[0] * self.m[1] * self.m[2]

    # -- index math (must mirror rust/src/tt/shapes.rs) --------------------
    def tt_indices(self, i: int) -> Tuple[int, int, int]:
        m2, m3 = self.m[1], self.m[2]
        return i // (m2 * m3), (i // m3) % m2, i % m3

    def prefix_of(self, i: int) -> int:
        """Reuse-buffer key (Algorithm 1: ``Bufe_index = Index / length_3``)."""
        return i // self.m[2]

    # -- accounting --------------------------------------------------------
    def tt_params(self) -> int:
        return sum(int(math.prod(s)) for s in self.core_shapes)

    def plain_params(self) -> int:
        return self.rows * self.dim

    def compression_ratio(self) -> float:
        return self.plain_params() / self.tt_params()

    def vmem_bytes(self, batch_prefixes: int) -> int:
        """Estimated VMEM residency for one kernel tile (see DESIGN §8):
        all three cores + the reuse-buffer scratch [U, n1*n2, R]."""
        cores = self.tt_params() * 4
        scratch = batch_prefixes * self.n[0] * self.n[1] * self.rank * 4
        return cores + scratch
