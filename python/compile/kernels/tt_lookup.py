"""Eff-TT embedding lookup (paper §III-B/C) built on the Pallas bgemm kernel.

The lookup of row ``i`` from a 3-core TT table is two chained GEMMs:

    P(i1,i2) = D1[i1]  @ D2[:, i2]        # [n1,R] @ [R, n2·R]  -> "prefix"
    row(i)   = P(i1,i2) @ D3[:, i3]       # [n1·n2, R] @ [R, n3]

The Eff-TT insight: rows sharing the prefix ``p = i // m3`` share P, so P
is computed **once per distinct prefix in the batch** and held in the
Reuse Buffer (Algorithm 1).  The paper deduplicates with a CUDA
atomicCAS flag array; the TPU/Pallas rethink (DESIGN.md §3) deduplicates
with ``jnp.unique`` (integer work outside the kernel, folded into the same
HLO) and contracts one GEMM per *unique* prefix on the MXU.

Both GEMM hops run through :func:`kernels.bgemm.bgemm`, so forward AND
backward (via bgemm's custom VJP) execute in the Pallas kernel.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.tt_spec import TtSpec
from compile.kernels.bgemm import bgemm


def split_indices(spec: TtSpec, idx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Flat row index -> (reuse prefix ``i//m3``, last TT index ``i%m3``)."""
    m3 = spec.m[2]
    return idx // m3, idx % m3


def prefix_products(spec: TtSpec, cores, prefixes: jax.Array) -> jax.Array:
    """Reuse-Buffer contents: P[g] for each (already unique) prefix.

    prefixes: [U] int32 with values in [0, m1*m2).
    Returns [U, n1*n2, R].
    """
    d1, d2, _ = cores
    m2 = spec.m[1]
    n1, n2, _ = spec.n
    r = spec.rank
    i1 = prefixes // m2
    i2 = prefixes % m2
    a = jnp.take(d1, i1, axis=0)                       # [U, n1, R]
    b = jnp.take(d2, i2, axis=1)                       # [R, U, n2, R]
    b = jnp.transpose(b, (1, 0, 2, 3)).reshape(-1, r, n2 * r)  # [U, R, n2·R]
    p = bgemm(a, b)                                    # [U, n1, n2·R]
    return p.reshape(-1, n1 * n2, r)


def tt_lookup(spec: TtSpec, cores, indices: jax.Array) -> jax.Array:
    """Gather rows [..., N] from the TT table with prefix reuse.

    indices: any int32 shape; flattened internally.  The unique() size is
    static (= #indices) as required under jit; padding slots recompute
    prefix 0 harmlessly (they are never scattered to output).
    """
    shape = indices.shape
    flat = indices.reshape(-1)
    k = flat.shape[0]
    pref, i3 = split_indices(spec, flat)

    # --- Reuse-Buffer construction: one P per distinct prefix ------------
    uniq, inv = jnp.unique(pref, return_inverse=True, size=k, fill_value=0)
    p = prefix_products(spec, cores, uniq)             # [k, n1·n2, R]

    # --- second hop: gather P by inverse map, contract with D3 slices ----
    d3 = cores[2]                                      # [R, m3, n3]
    c = jnp.take(d3, i3, axis=1)                       # [R, k, n3]
    c = jnp.transpose(c, (1, 0, 2))                    # [k, R, n3]
    rows = bgemm(jnp.take(p, inv, axis=0), c)          # [k, n1·n2, n3]
    return rows.reshape(*shape, spec.dim)


def tt_lookup_noreuse(spec: TtSpec, cores, indices: jax.Array) -> jax.Array:
    """Ablation path (Fig. 12 'w/o intermediate reuse'): recompute P for
    every index occurrence — the TT-Rec baseline behaviour."""
    shape = indices.shape
    flat = indices.reshape(-1)
    pref, i3 = split_indices(spec, flat)
    p = prefix_products(spec, cores, pref)             # [k, n1·n2, R] (dup work)
    d3 = cores[2]
    c = jnp.transpose(jnp.take(d3, i3, axis=1), (1, 0, 2))
    rows = bgemm(p, c)
    return rows.reshape(*shape, spec.dim)


def tt_embedding_bag(spec: TtSpec, cores, indices: jax.Array,
                     reuse: bool = True) -> jax.Array:
    """nn.EmbeddingBag(mode='sum') drop-in (the paper's API claim).

    indices: [B, K] -> pooled [B, N].
    """
    f = tt_lookup if reuse else tt_lookup_noreuse
    rows = f(spec, cores, indices)                     # [B, K, N]
    return rows.sum(axis=1)


def init_cores(spec: TtSpec, key: jax.Array) -> Tuple[jax.Array, ...]:
    """TT-Rec-style init: cores ~ N(0, σ) with σ chosen so the materialized
    rows have variance ≈ 1/dim (matching nn.EmbeddingBag defaults)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2, s3 = spec.core_shapes
    # Var(row) ≈ (σ²)³ · R² — pick σ = (1/(dim · R²))^(1/6)
    sigma = (1.0 / (spec.dim * spec.rank ** 2)) ** (1.0 / 6.0)
    return (
        jax.random.normal(k1, s1, jnp.float32) * sigma,
        jax.random.normal(k2, s2, jnp.float32) * sigma,
        jax.random.normal(k3, s3, jnp.float32) * sigma,
    )
