"""DLRM pairwise-dot feature interaction as a Pallas kernel.

The interaction layer (paper Fig. 2) projects the bottom-MLP output and all
sparse embeddings into a shared space, computes all pairwise dot products
Z·Zᵀ, and keeps the strictly-lower triangle.  On TPU this is a single
[F,D]×[D,F] MXU matmul per sample; the batch is tiled over the grid.

``pallas_call`` has no automatic transpose rule, so the gram product
carries a ``jax.custom_vjp``: for G = Z·Zᵀ, dZ = (dG + dGᵀ)·Z — one more
batched matmul, routed through the same bgemm Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.bgemm import _bgemm_raw

B_BLOCK = 32


def _gram_kernel(z_ref, o_ref):
    """z_ref: [B_BLOCK, F, D] -> o_ref: [B_BLOCK, F, F] (full gram)."""
    z = z_ref[...]
    o_ref[...] = jax.lax.dot_general(
        z, z,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _gram_raw(z: jax.Array) -> jax.Array:
    b, f, d = z.shape
    bp = (b + B_BLOCK - 1) // B_BLOCK * B_BLOCK
    zp = jnp.pad(z, ((0, bp - b), (0, 0), (0, 0))) if bp != b else z
    out = pl.pallas_call(
        _gram_kernel,
        grid=(bp // B_BLOCK,),
        in_specs=[pl.BlockSpec((B_BLOCK, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((B_BLOCK, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, f, f), jnp.float32),
        interpret=True,
    )(zp)
    return out[:b]


@jax.custom_vjp
def gram(z: jax.Array) -> jax.Array:
    """Batched Z·Zᵀ [B, F, F] via the Pallas kernel."""
    return _gram_raw(z)


def _gram_fwd(z):
    return _gram_raw(z), z


def _gram_bwd(z, dg):
    # d/dZ tr(dGᵀ·Z Zᵀ) = (dG + dGᵀ)·Z
    return (_bgemm_raw(dg + jnp.swapaxes(dg, 1, 2), z),)


gram.defvjp(_gram_fwd, _gram_bwd)


def interaction(z: jax.Array) -> jax.Array:
    """[B, F, D] -> [B, F(F-1)/2] lower-triangular pairwise dots.

    The gram matrix is produced by the Pallas kernel; the (cheap, gather-
    only) triangle extraction stays in XLA where it fuses with the top-MLP
    concat.
    """
    b, f, _ = z.shape
    g = gram(z)
    li, lj = jnp.tril_indices(f, k=-1)
    return g[:, li, lj]
