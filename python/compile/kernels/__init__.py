"""Pallas kernels for the Eff-TT / DLRM hot path (L1)."""
from compile.kernels.bgemm import bgemm  # noqa: F401
from compile.kernels.tt_lookup import (  # noqa: F401
    tt_lookup, tt_lookup_noreuse, tt_embedding_bag, init_cores,
)
from compile.kernels.tt_grad import tt_core_grads, fused_sgd_update  # noqa: F401
from compile.kernels.interaction import interaction  # noqa: F401
