"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Nothing here is performance-relevant: these functions materialize the full
embedding table from the TT cores and use textbook ops, so they are easy to
audit against the paper's Eq. 1/2/6/8 and serve as the `assert_allclose`
reference for pytest/hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.tt_spec import TtSpec


def bgemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """einsum oracle for kernels.bgemm."""
    return jnp.einsum("gmk,gkn->gmn", a, b)


def materialize(spec: TtSpec, cores) -> jax.Array:
    """Reconstruct the full (padded) embedding table W [padded_M, N].

    Direct transcription of paper Eq. 2:
        W[(i1 j1),(i2 j2),(i3 j3)] = D1[i1,j1,:] · D2[:,i2,j2,:] · D3[:,i3,j3]
    """
    d1, d2, d3 = cores
    m1, m2, m3 = spec.m
    n1, n2, n3 = spec.n
    # [m1,n1,r] x [r,m2,n2,r] -> [m1,n1,m2,n2,r]
    p = jnp.einsum("aur,rbvs->aubvs", d1, d2)
    # ... x [r,m3,n3] -> [m1,n1,m2,n2,m3,n3]
    w = jnp.einsum("aubvs,scw->aubvcw", p, d3)
    # rows are (i1,i2,i3) row-major, cols are (j1,j2,j3) row-major
    w = jnp.transpose(w, (0, 2, 4, 1, 3, 5))
    return w.reshape(m1 * m2 * m3, n1 * n2 * n3)


def lookup_ref(spec: TtSpec, cores, indices: jax.Array) -> jax.Array:
    """Plain-table lookup oracle: rows of the materialized table.

    indices: [...] int32 -> [..., N] f32.
    """
    w = materialize(spec, cores)
    return jnp.take(w, indices, axis=0)


def pooled_lookup_ref(spec: TtSpec, cores, indices: jax.Array) -> jax.Array:
    """EmbeddingBag(sum) oracle: indices [B, K] -> [B, N]."""
    return lookup_ref(spec, cores, indices).sum(axis=1)


def interaction_ref(z: jax.Array) -> jax.Array:
    """DLRM pairwise-dot feature interaction oracle.

    z: [B, F, D] stacked feature vectors (bottom-MLP output + embeddings).
    Returns [B, F(F-1)/2]: the strictly-lower-triangular entries of Z·Zᵀ,
    row-major — identical to Facebook DLRM's `interact_features`.
    """
    b, f, _ = z.shape
    zz = jnp.einsum("bfd,bgd->bfg", z, z)
    li, lj = jnp.tril_indices(f, k=-1)
    return zz[:, li, lj]


def tt_core_grads_ref(spec: TtSpec, cores, indices: jax.Array,
                      d_out: jax.Array):
    """Oracle for the backward pass (paper Eq. 8) via jax autodiff.

    indices: [B, K]; d_out: [B, N] gradient of the pooled embedding.
    Returns grads for (d1, d2, d3).
    """
    def f(cs):
        return pooled_lookup_ref(spec, cs, indices)

    _, vjp = jax.vjp(f, tuple(cores))
    (gc,) = vjp(d_out)
    return gc
