"""Explicit Eff-TT backward with advance gradient aggregation (paper §III-D/E).

``jax.grad`` through :mod:`tt_lookup` is already correct (bgemm carries a
custom VJP), but the paper's backward contribution is *structural*: before
touching the expensive chain-rule products of Eq. 8, gradients of repeated
rows are **aggregated** (Fig. 5b, "advance gradient aggregation"), so each
distinct row pays the (d−1) tensor multiplications once instead of once per
occurrence.  This module implements that pipeline explicitly — it is the
artifact-level proof of the Fig. 12 ablation (−52% throughput without it)
and is validated against ``ref.tt_core_grads_ref`` in pytest.

For a pooled bag ``out[b] = Σ_k row(idx[b,k])`` with upstream ``g[b] =
∂L/∂out[b]``, every occurrence (b,k) contributes g[b] to row idx[b,k]:

  step 1 (aggregation):  gE[u]  = Σ_{(b,k): idx=u} g[b]      (segment-sum)
  step 2 (Eq. 8):        dD3[:,i3(u)] += P(u)ᵀ · gE[u]
                         dP(u)        = gE[u] · D3[:,i3(u)]ᵀ
                         dD2[:,i2(u)] += D1[i1(u)]ᵀ · dP(u)
                         dD1[i1(u)]   += dP(u) · D2[:,i2(u)]ᵀ

Steps 2's products are bgemm (Pallas) calls over the *unique* rows only.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from compile.tt_spec import TtSpec
from compile.kernels.bgemm import bgemm
from compile.kernels.tt_lookup import prefix_products


def aggregate_row_grads(indices: jax.Array, g: jax.Array, k_unique: int
                        ) -> Tuple[jax.Array, jax.Array]:
    """Step 1: merge gradients of repeated rows (Fig. 5b, first step).

    indices: [B, K] int32; g: [B, N] pooled-output grad.
    Returns (uniq_rows [U], gE [U, N]) with U = k_unique (static size;
    padding slots map to row `fill` with zero grad).
    """
    b, k = indices.shape
    flat = indices.reshape(-1)
    uniq, inv = jnp.unique(flat, return_inverse=True, size=k_unique,
                           fill_value=0)
    # grad of occurrence (b, k) is g[b]
    occ = jnp.repeat(g, k, axis=0)                     # [B·K, N]
    ge = jax.ops.segment_sum(occ, inv.reshape(-1), num_segments=k_unique)
    # zero out slots that no real occurrence mapped to (unique padding)
    counts = jax.ops.segment_sum(jnp.ones_like(inv.reshape(-1), jnp.float32),
                                 inv.reshape(-1), num_segments=k_unique)
    ge = ge * (counts > 0)[:, None]
    return uniq, ge


def tt_core_grads(spec: TtSpec, cores, indices: jax.Array, g: jax.Array):
    """Aggregated backward: returns (dD1, dD2, dD3) matching autodiff.

    indices: [B, K]; g: [B, N] = ∂L/∂(pooled bag output).
    """
    d1, d2, d3 = cores
    m2, m3 = spec.m[1], spec.m[2]
    n1, n2, n3 = spec.n
    r = spec.rank
    bk = indices.size

    # ---- step 1: advance gradient aggregation over distinct rows --------
    uniq, ge = aggregate_row_grads(indices, g, bk)     # [U], [U, N]
    u = uniq.shape[0]
    ge = ge.reshape(u, n1 * n2, n3)                    # unpooled col layout

    i1 = uniq // (m2 * m3)
    i2 = (uniq // m3) % m2
    i3 = uniq % m3
    pref = uniq // m3

    # ---- recompute (or reuse) the prefix products P(u) -------------------
    p = prefix_products(spec, cores, pref)             # [U, n1·n2, R]

    # ---- step 2a: dD3 slices = Pᵀ · gE ----------------------------------
    dslice3 = bgemm(jnp.swapaxes(p, 1, 2), ge)         # [U, R, n3]
    dd3 = jnp.zeros_like(d3)                           # [R, m3, n3]
    dd3 = dd3.at[:, i3, :].add(jnp.swapaxes(dslice3, 0, 1))

    # ---- step 2b: dP = gE · (D3 slice)ᵀ ---------------------------------
    c = jnp.transpose(jnp.take(d3, i3, axis=1), (1, 0, 2))   # [U, R, n3]
    dp = bgemm(ge, jnp.swapaxes(c, 1, 2))              # [U, n1·n2, R]
    dp = dp.reshape(u, n1, n2 * r)                     # un-fold prefix

    # ---- step 2c: dD2 slices = (D1 slice)ᵀ · dP -------------------------
    a = jnp.take(d1, i1, axis=0)                       # [U, n1, R]
    dslice2 = bgemm(jnp.swapaxes(a, 1, 2), dp)         # [U, R, n2·R]
    dd2 = jnp.zeros_like(d2)                           # [R, m2, n2, R]
    dd2 = dd2.at[:, i2, :, :].add(
        jnp.transpose(dslice2.reshape(u, r, n2, r), (1, 0, 2, 3)))

    # ---- step 2d: dD1 slices = dP · (D2 slice)ᵀ -------------------------
    b2 = jnp.take(d2, i2, axis=1)                      # [R, U, n2, R]
    b2 = jnp.transpose(b2, (1, 0, 2, 3)).reshape(u, r, n2 * r)
    dslice1 = bgemm(dp, jnp.swapaxes(b2, 1, 2))        # [U, n1, R]
    dd1 = jnp.zeros_like(d1).at[i1].add(dslice1)

    return dd1, dd2, dd3


def fused_sgd_update(spec: TtSpec, cores, indices: jax.Array, g: jax.Array,
                     lr: float):
    """Fused TT core update (paper §III-F): compute aggregated grads and
    apply SGD in one traced function — no intermediate materialization of
    per-occurrence gradients, no extra copies."""
    dd1, dd2, dd3 = tt_core_grads(spec, cores, indices, g)
    d1, d2, d3 = cores
    return d1 - lr * dd1, d2 - lr * dd2, d3 - lr * dd3
