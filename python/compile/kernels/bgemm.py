"""Pallas batched-GEMM kernel — the Eff-TT contraction hot-spot.

The paper's CUDA implementation prepares pointer arrays (Algorithm 1) and
issues one ``cublasGemmBatchedEx`` over the distinct TT prefixes.  The TPU
rethink (DESIGN.md §3): the L2/L3 side computes the *unique-prefix
segmentation* with integer ops, then this kernel contracts one GEMM per
grid step with all operands staged in VMEM.  ``interpret=True`` everywhere
— the CPU PJRT plugin cannot run Mosaic custom-calls.

Reverse-mode autodiff: ``pallas_call`` has no automatic transpose rule, so
``bgemm`` carries a ``jax.custom_vjp`` whose backward is two more bgemm
calls (dA = dO·Bᵀ, dB = Aᵀ·dO) — exactly the paper's observation that the
TT backward is "d× the lookup cost" (Eq. 8) expressed as kernel reuse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Grid-step tile over the batch (G) axis.  The M/K/N dims of the per-prefix
# GEMMs are small (n1·n2 ≈ dim, R ≈ 8–32), so a whole [GB, M, K] tile fits
# VMEM comfortably; tiling G keeps the scratch bounded for large batches.
G_BLOCK = 32


def _bgemm_kernel(a_ref, b_ref, o_ref):
    """One grid step: contract G_BLOCK stacked GEMMs on the MXU.

    a_ref: [G_BLOCK, M, K]   b_ref: [G_BLOCK, K, N]   o_ref: [G_BLOCK, M, N]
    """
    a = a_ref[...]
    b = b_ref[...]
    # dot_general with a leading batch dim maps to MXU-batched matmul.
    o_ref[...] = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _bgemm_raw(a: jax.Array, b: jax.Array) -> jax.Array:
    """[G, M, K] @ [G, K, N] -> [G, M, N] via the Pallas kernel."""
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2, (a.shape, b.shape)
    # Pad G up to a multiple of the block so BlockSpec tiling is exact.
    gp = (g + G_BLOCK - 1) // G_BLOCK * G_BLOCK
    if gp != g:
        a = jnp.pad(a, ((0, gp - g), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, gp - g), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _bgemm_kernel,
        grid=(gp // G_BLOCK,),
        in_specs=[
            pl.BlockSpec((G_BLOCK, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((G_BLOCK, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((G_BLOCK, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, m, n), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:g]


@jax.custom_vjp
def bgemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched matmul ``einsum('gmk,gkn->gmn')`` as a Pallas kernel."""
    return _bgemm_raw(a, b)


def _bgemm_fwd(a, b):
    return _bgemm_raw(a, b), (a, b)


def _bgemm_bwd(res, g):
    a, b = res
    da = _bgemm_raw(g, jnp.swapaxes(b, 1, 2))   # dO · Bᵀ
    db = _bgemm_raw(jnp.swapaxes(a, 1, 2), g)   # Aᵀ · dO
    return da, db


bgemm.defvjp(_bgemm_fwd, _bgemm_bwd)


@functools.partial(jax.jit, static_argnames=())
def bgemm_jit(a, b):
    return bgemm(a, b)
