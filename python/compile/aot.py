"""AOT lowering: jax (L2) → HLO **text** artifacts for the rust runtime (L3).

Interchange is HLO text, not ``.serialize()``: jax ≥ 0.5 emits HloModule
protos with 64-bit instruction ids which the ``xla`` crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ../artifacts, one PJRT executable each):

  tt_lookup.hlo.txt       pooled Eff-TT bag lookup (cores, idx) → [B, N]
  dlrm_fwd.hlo.txt        (params…, dense, idx) → probs [B]       (serving)
  dlrm_train_step.hlo.txt (params…, dense, idx, labels) → (loss, params…)
  meta.json               shapes/param layout consumed by rust/src/runtime

Run via ``make artifacts`` (no-op when inputs are unchanged).  Python never
runs again after this — the rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.tt_spec import TtSpec
from compile.kernels.tt_lookup import tt_embedding_bag

# Artifact-scale model: IEEE118 schema at 1/2000 scale → two TT tables of
# 6000/3750 rows + five small plain tables.  Structure (7 sparse, 6 dense,
# TT rank 8, dim 16) matches the paper's Table II row exactly.
SCALE = 1.0 / 2000.0
FWD_BATCH = 128          # serving batch (router pads to this)
TRAIN_BATCH = 64         # per-step mini-batch on the PJRT path
LOOKUP_BATCH = 256
LOOKUP_BAG = 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tt_lookup(spec: TtSpec):
    """Standalone Eff-TT pooled lookup artifact (runtime unit tests +
    serving-side embedding microbench)."""
    def fn(d1, d2, d3, idx):
        return (tt_embedding_bag(spec, (d1, d2, d3), idx),)

    s1, s2, s3 = spec.core_shapes
    args = (
        jax.ShapeDtypeStruct(s1, jnp.float32),
        jax.ShapeDtypeStruct(s2, jnp.float32),
        jax.ShapeDtypeStruct(s3, jnp.float32),
        jax.ShapeDtypeStruct((LOOKUP_BATCH, LOOKUP_BAG), jnp.int32),
    )
    return jax.jit(fn).lower(*args)


def lower_fwd(cfg: model.ModelCfg, n_params: int):
    def fn(*args):
        leaves, dense, idx = args[:n_params], args[n_params], args[n_params + 1]
        params = jax.tree_util.tree_unflatten(model.params_treedef(cfg), leaves)
        return (model.predict(cfg, params, dense, idx),)

    shapes = _param_shapes(cfg) + [
        jax.ShapeDtypeStruct((FWD_BATCH, cfg.dense_dim), jnp.float32),
        jax.ShapeDtypeStruct((FWD_BATCH, cfg.num_tables), jnp.int32),
    ]
    return jax.jit(fn).lower(*shapes)


def lower_train_step(cfg: model.ModelCfg, n_params: int):
    def fn(*args):
        leaves = args[:n_params]
        dense, idx, labels = args[n_params:]
        params = jax.tree_util.tree_unflatten(model.params_treedef(cfg), leaves)
        loss, new = model.train_step(cfg, params, dense, idx, labels)
        return (loss, *model.flatten_params(new))

    shapes = _param_shapes(cfg) + [
        jax.ShapeDtypeStruct((TRAIN_BATCH, cfg.dense_dim), jnp.float32),
        jax.ShapeDtypeStruct((TRAIN_BATCH, cfg.num_tables), jnp.int32),
        jax.ShapeDtypeStruct((TRAIN_BATCH,), jnp.float32),
    ]
    return jax.jit(fn).lower(*shapes)


@functools.lru_cache(maxsize=None)
def _cfg():
    return model.ieee118_cfg(scale=SCALE)


def _param_shapes(cfg):
    return [jax.ShapeDtypeStruct(tuple(m["shape"]), jnp.dtype(m["dtype"]))
            for m in model.param_meta(cfg)]


def init_param_values(cfg, seed: int = 0):
    """Initial parameter leaves — exported so rust can bootstrap training
    from the same init the python tests use (written as meta + .npy-like
    raw f32 blobs)."""
    return model.flatten_params(model.init_params(cfg, jax.random.PRNGKey(seed)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = _cfg()
    meta_params = model.param_meta(cfg)
    n_params = len(meta_params)
    spec = TtSpec.plan(6000, cfg.emb_dim, rank=8)

    artifacts = {
        "tt_lookup": lower_tt_lookup(spec),
        "dlrm_fwd": lower_fwd(cfg, n_params),
        "dlrm_train_step": lower_train_step(cfg, n_params),
    }
    for name, lowered in artifacts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # initial parameter blob: flat little-endian f32 concatenation
    leaves = init_param_values(cfg)
    blob_path = os.path.join(args.out_dir, "init_params.bin")
    with open(blob_path, "wb") as f:
        import numpy as np
        for leaf in leaves:
            f.write(np.asarray(leaf, dtype=np.float32).tobytes())
    print(f"wrote {blob_path}")

    meta = {
        "model": {
            "dense_dim": cfg.dense_dim,
            "emb_dim": cfg.emb_dim,
            "num_tables": cfg.num_tables,
            "tables": [
                {"rows": t.rows, "compressed": t.compressed, "rank": t.rank}
                for t in cfg.tables
            ],
            "lr": cfg.lr,
        },
        "batches": {"fwd": FWD_BATCH, "train": TRAIN_BATCH,
                    "lookup": [LOOKUP_BATCH, LOOKUP_BAG]},
        "tt_lookup_spec": {"rows": spec.rows, "dim": spec.dim,
                           "m": list(spec.m), "n": list(spec.n),
                           "rank": spec.rank},
        "params": meta_params,
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote meta.json ({n_params} params)")


if __name__ == "__main__":
    main()
