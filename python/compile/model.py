"""L2: the Rec-AD DLRM (paper Fig. 2) in JAX, calling the L1 Pallas kernels.

Architecture (faithful to Facebook DLRM / paper §II-A):

    dense [B, Dd] ──► bottom MLP ──► z0 [B, E] ─┐
    sparse idx [B, T] ──► per-table lookup ──► z1..zT [B, E] ─┤
                                                              ▼
                         interaction (pairwise dots, Pallas) [B, T(T+1)/2]
                                                              ▼
                            concat(z0, interactions) ──► top MLP ──► logit

Large tables are Eff-TT compressed (kernels.tt_lookup); small ones stay
plain — exactly the paper's policy ("tables with over one million rows are
compressed, smaller ones left uncompressed", §V-C), scaled to artifact size.

The classification head replaces CTR: sigmoid(logit) is P(state vector is
FDIA-compromised).  Loss is BCE-with-logits; `train_step` is a fused
fwd+bwd+SGD update lowered to a single HLO artifact so the rust runtime
performs one PJRT call per mini-batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.tt_spec import TtSpec
from compile.kernels.tt_lookup import tt_lookup, init_cores
from compile.kernels.interaction import interaction


@dataclasses.dataclass(frozen=True)
class TableCfg:
    rows: int
    compressed: bool          # Eff-TT vs plain nn.Embedding
    rank: int = 8


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Shape plan for one DLRM variant (mirrors rust/src/config)."""

    dense_dim: int
    tables: Tuple[TableCfg, ...]
    emb_dim: int = 16
    bot_mlp: Tuple[int, ...] = (64, 32)
    top_mlp: Tuple[int, ...] = (64, 32)
    lr: float = 0.05

    @property
    def num_tables(self) -> int:
        return len(self.tables)

    def tt_specs(self):
        return [TtSpec.plan(t.rows, self.emb_dim, t.rank) if t.compressed
                else None for t in self.tables]


def ieee118_cfg(scale: float = 1.0) -> ModelCfg:
    """IEEE 118-bus detection model (Table II row: 6 dense / 7 sparse).

    The paper's 19.53M-row aggregate table is represented by two large
    (compressed) tables + five small categorical ones; `scale` shrinks row
    counts for CPU-sized artifacts while preserving structure.
    """
    s = lambda r: max(32, int(r * scale))
    return ModelCfg(
        dense_dim=6,
        tables=(
            TableCfg(rows=s(12_000_000), compressed=True),   # bus-pair topo
            TableCfg(rows=s(7_500_000), compressed=True),    # load profile id
            TableCfg(rows=118, compressed=False),            # bus id
            TableCfg(rows=186, compressed=False),            # branch id
            TableCfg(rows=54, compressed=False),             # generator id
            TableCfg(rows=24, compressed=False),             # hour of day
            TableCfg(rows=91, compressed=False),             # measurement type
        ),
    )


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def _init_mlp(key, dims: Sequence[int]) -> List[Tuple[jax.Array, jax.Array]]:
    layers = []
    for din, dout in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), jnp.float32) * (2.0 / din) ** 0.5
        layers.append((w, jnp.zeros((dout,), jnp.float32)))
    return layers


def init_params(cfg: ModelCfg, key: jax.Array) -> Dict[str, Any]:
    specs = cfg.tt_specs()
    n_feat = cfg.num_tables + 1
    n_inter = n_feat * (n_feat - 1) // 2
    key, kb, kt = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "bot": _init_mlp(kb, (cfg.dense_dim, *cfg.bot_mlp, cfg.emb_dim)),
        "top": _init_mlp(kt, (cfg.emb_dim + n_inter, *cfg.top_mlp, 1)),
        "tables": [],
    }
    for t, spec in zip(cfg.tables, specs):
        key, sub = jax.random.split(key)
        if spec is not None:
            params["tables"].append(tuple(init_cores(spec, sub)))
        else:
            w = jax.random.normal(sub, (t.rows, cfg.emb_dim), jnp.float32)
            params["tables"].append(w * (1.0 / cfg.emb_dim) ** 0.5)
    return params


def _mlp(layers, x, final_relu: bool) -> jax.Array:
    for i, (w, b) in enumerate(layers):
        x = x @ w + b
        if i + 1 < len(layers) or final_relu:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------------------
# Forward / loss / train step
# --------------------------------------------------------------------------

def forward(cfg: ModelCfg, params, dense: jax.Array, idx: jax.Array
            ) -> jax.Array:
    """dense [B, Dd] f32, idx [B, T] int32 -> logits [B]."""
    specs = cfg.tt_specs()
    z0 = _mlp(params["bot"], dense, final_relu=True)        # [B, E]
    feats = [z0]
    for t, (spec, tab) in enumerate(zip(specs, params["tables"])):
        col = idx[:, t]
        if spec is not None:
            feats.append(tt_lookup(spec, tab, col))         # Pallas path
        else:
            feats.append(jnp.take(tab, col, axis=0))
    z = jnp.stack(feats, axis=1)                            # [B, T+1, E]
    inter = interaction(z)                                  # Pallas path
    x = jnp.concatenate([z0, inter], axis=1)
    return _mlp(params["top"], x, final_relu=False)[:, 0]


def predict(cfg: ModelCfg, params, dense, idx) -> jax.Array:
    """Attack probability per sample (serving head)."""
    return jax.nn.sigmoid(forward(cfg, params, dense, idx))


def bce_loss(cfg: ModelCfg, params, dense, idx, labels) -> jax.Array:
    logits = forward(cfg, params, dense, idx)
    # numerically stable BCE-with-logits
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def train_step(cfg: ModelCfg, params, dense, idx, labels):
    """One fused SGD step: returns (loss, new_params).

    Lowered as a single HLO module so L3 pays one dispatch per batch; TT
    core grads flow through the bgemm custom-VJP (aggregation happens via
    the unique/segment structure of the forward — see tt_grad.py for the
    explicit formulation used by the ablation artifacts).
    """
    loss, grads = jax.value_and_grad(
        lambda p: bce_loss(cfg, p, dense, idx, labels))(params)
    new = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g, params, grads)
    return loss, new


# --------------------------------------------------------------------------
# Flat interchange layout (rust side reads meta.json; order must be stable)
# --------------------------------------------------------------------------

def flatten_params(params) -> List[jax.Array]:
    leaves, _ = jax.tree_util.tree_flatten(params)
    return leaves


def params_treedef(cfg: ModelCfg):
    dummy = init_params(cfg, jax.random.PRNGKey(0))
    return jax.tree_util.tree_structure(dummy)


def param_meta(cfg: ModelCfg) -> List[Dict[str, Any]]:
    """Name+shape+dtype per flat leaf, for artifacts/meta.json."""
    params = init_params(cfg, jax.random.PRNGKey(0))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves_with_path:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append({"name": name, "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype)})
    return out
