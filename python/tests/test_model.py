"""L2 model tests: shapes, loss descent, numerical gradient check, and the
artifact interchange layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.tt_spec import TtSpec


def tiny_cfg():
    return model.ModelCfg(
        dense_dim=6,
        tables=(
            model.TableCfg(rows=600, compressed=True, rank=4),
            model.TableCfg(rows=450, compressed=True, rank=4),
            model.TableCfg(rows=30, compressed=False),
        ),
        emb_dim=8,
        bot_mlp=(16,),
        top_mlp=(16,),
        lr=0.1,
    )


def batch(cfg, b, seed=0):
    r = np.random.default_rng(seed)
    dense = jnp.asarray(r.normal(size=(b, cfg.dense_dim)), jnp.float32)
    idx = jnp.asarray(
        np.stack([r.integers(0, t.rows, b) for t in cfg.tables], axis=1),
        jnp.int32)
    labels = jnp.asarray(r.random(b) > 0.5, jnp.float32)
    return dense, idx, labels


def test_forward_shapes():
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    dense, idx, _ = batch(cfg, 5)
    logits = model.forward(cfg, params, dense, idx)
    assert logits.shape == (5,)
    probs = model.predict(cfg, params, dense, idx)
    assert float(jnp.min(probs)) >= 0.0 and float(jnp.max(probs)) <= 1.0


def test_train_step_descends_and_updates_all_leaves():
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    dense, idx, labels = batch(cfg, 16, seed=3)
    loss0, new = model.train_step(cfg, params, dense, idx, labels)
    loss1, _ = model.train_step(cfg, new, dense, idx, labels)
    assert float(loss1) < float(loss0)
    # every MLP leaf must have moved (TT cores too, except untouched rows)
    for (a, b) in zip(model.flatten_params(params)[:4],
                      model.flatten_params(new)[:4]):
        assert not np.allclose(np.asarray(a), np.asarray(b))


def test_overfits_tiny_dataset():
    """End-to-end learnability: 32 samples should be separable."""
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    dense, idx, labels = batch(cfg, 32, seed=9)
    loss = None
    for _ in range(120):
        loss, params = model.train_step(cfg, params, dense, idx, labels)
    assert float(loss) < 0.2


def test_grad_matches_numerical():
    """Finite-difference check through the full model (incl. Pallas path)."""
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(4))
    dense, idx, labels = batch(cfg, 4, seed=5)
    f = lambda p: model.bce_loss(cfg, p, dense, idx, labels)
    g = jax.grad(f)(params)
    # probe one TT core entry and one MLP weight
    leaves, tree = jax.tree_util.tree_flatten(params)
    gleaves = jax.tree_util.tree_flatten(g)[0]
    for li in [0, len(leaves) - 2]:
        eps = 1e-3
        bumped = [l for l in leaves]
        probe = np.zeros(leaves[li].shape, np.float32)
        probe_idx = tuple(0 for _ in leaves[li].shape)
        probe[probe_idx] = eps
        bumped[li] = leaves[li] + probe
        fplus = float(f(jax.tree_util.tree_unflatten(tree, bumped)))
        bumped[li] = leaves[li] - probe
        fminus = float(f(jax.tree_util.tree_unflatten(tree, bumped)))
        num = (fplus - fminus) / (2 * eps)
        ana = float(np.asarray(gleaves[li])[probe_idx])
        assert abs(num - ana) < 5e-2 * max(1.0, abs(ana)), (li, num, ana)


def test_param_meta_is_stable_and_complete():
    cfg = tiny_cfg()
    meta = model.param_meta(cfg)
    params = model.flatten_params(model.init_params(cfg, jax.random.PRNGKey(0)))
    assert len(meta) == len(params)
    for m, p in zip(meta, params):
        assert tuple(m["shape"]) == p.shape
        assert m["dtype"] == str(p.dtype)
    # deterministic across calls
    assert meta == model.param_meta(cfg)


def test_ieee118_schema_matches_table2():
    cfg = model.ieee118_cfg(scale=1.0)
    assert cfg.dense_dim == 6 and cfg.num_tables == 7      # Table II row
    rows = sum(t.rows for t in cfg.tables)
    assert abs(rows - 19_530_000) / 19_530_000 < 0.01      # ≈19.53M rows
    specs = [s for s in cfg.tt_specs() if s is not None]
    assert len(specs) == 2                                  # >1M rows ⇒ TT
    for s in specs:
        assert s.compression_ratio() > 4                    # Table IV: 5.33×
