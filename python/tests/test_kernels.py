"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in ref.py, with
hypothesis sweeping shapes / ranks / index distributions.  Everything runs
under interpret=True on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.tt_spec import TtSpec, factorize3, padded_rows
from compile.kernels.bgemm import bgemm
from compile.kernels.tt_lookup import (
    tt_lookup, tt_lookup_noreuse, tt_embedding_bag, init_cores,
    split_indices,
)
from compile.kernels.tt_grad import tt_core_grads, aggregate_row_grads, \
    fused_sgd_update
from compile.kernels.interaction import interaction
from compile.kernels import ref

SET = settings(max_examples=12, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# tt_spec shape planning
# ---------------------------------------------------------------------------

@SET
@given(st.integers(min_value=1, max_value=100_000))
def test_factorize3_product(x):
    a, b, c = factorize3(x)
    assert a * b * c == x
    assert a <= b <= c


@SET
@given(st.integers(min_value=32, max_value=5_000_000))
def test_padded_rows_covers(rows):
    m = padded_rows(rows)
    assert m >= rows
    f = factorize3(m)
    assert max(f) <= 4 * min(f) or max(f) <= 64


@SET
@given(st.integers(min_value=100, max_value=200_000),
       st.sampled_from([8, 16, 32, 64]),
       st.sampled_from([4, 8, 16]))
def test_spec_index_roundtrip(rows, dim, rank):
    spec = TtSpec.plan(rows, dim, rank)
    m1, m2, m3 = spec.m
    for i in [0, 1, rows - 1, rows // 2]:
        i1, i2, i3 = spec.tt_indices(i)
        assert 0 <= i1 < m1 and 0 <= i2 < m2 and 0 <= i3 < m3
        assert i1 * m2 * m3 + i2 * m3 + i3 == i
        assert spec.prefix_of(i) == i1 * m2 + i2


def test_compression_ratio_matches_paper_scale():
    # Table IV, Criteo-Terabyte-like: 242.5M x 64 at rank 32 compresses by
    # orders of magnitude; sanity-check the accounting direction.
    spec = TtSpec.plan(242_500_000, 64, rank=32)
    assert spec.compression_ratio() > 1000


# ---------------------------------------------------------------------------
# bgemm kernel
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 70), st.integers(1, 9), st.integers(1, 9),
       st.integers(1, 9), st.integers(0, 2 ** 31 - 1))
def test_bgemm_matches_einsum(g, m, k, n, seed):
    r = rng(seed)
    a = jnp.asarray(r.normal(size=(g, m, k)), jnp.float32)
    b = jnp.asarray(r.normal(size=(g, k, n)), jnp.float32)
    np.testing.assert_allclose(np.asarray(bgemm(a, b)),
                               np.asarray(ref.bgemm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_bgemm_grad_matches_einsum_grad():
    r = rng(7)
    a = jnp.asarray(r.normal(size=(5, 3, 4)), jnp.float32)
    b = jnp.asarray(r.normal(size=(5, 4, 2)), jnp.float32)
    f_k = lambda a, b: jnp.sum(jnp.sin(bgemm(a, b)))
    f_r = lambda a, b: jnp.sum(jnp.sin(ref.bgemm_ref(a, b)))
    gk = jax.grad(f_k, argnums=(0, 1))(a, b)
    gr = jax.grad(f_r, argnums=(0, 1))(a, b)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Eff-TT lookup (forward)
# ---------------------------------------------------------------------------

@SET
@given(st.integers(100, 20_000), st.sampled_from([8, 16, 32]),
       st.sampled_from([2, 4, 8, 16]), st.integers(1, 16),
       st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_tt_lookup_matches_materialized(rows, dim, rank, batch, bag, seed):
    spec = TtSpec.plan(rows, dim, rank)
    cores = init_cores(spec, jax.random.PRNGKey(seed % 997))
    idx = jnp.asarray(rng(seed).integers(0, rows, (batch, bag)), jnp.int32)
    out = tt_lookup(spec, cores, idx)
    expect = ref.lookup_ref(spec, cores, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


@SET
@given(st.integers(0, 2 ** 31 - 1))
def test_reuse_and_noreuse_agree(seed):
    """Fig. 12 ablation invariant: reuse changes cost, never values."""
    spec = TtSpec.plan(5000, 16, 8)
    cores = init_cores(spec, jax.random.PRNGKey(3))
    # skewed indices -> many shared prefixes (power-law-ish)
    r = rng(seed)
    idx = jnp.asarray((r.zipf(1.5, (8, 4)) - 1) % spec.rows, jnp.int32)
    a = tt_lookup(spec, cores, idx)
    b = tt_lookup_noreuse(spec, cores, idx)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_embedding_bag_pools_sum():
    spec = TtSpec.plan(800, 16, 4)
    cores = init_cores(spec, jax.random.PRNGKey(5))
    idx = jnp.asarray([[1, 2, 2, 7], [0, 0, 0, 0]], jnp.int32)
    pooled = tt_embedding_bag(spec, cores, idx)
    rows = ref.lookup_ref(spec, cores, idx)
    np.testing.assert_allclose(np.asarray(pooled),
                               np.asarray(rows.sum(axis=1)),
                               rtol=1e-4, atol=1e-5)


def test_duplicate_heavy_batch_exact():
    """Paper §III-B worked example: duplicates within a bag must still sum
    (Emb = Row0 + Row1 even when prefixes collide)."""
    spec = TtSpec.plan(1000, 8, 4)
    cores = init_cores(spec, jax.random.PRNGKey(11))
    m3 = spec.m[2]
    # same prefix, different last index  +  identical indices
    idx = jnp.asarray([[5 * m3 + 1, 5 * m3 + 2], [42, 42]], jnp.int32)
    out = tt_embedding_bag(spec, cores, idx)
    expect = ref.pooled_lookup_ref(spec, cores, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


@SET
@given(st.integers(0, 2 ** 31 - 1))
def test_split_indices_bounds(seed):
    spec = TtSpec.plan(3000, 16, 4)
    idx = jnp.asarray(rng(seed).integers(0, spec.rows, (32,)), jnp.int32)
    pref, i3 = split_indices(spec, idx)
    assert int(jnp.max(pref)) < spec.m[0] * spec.m[1]
    assert int(jnp.max(i3)) < spec.m[2]
    np.testing.assert_array_equal(np.asarray(pref * spec.m[2] + i3),
                                  np.asarray(idx))


# ---------------------------------------------------------------------------
# Backward: gradient aggregation + explicit core grads (Eq. 8)
# ---------------------------------------------------------------------------

@SET
@given(st.integers(500, 20_000), st.sampled_from([8, 16]),
       st.sampled_from([2, 4, 8]), st.integers(1, 8), st.integers(1, 5),
       st.integers(0, 2 ** 31 - 1))
def test_tt_core_grads_match_autodiff(rows, dim, rank, batch, bag, seed):
    spec = TtSpec.plan(rows, dim, rank)
    cores = init_cores(spec, jax.random.PRNGKey(seed % 991))
    r = rng(seed)
    idx = jnp.asarray(r.integers(0, rows, (batch, bag)), jnp.int32)
    g = jnp.asarray(r.normal(size=(batch, dim)), jnp.float32)
    ours = tt_core_grads(spec, cores, idx, g)
    oracle = ref.tt_core_grads_ref(spec, cores, idx, g)
    for a, b in zip(ours, oracle):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_aggregation_merges_duplicates():
    """Fig. 5(b): repeated rows must contribute summed gradients once."""
    idx = jnp.asarray([[3, 3], [3, 9]], jnp.int32)
    g = jnp.asarray([[1.0, 2.0], [10.0, 20.0]], jnp.float32)
    uniq, ge = aggregate_row_grads(idx, g, idx.size)
    u = np.asarray(uniq)
    gg = np.asarray(ge)
    i3 = int(np.where(u == 3)[0][0])
    i9 = int(np.where(u == 9)[0][0])
    # row 3 appears twice in sample 0 and once in sample 1
    np.testing.assert_allclose(gg[i3], [12.0, 24.0])
    np.testing.assert_allclose(gg[i9], [10.0, 20.0])


def test_fused_update_descends():
    spec = TtSpec.plan(2000, 16, 4)
    cores = init_cores(spec, jax.random.PRNGKey(2))
    idx = jnp.asarray(rng(0).integers(0, spec.rows, (4, 3)), jnp.int32)
    target = jnp.ones((4, 16), jnp.float32)

    def loss(cs):
        return jnp.mean((tt_embedding_bag(spec, cs, idx) - target) ** 2)

    l0 = float(loss(cores))
    g = jax.grad(lambda cs: loss(cs))(cores)
    # fused update path: same as SGD on aggregated grads
    pooled_grad = jax.grad(
        lambda out: jnp.mean((out - target) ** 2))(tt_embedding_bag(spec, cores, idx))
    new = fused_sgd_update(spec, cores, idx, pooled_grad, lr=0.5)
    l1 = float(loss(new))
    assert l1 < l0


# ---------------------------------------------------------------------------
# interaction kernel
# ---------------------------------------------------------------------------

@SET
@given(st.integers(1, 70), st.integers(2, 9), st.sampled_from([4, 8, 16]),
       st.integers(0, 2 ** 31 - 1))
def test_interaction_matches_ref(b, f, d, seed):
    z = jnp.asarray(rng(seed).normal(size=(b, f, d)), jnp.float32)
    np.testing.assert_allclose(np.asarray(interaction(z)),
                               np.asarray(ref.interaction_ref(z)),
                               rtol=1e-4, atol=1e-5)


def test_interaction_grad_flows():
    z = jnp.asarray(rng(1).normal(size=(3, 4, 8)), jnp.float32)
    gk = jax.grad(lambda z: jnp.sum(interaction(z) ** 2))(z)
    gr = jax.grad(lambda z: jnp.sum(ref.interaction_ref(z) ** 2))(z)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)
