"""AOT lowering smoke tests: artifacts must exist, parse as HLO text, and
the lowered computations must agree with eager execution."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.tt_spec import TtSpec
from compile.kernels.tt_lookup import tt_embedding_bag, init_cores

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_roundtrip_tiny():
    """Lower a tiny lookup and check the text contains an HLO module with
    the right entry shapes (the format the rust parser consumes)."""
    spec = TtSpec.plan(500, 8, 4)

    def fn(d1, d2, d3, idx):
        return (tt_embedding_bag(spec, (d1, d2, d3), idx),)

    s1, s2, s3 = spec.core_shapes
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(s1, jnp.float32),
        jax.ShapeDtypeStruct(s2, jnp.float32),
        jax.ShapeDtypeStruct(s3, jnp.float32),
        jax.ShapeDtypeStruct((4, 2), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # interpret-mode pallas must lower to plain HLO — no custom-call opaque
    # mosaic payloads that the CPU PJRT client cannot execute.
    assert "mosaic" not in text.lower()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_artifacts_complete_and_consistent():
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    for name in ["tt_lookup", "dlrm_fwd", "dlrm_train_step"]:
        p = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(p), p
        head = open(p).read(64)
        assert head.startswith("HloModule")
    cfg = aot._cfg()
    assert meta["model"]["dense_dim"] == cfg.dense_dim
    assert meta["model"]["num_tables"] == cfg.num_tables
    assert len(meta["params"]) == len(model.param_meta(cfg))
    # init_params blob length == sum of param sizes * 4 bytes
    total = sum(int(np.prod(m["shape"])) for m in meta["params"])
    blob = os.path.getsize(os.path.join(ART, "init_params.bin"))
    assert blob == total * 4


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_train_batch_shapes_match_meta():
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    assert meta["batches"]["train"] == aot.TRAIN_BATCH
    assert meta["batches"]["fwd"] == aot.FWD_BATCH
    spec = meta["tt_lookup_spec"]
    m = spec["m"]
    assert m[0] * m[1] * m[2] >= spec["rows"]
