// L3 perf probe: Eff-TT fwd+bwd at serving-relevant shapes.
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::prng::Rng;
use std::time::Instant;

fn main() {
    for (rows, rank, batch) in [(100_000u64, 8usize, 4096usize), (100_000, 16, 4096), (1_000_000, 16, 4096)] {
        let shapes = TtShapes::plan(rows, 16, rank);
        let mut rng = Rng::new(1);
        let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let zipf = recad::data::zipf::Zipf::new(rows, 1.2);
        let idx: Vec<u64> = (0..batch).map(|_| zipf.sample(&mut rng)).collect();
        let offsets: Vec<usize> = (0..=batch).collect();
        let mut out = vec![0.0f32; batch * 16];
        let g = vec![0.05f32; batch * 16];
        let mut scratch = TtScratch::default();
        // warmup
        t.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
        t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch);
        let reps = 20;
        let mut fwd_best = f64::INFINITY;
        let mut bwd_best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            for _ in 0..reps { t.embedding_bag(&idx, &offsets, &mut out, &mut scratch); }
            fwd_best = fwd_best.min(t0.elapsed().as_secs_f64() / reps as f64);
            let t0 = Instant::now();
            for _ in 0..reps { t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch); }
            bwd_best = bwd_best.min(t0.elapsed().as_secs_f64() / reps as f64);
        }
        println!("rows={rows:>8} rank={rank:>2} batch={batch}: fwd {:.0}µs ({:.1} Mlookup/s)  bwd {:.0}µs",
            fwd_best*1e6, batch as f64/fwd_best/1e6, bwd_best*1e6);
    }
}
