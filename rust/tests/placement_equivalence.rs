//! Placement-equivalence properties of data-parallel training:
//!
//! * `placement=replicated` at one worker == plan-placed at one worker
//!   == plain single-engine SGD, **bit-identically** (losses and
//!   post-training predictions).
//! * Uneven shards (`batch_size % workers != 0`) are exact global-batch
//!   SGD under the shard-size weighted reduce — the bug the old
//!   uniform mean had.
//! * Plan-placed DP at workers 2/4 is convergence-equivalent to
//!   replicated (both compute the same weighted global-batch step in
//!   exact arithmetic; only float summation order differs).
//! * Plan placement's all-reduce payload is strictly below replicated's
//!   at workers ≥ 2 (the sparse TT exchange ships touched slices only).
//! * `AllReduce` survives multi-round use with uneven arrival order —
//!   the deposit/merge protocol is deterministic by construction.

use std::time::Duration;

use recad::access::AccessPlanner;
use recad::coordinator::allreduce::AllReduce;
use recad::coordinator::data_parallel::{
    train_data_parallel, train_data_parallel_placed, DpCfg, Placement,
};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::platform::CostModel;
use recad::data::ctr::{Batch, CtrGenerator};
use recad::data::schema::DatasetSchema;
use recad::exec::ExecCfg;
use recad::tt::table::EffTtOptions;
use recad::util::prng::Rng;

fn zero_cost() -> CostModel {
    CostModel {
        h2d_bps: 1e18,
        d2d_bps: 1e18,
        transfer_latency: Duration::ZERO,
        ps_row: Duration::ZERO,
        dispatch: Duration::ZERO,
    }
}

fn cfg(vocab: u64) -> EngineCfg {
    EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(vocab, true), (60, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::default(),
    }
}

fn batches(vocab: u64, n: usize, batch: usize, seed: u64) -> Vec<Batch> {
    let schema = DatasetSchema {
        name: "placement-test",
        n_dense: 4,
        vocabs: vec![vocab, 60],
        emb_dim: 8,
        zipf_s: 1.2,
        ft_rank: 8,
    };
    CtrGenerator::new(schema, seed).batches(n, batch)
}

fn run(
    cfg: &EngineCfg,
    batches: &[Batch],
    workers: usize,
    placement: Placement,
) -> (Vec<f32>, Vec<f32>, u64) {
    let planner = AccessPlanner::for_engine_cfg(cfg);
    let dp = DpCfg { workers, placement, cost: zero_cost(), seed: 9, quantize_comm: false };
    let (report, mut engine) =
        train_data_parallel_placed(cfg.clone(), &planner, batches, &dp);
    // post-training predictions on the first batch fingerprint the params
    let probe = engine.predict(&batches[0]);
    (report.losses, probe, report.payload_bytes)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Plan-placed at one worker must match replicated at one worker AND
/// plain single-engine SGD, bit for bit.
#[test]
fn one_worker_plan_equals_replicated_equals_plain() {
    let cfg = cfg(1500);
    let bs = batches(1500, 12, 32, 11);
    let (rep_l, rep_p, rep_bytes) = run(&cfg, &bs, 1, Placement::Replicated);
    let (plan_l, plan_p, plan_bytes) = run(&cfg, &bs, 1, Placement::Plan);
    assert_eq!(bits(&rep_l), bits(&plan_l), "1-worker losses diverged");
    assert_eq!(bits(&rep_p), bits(&plan_p), "1-worker params diverged");
    assert_eq!(rep_bytes, 0);
    assert_eq!(plan_bytes, 0);
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(9));
    let direct: Vec<f32> = bs.iter().map(|b| engine.train_step(b)).collect();
    assert_eq!(bits(&direct), bits(&plan_l), "1-worker DP != plain SGD");
}

/// THE uneven-shard regression (batch_size 33, workers 4): the
/// shard-size weighted reduce makes DP exactly global-batch SGD, so the
/// DP loss sequence must track the single-engine sequence to float
/// noise.  (The old uniform mean over 9/8/8/8-sized shards biased every
/// step toward the small shards and drifted off the global trajectory.)
#[test]
fn uneven_shards_match_global_batch_sgd() {
    let cfg = cfg(1500);
    let bs = batches(1500, 16, 33, 7);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(9));
    let direct: Vec<f32> = bs.iter().map(|b| engine.train_step(b)).collect();
    for placement in [Placement::Replicated, Placement::Plan] {
        let (losses, _, _) = run(&cfg, &bs, 4, placement);
        assert_eq!(losses.len(), direct.len());
        for (step, (&dp, &gb)) in losses.iter().zip(&direct).enumerate() {
            // float-order noise only; the old uniform mean drifted ~1e-2
            let tol = 5e-3 * gb.abs().max(0.2);
            assert!(
                (dp - gb).abs() <= tol,
                "[{}] step {step}: DP loss {dp} vs global-batch {gb} \
                 (|Δ| {} > {tol})",
                placement.as_str(),
                (dp - gb).abs()
            );
        }
    }
}

/// Plan-placed training at 2 and 4 workers stays on the replicated
/// trajectory (convergence-equivalent) and still learns.
#[test]
fn plan_placement_convergence_equivalent_at_2_and_4() {
    let cfg = cfg(1500);
    let bs = batches(1500, 16, 32, 5);
    let (rep_l, _, _) = run(&cfg, &bs, 1, Placement::Replicated);
    for workers in [2usize, 4] {
        let (plan_l, _, _) = run(&cfg, &bs, workers, Placement::Plan);
        for (step, (&a, &b)) in plan_l.iter().zip(&rep_l).enumerate() {
            let tol = 5e-3 * b.abs().max(0.2);
            assert!(
                (a - b).abs() <= tol,
                "workers={workers} step {step}: plan {a} vs replicated {b}"
            );
        }
        let head = plan_l[0];
        let tail = plan_l[plan_l.len() - 1];
        assert!(tail < head, "plan-placed DP stopped learning: {head} -> {tail}");
    }
}

/// The sparse TT exchange must move strictly fewer bytes than the dense
/// replicated all-reduce at every multi-worker width.
#[test]
fn plan_payload_strictly_below_replicated() {
    let cfg = cfg(20_000);
    let bs = batches(20_000, 6, 64, 3);
    for workers in [2usize, 4] {
        let (_, _, rep_bytes) = run(&cfg, &bs, workers, Placement::Replicated);
        let (_, _, plan_bytes) = run(&cfg, &bs, workers, Placement::Plan);
        assert!(
            plan_bytes > 0 && plan_bytes < rep_bytes,
            "workers={workers}: plan payload {plan_bytes} !< replicated {rep_bytes}"
        );
    }
}

/// Degenerate routing: every sample shares one TT prefix, so plan
/// placement routes the whole batch to one worker and the others run
/// empty shards (weight 0) — training must survive and still match the
/// single-engine trajectory.
#[test]
fn plan_placement_survives_empty_shards() {
    let cfg = cfg(1500);
    let mut bs = batches(1500, 6, 16, 3);
    for b in bs.iter_mut() {
        for r in 0..b.batch_size {
            b.sparse[r * 2] = 7; // constant row => one owner for everyone
        }
    }
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(9));
    let direct: Vec<f32> = bs.iter().map(|b| engine.train_step(b)).collect();
    let (losses, _, _) = run(&cfg, &bs, 3, Placement::Plan);
    assert_eq!(losses.len(), direct.len());
    for (&dp, &gb) in losses.iter().zip(&direct) {
        assert!(dp.is_finite());
        // one worker holds the whole batch: its step IS the global step
        let tol = 3e-3 * gb.abs().max(0.2);
        assert!((dp - gb).abs() <= tol, "degenerate routing drifted: {dp} vs {gb}");
    }
}

/// Clamping: more workers than samples must not hand any engine an
/// empty contiguous shard.
#[test]
fn replicated_clamps_workers_below_tiny_batches() {
    let cfg = cfg(1500);
    let bs = batches(1500, 4, 2, 3);
    let report = train_data_parallel(cfg, &bs, 6, zero_cost(), 9);
    assert_eq!(report.workers, 2, "6 workers over 2-sample batches must clamp");
    assert!(report.losses.iter().all(|l| l.is_finite()));
}

/// AllReduce multi-round determinism under uneven arrival order: workers
/// arrive at each round staggered differently, yet every round's result
/// is the exact weighted mean (values and weights chosen exact in f32).
#[test]
fn allreduce_multi_round_uneven_arrival() {
    let n = 3;
    let rounds = 5;
    let ar = AllReduce::new(n, 4, zero_cost());
    let handles: Vec<_> = (0..n)
        .map(|w| {
            let ar = ar.clone();
            std::thread::spawn(move || {
                let mut out = Vec::new();
                for r in 0..rounds {
                    // rotate which worker arrives last each round
                    let delay_ms = ((w + r) % n) as u64 * 7;
                    std::thread::sleep(Duration::from_millis(delay_ms));
                    // weights 1, 2, 1 (sum 4); values (w+1)*(r+1)
                    let weight = if w == 1 { 2.0f32 } else { 1.0 };
                    let mut v = vec![((w + 1) * (r + 1)) as f32; 4];
                    ar.allreduce_weighted(w, &mut v, weight);
                    out.push(v);
                }
                out
            })
        })
        .collect();
    for h in handles {
        let rows = h.join().unwrap();
        for (r, v) in rows.iter().enumerate() {
            // (1*1 + 2*2 + 1*3)/4 * (r+1) = 2*(r+1), exact in f32
            let want = 2.0 * (r + 1) as f32;
            assert_eq!(v, &vec![want; 4], "round {r} drifted under uneven arrival");
        }
    }
}
