//! Cross-module property tests and failure injection.

use recad::cli::Cli;
use recad::config::{RecAdConfig, Toml};
use recad::coordinator::cache::{EmbeddingCache, PrefetchBatch, PrefetchedRow};
use recad::coordinator::queues::BoundedQueue;
use recad::data::zipf::Zipf;
use recad::powersys::dcpf::DcPowerFlow;
use recad::powersys::ieee118::{Grid, N_BUS};
use recad::reorder::bijection::IndexBijection;
use recad::runtime::{ArtifactMeta, Artifacts};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::check::{assert_allclose, check_cases};
use recad::util::prng::Rng;

/// Eff-TT must behave exactly like a plain table initialized with its
/// materialization — across random shapes, ranks, bags and skew.
#[test]
fn tt_is_a_plain_table_in_disguise() {
    check_cases("tt-plain-equiv", 15, |rng, _| {
        let rows = rng.below(4000) + 64;
        let dim = [8usize, 16, 32][rng.usize_below(3)];
        let rank = [2usize, 4, 8][rng.usize_below(3)];
        let shapes = TtShapes::plan(rows, dim, rank);
        let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut Rng::new(rng.next_u64()));
        let w = t.materialize();
        // random multi-bag layout
        let n_idx = rng.usize_below(24) + 1;
        let idx: Vec<u64> = (0..n_idx).map(|_| rng.below(rows)).collect();
        let mut offsets = vec![0usize];
        let mut at = 0usize;
        while at < n_idx {
            at = (at + 1 + rng.usize_below(4)).min(n_idx);
            offsets.push(at);
        }
        let bags = offsets.len() - 1;
        let mut out = vec![0.0; bags * dim];
        let mut scratch = TtScratch::default();
        t.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
        let mut expect = vec![0.0f32; bags * dim];
        for b in 0..bags {
            for k in offsets[b]..offsets[b + 1] {
                for d in 0..dim {
                    expect[b * dim + d] += w[idx[k] as usize * dim + d];
                }
            }
        }
        assert_allclose(&out, &expect, 1e-4, 1e-5);
    });
}

/// The dense bijection is a true permutation of the row space.
#[test]
fn bijection_is_total_permutation() {
    check_cases("bijection-perm", 5, |rng, _| {
        let rows = rng.below(3000) + 200;
        let batches: Vec<Vec<u64>> = (0..10)
            .map(|_| (0..32).map(|_| rng.below(rows)).collect())
            .collect();
        let refs: Vec<&[u64]> = batches.iter().map(|b| b.as_slice()).collect();
        let bij = IndexBijection::build(rows, &refs, 0.1);
        let mut seen = vec![false; rows as usize];
        for old in 0..rows {
            let new = bij.apply(old);
            assert!(new < rows, "out of range");
            assert!(!seen[new as usize], "collision at old={old}");
            seen[new as usize] = true;
        }
    });
}

/// DC power flow conserves energy: injections sum to ~0 after solving
/// a balanced case, and flows are antisymmetric under branch reversal.
#[test]
fn power_flow_conservation() {
    check_cases("pf-conserve", 5, |rng, _| {
        let pf = DcPowerFlow::new(Grid::ieee118(rng.next_u64()));
        let mut inj: Vec<f64> = (0..N_BUS).map(|_| rng.normal() * 0.2).collect();
        let mean = inj.iter().sum::<f64>() / N_BUS as f64;
        for v in inj.iter_mut() {
            *v -= mean; // balance
        }
        let theta = pf.solve_angles(&inj);
        let implied = pf.injections(&theta);
        let total: f64 = implied.iter().sum();
        assert!(total.abs() < 1e-6, "energy not conserved: {total}");
    });
}

/// Zipf CDF dominance: lower ranks always at least as probable.
#[test]
fn zipf_rank_dominance() {
    let z = Zipf::new(1000, 1.3);
    let mut rng = Rng::new(5);
    let mut counts = vec![0u64; 1000];
    for _ in 0..200_000 {
        counts[z.sample(&mut rng) as usize] += 1;
    }
    // coarse bucket comparison to dodge sampling noise
    let head: u64 = counts[..10].iter().sum();
    let mid: u64 = counts[10..100].iter().sum();
    let tail: u64 = counts[100..].iter().sum();
    assert!(head > mid / 3, "head {head} mid {mid}");
    assert!(head + mid > tail / 2);
}

/// Cache RAW property under a random interleaving of writes, prefetches
/// and lifecycle steps: a synced prefetch must never be older than the
/// newest device write.
#[test]
fn cache_raw_random_interleaving() {
    check_cases("cache-raw", 10, |rng, _| {
        let mut cache = EmbeddingCache::new(4 + rng.next_u32() % 8);
        let rows = 16u64;
        let mut device_version = vec![0u64; rows as usize];
        let mut device_value = vec![0.0f32; rows as usize];
        let mut host_version = 0u64;
        for step in 1..60u64 {
            match rng.usize_below(3) {
                0 => {
                    // device write
                    let r = rng.below(rows);
                    device_version[r as usize] = step;
                    device_value[r as usize] = step as f32;
                    cache.record_update(0, r, &[step as f32; 4], step);
                }
                1 => {
                    // host catches up to some earlier version
                    host_version = host_version.max(step.saturating_sub(rng.below(5)));
                }
                _ => {
                    // prefetch a random row at host_version
                    let r = rng.below(rows);
                    // host value reflects all device writes ≤ host_version
                    let host_val = if device_version[r as usize] <= host_version {
                        device_value[r as usize]
                    } else {
                        -1.0 // stale placeholder the host would serve
                    };
                    let mut batch = PrefetchBatch {
                        step,
                        rows: vec![(
                            0usize,
                            PrefetchedRow { row: r, data: vec![host_val; 4], version: host_version },
                        )],
                    };
                    cache.sync_prefetch(&mut batch);
                    let got = batch.rows[0].1.data[0];
                    if device_version[r as usize] > host_version {
                        // stale at host: cache must have patched IF it
                        // still holds the row (lifecycle may have evicted;
                        // eviction only happens for rows untouched for LC
                        // steps, which the pipeline's queue bound prevents
                        // — emulate by asserting only when present)
                        if cache.get(0, r).is_some() {
                            assert_eq!(
                                got, device_value[r as usize],
                                "stale row served at step {step}"
                            );
                        }
                    } else {
                        assert_eq!(got, host_val);
                    }
                }
            }
            cache.end_step();
        }
    });
}

/// Queue under concurrent producers/consumers: nothing lost, nothing
/// duplicated.
#[test]
fn queue_mpmc_stress() {
    let q: std::sync::Arc<BoundedQueue<u64>> = BoundedQueue::new(8);
    let n_prod = 3;
    let per = 500u64;
    let mut handles = Vec::new();
    for p in 0..n_prod {
        let q = q.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per {
                q.push(p * 10_000 + i);
            }
        }));
    }
    let qc = q.clone();
    let consumer = std::thread::spawn(move || {
        let mut got = Vec::new();
        while let Some(v) = qc.pop() {
            got.push(v);
        }
        got
    });
    for h in handles {
        h.join().unwrap();
    }
    q.close();
    let got = consumer.join().unwrap();
    assert_eq!(got.len(), (n_prod * per) as usize);
    let set: std::collections::HashSet<u64> = got.iter().copied().collect();
    assert_eq!(set.len(), got.len(), "duplicated items");
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn artifacts_missing_dir_is_graceful() {
    let err = match Artifacts::load("/nonexistent/path") {
        Err(e) => e,
        Ok(_) => panic!("load of missing dir must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("meta.json"), "unhelpful error: {msg}");
}

#[test]
fn meta_json_garbage_rejected() {
    assert!(ArtifactMeta::parse("{not json").is_err());
    assert!(ArtifactMeta::parse("{}").is_err()); // missing sections
    assert!(ArtifactMeta::parse(r#"{"model": {}, "batches": {}, "tt_lookup_spec": {}, "params": []}"#).is_err());
}

#[test]
fn config_errors_are_located() {
    let err = Toml::parse("key = {bad}\n").unwrap_err();
    assert!(format!("{err:#}").contains("line 1"));
    assert!(RecAdConfig::load("/no/such/file.toml").is_err());
}

#[test]
fn cli_rejects_malformed() {
    let bad = vec!["train".to_string(), "stray".to_string()];
    assert!(Cli::parse(&bad).is_err());
    let none: Vec<String> = vec![];
    assert!(Cli::parse(&none).is_err());
}

#[test]
fn tt_lookup_out_of_range_panics() {
    let shapes = TtShapes::plan(100, 8, 4);
    let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut Rng::new(1));
    let mut out = vec![0.0; 8];
    let mut scratch = TtScratch::default();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        t.embedding_bag(&[9999], &[0, 1], &mut out, &mut scratch);
    }));
    assert!(result.is_err(), "out-of-range index must be rejected");
}

/// Serving router: micro-batching (max_batch > 1) must preserve verdict
/// probabilities exactly vs batch-1 serving (the router trade-off is
/// latency/throughput, never numerics).
#[test]
fn router_microbatching_preserves_verdicts() {
    use recad::coordinator::engine::{EngineCfg, NativeDlrm};
    use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use recad::serve::{Detector, StreamingServer};
    use std::time::Duration;

    let ds = generate(&DatasetCfg {
        n_normal: 60,
        n_attack: 15,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 77,
    });
    let cfg = EngineCfg::ieee118(1.0 / 2000.0);
    let mk = || Detector::new(NativeDlrm::new(cfg.clone(), &mut Rng::new(9)), 0.5);

    let single = StreamingServer::start(mk(), 1, Duration::ZERO);
    let p1: Vec<f32> = ds.samples[..20].iter().map(|s| single.infer(s).prob).collect();
    let _ = single.run_stream(&ds.samples[20..21], 0);

    let batched = StreamingServer::start(mk(), 8, Duration::ZERO);
    let p8: Vec<f32> = ds.samples[..20].iter().map(|s| batched.infer(s).prob).collect();
    let _ = batched.run_stream(&ds.samples[20..21], 0);

    for (a, b) in p1.iter().zip(&p8) {
        assert!((a - b).abs() < 1e-5, "router changed numerics: {a} vs {b}");
    }
}
