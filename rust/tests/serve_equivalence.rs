//! Serving-stack equivalence pins (the api_redesign acceptance gates):
//!
//! 1. Verdicts are BITWISE identical across `RoundRobin` /
//!    `LeastQueued` / `PlanAffinity` and replica counts 1/2/4 — replicas
//!    are clones of one trained detector, so routing can only move
//!    requests, never change scores.
//! 2. The open-loop Poisson generator serves every offered request and
//!    its queue-delay/service-time split re-adds to the attack window.
//! 3. The micro-batch deadline path scores exactly like batch-1 serving
//!    (forward passes are row-independent).

use std::time::Duration;

use recad::access::AccessPlanner;
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::data::batcher::EpochIter;
use recad::data::ctr::Batch;
use recad::powersys::dataset::{generate, DatasetCfg, Sample, SparseVocab};
use recad::serve::{run_open_loop, OpenLoopCfg, Policy, QueueDepths, RoutePolicy, ServeSession};
use recad::util::prng::Rng;

const POLICIES: [Policy; 3] = [Policy::RoundRobin, Policy::LeastQueued, Policy::PlanAffinity];

fn dataset(n: usize) -> Vec<Sample> {
    generate(&DatasetCfg {
        n_normal: n,
        n_attack: n / 4,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 2,
    })
    .samples
}

/// A session whose planner carries REAL (profiled) bijections, so
/// `PlanAffinity` hashes through a non-identity remap — the serving
/// configuration every reordered training run produces.
fn profiled_session(samples: &[Sample]) -> ServeSession {
    let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(1));
    let mut rng = Rng::new(3);
    let profile: Vec<Batch> = EpochIter::new(samples, 32, &mut rng).take(4).collect();
    let planner = AccessPlanner::with_profile(&engine.cfg, &profile, 0.1);
    ServeSession::from_trained(engine, planner)
}

#[test]
fn verdicts_bitwise_identical_across_policies_and_replicas() {
    let samples = dataset(120);
    let stream = &samples[..24];
    let base = profiled_session(&samples);
    let want: Vec<u32> = {
        let server = base.clone().start();
        let bits = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        bits
    };
    for policy in POLICIES {
        for replicas in [1usize, 2, 4] {
            let server = base.clone().replicas(replicas).policy(policy).start();
            assert_eq!(server.replicas(), replicas);
            let got: Vec<u32> =
                stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
            assert_eq!(
                want, got,
                "{policy:?} x {replicas} replicas changed verdict bits"
            );
            let (lifetime, _) = server.shutdown();
            assert_eq!(lifetime, stream.len() as u64, "requests lost by {policy:?}");
        }
    }
}

#[test]
fn open_loop_serves_everything_with_sane_window_split() {
    let samples = dataset(160);
    let stream = &samples[..60];
    let base = profiled_session(&samples);
    for policy in POLICIES {
        let server = base.clone().replicas(2).policy(policy).start();
        let report = run_open_loop(
            server,
            stream,
            &OpenLoopCfg { rate_per_sec: 3000.0, seed: 7 },
        );
        assert_eq!(report.offered, stream.len());
        assert_eq!(report.served, stream.len() as u64, "open loop dropped requests");
        assert_eq!(report.window_samples.len(), stream.len());
        assert!(report.achieved_rate > 0.0);
        // queue delay is non-negative by construction and the split
        // re-adds to the window (service = window − queue, pointwise)
        assert!(report.p50_window <= report.p99_window);
        assert!(report.p99_window <= report.max_window);
        let sum = report.mean_queue_delay + report.mean_service;
        let drift = if sum > report.mean_window {
            sum - report.mean_window
        } else {
            report.mean_window - sum
        };
        assert!(
            drift < Duration::from_millis(1),
            "queue/service split drifted {drift:?} under {policy:?}"
        );
        assert!(
            report.window_samples.windows(2).all(|w| w[0] <= w[1]),
            "window samples must come back sorted"
        );
    }
}

#[test]
fn microbatch_deadline_path_matches_batch1_scores() {
    let samples = dataset(120);
    let stream = &samples[..16];
    let base = profiled_session(&samples);
    let want: Vec<u32> = {
        let server = base.clone().start(); // batch-1 reference
        let bits = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        bits
    };
    let server = base
        .max_batch(8)
        .deadline(Duration::from_millis(4))
        .start();
    // submit everything up front so the deadline batcher actually groups
    let rxs: Vec<_> = stream.iter().map(|s| server.submit(s)).collect();
    let got: Vec<u32> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("reply").prob.to_bits())
        .collect();
    assert_eq!(want, got, "deadline micro-batching changed scores");
    let (lifetime, hist) = server.shutdown();
    assert_eq!(lifetime, stream.len() as u64);
    assert_eq!(hist.count(), stream.len() as u64);
}

#[test]
fn plan_affinity_routes_consistently_and_spreads_hot_prefixes() {
    use recad::serve::PlanAffinity;
    let samples = dataset(200);
    let planner = AccessPlanner::for_engine_cfg(&EngineCfg::ieee118(1.0 / 2000.0));
    let policy = PlanAffinity::new(planner.affinity_map());
    let depths = QueueDepths::new(4);
    let mut hit = [false; 4];
    for s in &samples[..64] {
        let a = policy.route(s, &depths);
        assert!(a < 4);
        // stateless + deterministic: the same sample always lands on the
        // same replica, whatever the queues look like
        depths.enter((a + 1) % 4);
        assert_eq!(policy.route(s, &depths), a);
        depths.leave((a + 1) % 4);
        hit[a] = true;
    }
    assert!(
        hit.iter().filter(|&&h| h).count() > 1,
        "affinity routing collapsed onto one replica: {hit:?}"
    );
}
