//! Cross-module integration tests.
//!
//! PJRT-dependent tests auto-skip when `artifacts/` has not been built
//! (run `make artifacts`), so `cargo test` is meaningful both before and
//! after the python compile step.

use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::pipeline::{self, PipelineCfg};
use recad::coordinator::platform::CostModel;
use recad::coordinator::trainer::{evaluate_on, train_ieee118};
use recad::data::ctr::CtrGenerator;
use recad::data::schema::DatasetSchema;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::runtime::{Artifacts, DlrmFwd, DlrmTrainStep, TtLookupExe};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::check::assert_allclose;
use recad::util::prng::Rng;
use std::time::Duration;

fn artifacts() -> Option<Artifacts> {
    if !std::path::Path::new("artifacts/meta.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Artifacts::load("artifacts").expect("artifacts load"))
}

/// The central cross-language numeric check: the native rust Eff-TT
/// engine and the jax/pallas-lowered `tt_lookup` artifact must agree on
/// pooled embedding bags for identical cores.
#[test]
fn native_tt_matches_pjrt_artifact() {
    let Some(arts) = artifacts() else { return };
    let m = arts.meta.clone();
    let shapes = TtShapes::plan(m.lookup_rows, m.emb_dim, m.lookup_rank);
    assert_eq!(shapes.m, m.lookup_m, "shape plan drifted between languages");

    let mut rng = Rng::new(0xA11CE);
    let mut table = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    let (d1, d2, d3) = table.to_jax_cores();
    let r = m.lookup_rank;

    let idx: Vec<i32> = (0..m.lookup_batch * m.lookup_bag)
        .map(|_| rng.below(m.lookup_rows) as i32)
        .collect();

    // PJRT side
    let exe = TtLookupExe::new(&arts);
    let pjrt_out = exe
        .run(
            (&d1, &[shapes.m[0] as usize, shapes.n[0], r]),
            (&d2, &[r, shapes.m[1] as usize, shapes.n[1], r]),
            (&d3, &[r, shapes.m[2] as usize, shapes.n[2]]),
            &idx,
        )
        .expect("pjrt lookup");

    // native side: same bags (bag size = lookup_bag)
    let flat: Vec<u64> = idx.iter().map(|&i| i as u64).collect();
    let offsets: Vec<usize> = (0..=m.lookup_batch).map(|b| b * m.lookup_bag).collect();
    let mut native_out = vec![0.0f32; m.lookup_batch * m.emb_dim];
    let mut scratch = TtScratch::default();
    table.embedding_bag(&flat, &offsets, &mut native_out, &mut scratch);

    assert_allclose(&native_out, &pjrt_out, 1e-4, 1e-5);
}

#[test]
fn pjrt_train_step_descends_and_fwd_serves() {
    let Some(arts) = artifacts() else { return };
    let m = arts.meta.clone();
    let mut rng = Rng::new(7);
    let mut dense = vec![0f32; m.train_batch * m.dense_dim];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let idx: Vec<i32> = (0..m.train_batch * m.num_tables)
        .map(|i| rng.below(m.table_rows[i % m.num_tables]) as i32)
        .collect();
    let labels: Vec<f32> = (0..m.train_batch)
        .map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 })
        .collect();
    let mut step = DlrmTrainStep::new(&arts).expect("train step");
    let mut losses = Vec::new();
    for _ in 0..6 {
        losses.push(step.step(&dense, &idx, &labels).expect("step"));
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "no descent: {losses:?}"
    );

    // serve with the trained params
    let leaves = step.params_host().expect("params");
    let fwd = DlrmFwd::with_params(&arts, &leaves).expect("fwd");
    let mut fdense = vec![0f32; m.fwd_batch * m.dense_dim];
    rng.fill_normal(&mut fdense, 0.0, 1.0);
    let fidx: Vec<i32> = (0..m.fwd_batch * m.num_tables)
        .map(|i| rng.below(m.table_rows[i % m.num_tables]) as i32)
        .collect();
    let probs = fwd.predict(&fdense, &fidx).expect("predict");
    assert_eq!(probs.len(), m.fwd_batch);
    for &p in &probs {
        assert!((0.0..=1.0).contains(&p), "prob {p}");
    }

    // padded batch-1 path (Table VI serving mode)
    let one = fwd
        .predict_padded(&fdense[..m.dense_dim], &fidx[..m.num_tables], 1)
        .expect("padded");
    assert_eq!(one.len(), 1);
    assert!((one[0] - probs[0]).abs() < 1e-4, "padding changed numerics");
}

/// Full system loop: dataset → training → detection quality within reach
/// of the paper's Table III row, and the trained model transfers across
/// evaluation paths.
#[test]
fn end_to_end_detection_quality() {
    let ds = generate(&DatasetCfg {
        n_normal: 2000,
        n_attack: 500,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 60,
        noise_std: 0.005,
        seed: 0xE2E,
    });
    let (report, mut engine) = train_ieee118(EngineCfg::ieee118(1.0 / 2000.0), &ds, 3, 64, 2);
    assert!(report.eval.accuracy > 0.9, "accuracy {}", report.eval.accuracy);
    assert!(report.eval.recall > 0.7, "recall {}", report.eval.recall);
    // re-evaluation is deterministic
    let again = evaluate_on(&mut engine, ds.split(0.8).1);
    assert_eq!(again.confusion, report.eval.confusion);
}

/// Pipeline over a CTR-shaped workload: pipelined == sequential losses
/// (RAW protocol), while wall time improves once costs are non-zero.
#[test]
fn pipeline_integration_with_costs() {
    let ecfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(3000, true), (600, false), (500, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: recad::exec::ExecCfg::default(),
    };
    let schema = DatasetSchema {
        name: "integration",
        n_dense: 4,
        vocabs: vec![3000, 600, 500],
        emb_dim: 8,
        zipf_s: 1.2,
        ft_rank: 8,
    };
    let mut gen = CtrGenerator::new(schema, 17);
    let batches = gen.batches(40, 32);

    // comm cost calibrated to ≈ the measured compute of one batch so that
    // overlap is visible but bounded
    let mut probe = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
    let t0 = std::time::Instant::now();
    probe.train_step(&batches[0]);
    let compute = t0.elapsed();
    let cost = CostModel {
        h2d_bps: 1e12,
        d2d_bps: 1e12,
        transfer_latency: compute / 4,
        ps_row: Duration::ZERO,
        dispatch: Duration::ZERO,
    };

    let run_mode = |pipelined: bool| {
        let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
        let host = pipeline::split_to_host(&mut engine, &[1, 2], &mut Rng::new(2));
        let mut pcfg = PipelineCfg::new(cost, vec![1, 2]);
        pcfg.pipelined = pipelined;
        pcfg.lc = 4;
        pipeline::run(engine, host, &batches, &pcfg)
    };
    let (seq, _, _) = run_mode(false);
    let (pipe, _, _) = run_mode(true);
    assert_eq!(seq.losses, pipe.losses, "RAW protocol must preserve numerics");
    assert!(
        pipe.wall < seq.wall,
        "pipeline {:?} !< sequential {:?}",
        pipe.wall,
        seq.wall
    );
}
