//! Access-layer equivalence properties.
//!
//! The refactor's contract: the PLANNED path (plans built by the access
//! layer, possibly on an overlapped ingest thread, possibly with the
//! bijection refreshed online mid-epoch) is **bit-identical** to the
//! UNPLANNED path (the legacy per-call APIs, which now build plans
//! inline) — for workers = 1 and N, reuse on and off, unit and multi
//! bags.  Plus the drift property the online mode exists for: after a
//! distribution shift, the refreshed bijection recovers the reuse-hit
//! rate that a stale offline bijection loses.

use recad::access::plan::{BagLayout, TtPlan};
use recad::access::{run_prefetched, AccessCfg, AccessPlanner, BatchPlan};
use recad::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use recad::data::ctr::Batch;
use recad::data::zipf::DriftingZipf;
use recad::exec::{ExecCfg, ExecPool};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::prng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// TT table: planned forward/backward with an externally-built plan must
/// be bit-identical to the unplanned API, across worker counts, reuse
/// on/off, and unit vs multi-bag layouts.
#[test]
fn tt_planned_matches_unplanned_bitwise() {
    let mut meta = Rng::new(0xACCE55);
    for case in 0..8 {
        let rows = meta.below(2500) + 600;
        let rank = [4usize, 8][meta.usize_below(2)];
        let opts = if case % 3 == 2 {
            EffTtOptions::ttrec_baseline()
        } else {
            EffTtOptions::default()
        };
        let seed = meta.next_u64();
        let shapes = TtShapes::plan(rows, 16, rank);
        let dim = 16usize;

        // skewed stream above the exec layer's PAR_MIN_WORK gates
        let n_idx = meta.usize_below(512) + 3584;
        let hot = rows.min(500);
        let idx: Vec<u64> = (0..n_idx).map(|_| meta.below(hot)).collect();
        let unit_bags = case % 2 == 0;
        let (used, offsets): (usize, Vec<usize>) = if unit_bags {
            (n_idx, (0..=n_idx).collect())
        } else {
            let bag = 4usize;
            let bags = n_idx / bag;
            (bags * bag, (0..=bags).map(|b| b * bag).collect())
        };
        let bags = offsets.len() - 1;
        let grad: Vec<f32> = (0..bags * dim).map(|i| (i as f32 * 0.21).sin()).collect();

        for workers in [1usize, 4] {
            let pool = ExecPool::new(ExecCfg::with_workers(workers));
            // ---- unplanned (legacy API, inline plan) --------------------
            let mut a = EffTtTable::new(shapes, opts, &mut Rng::new(seed));
            a.set_pool(pool);
            let mut out_a = vec![0.0f32; bags * dim];
            let mut scr_a = TtScratch::default();
            a.embedding_bag(&idx[..used], &offsets, &mut out_a, &mut scr_a);
            a.backward_sgd(&idx[..used], &offsets, &grad, 0.05, &mut scr_a);

            // ---- planned (external plan, built once for fwd + bwd) ------
            let mut b = EffTtTable::new(shapes, opts, &mut Rng::new(seed));
            b.set_pool(pool);
            let layout = if unit_bags {
                BagLayout::Unit(bags)
            } else {
                BagLayout::Offsets(&offsets[..])
            };
            let mut plan = TtPlan::default();
            plan.build(shapes, &idx[..used], layout);
            let mut out_b = vec![0.0f32; bags * dim];
            let mut scr_b = TtScratch::default();
            b.embedding_bag_planned(&idx[..used], layout, &plan, &mut out_b, &mut scr_b);
            b.backward_sgd_planned(&idx[..used], layout, &plan, &grad, 0.05, &mut scr_b);

            assert_eq!(
                bits(&out_a),
                bits(&out_b),
                "forward diverged (case {case}, workers {workers})"
            );
            assert_eq!(bits(&a.core1), bits(&b.core1), "core1 (case {case})");
            assert_eq!(bits(&a.core2), bits(&b.core2), "core2 (case {case})");
            assert_eq!(bits(&a.core3), bits(&b.core3), "core3 (case {case})");
            assert_eq!(a.stats.prefix_gemms, b.stats.prefix_gemms, "stats (case {case})");
            assert_eq!(a.stats.hop2_gemms, b.stats.hop2_gemms);
            assert_eq!(a.stats.reuse_hits, b.stats.reuse_hits);
            assert_eq!(a.stats.backward_chains, b.stats.backward_chains);
            assert_eq!(a.stats.grads_aggregated, b.stats.grads_aggregated);
        }
    }
}

/// Tiled (hottest-first, L2-tiled) plan execution must be bit-identical
/// to untiled planned execution: same outputs, same cores after the
/// update, same TtStats — for workers 1 and N, unit and multi bags, and
/// tile budgets from "everything in one tile" down to "a tile per tiny
/// group".
#[test]
fn tt_tiled_matches_untiled_bitwise() {
    let mut meta = Rng::new(0x711E);
    for case in 0..6 {
        let rows = meta.below(2500) + 600;
        let shapes = TtShapes::plan(rows, 16, 8);
        let dim = 16usize;
        let seed = meta.next_u64();
        let n_idx = meta.usize_below(512) + 3584;
        let hot = rows.min(400);
        let idx: Vec<u64> = (0..n_idx).map(|_| meta.below(hot)).collect();
        let unit_bags = case % 2 == 0;
        let (used, offsets): (usize, Vec<usize>) = if unit_bags {
            (n_idx, (0..=n_idx).collect())
        } else {
            let bag = 4usize;
            let bags = n_idx / bag;
            (bags * bag, (0..=bags).map(|b| b * bag).collect())
        };
        let bags = offsets.len() - 1;
        let layout = if unit_bags {
            BagLayout::Unit(bags)
        } else {
            BagLayout::Offsets(&offsets[..])
        };
        let grad: Vec<f32> = (0..bags * dim).map(|i| (i as f32 * 0.13).sin()).collect();
        // 1 KiB forces many tiny tiles; 256 KiB is the default budget
        let cache_kb = [1usize, 256][case % 2];

        for workers in [1usize, 4] {
            let pool = ExecPool::new(ExecCfg::with_workers(workers));
            let run = |tiled: bool| {
                let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut Rng::new(seed));
                t.set_pool(pool);
                let mut plan = TtPlan::default();
                plan.build(shapes, &idx[..used], layout);
                if tiled {
                    plan.build_layout(cache_kb);
                    assert!(plan.tiled(), "layout did not build");
                }
                let mut out = vec![0.0f32; bags * dim];
                let mut scr = TtScratch::default();
                t.embedding_bag_planned(&idx[..used], layout, &plan, &mut out, &mut scr);
                t.backward_sgd_planned(&idx[..used], layout, &plan, &grad, 0.05, &mut scr);
                (out, t)
            };
            let (out_u, t_u) = run(false);
            let (out_t, t_t) = run(true);
            assert_eq!(
                bits(&out_u),
                bits(&out_t),
                "forward diverged (case {case}, workers {workers}, cache_kb {cache_kb})"
            );
            assert_eq!(bits(&t_u.core1), bits(&t_t.core1), "core1 (case {case})");
            assert_eq!(bits(&t_u.core2), bits(&t_t.core2), "core2 (case {case})");
            assert_eq!(bits(&t_u.core3), bits(&t_t.core3), "core3 (case {case})");
            assert_eq!(t_u.stats.prefix_gemms, t_t.stats.prefix_gemms);
            assert_eq!(t_u.stats.hop2_gemms, t_t.stats.hop2_gemms);
            assert_eq!(t_u.stats.reuse_hits, t_t.stats.reuse_hits);
            assert_eq!(t_u.stats.backward_chains, t_t.stats.backward_chains);
            assert_eq!(t_u.stats.grads_aggregated, t_t.stats.grads_aggregated);
        }
    }
}

fn tiny_cfg(workers: usize) -> EngineCfg {
    EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(900, true), (300, true), (40, false)],
        tt_rank: 4,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::with_workers(workers),
    }
}

fn tiny_batches(cfg: &EngineCfg, n: usize, b: usize, seed: u64) -> Vec<Batch> {
    let mut rng = Rng::new(seed);
    let ns = cfg.tables.len();
    (0..n)
        .map(|_| {
            let mut dense = vec![0.0; b * cfg.dense_dim];
            rng.fill_normal(&mut dense, 0.0, 1.0);
            let sparse: Vec<u64> = (0..b * ns)
                .map(|i| rng.below(cfg.tables[i % ns].0.min(80)))
                .collect();
            let labels: Vec<f32> =
                (0..b).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect();
            Batch { dense, sparse, labels, batch_size: b }
        })
        .collect()
}

/// Engine: `train_step` (inline plan) == external planner + ingest stage
/// at every plan-ahead depth, bit-for-bit, for workers 1 and N.
#[test]
fn engine_training_planned_matches_unplanned_across_plan_ahead() {
    for workers in [1usize, 3] {
        let cfg = tiny_cfg(workers);
        let batches = tiny_batches(&cfg, 6, 384, 17);

        // reference: the legacy unplanned API (inline plans)
        let mut reference = NativeDlrm::new(cfg.clone(), &mut Rng::new(5));
        let unplanned: Vec<f32> = batches.iter().map(|b| reference.train_step(b)).collect();
        for plan_ahead in [0usize, 1, 3] {
            let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(5));
            let mut planner = AccessPlanner::for_engine_cfg(&cfg);
            let mut losses = Vec::new();
            run_prefetched(
                batches.iter().cloned(),
                &mut planner,
                plan_ahead,
                |batch, plan| losses.push(m.train_step_planned(batch, plan)),
            );
            assert_eq!(
                bits(&unplanned),
                bits(&losses),
                "loss curve diverged (workers {workers}, plan_ahead {plan_ahead})"
            );
            // parameters too, not just losses
            match (&m.tables[0], &reference.tables[0]) {
                (TableSlot::Tt(x), TableSlot::Tt(y)) => {
                    assert_eq!(bits(&x.core2), bits(&y.core2), "TT cores diverged");
                }
                _ => panic!("slot 0 must be TT"),
            }
            assert_eq!(bits(&m.bot[0].w), bits(&reference.bot[0].w));
        }
    }
}

/// Engine training through a tiled planner (default cache budget) must
/// be bit-identical to the untiled (PR-2) planner — losses and
/// parameters — for workers 1 and N.
#[test]
fn engine_training_tiled_matches_untiled_bitwise() {
    for workers in [1usize, 3] {
        let cfg = tiny_cfg(workers);
        let batches = tiny_batches(&cfg, 6, 384, 71);
        let run = |cache_kb: usize, fuse: bool| -> (Vec<f32>, NativeDlrm) {
            let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(9));
            let mut planner = AccessPlanner::for_engine_cfg(&cfg);
            planner.set_layout_policy(cache_kb, fuse);
            let mut losses = Vec::new();
            run_prefetched(batches.iter().cloned(), &mut planner, 1, |b, p| {
                losses.push(m.train_step_planned(b, p))
            });
            (losses, m)
        };
        let (base, m_base) = run(0, false);
        for (cache_kb, fuse) in [(256usize, false), (1, false), (256, true)] {
            let (losses, m) = run(cache_kb, fuse);
            assert_eq!(
                bits(&base),
                bits(&losses),
                "losses diverged (workers {workers}, cache_kb {cache_kb}, fuse {fuse})"
            );
            match (&m.tables[0], &m_base.tables[0]) {
                (TableSlot::Tt(x), TableSlot::Tt(y)) => {
                    assert_eq!(bits(&x.core2), bits(&y.core2), "TT cores diverged");
                }
                _ => panic!("slot 0 must be TT"),
            }
        }
    }
}

/// Fused cross-table sweeps: a config whose TT slots share a vocabulary
/// must produce per-slot plans bitwise identical to per-table planning,
/// and identical training.
#[test]
fn fused_plans_match_per_table_bitwise() {
    let vocab = 1200u64;
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(vocab, true), (vocab, true), (vocab, true), (40, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let batches = tiny_batches(&cfg, 5, 256, 123);

    // plan-level equivalence
    let mut p_fused = AccessPlanner::for_engine_cfg(&cfg);
    p_fused.set_layout_policy(0, true);
    let mut p_solo = AccessPlanner::for_engine_cfg(&cfg);
    p_solo.set_layout_policy(0, false);
    let mut plan_f = BatchPlan::default();
    let mut plan_s = BatchPlan::default();
    for batch in &batches {
        p_fused.plan_into(batch, &mut plan_f);
        p_solo.plan_into(batch, &mut plan_s);
        assert!(plan_f.fused_stats.sweeps >= 1, "fusion never engaged");
        assert_eq!(plan_f.fused_stats.fused_slots, 3);
        for t in 0..3 {
            let (f, s) = (plan_f.tt_plan(t).unwrap(), plan_s.tt_plan(t).unwrap());
            assert_eq!(f.uniq_rows, s.uniq_rows, "slot {t} distinct rows");
            assert_eq!(f.index_slot, s.index_slot, "slot {t} scatter map");
            assert_eq!(f.group_starts, s.group_starts, "slot {t} groups");
            assert_eq!(f.occ_sorted(), s.occ_sorted(), "slot {t} backward order");
        }
        assert!(plan_f.tt_plan(3).is_none());
    }

    // end-to-end training equivalence (fused + tiled vs neither)
    let run = |fuse: bool| -> Vec<f32> {
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(4));
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.set_layout_policy(if fuse { 256 } else { 0 }, fuse);
        let mut losses = Vec::new();
        run_prefetched(batches.iter().cloned(), &mut planner, 2, |b, p| {
            losses.push(m.train_step_planned(b, p))
        });
        losses
    };
    assert_eq!(bits(&run(false)), bits(&run(true)), "fused training diverged");
}

/// Fused-class RANKED layouts: with fusion + tiling both on, every
/// member of a fused class walks its prefix groups in ONE class-wide
/// heat order (their scheduled prefix sequences agree on every common
/// prefix), and training through the ranked schedule stays bit-identical
/// to the untiled, unfused baseline — the layout is pure scheduling
/// metadata.
#[test]
fn fused_ranked_layout_shares_walk_order_and_stays_bit_identical() {
    let vocab = 1200u64;
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(vocab, true), (vocab, true), (vocab, true), (40, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let batches = tiny_batches(&cfg, 5, 256, 321);

    // plan level: the fused class's members share one walk order
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.set_layout_policy(256, true);
    let mut plan = BatchPlan::default();
    for batch in &batches {
        planner.plan_into(batch, &mut plan);
        assert!(plan.fused_stats.sweeps >= 1, "fusion never engaged");
        let seqs: Vec<Vec<u64>> = (0..3)
            .map(|t| {
                let p = plan.tt_plan(t).unwrap();
                assert!(p.tiled(), "slot {t} missing its layout");
                let sh = p.shapes().unwrap();
                p.sched_group_starts()
                    .iter()
                    .map(|&g| {
                        sh.prefix_of(p.uniq_rows[p.sched()[g as usize] as usize])
                    })
                    .collect()
            })
            .collect();
        for t in 1..3 {
            let (a, b) = (&seqs[0], &seqs[t]);
            let common_a: Vec<u64> =
                a.iter().copied().filter(|p| b.contains(p)).collect();
            let common_b: Vec<u64> =
                b.iter().copied().filter(|p| a.contains(p)).collect();
            assert!(!common_a.is_empty(), "slots 0/{t} share no prefixes");
            assert_eq!(
                common_a, common_b,
                "slot {t} walks common prefixes in a different order"
            );
        }
    }

    // end-to-end: ranked fused training == untiled unfused, bit for bit,
    // including the tiny-tile budget that cuts many ranked tiles
    let run = |cache_kb: usize, fuse: bool| -> Vec<f32> {
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(6));
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.set_layout_policy(cache_kb, fuse);
        let mut losses = Vec::new();
        run_prefetched(batches.iter().cloned(), &mut planner, 1, |b, p| {
            losses.push(m.train_step_planned(b, p))
        });
        losses
    };
    let base = run(0, false);
    assert_eq!(bits(&base), bits(&run(256, true)), "ranked fused training diverged");
    assert_eq!(bits(&base), bits(&run(1, true)), "tiny-tile ranked training diverged");
}

/// Background bijection refresh mid-epoch must produce the same losses
/// AND the same detections as the synchronous-compute twin with the same
/// adoption schedule — while actually recording ingest stall samples.
#[test]
fn background_refresh_matches_synchronous_detections() {
    let vocab = 6000u64;
    let cfg = EngineCfg {
        dense_dim: 2,
        emb_dim: 16,
        tables: vec![(vocab, true), (40, false)],
        tt_rank: 8,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let mut stream = DriftingZipf::new(vocab, 1.25, 0xBEEF);
    let mut rng = Rng::new(55);
    let batch_of = |stream: &DriftingZipf, rng: &mut Rng| -> Batch {
        let b = 128usize;
        let sparse: Vec<u64> =
            (0..b).flat_map(|_| [stream.sample(rng), rng.below(40)]).collect();
        let labels: Vec<f32> = (0..b).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect();
        Batch { dense: vec![0.0; b * 2], sparse, labels, batch_size: b }
    };
    let mut train: Vec<Batch> = (0..10).map(|_| batch_of(&stream, &mut rng)).collect();
    stream.drift(vocab / 2); // force the refreshes to matter
    train.extend((0..10).map(|_| batch_of(&stream, &mut rng)));
    let held_out: Vec<Batch> = (0..4).map(|_| batch_of(&stream, &mut rng)).collect();

    let access = AccessCfg { refresh_every: 4, window: 8, hot_ratio: 0.1, ..AccessCfg::default() };
    let run = |background: bool| -> (Vec<f32>, Vec<Vec<f32>>, u64, usize) {
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.enable_scheduled_online(&cfg, &access, background);
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(77));
        let mut losses = Vec::new();
        run_prefetched(train.iter().cloned(), &mut planner, 1, |b, p| {
            losses.push(m.train_step_planned(b, p))
        });
        // detections: frozen planner, same remap the model trained under
        let mut plan = BatchPlan::default();
        let probs: Vec<Vec<f32>> = held_out
            .iter()
            .map(|b| {
                planner.plan_frozen_into(b, &mut plan);
                m.predict_planned(b, &plan)
            })
            .collect();
        let stalls = planner.reorder_stall_samples().len();
        (losses, probs, planner.refreshes, stalls)
    };
    let (l_sync, d_sync, r_sync, s_sync) = run(false);
    let (l_bg, d_bg, r_bg, s_bg) = run(true);
    assert!(r_sync >= 4, "not enough refreshes to exercise the swap: {r_sync}");
    assert_eq!(r_sync, r_bg, "refresh counts diverged");
    assert!(s_sync > 0 && s_bg > 0, "stall samples missing: {s_sync}/{s_bg}");
    assert_eq!(bits(&l_sync), bits(&l_bg), "losses diverged under background refresh");
    for (i, (a, b)) in d_sync.iter().zip(&d_bg).enumerate() {
        assert_eq!(bits(a), bits(b), "detections diverged on held-out batch {i}");
    }
}

/// Remap path: a planner holding a bijection must equal manually
/// remapping the batch and running the identity path.
#[test]
fn planner_remap_matches_manual_remap_bitwise() {
    let cfg = tiny_cfg(1);
    let profile = tiny_batches(&cfg, 8, 128, 99);
    let batches = tiny_batches(&cfg, 5, 256, 100);
    let planner_ref = AccessPlanner::with_profile(&cfg, &profile, 0.1);

    // manual: remap sparse columns with the same bijections, then train
    // through the legacy API
    let manual: Vec<f32> = {
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(8));
        let ns = cfg.tables.len();
        batches
            .iter()
            .map(|b| {
                let mut rb = b.clone();
                for t in 0..ns {
                    if let Some(bij) = planner_ref.bijection(t) {
                        for r in 0..rb.batch_size {
                            rb.sparse[r * ns + t] = bij.apply(rb.sparse[r * ns + t]);
                        }
                    }
                }
                m.train_step(&rb)
            })
            .collect()
    };

    // planned: the planner applies the bijection inside plan_into
    let mut planner = planner_ref.clone();
    let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(8));
    let mut planned = Vec::new();
    run_prefetched(batches.iter().cloned(), &mut planner, 2, |batch, plan| {
        planned.push(m.train_step_planned(batch, plan))
    });
    assert_eq!(bits(&manual), bits(&planned), "remap path diverged");
}

/// Online refresh mid-epoch: overlapped ingest must be bit-identical to
/// inline planning even while the bijection is being swapped under the
/// stream every K batches.
#[test]
fn online_refresh_mid_epoch_deterministic_under_overlap() {
    let cfg = tiny_cfg(1);
    let batches = tiny_batches(&cfg, 12, 128, 33);
    let access = AccessCfg { refresh_every: 4, window: 8, ..AccessCfg::default() };
    let run = |plan_ahead: usize| -> (Vec<f32>, u64) {
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.enable_online(&cfg, &access);
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(21));
        let mut losses = Vec::new();
        run_prefetched(batches.iter().cloned(), &mut planner, plan_ahead, |b, p| {
            losses.push(m.train_step_planned(b, p))
        });
        (losses, planner.refreshes)
    };
    let (inline, r0) = run(0);
    // two TT slots refresh every 4 batches over 12 batches = 3 each
    assert_eq!(r0, 6, "online refresh did not fire mid-epoch");
    for plan_ahead in [1usize, 4] {
        let (overlapped, rn) = run(plan_ahead);
        assert_eq!(r0, rn, "refresh count changed under overlap");
        assert_eq!(
            bits(&inline),
            bits(&overlapped),
            "online-reorder training diverged at plan_ahead {plan_ahead}"
        );
    }
}

/// The drift property (new `zipf` drift scenario): after the hot set
/// moves, a stale offline bijection loses prefix sharing; the online
/// refresh recovers it.  Measured at the plan level (distinct prefixes
/// per batch == first-hop GEMMs the reuse buffer must pay).
#[test]
fn online_reorder_recovers_reuse_after_drift() {
    let vocab = 8000u64;
    let cfg = EngineCfg {
        dense_dim: 2,
        emb_dim: 16,
        tables: vec![(vocab, true)],
        tt_rank: 8,
        bot_hidden: vec![8],
        top_hidden: vec![8],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let mut stream = DriftingZipf::new(vocab, 1.25, 0xD21F7);
    let mut rng = Rng::new(41);
    let batch_of = |stream: &DriftingZipf, rng: &mut Rng| -> Batch {
        let b = 256usize;
        let sparse: Vec<u64> = (0..b).map(|_| stream.sample(rng)).collect();
        Batch { dense: vec![0.0; b * 2], sparse, labels: vec![0.0; b], batch_size: b }
    };

    // offline profile on the pre-drift distribution
    let profile: Vec<Batch> = (0..24).map(|_| batch_of(&stream, &mut rng)).collect();
    let mean_prefixes = |planner: &mut AccessPlanner, batches: &[Batch]| -> f64 {
        let mut plan = BatchPlan::default();
        let mut total = 0usize;
        for b in batches {
            planner.plan_into(b, &mut plan);
            total += plan.tt_plan(0).unwrap().distinct_prefixes();
        }
        total as f64 / batches.len() as f64
    };

    let mut offline = AccessPlanner::with_profile(&cfg, &profile, 0.1);
    let access =
        AccessCfg { refresh_every: 8, window: 16, hot_ratio: 0.1, ..AccessCfg::default() };
    let mut online = offline.clone();
    online.enable_online(&cfg, &access);

    // pre-drift: both planners share the profiled bijection
    let pre: Vec<Batch> = (0..8).map(|_| batch_of(&stream, &mut rng)).collect();
    let pre_offline = mean_prefixes(&mut offline, &pre);

    // drift: the hot mass moves to a scrambled cold region
    stream.drift(vocab / 2);
    let post: Vec<Batch> = (0..8).map(|_| batch_of(&stream, &mut rng)).collect();
    let post_offline = mean_prefixes(&mut offline, &post);
    assert!(
        post_offline > 1.15 * pre_offline,
        "drift did not hurt the stale bijection: {pre_offline:.1} -> {post_offline:.1}"
    );

    // online: feed enough post-drift batches to trigger refreshes, then
    // measure on fresh batches from the drifted distribution
    let warm: Vec<Batch> = (0..16).map(|_| batch_of(&stream, &mut rng)).collect();
    mean_prefixes(&mut online, &warm);
    assert!(online.refreshes >= 1, "online refresh never fired");
    let eval: Vec<Batch> = (0..8).map(|_| batch_of(&stream, &mut rng)).collect();
    let post_online = mean_prefixes(&mut online, &eval);
    assert!(
        post_online < 0.9 * post_offline,
        "online refresh failed to recover reuse: online {post_online:.1} vs stale {post_offline:.1}"
    );
}
