//! Self-tuning-runtime equivalence pins (the perf_opt acceptance gates):
//!
//! 1. With autotune OFF (the default), `train_ieee118_auto` is BITWISE
//!    identical to a hand-inlined static training loop — the controller
//!    layer must be provably inert when disabled, not merely similar.
//! 2. The serving path with autotune off (or the serve loop disabled)
//!    installs no tuner and scores bit-identically.
//! 3. With the serve loop ON the knobs may move mid-stream, but scores
//!    stay bit-identical — batching/scheduling changes can move requests
//!    between micro-batches, never change a forward pass (forward passes
//!    are row-independent).
//! 4. The reorder-cadence controller, fed purely through
//!    `AccessPlanner::plan_into`, shortens `refresh_every` when the hot
//!    set drifts (reuse-rate peak decay).
//! 5. The cache-budget controller, fed through the same planning path
//!    plus the step-time feedback bus, commits a ladder rung.

use std::time::Duration;

use recad::access::{run_prefetched_fill, AccessCfg, AccessPlanner, BatchPlan};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::trainer::{evaluate_on_with, train_ieee118_auto};
use recad::data::batcher::EpochIter;
use recad::data::ctr::Batch;
use recad::data::zipf::GradualDriftZipf;
use recad::exec::ExecCfg;
use recad::powersys::dataset::{generate, DatasetCfg, Ieee118Dataset, Sample, SparseVocab};
use recad::runtime::AutotuneCfg;
use recad::serve::ServeSession;
use recad::tt::table::EffTtOptions;
use recad::util::prng::Rng;

fn train_dataset() -> Ieee118Dataset {
    generate(&DatasetCfg {
        n_normal: 300,
        n_attack: 75,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 5,
    })
}

/// The pre-autotune static training loop, inlined from the trainer:
/// engine + planner + epoch shuffle + prefetched ingest, with NO step
/// timing and NO tuner consultation.  `train_ieee118_auto` with the
/// loops off must reproduce every loss bit and the final eval.
fn static_reference(
    cfg: EngineCfg,
    access: &AccessCfg,
    ds: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> (Vec<u32>, u64) {
    let (train, test) = ds.split(0.8);
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(seed));
    let mut planner = AccessPlanner::for_engine_cfg(&engine.cfg);
    planner.configure(&engine.cfg, access);
    let mut rng = Rng::new(seed ^ 0xE90C);
    let mut losses = Vec::new();
    for _ in 0..epochs {
        let mut iter = EpochIter::new(train, batch_size, &mut rng);
        let _ = run_prefetched_fill(
            |out| iter.next_into(out),
            &mut planner,
            access.plan_ahead,
            |batch, plan| losses.push(engine.train_step_planned(batch, plan).to_bits()),
        );
    }
    let eval = evaluate_on_with(&mut engine, &planner, test);
    (losses, eval.accuracy.to_bits())
}

#[test]
fn autotune_off_is_bit_identical_to_the_static_trainer() {
    let ds = train_dataset();
    // online reorder + cache budget + lookahead: the config where every
    // tuner hook sits on the hot path and must still be inert
    let access = AccessCfg {
        online_reorder: true,
        cache_kb: 128,
        plan_ahead: 2,
        refresh_every: 4,
        window: 4,
        ..AccessCfg::default()
    };
    let cfg = EngineCfg::ieee118(1.0 / 2000.0);
    let (want_losses, want_acc) = static_reference(cfg.clone(), &access, &ds, 2, 32, 9);
    assert!(!want_losses.is_empty());
    let off_cfgs = [
        AutotuneCfg::default(),
        // master switch off overrides per-loop switches…
        AutotuneCfg { enabled: false, cache: true, reorder: true, serve: true, ..AutotuneCfg::default() },
        // …and enabled with every loop off installs nothing either
        AutotuneCfg { enabled: true, cache: false, reorder: false, serve: false, ..AutotuneCfg::default() },
    ];
    for at in off_cfgs {
        let (report, _, planner) =
            train_ieee118_auto(cfg.clone(), &access, &at, &ds, 2, 32, 9);
        let got: Vec<u32> = report.loss_curve.iter().map(|l| l.to_bits()).collect();
        assert_eq!(want_losses, got, "autotune-off loss bits drifted under {at:?}");
        assert_eq!(want_acc, report.eval.accuracy.to_bits(), "eval drifted under {at:?}");
        assert!(planner.cache_tuner().is_none(), "no cache tuner may install: {at:?}");
        assert!(planner.cache_feedback().is_none(), "no feedback bus may install: {at:?}");
        for t in 0..planner.num_tables() {
            assert!(planner.cadence_tuner(t).is_none(), "no cadence tuner on slot {t}: {at:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Serving
// ---------------------------------------------------------------------------

fn serve_dataset(n: usize) -> Vec<Sample> {
    generate(&DatasetCfg {
        n_normal: n,
        n_attack: n / 4,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 2,
    })
    .samples
}

/// A session whose planner carries REAL (profiled) bijections — the
/// serving configuration every reordered training run produces.
fn profiled_session(samples: &[Sample]) -> ServeSession {
    let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(1));
    let mut rng = Rng::new(3);
    let profile: Vec<Batch> = EpochIter::new(samples, 32, &mut rng).take(4).collect();
    let planner = AccessPlanner::with_profile(&engine.cfg, &profile, 0.1);
    ServeSession::from_trained(engine, planner)
}

#[test]
fn serve_autotune_off_installs_nothing_and_scores_identically() {
    let samples = serve_dataset(120);
    let stream = &samples[..24];
    let base = profiled_session(&samples);
    let want: Vec<u32> = {
        let server = base.clone().start();
        let bits = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        bits
    };
    let off_cfgs = [
        AutotuneCfg::default(),
        AutotuneCfg { enabled: true, serve: false, ..AutotuneCfg::default() },
    ];
    for at in off_cfgs {
        let server = base.clone().replicas(2).autotune(&at).start();
        let got: Vec<u32> =
            stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        assert_eq!(want, got, "autotune-off serving changed verdict bits under {at:?}");
        let (lifetime, _) = server.shutdown();
        assert_eq!(lifetime, stream.len() as u64);
    }
}

#[test]
fn serve_autotune_on_keeps_score_bits() {
    let samples = serve_dataset(120);
    let stream = &samples[..80];
    let base = profiled_session(&samples);
    let want: Vec<u32> = {
        let server = base.clone().start(); // batch-1 reference
        let bits = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        bits
    };
    // one replica + 80 up-front submissions: the reply count crosses the
    // tuner's adjust_every, so the knobs actually move mid-stream
    let at = AutotuneCfg {
        enabled: true,
        cache: false,
        reorder: false,
        target_p99_us: 5_000,
        ..AutotuneCfg::default()
    };
    let server = base
        .max_batch(4)
        .deadline(Duration::from_micros(200))
        .autotune(&at)
        .start();
    let rxs: Vec<_> = stream.iter().map(|s| server.submit(s)).collect();
    let got: Vec<u32> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("reply").prob.to_bits())
        .collect();
    assert_eq!(want, got, "serve autotune changed verdict bits");
    let (lifetime, hist) = server.shutdown();
    assert_eq!(lifetime, stream.len() as u64);
    assert_eq!(hist.count(), stream.len() as u64);
}

// ---------------------------------------------------------------------------
// Planner-fed controllers
// ---------------------------------------------------------------------------

fn small_cfg() -> EngineCfg {
    EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(4000, true), (16, false)],
        tt_rank: 4,
        bot_hidden: vec![8],
        top_hidden: vec![8],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    }
}

fn zipf_batch(z: &GradualDriftZipf, rng: &mut Rng, b: usize) -> Batch {
    let sparse: Vec<u64> =
        (0..b).flat_map(|_| [z.sample(rng), rng.below(16)]).collect();
    Batch { dense: vec![0.0; b * 4], sparse, labels: vec![0.0; b], batch_size: b }
}

#[test]
fn cadence_tuner_shortens_refresh_under_hot_set_drift() {
    let cfg = small_cfg();
    let access =
        AccessCfg { refresh_every: 8, window: 4, hot_ratio: 0.1, ..AccessCfg::default() };
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.enable_scheduled_online(&cfg, &access, false);
    planner.enable_autotune(&AutotuneCfg {
        enabled: true,
        cache: false,
        serve: false,
        ..AutotuneCfg::default()
    });
    let mut rng = Rng::new(11);
    let mut z = GradualDriftZipf::new(4000, 1.2, 13);
    let mut plan = BatchPlan::default();
    // stationary warmup: the bijection adapts, reuse plateaus (the
    // cadence may legitimately RELAX here — compare against drift onset)
    for _ in 0..32 {
        let b = zipf_batch(&z, &mut rng, 64);
        planner.plan_into(&b, &mut plan);
    }
    let onset = planner.online_refresh_every(0).expect("slot 0 is online");
    let onset_shortens = planner.cadence_tuner(0).expect("cadence tuner installed").shortens;
    // hot-set drift: half the vocabulary rotates in; reuse under the
    // stale bijection decays and the controller must refresh sooner
    z.begin_drift(2000);
    for _ in 0..24 {
        z.advance(1.5 / 24.0);
        let b = zipf_batch(&z, &mut rng, 64);
        planner.plan_into(&b, &mut plan);
    }
    let fin = planner.online_refresh_every(0).expect("slot 0 is online");
    let tuner = planner.cadence_tuner(0).expect("cadence tuner installed");
    assert!(
        tuner.shortens > onset_shortens,
        "drift must register at least one shorten ({onset_shortens} -> {})",
        tuner.shortens
    );
    assert!(fin < onset, "refresh_every must shorten under drift: {onset} -> {fin}");
    // the tuner's mirror of the interval tracks the engine's
    assert_eq!(tuner.every(), fin);
    // the plain (uncompressed) slot never grows a cadence tuner
    assert!(planner.cadence_tuner(1).is_none());
}

#[test]
fn cache_tuner_commits_a_ladder_rung_through_plan_into() {
    let cfg = small_cfg();
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.configure(&cfg, &AccessCfg::default());
    let at = AutotuneCfg {
        enabled: true,
        reorder: false,
        serve: false,
        probe_batches: 2,
        ..AutotuneCfg::default()
    };
    planner.enable_autotune(&at);
    let fb = planner.cache_feedback().expect("cache loop installs a feedback bus");
    let mut rng = Rng::new(21);
    let z = GradualDriftZipf::new(4000, 1.2, 23); // stationary (no drift begun)
    let mut plan = BatchPlan::default();
    for _ in 0..32 {
        let b = zipf_batch(&z, &mut rng, 64);
        planner.plan_into(&b, &mut plan);
        fb.push(1.0e-3); // flat cost: any rung may win, but one MUST
    }
    let tuner = planner.cache_tuner().expect("cache tuner installed");
    let kb = tuner.committed_kb().expect("ladder commits after probing every rung");
    assert!(
        at.cache_ladder.contains(&kb),
        "committed budget {kb} KiB not on the ladder {:?}",
        at.cache_ladder
    );
    assert_eq!(tuner.reprobes, 0, "stationary stream must not re-open the probe");
}
