// D5 clean: deterministic splitmix64-style mixing from an explicit seed.
pub fn next_seed(state: u64) -> u64 {
    state.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}
