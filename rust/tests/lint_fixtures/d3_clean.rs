// D3 clean: the same decode degrades to None instead of unwinding.
pub fn decode_tag(buf: &[u8]) -> Option<u32> {
    let head = buf.first()?;
    if *head > 4 {
        return None;
    }
    Some(u32::from(*head))
}
