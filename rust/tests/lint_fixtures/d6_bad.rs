// D6 bad: an unsafe block with no lint:allow(D6) justification.
pub fn read_first(v: &[u32]) -> u32 {
    unsafe { *v.get_unchecked(0) }
}
