// Pragma respected: a reasoned lint:allow suppresses the finding.
use std::collections::HashMap;

pub fn count_all(m: &HashMap<u64, u64>) -> u64 {
    // lint:allow(D1) u64 sum is commutative across any visit order
    m.values().sum()
}
