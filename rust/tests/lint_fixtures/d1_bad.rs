// D1 bad: iterating a HashMap accumulates floats in hash order.
use std::collections::HashMap;

pub fn sum_scores(scores: &HashMap<u64, f32>) -> f32 {
    let mut acc = 0.0;
    for (_, v) in scores.iter() {
        acc += v;
    }
    acc
}
