// D5 bad: OS-seeded hasher state makes every run's hash order unique.
use std::collections::hash_map::RandomState;

pub fn fresh_hasher() -> RandomState {
    RandomState::new()
}
