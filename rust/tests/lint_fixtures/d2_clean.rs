// D2 clean: naming the Instant type (imports, signatures) is fine —
// only reading the clock (`Instant::now`) is flagged.
use std::time::Instant;

pub fn took(t0: Instant) -> std::time::Duration {
    t0.elapsed()
}
