// Pragma misuse: no reason given, so the finding survives AND the
// empty pragma itself is reported.
use std::collections::HashMap;

pub fn count_all(m: &HashMap<u64, u64>) -> u64 {
    // lint:allow(D1)
    m.values().sum()
}
