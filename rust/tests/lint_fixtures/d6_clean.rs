// D6 clean: no unsafe at all — the bounds check stays.
pub fn read_first(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}
