// D4 bad: a raw fire-and-forget thread nobody joins or supervises.
pub fn fire_and_forget(job: impl FnOnce() + Send + 'static) {
    std::thread::spawn(job);
}
