// D3 bad: panic paths on a request-serving code path.
pub fn decode_tag(buf: &[u8]) -> u32 {
    let head = buf.first().expect("empty frame");
    if *head > 4 {
        panic!("bad tag");
    }
    u32::from(*head)
}
