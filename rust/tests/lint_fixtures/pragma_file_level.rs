// lint:allow-file(D2) fixture: a whole-file timing shim
pub fn t0() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn t1() -> std::time::Instant {
    std::time::Instant::now()
}
