// D4 clean: no raw spawn; work runs inline (or through exec::pool).
pub fn run_inline(job: impl FnOnce()) {
    job();
}
