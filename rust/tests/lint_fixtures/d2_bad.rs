// D2 bad: raw wall-clock reads outside util/clock and bench code.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn epoch_secs() -> u64 {
    use std::time::SystemTime;
    match SystemTime::now().duration_since(SystemTime::UNIX_EPOCH) {
        Ok(d) => d.as_secs(),
        Err(_) => 0,
    }
}
