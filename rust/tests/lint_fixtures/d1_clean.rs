// D1 clean: ordered iteration comes from a BTreeMap; the HashMap is
// only used for point lookups, never iterated.
use std::collections::{BTreeMap, HashMap};

pub fn ordered_sum(ordered: &BTreeMap<u64, f32>, index: &HashMap<u64, usize>) -> f32 {
    let mut acc = 0.0;
    for (k, v) in ordered.iter() {
        if index.get(k).is_some() {
            acc += v;
        }
    }
    acc
}
