//! Fault-tolerance acceptance pins (ISSUE 8):
//!
//! 1. **Disabled == fault-free, bit-identically.**  A `None` plan — or a
//!    plan carrying only irrelevant faults — must leave train loss bits
//!    and serve verdict bits exactly where the unguarded stack puts
//!    them, supervision on or off.
//! 2. **Replica kill loses nothing.**  With a kill injected mid-stream
//!    and the supervisor on, every offered request is served or
//!    explicitly shed — never silently dropped — and the supervisor
//!    logs at least one respawn.
//! 3. **Straggler exclusion converges.**  Weight-0 exclusion with
//!    error-feedback carry keeps the training trajectory within
//!    tolerance of full participation.
//! 4. **Deterministic replay.**  The same fault seed reproduces the
//!    same recovery event log; a different seed does not.

use std::time::Duration;

use recad::access::AccessPlanner;
use recad::coordinator::data_parallel::{
    train_data_parallel_faulted, train_data_parallel_placed, DpCfg, Placement,
};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::platform::CostModel;
use recad::data::ctr::{Batch, CtrGenerator};
use recad::data::schema::DatasetSchema;
use recad::exec::ExecCfg;
use recad::net::{run_open_loop_net, NetClient, NodeServer};
use recad::powersys::dataset::{generate, DatasetCfg, Sample, SparseVocab};
use recad::runtime::{FaultCfg, FaultPlan};
use recad::serve::{run_open_loop, OpenLoopCfg, ServeSession};
use recad::tt::table::EffTtOptions;
use recad::util::prng::Rng;

fn zero_cost() -> CostModel {
    CostModel {
        h2d_bps: 1e18,
        d2d_bps: 1e18,
        transfer_latency: Duration::ZERO,
        ps_row: Duration::ZERO,
        dispatch: Duration::ZERO,
    }
}

fn train_cfg() -> EngineCfg {
    EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(1500, true), (60, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::default(),
    }
}

fn train_batches(n: usize, batch: usize, seed: u64) -> Vec<Batch> {
    let schema = DatasetSchema {
        name: "fault-test",
        n_dense: 4,
        vocabs: vec![1500, 60],
        emb_dim: 8,
        zipf_s: 1.2,
        ft_rank: 8,
    };
    CtrGenerator::new(schema, seed).batches(n, batch)
}

fn serve_samples(n: usize) -> Vec<Sample> {
    generate(&DatasetCfg {
        n_normal: n,
        n_attack: n / 4,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 2,
    })
    .samples
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn dp(workers: usize, placement: Placement) -> DpCfg {
    DpCfg { workers, placement, cost: zero_cost(), seed: 9, quantize_comm: false }
}

/// (1a) Training: a `None` plan and a serve-faults-only plan are both
/// bit-identical to the fault-free entry point, under both placements.
#[test]
fn disabled_fault_plan_train_losses_bit_identical() {
    let cfg = train_cfg();
    let bs = train_batches(10, 32, 11);
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    for placement in [Placement::Replicated, Placement::Plan] {
        let d = dp(3, placement);
        let (want, mut want_engine) =
            train_data_parallel_placed(cfg.clone(), &planner, &bs, &d);
        let (none, _) =
            train_data_parallel_faulted(cfg.clone(), &planner, &bs, &d, None);
        let serve_only = FaultCfg {
            enabled: true,
            kill_replica: Some(0),
            sever_rate: 0.5,
            flood_rate: 0.5,
            ..FaultCfg::default()
        }
        .plan()
        .unwrap();
        let (irrelevant, mut irr_engine) = train_data_parallel_faulted(
            cfg.clone(),
            &planner,
            &bs,
            &d,
            Some(&serve_only),
        );
        assert_eq!(
            bits(&want.losses),
            bits(&none.losses),
            "{placement:?}: None plan drifted"
        );
        assert_eq!(
            bits(&want.losses),
            bits(&irrelevant.losses),
            "{placement:?}: serve-only plan drifted"
        );
        // parameters, not just losses
        let probe = want_engine.predict(&bs[0]);
        let probe_irr = irr_engine.predict(&bs[0]);
        assert_eq!(bits(&probe), bits(&probe_irr), "{placement:?}: params drifted");
    }
}

/// (1b) Serving: a guarded session (supervisor on, zero-rate plan
/// attached) produces bitwise the verdicts of the unguarded one.
#[test]
fn disabled_fault_plan_serve_verdicts_bit_identical() {
    let samples = serve_samples(80);
    let stream = &samples[..24];
    let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(1));
    let base = ServeSession::from_engine(engine);
    let want: Vec<u32> = {
        let server = base.clone().replicas(2).start();
        let b = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        b
    };
    // all rates zero: the plan exists but never fires
    let idle_plan = FaultCfg { enabled: true, ..FaultCfg::default() }.plan().unwrap();
    let server = base
        .clone()
        .replicas(2)
        .heartbeat(Duration::from_millis(2))
        .fault(Some(idle_plan.clone()))
        .start();
    let got: Vec<u32> = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
    assert_eq!(server.respawns(), 0, "supervisor respawned a healthy replica");
    let (lifetime, _) = server.shutdown();
    assert_eq!(want, got, "guarded session changed verdict bits");
    assert_eq!(lifetime, stream.len() as u64);
    assert!(idle_plan.events().is_empty(), "zero-rate plan fired: {:?}", idle_plan.events());
}

/// (2) A replica killed mid-stream loses zero accepted requests: every
/// offered request comes back served (or explicitly shed) after the
/// supervisor respawns the replica from the frozen snapshot.
#[test]
fn replica_kill_mid_stream_loses_no_requests() {
    let samples = serve_samples(120);
    let stream = &samples[..60];
    let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(1));
    let plan = FaultCfg {
        enabled: true,
        seed: 7,
        kill_replica: Some(0),
        kill_after: 5,
        ..FaultCfg::default()
    }
    .plan()
    .unwrap();
    let server = ServeSession::from_engine(engine)
        .replicas(2)
        .heartbeat(Duration::from_millis(2))
        .fault(Some(plan.clone()))
        .start();
    let report = run_open_loop(
        server,
        stream,
        &OpenLoopCfg { rate_per_sec: 4000.0, seed: 3 },
    );
    assert_eq!(report.offered, 60);
    assert_eq!(
        report.served as usize + report.shed + report.dropped,
        report.offered,
        "request accounting leaked"
    );
    assert_eq!(report.dropped, 0, "killed replica silently dropped requests");
    assert!(report.respawns >= 1, "supervisor never respawned the killed replica");
    assert!(plan.event_count("panic") >= 1, "kill fault never fired");
    assert!(plan.event_count("respawn") >= 1, "respawn not logged");
}

/// (2b) Multi-node: a NODE killed mid-stream loses zero requests.  The
/// router notices the dead connection, drains its in-flight sequence
/// numbers back to the FRONT of the pending queue (the PR 8 requeue
/// discipline, one tier up) and re-routes them to the survivor — every
/// offered request is served or explicitly shed, never silently dropped.
#[test]
fn node_kill_mid_stream_loses_no_requests() {
    let samples = serve_samples(120);
    let stream = &samples[..60];
    let ecfg = EngineCfg::ieee118(1.0 / 2000.0);
    let engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
    let affinity = AccessPlanner::for_engine_cfg(&ecfg).affinity_map();
    let plan = FaultCfg {
        enabled: true,
        seed: 7,
        kill_node: Some(1),
        node_kill_after: 5,
        ..FaultCfg::default()
    }
    .plan()
    .unwrap();
    let session = ServeSession::from_engine(engine);
    let n0 =
        NodeServer::spawn(0, 0, session.clone(), "127.0.0.1:0", Some(plan.clone())).unwrap();
    let n1 = NodeServer::spawn(1, 0, session, "127.0.0.1:0", Some(plan.clone())).unwrap();
    let addrs = vec![n0.addr().to_string(), n1.addr().to_string()];
    let mut client = NetClient::connect(affinity, &addrs, 32, 64)
        .unwrap()
        .timeouts(Duration::from_millis(10), Duration::from_millis(200));
    let nl = run_open_loop_net(
        &mut client,
        stream,
        &OpenLoopCfg { rate_per_sec: 4000.0, seed: 3 },
        None,
    );
    client.close();
    let report = &nl.report;
    assert_eq!(report.offered, 60);
    assert_eq!(
        report.served as usize + report.shed + report.dropped,
        report.offered,
        "request accounting leaked"
    );
    assert_eq!(report.dropped, 0, "killed node silently dropped requests");
    assert!(nl.evictions >= 1, "router never evicted the killed node");
    assert!(plan.event_count("node_kill") >= 1, "node-kill fault never fired");
    n0.shutdown();
    n1.shutdown();
}

/// (3) Straggler-excluded all-reduce converges within tolerance of full
/// participation (the carry re-injects missed progress next round).
#[test]
fn straggler_excluded_allreduce_converges_within_tolerance() {
    let cfg = train_cfg();
    let bs = train_batches(16, 32, 5);
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    for placement in [Placement::Replicated, Placement::Plan] {
        let d = dp(3, placement);
        let (full, _) = train_data_parallel_placed(cfg.clone(), &planner, &bs, &d);
        let plan = FaultCfg {
            enabled: true,
            seed: 13,
            straggle_rate: 0.3,
            straggle_ms: 0,
            ..FaultCfg::default()
        }
        .plan()
        .unwrap();
        let (lossy, _) =
            train_data_parallel_faulted(cfg.clone(), &planner, &bs, &d, Some(&plan));
        assert!(
            plan.event_count("straggle") > 0,
            "{placement:?}: straggle rate 0.3 never fired"
        );
        assert!(lossy.losses.iter().all(|l| l.is_finite()));
        let f_tail = full.losses[full.losses.len() - 1];
        let l_tail = lossy.losses[lossy.losses.len() - 1];
        assert!(
            (l_tail - f_tail).abs() < 0.1,
            "{placement:?}: straggler tail {l_tail} drifted from full {f_tail}"
        );
        assert!(
            l_tail < lossy.losses[0],
            "{placement:?}: no learning under stragglers"
        );
    }
}

/// (4) Deterministic replay: the same fault seed reproduces the same
/// recovery event log, bit for bit; a different seed diverges.
#[test]
fn same_fault_seed_replays_identical_event_log() {
    let cfg = train_cfg();
    let bs = train_batches(12, 32, 5);
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let d = dp(3, Placement::Replicated);
    let mk = |seed: u64| {
        FaultCfg {
            enabled: true,
            seed,
            straggle_rate: 0.25,
            straggle_ms: 0,
            dead_worker: Some(2),
            dead_round: 4,
            ..FaultCfg::default()
        }
        .plan()
        .unwrap()
    };
    let run = |plan: &std::sync::Arc<FaultPlan>| {
        let (rep, _) =
            train_data_parallel_faulted(cfg.clone(), &planner, &bs, &d, Some(plan));
        (rep.losses, plan.events())
    };
    let (l1, e1) = run(&mk(21));
    let (l2, e2) = run(&mk(21));
    assert_eq!(e1, e2, "same seed produced different event logs");
    assert!(!e1.is_empty(), "chaos plan fired nothing");
    assert_eq!(bits(&l1), bits(&l2), "same seed produced different losses");
    let (_, e3) = run(&mk(22));
    assert_ne!(e1, e3, "different seeds replayed the same schedule");
}

/// Env-gated live chaos arm (the CI matrix sets `RECAD_FAULT_SEED`):
/// drive an open-loop stream under the mild env-derived chaos plan and
/// check the accounting still closes — every request served, shed, or
/// counted dropped (sever faults legitimately drop replies), with the
/// supervisor keeping the replica set alive.
#[test]
fn env_seeded_chaos_run_completes_with_closed_accounting() {
    let cfg = match FaultCfg::from_env() {
        Some(c) => c,
        None => return, // RECAD_FAULT_SEED not set: nothing to do
    };
    let plan = cfg.plan().expect("env cfg is enabled by construction");
    let samples = serve_samples(100);
    let stream = &samples[..50];
    let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(1));
    let server = ServeSession::from_engine(engine)
        .replicas(2)
        .heartbeat(Duration::from_millis(2))
        .fault(Some(plan.clone()))
        .start();
    let report = run_open_loop(
        server,
        stream,
        &OpenLoopCfg { rate_per_sec: 4000.0, seed: 3 },
    );
    assert_eq!(
        report.served as usize + report.shed + report.dropped,
        report.offered,
        "request accounting leaked under env chaos (seed {})",
        cfg.seed
    );
    assert!(
        report.respawns >= 1,
        "env chaos kills replica 0 after 4 requests; supervisor never respawned"
    );
}
