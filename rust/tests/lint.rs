//! Fixture + self-run suite for `recad lint` (`src/analysis/`).
//!
//! Fixtures live in `tests/lint_fixtures/` — one known-bad and one
//! known-clean snippet per rule, plus the pragma cases.  The fixture
//! directory is excluded from both compilation and the real lint walk;
//! this harness feeds each file through `lint_source` with path
//! scoping disabled (`LintCfg::fixture`) so every rule fires
//! regardless of location.  The final test is the burn-down gate: the
//! crate's own source must come back clean, same as the CI
//! `recad lint --deny` run.

use std::fs;
use std::path::Path;

use recad::analysis::rules::FileFindings;
use recad::analysis::{lint_source, run_lint, LintCfg};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("lint_fixtures")
        .join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Lint one fixture under a synthetic `src/` path (D4 only looks at
/// files under `src/`) with every allowlist emptied.
fn lint_fixture(name: &str) -> FileFindings {
    let src = fixture(name);
    lint_source(&format!("src/fixture/{name}"), &src, &LintCfg::fixture(), None)
}

#[test]
fn bad_fixtures_flag_their_rule_and_only_it() {
    for rule in ["D1", "D2", "D3", "D4", "D5", "D6"] {
        let name = format!("{}_bad.rs", rule.to_lowercase());
        let ff = lint_fixture(&name);
        assert!(!ff.after.is_empty(), "{name}: expected at least one finding");
        for f in &ff.after {
            assert_eq!(f.rule, rule, "{name}: stray {} finding: {}", f.rule, f.message);
        }
        assert_eq!(ff.raw, ff.after.len(), "{name}: nothing should be suppressed");
        assert_eq!(ff.suppressed, 0, "{name}");
    }
}

#[test]
fn clean_fixtures_are_clean() {
    for name in [
        "d1_clean.rs",
        "d2_clean.rs",
        "d3_clean.rs",
        "d4_clean.rs",
        "d5_clean.rs",
        "d6_clean.rs",
    ] {
        let ff = lint_fixture(name);
        assert!(ff.after.is_empty(), "{name}: {:?}", ff.after);
        assert_eq!(ff.raw, 0, "{name}: raw findings should be zero");
    }
}

#[test]
fn reasoned_pragma_suppresses() {
    let ff = lint_fixture("pragma_ok.rs");
    assert!(ff.after.is_empty(), "{:?}", ff.after);
    assert_eq!(ff.raw, 1, "the D1 site should still be counted pre-pragma");
    assert_eq!(ff.suppressed, 1);
}

#[test]
fn file_level_pragma_covers_whole_file() {
    let ff = lint_fixture("pragma_file_level.rs");
    assert!(ff.after.is_empty(), "{:?}", ff.after);
    assert_eq!(ff.raw, 2, "both clock reads counted pre-pragma");
    assert_eq!(ff.suppressed, 2);
}

#[test]
fn reasonless_pragma_suppresses_nothing_and_is_reported() {
    let ff = lint_fixture("pragma_missing_reason.rs");
    assert_eq!(ff.suppressed, 0);
    assert_eq!(ff.raw, 1);
    let rules: Vec<&str> = ff.after.iter().map(|f| f.rule.as_str()).collect();
    assert!(rules.contains(&"D1"), "original finding must survive: {:?}", ff.after);
    assert!(rules.contains(&"pragma"), "empty pragma must be reported: {:?}", ff.after);
    assert_eq!(ff.after.len(), 2, "{:?}", ff.after);
}

#[test]
fn rule_filter_restricts_findings() {
    let src = fixture("d3_bad.rs");
    let cfg = LintCfg::fixture();
    let ff = lint_source("src/fixture/d3_bad.rs", &src, &cfg, Some("D2"));
    assert!(ff.after.is_empty(), "D2 filter must hide D3 findings: {:?}", ff.after);
    let ff = lint_source("src/fixture/d3_bad.rs", &src, &cfg, Some("D3"));
    assert!(!ff.after.is_empty(), "D3 filter must keep D3 findings");
}

/// The burn-down gate: the crate's own source lints clean under the
/// default config — the exact check CI runs as `recad lint --deny` —
/// and the pass demonstrably did work (rules fired pre-pragma, and
/// reasoned pragmas suppressed real sites, not an empty universe).
#[test]
fn self_run_over_crate_source_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let run = run_lint(root, &LintCfg::default(), None).expect("lint walk");
    assert!(run.files > 50, "suspiciously few files scanned: {}", run.files);
    assert!(
        run.findings_raw > 10,
        "rules found almost nothing pre-pragma ({}) — rules broken?",
        run.findings_raw
    );
    assert!(run.suppressed > 10, "pragmas barely fired ({})", run.suppressed);
    assert!(
        run.findings.is_empty(),
        "crate must lint clean; findings:\n{}",
        run.findings
            .iter()
            .map(|f| format!("  {}:{} [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
