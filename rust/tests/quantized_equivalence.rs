//! Quantized-fast-path equivalence pins (the perf_opt acceptance gates):
//!
//! 1. With quantize=off the f32 path — scalar or wide/SIMD kernels,
//!    untiled or tiled layouts, 1 or N exec workers — stays BITWISE
//!    identical: the wide microkernels replaced the scalar ones inside
//!    the tiled mirrors, so every (workers × cache_kb) combination must
//!    produce the same losses and verdict bits.
//! 2. int8 / f16 frozen serving scores within AUC tolerance of f32 on
//!    the IEEE-118 smoke model (quantization moves probabilities, not
//!    ranking quality).
//! 3. The int8 sparse all-reduce with error feedback converges to
//!    within tolerance of the f32 dense exchange, at strictly smaller
//!    payload.
//! 4. Quantized serving verdict bits are stable across `RoutePolicy` ×
//!    replica counts — replicas share one frozen engine, so routing can
//!    only move requests, never change scores.

use recad::access::{AccessCfg, AccessPlanner};
use recad::coordinator::data_parallel::{train_data_parallel_placed, DpCfg, Placement};
use recad::coordinator::engine::EngineCfg;
use recad::coordinator::platform::CostModel;
use recad::coordinator::trainer;
use recad::data::ctr::{Batch, CtrGenerator};
use recad::data::schema::DatasetSchema;
use recad::exec::ExecCfg;
use recad::metrics::auc;
use recad::powersys::dataset::{generate, DatasetCfg, Ieee118Dataset, SparseVocab};
use recad::serve::{Detector, Policy, ServeSession};
use recad::tt::table::QuantizeMode;
use std::time::Duration;

const SCALE: f64 = 1.0 / 2000.0;

fn smoke_dataset(seed: u64) -> Ieee118Dataset {
    generate(&DatasetCfg {
        n_normal: 240,
        n_attack: 60,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 20,
        noise_std: 0.005,
        seed,
    })
}

fn engine_cfg(workers: usize) -> EngineCfg {
    let mut cfg = EngineCfg::ieee118(SCALE);
    cfg.exec = ExecCfg::with_workers(workers);
    cfg
}

/// Train the smoke model under (workers, cache_kb) and fingerprint it:
/// the loss curve plus per-sample verdict bits on the eval split.
fn train_fingerprint(workers: usize, cache_kb: usize, ds: &Ieee118Dataset) -> (Vec<u32>, Vec<u32>) {
    let access = AccessCfg { cache_kb, ..AccessCfg::default() };
    let (report, engine, planner) =
        trainer::train_ieee118_full(engine_cfg(workers), &access, ds, 1, 32, 7);
    let mut det = Detector::with_planner(engine, 0.5, planner);
    let bits = ds
        .split(0.8)
        .1
        .iter()
        .map(|s| det.score(s).to_bits())
        .collect();
    (report.loss_curve.iter().map(|l| l.to_bits()).collect(), bits)
}

#[test]
fn f32_path_bit_identical_across_workers_and_tile_budgets() {
    let ds = smoke_dataset(11);
    // cache_kb = 0 walks the untouched scalar kernels; cache_kb > 0 walks
    // the tiled mirrors, which now run the wide/SIMD microkernels
    let (want_losses, want_bits) = train_fingerprint(1, 0, &ds);
    for (workers, cache_kb) in [(1usize, 4usize), (3, 0), (3, 4)] {
        let (losses, bits) = train_fingerprint(workers, cache_kb, &ds);
        assert_eq!(
            want_losses, losses,
            "loss curve drifted at workers={workers} cache_kb={cache_kb}"
        );
        assert_eq!(
            want_bits, bits,
            "verdict bits drifted at workers={workers} cache_kb={cache_kb}"
        );
    }
}

#[test]
fn quantized_serving_auc_within_tolerance_of_f32() {
    let ds = smoke_dataset(13);
    let (_, engine, planner) =
        trainer::train_ieee118_full(engine_cfg(1), &AccessCfg::default(), &ds, 2, 32, 7);
    let eval = ds.split(0.8).1;
    let labels: Vec<f32> = eval.iter().map(|s| s.label).collect();
    let score_all = |engine: recad::coordinator::engine::NativeDlrm| -> Vec<f32> {
        let mut det = Detector::with_planner(engine, 0.5, planner.clone());
        eval.iter().map(|s| det.score(s)).collect()
    };
    let f32_auc = auc(&score_all(engine.clone()), &labels);
    assert!(f32_auc > 0.7, "smoke model failed to learn: AUC {f32_auc}");
    for (mode, tol) in [(QuantizeMode::F16, 0.01), (QuantizeMode::Int8, 0.05)] {
        let mut frozen = engine.clone();
        frozen.freeze_quantized(mode);
        assert!(
            frozen.embedding_bytes() < engine.embedding_bytes(),
            "{mode:?} tables must shrink the embedding footprint"
        );
        let q_auc = auc(&score_all(frozen), &labels);
        assert!(
            (q_auc - f32_auc).abs() <= tol,
            "{mode:?} AUC {q_auc} drifted more than {tol} from f32 {f32_auc}"
        );
    }
}

fn dp_batches() -> (EngineCfg, Vec<Batch>) {
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(1500, true), (60, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: recad::tt::table::EffTtOptions::default(),
        exec: ExecCfg::default(),
    };
    let schema = DatasetSchema {
        name: "q8-test",
        n_dense: 4,
        vocabs: vec![1500, 60],
        emb_dim: 8,
        zipf_s: 1.2,
        ft_rank: 8,
    };
    (cfg, CtrGenerator::new(schema, 17).batches(24, 32))
}

fn zero_cost() -> CostModel {
    CostModel {
        h2d_bps: 1e18,
        d2d_bps: 1e18,
        transfer_latency: Duration::ZERO,
        ps_row: Duration::ZERO,
        dispatch: Duration::ZERO,
    }
}

#[test]
fn q8_allreduce_converges_with_f32_dense_exchange_at_lower_payload() {
    let (cfg, batches) = dp_batches();
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let run = |placement: Placement, quantize_comm: bool| {
        let dp = DpCfg {
            workers: 2,
            placement,
            cost: zero_cost(),
            seed: 9,
            quantize_comm,
        };
        train_data_parallel_placed(cfg.clone(), &planner, &batches, &dp).0
    };
    let dense = run(Placement::Replicated, false);
    let sparse = run(Placement::Plan, false);
    let q8 = run(Placement::Plan, true);
    // strict payload ordering: q8 < f32 sparse < f32 dense
    assert!(q8.payload_bytes < sparse.payload_bytes, "q8 must undercut f32 sparse");
    assert!(sparse.payload_bytes < dense.payload_bytes, "sparse must undercut dense");
    // convergence equivalence vs the dense exchange: error feedback keeps
    // the quantized trajectory within tolerance step by step
    for (i, (a, b)) in q8.losses.iter().zip(&dense.losses).enumerate() {
        assert!(
            (a - b).abs() < 0.1,
            "step {i}: q8 loss {a} drifted from dense f32 {b}"
        );
    }
    let tail = |l: &[f32]| l[l.len() - 4..].iter().sum::<f32>() / 4.0;
    let (tq, td) = (tail(&q8.losses), tail(&dense.losses));
    assert!((tq - td).abs() < 0.05, "tail loss drifted: q8 {tq} vs dense {td}");
    assert!(tq < q8.losses[0], "q8 run failed to learn");
}

#[test]
fn quantized_serving_verdicts_stable_across_policies_and_replicas() {
    let ds = smoke_dataset(19);
    let (_, engine, planner) =
        trainer::train_ieee118_full(engine_cfg(1), &AccessCfg::default(), &ds, 1, 32, 7);
    let stream = &ds.samples[..16];
    let base = ServeSession::from_trained(engine, planner).quantize(QuantizeMode::Int8);
    let want: Vec<u32> = {
        let server = base.clone().start();
        let bits = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        bits
    };
    for policy in [Policy::RoundRobin, Policy::LeastQueued, Policy::PlanAffinity] {
        for replicas in [1usize, 2, 4] {
            let server = base.clone().replicas(replicas).policy(policy).start();
            let got: Vec<u32> =
                stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
            assert_eq!(
                want, got,
                "{policy:?} x {replicas} replicas changed quantized verdict bits"
            );
            let (lifetime, _) = server.shutdown();
            assert_eq!(lifetime, stream.len() as u64, "requests lost by {policy:?}");
        }
    }
}
