//! Multi-node serving tier acceptance pins (ISSUE 9):
//!
//! 1. **Loopback == in-process, bitwise.**  The same trained session
//!    served through `recad node` TCP loopback nodes must return the
//!    exact verdict bits of the in-process `ServeSession`, for 1, 2 and
//!    3 nodes — the wire, the ring and the router add zero numeric
//!    drift.
//! 2. **Bounded rebalancing at the router level.**  Evicting one of n
//!    nodes re-routes only the dead node's keys (≤ 2/n of a sampled
//!    workload); surviving-node keys never move, and a rejoin snaps
//!    every key back to its original owner.
//! 3. **Deterministic routing per ring epoch.**  The same sparse vector
//!    routes to the same node for as long as membership is unchanged.

use recad::access::AccessPlanner;
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::net::{HashRing, NetClient, NodeServer, RemoteRouter};
use recad::powersys::dataset::{generate, DatasetCfg, Sample, SparseVocab};
use recad::serve::ServeSession;
use recad::util::prng::Rng;

fn serve_samples(n: usize) -> Vec<Sample> {
    generate(&DatasetCfg {
        n_normal: n,
        n_attack: n / 4,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 2,
    })
    .samples
}

/// (1) Verdict bits served over loopback TCP equal the in-process
/// session's, for every node count — training is seeded, so every node
/// holds the identical engine and any ring placement is equivalent.
#[test]
fn loopback_nodes_match_in_process_session_bitwise() {
    let samples = serve_samples(60);
    let stream = &samples[..24];
    let ecfg = EngineCfg::ieee118(1.0 / 2000.0);
    let engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
    let affinity = AccessPlanner::for_engine_cfg(&ecfg).affinity_map();
    let base = ServeSession::from_engine(engine);
    let want: Vec<u32> = {
        let server = base.clone().start();
        let b = stream.iter().map(|s| server.infer(s).prob.to_bits()).collect();
        let _ = server.shutdown();
        b
    };
    for n in 1..=3usize {
        let nodes: Vec<NodeServer> = (0..n)
            .map(|i| {
                NodeServer::spawn(i as u64, 0, base.clone(), "127.0.0.1:0", None).unwrap()
            })
            .collect();
        let addrs: Vec<String> = nodes.iter().map(|nd| nd.addr().to_string()).collect();
        let mut client = NetClient::connect(affinity.clone(), &addrs, 32, 64).unwrap();
        let got: Vec<u32> = stream
            .iter()
            .map(|s| client.infer(s).unwrap().prob.to_bits())
            .collect();
        assert_eq!(want, got, "{n}-node loopback verdicts diverged from in-process");
        client.close();
        for nd in nodes {
            nd.shutdown();
        }
    }
}

/// (2 + 3) Router-level rebalancing bound over REAL workload keys (the
/// affinity key of each sample's sparse vector, the exact key `pick`
/// hashes): eviction moves only the dead node's share, survivors hold
/// every key they had, rejoin restores the original routing bit for bit.
#[test]
fn router_eviction_moves_bounded_fraction_and_rejoin_snaps_back() {
    let ecfg = EngineCfg::ieee118(1.0 / 2000.0);
    let affinity = AccessPlanner::for_engine_cfg(&ecfg).affinity_map();
    let samples = serve_samples(600);
    for n in [2usize, 3, 4] {
        let router = RemoteRouter::new(affinity.clone(), n, 64);
        let before: Vec<usize> = samples.iter().map(|s| router.pick(&s.sparse)).collect();
        // deterministic within an epoch
        let again: Vec<usize> = samples.iter().map(|s| router.pick(&s.sparse)).collect();
        assert_eq!(before, again, "routing not deterministic within an epoch");
        let epoch0 = router.epoch();
        assert!(router.evict(n - 1));
        assert_eq!(router.epoch(), epoch0 + 1, "eviction must bump the epoch");
        let mut moved = 0usize;
        for (s, &b) in samples.iter().zip(&before) {
            let now = router.pick(&s.sparse);
            if b == n - 1 {
                moved += 1;
                assert_ne!(now, n - 1, "key still routed to the evicted node");
            } else {
                assert_eq!(now, b, "surviving-node key moved on eviction");
            }
        }
        let bound = 2.0 * samples.len() as f64 / n as f64;
        assert!(
            (moved as f64) <= bound,
            "n={n}: eviction moved {moved}/{} keys (bound {bound:.0})",
            samples.len()
        );
        assert!(moved > 0, "n={n}: the evicted node owned no sampled keys");
        assert!(router.rejoin(n - 1));
        assert_eq!(router.epoch(), epoch0 + 2);
        let back: Vec<usize> = samples.iter().map(|s| router.pick(&s.sparse)).collect();
        assert_eq!(before, back, "rejoin did not snap keys back to their owners");
    }
}

/// The ring the router builds is the library ring: spot-check the same
/// membership through the public `HashRing` API so the property holds
/// for arbitrary u64 keys, not only affinity keys.
#[test]
fn public_ring_agrees_with_itself_across_epochs() {
    let mut ring = HashRing::with_nodes(64, &[0, 1, 2]);
    let keys: Vec<u64> = (0..4096u64).map(|k| k.wrapping_mul(0x9E37_79B9)).collect();
    let before: Vec<u64> = keys.iter().map(|&k| ring.node_for(k).unwrap()).collect();
    assert!(ring.remove(1));
    let mut moved = 0usize;
    for (&k, &b) in keys.iter().zip(&before) {
        let now = ring.node_for(k).unwrap();
        if b == 1 {
            moved += 1;
        } else {
            assert_eq!(now, b, "survivor key moved");
        }
        assert_ne!(now, 1);
    }
    assert!(moved > 0 && (moved as f64) <= 2.0 * keys.len() as f64 / 3.0);
    assert!(ring.add(1));
    let back: Vec<u64> = keys.iter().map(|&k| ring.node_for(k).unwrap()).collect();
    assert_eq!(before, back, "re-add did not restore the mapping");
}
