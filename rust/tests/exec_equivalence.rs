//! Exec-layer equivalence properties: running the engine or the Eff-TT
//! table with `workers = N` must be **bit-identical** to `workers = 1`.
//! The exec layer shards work only along disjoint output blocks whose
//! per-element reduction order matches the serial loop, and applies every
//! cross-item update serially in a fixed order — these tests pin that
//! contract across random shapes, batches and optimization switches.

use recad::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use recad::data::ctr::Batch;
use recad::exec::ExecCfg;
use recad::exec::ExecPool;
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::prng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Batch/layer sizes are chosen to clear the exec layer's PAR_MIN_WORK
/// gates, so `workers > 1` really does take the parallel code paths.
fn tiny_cfg(workers: usize) -> EngineCfg {
    EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(900, true), (300, true), (40, false)],
        tt_rank: 4,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::with_workers(workers),
    }
}

fn tiny_batch(cfg: &EngineCfg, b: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let ns = cfg.tables.len();
    let mut dense = vec![0.0; b * cfg.dense_dim];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    // skewed indices so prefixes and rows repeat (exercises the dedup,
    // aggregation and shard-boundary paths)
    let sparse: Vec<u64> = (0..b * ns)
        .map(|i| rng.below(cfg.tables[i % ns].0.min(60)))
        .collect();
    let labels: Vec<f32> = (0..b).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect();
    Batch { dense, sparse, labels, batch_size: b }
}

/// Train the same model with different worker counts; loss trajectories
/// and every parameter must match bit-for-bit.
#[test]
fn engine_training_bit_identical_across_workers() {
    for seed in [1u64, 7, 23] {
        let run = |workers: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let cfg = tiny_cfg(workers);
            let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(seed));
            assert_eq!(m.workers(), workers);
            let mut losses = Vec::new();
            for step in 0..4u64 {
                let batch = tiny_batch(&cfg, 512, seed ^ (step + 1));
                losses.push(m.train_step(&batch));
            }
            let w0 = m.bot[0].w.clone();
            let cores = match &m.tables[0] {
                TableSlot::Tt(t) => t.core2.clone(),
                TableSlot::Plain(_) => unreachable!("slot 0 is TT"),
            };
            (losses, w0, cores)
        };
        let (l1, w1, c1) = run(1);
        for workers in [2usize, 4] {
            let (ln, wn, cn) = run(workers);
            assert_eq!(bits(&l1), bits(&ln), "loss curve diverged (workers={workers}, seed={seed})");
            assert_eq!(bits(&w1), bits(&wn), "MLP weights diverged (workers={workers})");
            assert_eq!(bits(&c1), bits(&cn), "TT cores diverged (workers={workers})");
        }
    }
}

/// Forward outputs, post-backward cores AND TtStats counters must be
/// invariant to the worker count, across random shapes and both the
/// Eff-TT and TT-Rec-baseline option sets.
#[test]
fn tt_table_forward_backward_bit_identical_across_workers() {
    let mut meta = Rng::new(0xE8EC);
    for case in 0..10 {
        let rows = meta.below(3000) + 700;
        let dim = 16usize;
        let rank = [4usize, 8][meta.usize_below(2)];
        let opts = if case % 3 == 2 {
            EffTtOptions::ttrec_baseline()
        } else {
            EffTtOptions::default()
        };
        let seed = meta.next_u64();
        let shapes = TtShapes::plan(rows, dim, rank);

        // big enough that fill/scatter/backward clear PAR_MIN_WORK and the
        // parallel shards genuinely run when workers > 1
        let n_idx = meta.usize_below(1024) + 3072;
        let hot = rows.min(600); // heavy repetition => shared prefixes
        let idx: Vec<u64> = (0..n_idx).map(|_| meta.below(hot)).collect();
        let bag = 4usize;
        let bags = n_idx / bag;
        let used = bags * bag;
        let offsets: Vec<usize> = (0..=bags).map(|b| b * bag).collect();
        let grad: Vec<f32> = (0..bags * dim).map(|i| (i as f32 * 0.13).sin()).collect();

        let run = |workers: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, u64, u64, u64) {
            let mut t = EffTtTable::new(shapes, opts, &mut Rng::new(seed));
            t.set_pool(ExecPool::new(ExecCfg::with_workers(workers)));
            let mut out = vec![0.0f32; bags * dim];
            let mut scratch = TtScratch::default();
            t.embedding_bag(&idx[..used], &offsets, &mut out, &mut scratch);
            t.backward_sgd(&idx[..used], &offsets, &grad, 0.05, &mut scratch);
            (
                out,
                t.core1,
                t.core2,
                t.core3,
                t.stats.prefix_gemms,
                t.stats.hop2_gemms,
                t.stats.backward_chains,
            )
        };

        let (o1, a1, b1, c1, p1, h1, bc1) = run(1);
        for workers in [3usize, 5] {
            let (on, an, bn, cn, pn, hn, bcn) = run(workers);
            assert_eq!(bits(&o1), bits(&on), "forward diverged (case {case}, workers {workers})");
            assert_eq!(bits(&a1), bits(&an), "core1 diverged (case {case})");
            assert_eq!(bits(&b1), bits(&bn), "core2 diverged (case {case})");
            assert_eq!(bits(&c1), bits(&cn), "core3 diverged (case {case})");
            assert_eq!(p1, pn, "prefix_gemms changed with workers (case {case})");
            assert_eq!(h1, hn, "hop2_gemms changed with workers (case {case})");
            assert_eq!(bc1, bcn, "backward_chains changed with workers (case {case})");
        }
    }
}

/// The serving path (predict) is also worker-invariant — batch-1 requests
/// and full batches alike.
#[test]
fn engine_predict_bit_identical_across_workers() {
    let seed = 99u64;
    let run = |workers: usize| -> Vec<f32> {
        let cfg = tiny_cfg(workers);
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(seed));
        let batch = tiny_batch(&cfg, 512, 5);
        m.predict(&batch)
    };
    let p1 = run(1);
    let p4 = run(4);
    assert_eq!(bits(&p1), bits(&p4));
}
