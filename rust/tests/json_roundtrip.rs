//! Serialization pins (ISSUE 9): every artifact the multi-node tier
//! ships across a process boundary — the affinity snapshot a joining
//! node validates, the serve/open-loop reports the router aggregates —
//! must round-trip through `util::json` losslessly: serialize → parse →
//! equality, and serialize → parse → serialize → string equality.

use std::time::Duration;

use recad::access::{AccessPlanner, AffinityMap};
use recad::coordinator::engine::EngineCfg;
use recad::data::ctr::CtrGenerator;
use recad::data::schema::DatasetSchema;
use recad::serve::{OpenLoopReport, ServeReport};
use recad::util::json::Json;
use recad::util::prng::Rng;

fn ieee_cfg() -> EngineCfg {
    EngineCfg::ieee118(1.0 / 2000.0)
}

/// Identity-planner snapshot: shapes only, no bijections.
#[test]
fn identity_affinity_map_round_trips() {
    let map = AccessPlanner::for_engine_cfg(&ieee_cfg()).affinity_map();
    let j1 = map.to_json().to_string();
    let parsed = Json::parse(&j1).unwrap();
    let back = AffinityMap::from_json(&parsed).unwrap();
    assert_eq!(back.to_json().to_string(), j1, "serialize → parse → serialize drifted");
    // the ring key is what routing hashes: it must agree everywhere
    let mut rng = Rng::new(77);
    for _ in 0..200 {
        let sparse: Vec<u64> = (0..8).map(|_| rng.below(5000)).collect();
        assert_eq!(map.key(&sparse), back.key(&sparse), "affinity key diverged");
    }
}

/// Profiled-planner snapshot: non-identity bijections must survive the
/// trip too (entries are canonicalized, the dense remap is re-derived).
#[test]
fn profiled_affinity_map_round_trips_with_bijections() {
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(1500, true), (60, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: Default::default(),
        exec: Default::default(),
    };
    let schema = DatasetSchema {
        name: "json-test",
        n_dense: 4,
        vocabs: vec![1500, 60],
        emb_dim: 8,
        zipf_s: 1.2,
        ft_rank: 8,
    };
    let profile = CtrGenerator::new(schema, 31).batches(8, 64);
    let map = AccessPlanner::with_profile(&cfg, &profile, 0.1).affinity_map();
    let j1 = map.to_json().to_string();
    let back = AffinityMap::from_json(&Json::parse(&j1).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), j1);
    let mut rng = Rng::new(13);
    for _ in 0..200 {
        let sparse: Vec<u64> = (0..2).map(|_| rng.below(1500)).collect();
        assert_eq!(map.key(&sparse), back.key(&sparse), "profiled key diverged");
    }
}

#[test]
fn serve_report_round_trips() {
    let want = ServeReport {
        served: 480,
        lifetime_served: 500,
        wall: Duration::from_micros(1_234_567),
        tps: 388.8,
        mean_latency: Duration::from_nanos(41_000),
        p99_latency: Duration::from_nanos(987_654),
        model_bytes: 123_456,
        replicas: 3,
        policy: "plan_affinity",
    };
    let s = want.to_json().to_string();
    let got = ServeReport::from_json(&Json::parse(&s).unwrap()).unwrap();
    assert_eq!(want, got);
    assert_eq!(got.to_json().to_string(), s);
}

#[test]
fn open_loop_report_round_trips() {
    let want = OpenLoopReport {
        offered: 300,
        served: 290,
        dropped: 4,
        shed: 6,
        respawns: 1,
        wall: Duration::from_millis(750),
        offered_rate: 400.0,
        achieved_rate: 386.7,
        mean_window: Duration::from_micros(900),
        p50_window: Duration::from_micros(700),
        p99_window: Duration::from_micros(4_500),
        max_window: Duration::from_micros(9_000),
        mean_queue_delay: Duration::from_micros(300),
        p99_queue_delay: Duration::from_micros(2_000),
        mean_service: Duration::from_micros(600),
        p99_service: Duration::from_micros(2_500),
        replicas: 2,
        policy: "ring_affinity",
        tail_p99_window: Duration::from_micros(3_800),
        window_samples: vec![0.0007, 0.0009, 0.0045],
    };
    let s = want.to_json().to_string();
    let got = OpenLoopReport::from_json(&Json::parse(&s).unwrap()).unwrap();
    assert_eq!(want, got);
    assert_eq!(got.to_json().to_string(), s);
    // unknown policies come back as the static "unknown" sentinel rather
    // than an error (forward compatibility across report versions)
    let mut doctored = want.clone();
    doctored.policy = "round_robin";
    let mut j = doctored.to_json().to_string();
    j = j.replace("round_robin", "future_policy");
    let lenient = OpenLoopReport::from_json(&Json::parse(&j).unwrap()).unwrap();
    assert_eq!(lenient.policy, "unknown");
}
