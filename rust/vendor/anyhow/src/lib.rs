//! Minimal offline stand-in for the `anyhow` crate (the real crates.io
//! dependency is unavailable in this build environment).  Implements the
//! subset recad uses: [`Error`] with a context chain, [`Result`],
//! [`Context`] for `Result` and `Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros.  `{:#}` formatting prints the full chain
//! outermost-first, `{}` prints the outermost message only — matching the
//! real crate's behaviour for the formatting the callers rely on.

use std::fmt;

/// A context-carrying error value.  Deliberately does NOT implement
/// `std::error::Error` so the blanket `From<E: Error>` conversion below
/// stays coherent (same trick the real anyhow uses).
pub struct Error {
    /// Context chain, outermost message first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, for both `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` / `anyhow!(expr)` — build an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(...)` — return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_formats_outermost_first() {
        let e: Error = Err::<(), std::io::Error>(io_err())
            .context("reading meta.json")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading meta.json");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading meta.json: "), "{full}");
        assert!(full.contains("missing thing"), "{full}");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing key");
        let v = 7;
        let e = anyhow!("bad value {v}");
        assert_eq!(format!("{e}"), "bad value 7");
        let e = anyhow!("bad value {}", v + 1);
        assert_eq!(format!("{e}"), "bad value 8");

        fn f(x: bool) -> Result<u32> {
            ensure!(x, "x was false");
            if !x {
                bail!("unreachable {x}");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert!(format!("{:#}", f(false).unwrap_err()).contains("x was false"));
    }
}
