//! Fig. 10 — end-to-end single-GPU training speedup over DLRM, per
//! dataset, on the V100-class and T4-class platforms.
//!
//! Paper shape: Rec-AD ≈3× DLRM (avg), ≈1.5× FAE, ≈1.4× TT-Rec on V100;
//! the same ordering holds on T4 with smaller gaps.

use recad::baselines::dlrm_ps::DlrmPs;
use recad::baselines::fae::Fae;
use recad::baselines::recad::RecAd;
use recad::baselines::ttrec::TtRec;
use recad::baselines::{run_arm, TrainArm};
use recad::bench_support::{bench_schemas, engine_for, workload, BENCH_SCALE};
use recad::coordinator::platform::SimPlatform;
use recad::util::bench::Table;
use recad::util::prng::Rng;

fn main() {
    for platform in [SimPlatform::v100(1), SimPlatform::t4(1)] {
        let mut table = Table::new(
            &format!("Fig. 10 — single-GPU speedup over DLRM ({})", platform.name),
            &["Dataset", "DLRM", "FAE", "TT-Rec", "Rec-AD", "Paper Rec-AD"],
        );
        for schema in bench_schemas() {
            let (profile, train) = workload(&schema, 10, 16, 512);
            let threshold = (1_000_000.0 * BENCH_SCALE) as u64;
            let cfg = engine_for(&schema, BENCH_SCALE, 8);
            let mut arms: Vec<Box<dyn TrainArm>> = vec![
                Box::new(DlrmPs::new(cfg.clone(), platform, threshold, &mut Rng::new(1))),
                Box::new(Fae::new(
                    cfg.clone(),
                    platform,
                    threshold,
                    &profile,
                    0.85,
                    &mut Rng::new(1),
                )),
                Box::new(TtRec::new(cfg.clone(), platform, &mut Rng::new(1))),
                Box::new(RecAd::new(cfg.clone(), platform, &profile, true, &mut Rng::new(1))),
            ];
            let reports: Vec<_> = arms.iter_mut().map(|a| run_arm(a.as_mut(), &train)).collect();
            let dlrm_t = reports[0].total().as_secs_f64();
            let speedup = |i: usize| dlrm_t / reports[i].total().as_secs_f64();
            table.row(&[
                schema.name.to_string(),
                "1.00x".to_string(),
                format!("{:.2}x", speedup(1)),
                format!("{:.2}x", speedup(2)),
                format!("{:.2}x", speedup(3)),
                "~3x (V100 avg)".to_string(),
            ]);
        }
        table.print();
    }
    println!("\nnote: compute measured on CPU; link costs from the platform model are");
    println!("slowdown-scaled so the compute:comm ratio matches the paper's testbed (DESIGN.md §4).");
}
