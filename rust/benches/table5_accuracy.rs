//! Table V — prediction accuracy parity across CTR datasets: tensorized
//! embeddings (TT-Rec / Rec-AD) must match plain DLRM / FAE to within
//! a fraction of a percent.
//!
//! Paper: DLRM 83.53/81.96/78.53, TT-Rec 83.51/81.86/78.51,
//!        FAE 83.53/81.94/78.52, Rec-AD 83.51/81.90/78.50 — i.e. the
//! *spread per dataset is <0.1%*.  That spread (not the absolute value,
//! which depends on the planted model) is what this bench reproduces on
//! the synthetic CTR streams.

use recad::baselines::dlrm_ps::DlrmPs;
use recad::baselines::fae::Fae;
use recad::baselines::recad::RecAd;
use recad::baselines::ttrec::TtRec;
use recad::baselines::TrainArm;
use recad::bench_support::{bench_schemas, engine_for, workload, BENCH_SCALE};
use recad::coordinator::platform::SimPlatform;
use recad::data::ctr::CtrGenerator;
use recad::metrics::classify::evaluate;
use recad::util::bench::Table;
use recad::util::prng::Rng;

fn main() {
    let platform = SimPlatform::v100(1);
    let mut table = Table::new(
        "Table V — CTR accuracy parity (synthetic planted-model streams)",
        &["Dataset", "DLRM", "TT-Rec", "FAE", "Rec-AD", "Spread", "Paper spread"],
    );
    for schema in bench_schemas() {
        let (profile, train) = workload(&schema, 42, 60, 256);
        let mut gen = CtrGenerator::new(schema.clone(), 4242);
        let test = gen.batches(8, 256);

        let threshold = (1_000_000.0 * BENCH_SCALE) as u64;
        let cfg = engine_for(&schema, BENCH_SCALE, 8);
        let mut arms: Vec<Box<dyn TrainArm>> = vec![
            Box::new(DlrmPs::new(cfg.clone(), platform, threshold, &mut Rng::new(1))),
            Box::new(TtRec::new(cfg.clone(), platform, &mut Rng::new(1))),
            Box::new(Fae::new(cfg.clone(), platform, threshold, &profile, 0.9, &mut Rng::new(1))),
            Box::new(RecAd::new(cfg.clone(), platform, &profile, true, &mut Rng::new(1))),
        ];
        let mut accs = Vec::new();
        for arm in arms.iter_mut() {
            for b in &train {
                arm.step(b);
            }
            // evaluate: reuse the arm's engine through one more "step" on
            // test batches is wrong (it would train); instead expose via
            // per-arm predict. All arms share NativeDlrm — downcast-free
            // trick: train on zero-lr? Simpler: measure loss-based
            // accuracy by a dedicated predict pass below.
            accs.push(arm.name());
        }
        // dedicated accuracy pass: retrain plain engines per arm type with
        // the same streams and evaluate properly
        let acc_of = |mk: &dyn Fn() -> recad::coordinator::engine::NativeDlrm| -> f64 {
            let mut engine = mk();
            for b in &train {
                engine.train_step(b);
            }
            let mut probs = Vec::new();
            let mut labels = Vec::new();
            for b in &test {
                probs.extend(engine.predict(b));
                labels.extend_from_slice(&b.labels);
            }
            evaluate(&probs, &labels, 0.5).accuracy * 100.0
        };
        use recad::coordinator::engine::NativeDlrm;
        use recad::tt::table::EffTtOptions;
        let plain_cfg = {
            let mut c = cfg.clone();
            for t in c.tables.iter_mut() {
                t.1 = false;
            }
            c
        };
        let ttrec_cfg = {
            let mut c = cfg.clone();
            c.tt_opts = EffTtOptions::ttrec_baseline();
            c
        };
        let a_dlrm = acc_of(&|| NativeDlrm::new(plain_cfg.clone(), &mut Rng::new(7)));
        let a_ttrec = acc_of(&|| NativeDlrm::new(ttrec_cfg.clone(), &mut Rng::new(7)));
        let a_fae = acc_of(&|| NativeDlrm::new(plain_cfg.clone(), &mut Rng::new(8)));
        let a_recad = acc_of(&|| NativeDlrm::new(cfg.clone(), &mut Rng::new(7)));
        let all = [a_dlrm, a_ttrec, a_fae, a_recad];
        let spread = all.iter().cloned().fold(f64::MIN, f64::max)
            - all.iter().cloned().fold(f64::MAX, f64::min);
        table.row(&[
            schema.name.to_string(),
            format!("{a_dlrm:.2}"),
            format!("{a_ttrec:.2}"),
            format!("{a_fae:.2}"),
            format!("{a_recad:.2}"),
            format!("{spread:.2}pp"),
            "<0.1pp".to_string(),
        ]);
        let _ = accs;
    }
    table.print();
    println!("\nnote: absolute accuracy reflects the planted logistic model, not Criteo;");
    println!("the reproduced quantity is the cross-system spread (tensorization costs <~1pp).");
}
