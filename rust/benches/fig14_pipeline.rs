//! Fig. 14 — pipeline training vs sequential vs DLRM (PS, no pipeline).
//!
//! Paper shape: Rec-AD (Pipeline) ≈2.44× DLRM; ≈1.30× Rec-AD (Sequential,
//! prefetch queue length 1).  The pipeline here is REAL overlap: two OS
//! threads, bounded queues, the Fig. 9(b) cache fixing RAW conflicts —
//! communication is charged as wall time from the platform cost model,
//! calibrated against the measured per-batch compute so the
//! compute:comm balance matches the paper's testbed.

use std::time::{Duration, Instant};

use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::pipeline::{self, PipelineCfg};
use recad::coordinator::platform::CostModel;
use recad::data::ctr::CtrGenerator;
use recad::data::schema::DatasetSchema;
use recad::tt::table::EffTtOptions;
use recad::util::bench::Table;
use recad::util::prng::Rng;

const BATCH: usize = 512;
const STEPS: usize = 24;

fn main() {
    // 1 big (TT, device) + 4 medium (plain, host) tables — the §IV layout
    let ecfg = EngineCfg {
        dense_dim: 8,
        emb_dim: 16,
        tables: vec![
            (50_000, true),
            (4_000, false),
            (4_000, false),
            (3_000, false),
            (3_000, false),
        ],
        tt_rank: 8,
        bot_hidden: vec![64, 32],
        top_hidden: vec![64, 32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        // serial by default so figures stay comparable to the paper's
        // single-stream baselines; RECAD_WORKERS opts into the exec arm
        exec: recad::exec::ExecCfg::from_env(recad::bench_support::WORKERS_ENV),
    };
    let schema = DatasetSchema {
        name: "pipeline-bench",
        n_dense: 8,
        vocabs: vec![50_000, 4_000, 4_000, 3_000, 3_000],
        emb_dim: 16,
        zipf_s: 1.15,
        ft_rank: 8,
    };
    let mut gen = CtrGenerator::new(schema, 33);
    let batches = gen.batches(STEPS, BATCH);
    let host_slots = vec![1usize, 2, 3, 4];

    // ---- calibrate comm to the measured compute --------------------------
    let mut probe = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
    probe.train_step(&batches[0]);
    let t0 = Instant::now();
    for b in &batches[..4] {
        probe.train_step(b);
    }
    let compute = t0.elapsed() / 4;
    // paper's testbed: PS gather+transfer ≈ 0.8× of GPU compute per step
    // (that balance is what makes the pipeline matter)
    let rows_per_step = BATCH * host_slots.len();
    let comm_target = compute.mul_f64(0.8);
    let cost = CostModel {
        h2d_bps: 1e12, // volume folded into ps_row for calibration clarity
        d2d_bps: 1e12,
        transfer_latency: Duration::ZERO,
        ps_row: comm_target / (rows_per_step as u32 * 2),
        dispatch: Duration::from_micros(8),
    };

    // ---- arms -------------------------------------------------------------
    let run_mode = |pipelined: bool, lc: usize| {
        let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
        let host = pipeline::split_to_host(&mut engine, &host_slots, &mut Rng::new(2));
        let mut pcfg = PipelineCfg::new(cost, host_slots.clone());
        pcfg.pipelined = pipelined;
        pcfg.lc = lc;
        let (r, _, _) = pipeline::run(engine, host, &batches, &pcfg);
        r
    };
    let seq = run_mode(false, 1);
    let pipe = run_mode(true, 4);

    // DLRM arm: no TT compression — the big table ALSO lives on host
    let dlrm_cfg = {
        let mut c = ecfg.clone();
        for t in c.tables.iter_mut() {
            t.1 = false;
        }
        c
    };
    let dlrm_slots = vec![0usize, 1, 2, 3, 4];
    let dlrm = {
        let mut engine = NativeDlrm::new(dlrm_cfg, &mut Rng::new(1));
        let host = pipeline::split_to_host(&mut engine, &dlrm_slots, &mut Rng::new(2));
        let mut pcfg = PipelineCfg::new(cost, dlrm_slots);
        pcfg.pipelined = false;
        pcfg.lc = 1;
        let (r, _, _) = pipeline::run(engine, host, &batches, &pcfg);
        r
    };

    let mut t = Table::new(
        "Fig. 14 — pipeline training speedup",
        &["System", "Throughput", "Speedup vs DLRM", "RAW fixed", "Paper"],
    );
    let rows = [
        ("DLRM (PS, sequential)", &dlrm, "1.00x"),
        ("Rec-AD (Sequential, LC=1)", &seq, "~1.9x"),
        ("Rec-AD (Pipeline, LC=4)", &pipe, "2.44x"),
    ];
    for (name, r, paper) in rows {
        t.row(&[
            name.into(),
            format!("{:.0}/s", r.throughput),
            format!("{:.2}x", r.throughput / dlrm.throughput),
            r.raw_fixed.to_string(),
            paper.into(),
        ]);
    }
    t.print();
    println!(
        "\npipeline vs sequential: {:.2}x (paper 1.30x); losses bit-identical: {}",
        pipe.throughput / seq.throughput,
        pipe.losses == seq.losses
    );

    // LC (prefetch-queue depth) sweep — §IV-B's Load Capacity parameter
    println!("\nLC sweep (pipeline throughput vs queue depth):");
    for lc in [1usize, 2, 4, 8] {
        let r = run_mode(true, lc);
        println!("  LC={lc}: {:.0} samples/s ({:.2}x vs sequential)",
                 r.throughput, r.throughput / seq.throughput);
    }
    println!("comm calibrated to 0.8x of measured compute per step (DESIGN.md §4).");
}
