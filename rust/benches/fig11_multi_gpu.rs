//! Fig. 11 — training throughput, 1 GPU vs 4 GPUs (Rec-AD data-parallel
//! replication vs DLRM model-parallel sharding).
//!
//! Paper shape: DLRM slightly ahead at 1 GPU (raw compute, no TT
//! overhead); Rec-AD 1.4× ahead at 4 GPUs (no peer-to-peer embedding
//! traffic).

use std::time::Instant;

use recad::baselines::multi_gpu::{
    dlrm_model_parallel_step, recad_step, throughput, MultiGpuWorkload,
};
use recad::bench_support::{engine_for, scaled, workload, BENCH_SCALE};
use recad::coordinator::engine::NativeDlrm;
use recad::coordinator::platform::SimPlatform;
use recad::data::schema;
use recad::util::bench::Table;
use recad::util::prng::Rng;

fn main() {
    let platform = SimPlatform::v100(4);
    let s = scaled(&schema::criteo_kaggle(), BENCH_SCALE);
    let (_, train) = workload(&s, 21, 6, 1024);

    // measure per-batch compute for both engines
    let measure = |compressed: bool| {
        let mut cfg = engine_for(&s, BENCH_SCALE, 8);
        if !compressed {
            for t in cfg.tables.iter_mut() {
                t.1 = false;
            }
        }
        let mut engine = NativeDlrm::new(cfg, &mut Rng::new(1));
        engine.train_step(&train[0]); // warmup
        let t0 = Instant::now();
        for b in &train {
            engine.train_step(b);
        }
        (t0.elapsed() / train.len() as u32, engine.embedding_bytes())
    };
    let (recad_compute, recad_bytes) = measure(true);
    let (dlrm_compute, _) = measure(false);

    let wl = |compute| MultiGpuWorkload {
        compute,
        batch_size: 1024,
        n_sparse: s.n_sparse(),
        emb_dim: 16,
        dp_grad_bytes: recad_bytes.min(8 << 20),
    };

    let mut t = Table::new(
        "Fig. 11 — throughput (samples/s), 1 vs 4 GPUs (Kaggle-shaped)",
        &["System", "1 GPU", "4 GPU", "4/1 scaling", "Paper shape"],
    );
    let r1 = throughput(&wl(recad_compute), recad_step(&wl(recad_compute), &platform.cost, 1), 1);
    let r4 = throughput(&wl(recad_compute), recad_step(&wl(recad_compute), &platform.cost, 4), 4);
    let d1 = throughput(
        &wl(dlrm_compute),
        dlrm_model_parallel_step(&wl(dlrm_compute), &platform.cost, 1),
        1,
    );
    let d4 = throughput(
        &wl(dlrm_compute),
        dlrm_model_parallel_step(&wl(dlrm_compute), &platform.cost, 4),
        4,
    );
    t.row(&[
        "DLRM (model-parallel)".into(),
        format!("{d1:.0}"),
        format!("{d4:.0}"),
        format!("{:.2}x", d4 / d1),
        "ahead at 1 GPU".into(),
    ]);
    t.row(&[
        "Rec-AD (data-parallel)".into(),
        format!("{r1:.0}"),
        format!("{r4:.0}"),
        format!("{:.2}x", r4 / r1),
        "1.4x DLRM at 4 GPU".into(),
    ]);
    t.print();
    println!("\nmeasured: Rec-AD(4)/DLRM(4) = {:.2}x (paper: 1.4x)", r4 / d4);
    println!("          DLRM(1)/Rec-AD(1) = {:.2}x (paper: DLRM slightly ahead)", d1 / r1);
}
