//! Fig. 13 — 40M×128 single large embedding table (19 GB > 16 GB HBM):
//! Rec-AD (Eff-TT fits one device, data-parallel) vs HugeCTR-like /
//! TorchRec-like model-parallel sharding, 1/2/4 GPUs.
//!
//! Paper shape: Rec-AD ≈1.07× HugeCTR, ≈1.35× TorchRec at 4 GPUs.

use std::time::Instant;

use recad::baselines::multi_gpu::{
    hugectr_step, recad_step, throughput, torchrec_step, MultiGpuWorkload,
};
use recad::coordinator::platform::SimPlatform;
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::bench::{fmt_bytes, Table};
use recad::util::prng::Rng;

const BATCH: usize = 4096;

fn main() {
    let platform = SimPlatform::v100(4);

    // full-scale premise
    let full = TtShapes::plan(40_000_000, 128, 32);
    println!(
        "premise: 40M x 128 = {} plain (> {} HBM) vs {} Eff-TT (fits)",
        fmt_bytes(full.plain_bytes()),
        fmt_bytes(platform.hbm_bytes),
        fmt_bytes(full.tt_bytes())
    );
    assert!(!platform.fits_hbm(full.plain_bytes()));
    assert!(platform.fits_hbm(full.tt_bytes()));

    // measured compute on the scaled instantiation (same shape, 1/100 rows)
    let shapes = TtShapes::plan(400_000, 128, 16);
    let mut rng = Rng::new(1);
    let mut table = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    let mut scratch = TtScratch::default();
    let idx: Vec<u64> = (0..BATCH).map(|_| rng.below(400_000)).collect();
    let offsets: Vec<usize> = (0..=BATCH).collect();
    let mut out = vec![0.0f32; BATCH * 128];
    table.embedding_bag(&idx, &offsets, &mut out, &mut scratch); // warmup
    let t0 = Instant::now();
    const REPS: usize = 3;
    for _ in 0..REPS {
        table.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
        let g = vec![0.1f32; BATCH * 128];
        table.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch);
    }
    let compute = t0.elapsed() / REPS as u32;

    let w = MultiGpuWorkload {
        compute,
        batch_size: BATCH,
        n_sparse: 1,
        emb_dim: 128,
        dp_grad_bytes: shapes.tt_bytes(),
    };

    let mut t = Table::new(
        "Fig. 13 — large-table training throughput (samples/s)",
        &["GPUs", "Rec-AD", "HugeCTR", "TorchRec", "RecAD/HugeCTR", "RecAD/TorchRec", "Paper"],
    );
    for n in [1usize, 2, 4] {
        let r = throughput(&w, recad_step(&w, &platform.cost, n), n);
        let h = throughput(&w, hugectr_step(&w, &platform.cost, n), n);
        let tc = throughput(&w, torchrec_step(&w, &platform.cost, n), n);
        t.row(&[
            n.to_string(),
            format!("{r:.0}"),
            format!("{h:.0}"),
            format!("{tc:.0}"),
            format!("{:.2}x", r / h),
            format!("{:.2}x", r / tc),
            if n == 4 { "1.07x / 1.35x".into() } else { "—".into() },
        ]);
    }
    t.print();
    println!("\nnote: compute measured on the 1/100-rows instantiation (same TT shape);");
    println!("collectives composed from the V100 cost model (DESIGN.md §4).");
}
