//! Fig. 12 — Eff-TT optimization decomposition: disable one optimization
//! at a time and measure the training-throughput drop, across table sizes.
//!
//! Paper shape: w/o gradient aggregation ≈ −52%; w/o index reordering
//! ≈ −13% (growing with table size); w/o intermediate reuse ≈ −10%.

use std::time::Instant;

use recad::access::{replay_fill, run_prefetched_fill, AccessPlanner};
use recad::bench_support::{write_bench_json, BenchArm};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::data::ctr::{Batch, CtrGenerator};
use recad::data::schema::DatasetSchema;
use recad::reorder::bijection::IndexBijection;
use recad::tt::table::EffTtOptions;
use recad::util::bench::Table;
use recad::util::prng::Rng;

/// Bench-scale stand-ins for the paper's 2.5M/5M/10M-row tables.
const TABLE_ROWS: [u64; 3] = [25_000, 50_000, 100_000];
const BATCH: usize = 1024;
const STEPS: usize = 10;

fn schema_for(rows: u64) -> DatasetSchema {
    DatasetSchema {
        name: "ablation",
        n_dense: 4,
        vocabs: vec![rows],
        emb_dim: 16,
        zipf_s: 1.35,
        ft_rank: 8,
    }
}

/// Batches with co-occurrence structure (themes) so reordering has
/// something to exploit, ids scrambled as hashes would be.
fn themed_batches(rows: u64, n: usize, seed: u64) -> Vec<Batch> {
    let mut gen = CtrGenerator::new(schema_for(rows / 4), seed);
    let mut perm_rng = Rng::new(0xFACE);
    let mut perm: Vec<u64> = (0..rows).collect();
    perm_rng.shuffle(&mut perm);
    (0..n)
        .map(|i| {
            let mut b = gen.next_batch(BATCH);
            let theme = (i % 4) as u64 * (rows / 4);
            for v in b.sparse.iter_mut() {
                *v = perm[(theme + *v) as usize];
            }
            b
        })
        .collect()
}

/// One ablation variant.  `plan_ahead`: 0 trains through the legacy
/// inline-plan wrappers; N>0 routes ingest through the access layer's
/// prefetch stage (bit-identical math, overlapped planning).
fn run_variant(
    rows: u64,
    opts: EffTtOptions,
    reorder: bool,
    plan_ahead: usize,
    batches: &[Batch],
) -> (f64, Vec<f64>, recad::tt::table::TtStats) {
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(rows, true)],
        tt_rank: 16,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: opts,
        // serial by default so figures stay comparable to the paper's
        // single-stream baselines; RECAD_WORKERS opts into the exec arm
        exec: recad::exec::ExecCfg::from_env(recad::bench_support::WORKERS_ENV),
    };
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(1));
    let bij = if reorder {
        let cols: Vec<Vec<u64>> = batches.iter().map(|b| b.sparse.clone()).collect();
        let refs: Vec<&[u64]> = cols.iter().map(|c| c.as_slice()).collect();
        Some(IndexBijection::build(rows, &refs, 0.05))
    } else {
        None
    };
    let mut remapped: Vec<Batch> = batches.to_vec();
    if let Some(b) = &bij {
        for batch in remapped.iter_mut() {
            b.apply_batch(&mut batch.sparse);
        }
    }
    engine.train_step(&remapped[0]); // warmup
    // single-core box: take the best of 3 repetitions to shed scheduler
    // noise (standard min-of-N for microbenches)
    let mut planner = AccessPlanner::for_engine_cfg(&engine.cfg);
    let mut reps = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        if plan_ahead > 0 {
            run_prefetched_fill(replay_fill(&remapped[..STEPS]), &mut planner, plan_ahead, |b, p| {
                engine.train_step_planned(b, p);
            });
        } else {
            for b in &remapped[..STEPS] {
                engine.train_step(b);
            }
        }
        reps.push(t0.elapsed().as_secs_f64());
    }
    let best = reps.iter().cloned().fold(f64::INFINITY, f64::min);
    ((STEPS * BATCH) as f64 / best, reps, engine.tt_stats())
}

fn main() {
    let mut t = Table::new(
        "Fig. 12 — throughput drop when disabling one optimization",
        &["Table rows", "full (samples/s)", "w/o grad-agg", "w/o reorder", "w/o reuse", "planned ingest", "paper"],
    );
    let mut arms: Vec<BenchArm> = Vec::new();
    let mut arm_of = |rows: u64, tag: &str, tput: f64, reps: &[f64]| {
        let per_iter: Vec<f64> = reps.iter().map(|r| r / STEPS as f64).collect();
        let mut a = BenchArm::from_iters(format!("fig12_rows{rows}_{tag}"), 1, &per_iter, BATCH);
        // the table reports best-of-N; keep the JSON headline consistent
        a.throughput = tput;
        arms.push(a);
    };
    for rows in TABLE_ROWS {
        let batches = themed_batches(rows, STEPS + 2, rows ^ 7);
        let (full, reps_full, _) =
            run_variant(rows, EffTtOptions::default(), true, 0, &batches);
        let (no_agg, reps_na, _) = run_variant(
            rows,
            EffTtOptions { grad_aggregation: false, ..Default::default() },
            true,
            0,
            &batches,
        );
        let (no_reorder, reps_nr, _) =
            run_variant(rows, EffTtOptions::default(), false, 0, &batches);
        let (no_reuse, reps_nu, _) = run_variant(
            rows,
            EffTtOptions { reuse: false, ..Default::default() },
            true,
            0,
            &batches,
        );
        // access-layer arm: full optimizations + prefetch-planned ingest
        let (planned, reps_pl, _) =
            run_variant(rows, EffTtOptions::default(), true, 2, &batches);
        let drop = |x: f64| 100.0 * (x - full) / full;
        t.row(&[
            format!("{rows}"),
            format!("{full:.0}"),
            format!("{:+.1}%", drop(no_agg)),
            format!("{:+.1}%", drop(no_reorder)),
            format!("{:+.1}%", drop(no_reuse)),
            format!("{:+.1}%", drop(planned)),
            "-52% / -13% / -10%".into(),
        ]);
        arm_of(rows, "full_unplanned", full, &reps_full);
        arm_of(rows, "no_grad_agg", no_agg, &reps_na);
        arm_of(rows, "no_reorder", no_reorder, &reps_nr);
        arm_of(rows, "no_reuse", no_reuse, &reps_nu);
        arm_of(rows, "full_planned", planned, &reps_pl);
    }
    t.print();
    println!("\nnote: batch {BATCH}, zipf-skewed themed streams; rows scaled 1/100 of the");
    println!("paper's 2.5M-10M tables (structure-preserving).");
    let path = write_bench_json("fig12", recad::bench_support::bench_workers(), &arms);
    println!("wrote {path}");
}
