//! Fig. 12 — Eff-TT optimization decomposition: disable one optimization
//! at a time and measure the training-throughput drop, across table sizes.
//!
//! Paper shape: w/o gradient aggregation ≈ −52%; w/o index reordering
//! ≈ −13% (growing with table size); w/o intermediate reuse ≈ −10%.

use std::time::Instant;

use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::data::ctr::{Batch, CtrGenerator};
use recad::data::schema::DatasetSchema;
use recad::reorder::bijection::IndexBijection;
use recad::tt::table::EffTtOptions;
use recad::util::bench::Table;
use recad::util::prng::Rng;

/// Bench-scale stand-ins for the paper's 2.5M/5M/10M-row tables.
const TABLE_ROWS: [u64; 3] = [25_000, 50_000, 100_000];
const BATCH: usize = 1024;
const STEPS: usize = 10;

fn schema_for(rows: u64) -> DatasetSchema {
    DatasetSchema {
        name: "ablation",
        n_dense: 4,
        vocabs: vec![rows],
        emb_dim: 16,
        zipf_s: 1.35,
        ft_rank: 8,
    }
}

/// Batches with co-occurrence structure (themes) so reordering has
/// something to exploit, ids scrambled as hashes would be.
fn themed_batches(rows: u64, n: usize, seed: u64) -> Vec<Batch> {
    let mut gen = CtrGenerator::new(schema_for(rows / 4), seed);
    let mut perm_rng = Rng::new(0xFACE);
    let mut perm: Vec<u64> = (0..rows).collect();
    perm_rng.shuffle(&mut perm);
    (0..n)
        .map(|i| {
            let mut b = gen.next_batch(BATCH);
            let theme = (i % 4) as u64 * (rows / 4);
            for v in b.sparse.iter_mut() {
                *v = perm[(theme + *v) as usize];
            }
            b
        })
        .collect()
}

fn run_variant(
    rows: u64,
    opts: EffTtOptions,
    reorder: bool,
    batches: &[Batch],
) -> (f64, recad::tt::table::TtStats) {
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(rows, true)],
        tt_rank: 16,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: opts,
        // serial by default so figures stay comparable to the paper's
        // single-stream baselines; RECAD_WORKERS opts into the exec arm
        exec: recad::exec::ExecCfg::from_env(recad::bench_support::WORKERS_ENV),
    };
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(1));
    let bij = if reorder {
        let cols: Vec<Vec<u64>> = batches.iter().map(|b| b.sparse.clone()).collect();
        let refs: Vec<&[u64]> = cols.iter().map(|c| c.as_slice()).collect();
        Some(IndexBijection::build(rows, &refs, 0.05))
    } else {
        None
    };
    let mut remapped: Vec<Batch> = batches.to_vec();
    if let Some(b) = &bij {
        for batch in remapped.iter_mut() {
            b.apply_batch(&mut batch.sparse);
        }
    }
    engine.train_step(&remapped[0]); // warmup
    // single-core box: take the best of 3 repetitions to shed scheduler
    // noise (standard min-of-N for microbenches)
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for b in &remapped[..STEPS] {
            engine.train_step(b);
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    ((STEPS * BATCH) as f64 / best, engine.tt_stats())
}

fn main() {
    let mut t = Table::new(
        "Fig. 12 — throughput drop when disabling one optimization",
        &["Table rows", "full (samples/s)", "w/o grad-agg", "w/o reorder", "w/o reuse", "paper"],
    );
    for rows in TABLE_ROWS {
        let batches = themed_batches(rows, STEPS + 2, rows ^ 7);
        let (full, _) = run_variant(rows, EffTtOptions::default(), true, &batches);
        let (no_agg, _) = run_variant(
            rows,
            EffTtOptions { grad_aggregation: false, ..Default::default() },
            true,
            &batches,
        );
        let (no_reorder, _) = run_variant(rows, EffTtOptions::default(), false, &batches);
        let (no_reuse, _) = run_variant(
            rows,
            EffTtOptions { reuse: false, ..Default::default() },
            true,
            &batches,
        );
        let drop = |x: f64| 100.0 * (x - full) / full;
        t.row(&[
            format!("{rows}"),
            format!("{full:.0}"),
            format!("{:+.1}%", drop(no_agg)),
            format!("{:+.1}%", drop(no_reorder)),
            format!("{:+.1}%", drop(no_reuse)),
            "-52% / -13% / -10%".into(),
        ]);
    }
    t.print();
    println!("\nnote: batch {BATCH}, zipf-skewed themed streams; rows scaled 1/100 of the");
    println!("paper's 2.5M-10M tables (structure-preserving).");
}
