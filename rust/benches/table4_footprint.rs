//! Tables II & IV — dataset schemas and embedding-table footprint
//! (plain DLRM vs Eff-TT at the calibrated ranks).
//!
//! Paper Table IV: Avazu 0.55GB→87.6MB (6.22×), Terabyte 59.2GB→797.9MB
//! (74.19×), Kaggle 1.9GB→258.2MB (7.29×), IEEE118 1.22GB→235.7MB (5.33×).
//! These are *analytic* at full scale (the tables are shape arithmetic)
//! plus an instantiated verification at bench scale.

use recad::bench_support::{scaled, BENCH_SCALE};
use recad::coordinator::engine::NativeDlrm;
use recad::data::schema::all_schemas;
use recad::tt::shapes::TtShapes;
use recad::util::bench::{fmt_bytes, Table};
use recad::util::prng::Rng;

fn main() {
    let paper = [6.22, 74.19, 7.29, 5.33];

    let mut t2 = Table::new(
        "Table II — dataset schemas",
        &["Dataset", "Dense", "Sparse", "Rows", "Dim", "Plain size", "Paper size"],
    );
    let paper_sizes = ["0.55GB", "59.2GB", "1.9GB", "1.22GB"];
    for (s, ps) in all_schemas().iter().zip(paper_sizes) {
        t2.row(&[
            s.name.to_string(),
            s.n_dense.to_string(),
            s.n_sparse().to_string(),
            format!("{:.1}M", s.total_rows() as f64 / 1e6),
            s.emb_dim.to_string(),
            fmt_bytes(s.plain_bytes()),
            ps.to_string(),
        ]);
    }
    t2.print();

    let mut t4 = Table::new(
        "Table IV — embedding footprint (full-scale, analytic)",
        &["Dataset", "DLRM", "Rec-AD", "Ratio", "Paper ratio"],
    );
    for (s, p) in all_schemas().iter().zip(paper) {
        let tt = s.tt_bytes(s.ft_rank, 1_000_000);
        t4.row(&[
            s.name.to_string(),
            fmt_bytes(s.plain_bytes()),
            fmt_bytes(tt),
            format!("{:.2}x", s.compression_ratio(s.ft_rank, 1_000_000)),
            format!("{p:.2}x"),
        ]);
    }
    t4.print();

    // instantiated verification at bench scale: the engine's actual
    // allocated bytes must match the analytic accounting
    let mut tv = Table::new(
        "Table IV(b) — instantiated verification (bench scale)",
        &["Dataset", "Engine bytes (TT)", "Analytic (TT)", "Match"],
    );
    for s in [scaled(&all_schemas()[0], BENCH_SCALE), scaled(&all_schemas()[3], BENCH_SCALE)] {
        let threshold = (1_000_000.0 * BENCH_SCALE) as u64;
        let cfg = recad::bench_support::engine_for(&s, BENCH_SCALE, 8);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(1));
        let analytic: u64 = s
            .vocabs
            .iter()
            .map(|&v| {
                if v > threshold {
                    TtShapes::plan(v, 16, 8).tt_bytes()
                } else {
                    v * 16 * 4
                }
            })
            .sum();
        let actual = engine.embedding_bytes();
        tv.row(&[
            s.name.to_string(),
            fmt_bytes(actual),
            fmt_bytes(analytic),
            format!("{}", actual == analytic),
        ]);
        assert_eq!(actual, analytic, "{}: engine/analytic drift", s.name);
    }
    tv.print();
}
