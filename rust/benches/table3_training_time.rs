//! Table III — IEEE118-Bus: normalized training time (CPU / 1 GPU /
//! 4 GPU) and detection performance for DLRM / TT-Rec / Rec-AD.
//!
//! Paper row:  DLRM 1.00/1.00/1.00, 94.1/92.2/92.1
//!             TT-Rec 0.90/0.82/0.68, 96.8/95.3/95.8
//!             Rec-AD 0.82/0.74/0.62, 97.5/96.2/96.3

use std::time::{Duration, Instant};

use recad::access::{replay_fill, run_prefetched_fill, AccessPlanner};
use recad::baselines::multi_gpu::{recad_step, MultiGpuWorkload};
use recad::bench_support::{write_bench_json, BenchArm};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer::train_ieee118;
use recad::data::batcher::EpochIter;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::tt::table::EffTtOptions;
use recad::util::bench::Table;
use recad::util::prng::Rng;

const SCALE: f64 = 1.0 / 2000.0;

fn cfg_for(arm: &str) -> EngineCfg {
    let mut cfg = EngineCfg::ieee118(SCALE);
    match arm {
        "DLRM" => {
            for t in cfg.tables.iter_mut() {
                t.1 = false; // uncompressed
            }
        }
        "TT-Rec" => cfg.tt_opts = EffTtOptions::ttrec_baseline(),
        _ => {}
    }
    cfg
}

fn main() {
    let ds = generate(&DatasetCfg {
        n_normal: 4000,
        n_attack: 1000,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 100,
        noise_std: 0.005,
        seed: 3,
    });
    let platform = SimPlatform::v100(4);

    // measure pure-compute time per epoch (the "CPU" column: everything
    // on one memory space, no transfers) and a 1-GPU column (compute +
    // PS transfer for the uncompressed arm; dispatch-only for TT arms),
    // then model the 4-GPU column from the multi-GPU composition.
    let mut rows = Vec::new();
    let mut dlrm_base: Option<[f64; 3]> = None;
    for arm in ["DLRM", "TT-Rec", "Rec-AD"] {
        let cfg = cfg_for(arm);
        // --- wall compute per step ------------------------------------
        let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut rng = Rng::new(9);
        let batches: Vec<_> = EpochIter::new(&ds.samples, 128, &mut rng).take(12).collect();
        // warmup
        engine.train_step(&batches[0]);
        let t0 = Instant::now();
        for b in &batches {
            engine.train_step(b);
        }
        let compute = t0.elapsed() / batches.len() as u32;

        // --- comm per step ---------------------------------------------
        let comm_1gpu = if arm == "DLRM" {
            // PS path: big tables on host.  IEEE118's tables are small
            // enough that the host gather is cache-resident (the paper
            // notes the acceleration is "less pronounced" on this small
            // dataset), so only the PCIe round trips are charged.
            let rows_per_batch = 128 * 2; // two big tables, bag 1
            let bytes = (rows_per_batch * 16 * 4) as u64;
            platform.cost.h2d_time(bytes) * 2
        } else {
            platform.cost.dispatch
        };
        let cpu_time = compute; // all-host: no transfer, same compute
        let gpu1_time = compute + comm_1gpu;
        let w = MultiGpuWorkload {
            compute,
            batch_size: 128,
            n_sparse: 7,
            emb_dim: 16,
            dp_grad_bytes: engine.embedding_bytes().min(4 << 20),
        };
        let gpu4_time = if arm == "DLRM" {
            recad::baselines::multi_gpu::dlrm_model_parallel_step(&w, &platform.cost, 4)
        } else if arm == "TT-Rec" {
            // TT-Rec is data-parallel like Rec-AD but with slower compute
            recad_step(&w, &platform.cost, 4)
        } else {
            recad_step(&w, &platform.cost, 4)
        };

        // --- detection quality ------------------------------------------
        let (report, _) = train_ieee118(cfg, &ds, 3, 64, 5);

        let secs = [cpu_time, gpu1_time, gpu4_time].map(|d: Duration| d.as_secs_f64());
        if arm == "DLRM" {
            dlrm_base = Some(secs);
        }
        rows.push((arm, secs, report.eval));
    }

    let base = dlrm_base.unwrap();
    let mut t = Table::new(
        "Table III — IEEE118 training time (normalized to DLRM) + detection",
        &["Model", "CPU", "1 GPU", "4 GPU", "Acc %", "Recall %", "F1 %", "Paper (time / acc)"],
    );
    let paper = [
        ("DLRM", "1.00/1.00/1.00 · 94.1/92.2/92.1"),
        ("TT-Rec", "0.90/0.82/0.68 · 96.8/95.3/95.8"),
        ("Rec-AD", "0.82/0.74/0.62 · 97.5/96.2/96.3"),
    ];
    for ((arm, secs, eval), (_, pp)) in rows.iter().zip(paper) {
        t.row(&[
            arm.to_string(),
            format!("{:.2}", secs[0] / base[0]),
            format!("{:.2}", secs[1] / base[1]),
            format!("{:.2}", secs[2] / base[2]),
            format!("{:.1}", eval.accuracy * 100.0),
            format!("{:.1}", eval.recall * 100.0),
            format!("{:.1}", eval.f1 * 100.0),
            pp.to_string(),
        ]);
    }
    t.print();
    println!("\nnote: vocab scale {SCALE}; 4-GPU column composed from measured compute + V100 cost model (DESIGN.md §4).");

    // ---- exec-layer arm: Rec-AD engine training, workers=1 vs N ---------
    // (intra-step parallelism from the shared exec layer; results are
    // bit-identical across worker counts, so this is pure speedup)
    let mut wt = Table::new(
        "Rec-AD engine training throughput vs exec workers (RECAD_WORKERS)",
        &["Workers", "samples/s", "speedup"],
    );
    let mut json_arms: Vec<BenchArm> = Vec::new();
    let mut base: Option<f64> = None;
    for w in recad::bench_support::exec_arms() {
        let mut cfg = cfg_for("Rec-AD");
        cfg.exec = recad::exec::ExecCfg::with_workers(w);
        let mut engine = NativeDlrm::new(cfg, &mut Rng::new(1));
        let mut rng = Rng::new(9);
        let batches: Vec<_> = EpochIter::new(&ds.samples, 512, &mut rng).take(8).collect();
        engine.train_step(&batches[0]); // warmup
        let t0 = Instant::now();
        for b in &batches {
            engine.train_step(b);
        }
        let dt = t0.elapsed().as_secs_f64();
        let n: usize = batches.iter().map(|b| b.batch_size).sum();
        let tput = n as f64 / dt;
        let b0 = *base.get_or_insert(tput);
        wt.row(&[format!("{w}"), format!("{tput:.0}"), format!("{:.2}x", tput / b0)]);
        // per-step units, matching perf_probe's schema
        json_arms.push(BenchArm::from_iters(
            "recad_train_step_batch512".to_string(),
            w,
            &[dt / batches.len() as f64],
            n / batches.len(),
        ));
    }
    wt.print();

    // ---- access-layer arm: planned-prefetch vs unplanned inline ingest --
    // (same Rec-AD config, same batches; planned assembles + plans batch
    // N+1 on the ingest worker while batch N trains — bit-identical math)
    let mut pt = Table::new(
        "Rec-AD ingest: unplanned inline vs planned prefetch (plan_ahead=2)",
        &["Ingest", "samples/s", "speedup"],
    );
    let mut rng = Rng::new(9);
    let batches: Vec<_> = EpochIter::new(&ds.samples, 256, &mut rng).take(16).collect();
    let n: usize = batches.iter().map(|b| b.batch_size).sum();
    let mut ingest_base: Option<f64> = None;
    for planned in [false, true] {
        let cfg = cfg_for("Rec-AD");
        let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        engine.train_step(&batches[0]); // warmup
        let mut reps = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            if planned {
                run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                    engine.train_step_planned(b, p);
                });
            } else {
                for b in &batches {
                    engine.train_step(b);
                }
            }
            // per-step units, matching perf_probe's schema
            reps.push(t0.elapsed().as_secs_f64() / batches.len() as f64);
        }
        let arm = BenchArm::from_iters(
            format!("ingest_{}", if planned { "planned" } else { "unplanned" }),
            1,
            &reps,
            n / batches.len(),
        );
        let b0 = *ingest_base.get_or_insert(arm.throughput);
        pt.row(&[
            if planned { "planned(2)".into() } else { "unplanned".to_string() },
            format!("{:.0}", arm.throughput),
            format!("{:.2}x", arm.throughput / b0),
        ]);
        json_arms.push(arm);
    }
    pt.print();

    let path = write_bench_json("table3", recad::bench_support::bench_workers(), &json_arms);
    println!("wrote {path}");
}
