//! Table VI — streaming real-time detection (batch size 1, RTX-2060-class
//! edge box): DLRM vs Rec-AD on latency, TPS, memory, deployment size,
//! and the 100 MB-scale total processing time.
//!
//! Paper: latency 25→21.5 ms (−14%), TPS 40→46.5 (+16%), GPU memory
//! 320→210 MB (−34%), deployment 180→95 MB (−47%), total 3.47→3.0 h.

use recad::coordinator::engine::EngineCfg;
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer::train_ieee118;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::serve::{Policy, ServeSession};
use recad::util::bench::{fmt_bytes, fmt_dur, Table};

const SCALE: f64 = 1.0 / 2000.0;
/// ~100 MB of 52-byte samples ≈ 2M samples; we serve a slice and
/// extrapolate the total (the paper's "100MB Total Time" row).
const SAMPLE_BYTES: f64 = 52.0;
const STREAM_REQUESTS: usize = 1500;

fn serve_arm(name: &str, compressed: bool, ds: &recad::powersys::dataset::Ieee118Dataset)
    -> (String, f64, f64, u64, u64) {
    let mut cfg = EngineCfg::ieee118(SCALE);
    if !compressed {
        for t in cfg.tables.iter_mut() {
            t.1 = false;
        }
    }
    let (_, engine) = train_ieee118(cfg, ds, 2, 64, 3);
    let deploy = engine.model_bytes();
    // peak run memory ≈ params + activations + cache slack (dominated by
    // the embedding tables at real scale; measured here at bench scale)
    let peak = deploy + 64 * 1024;
    let platform = SimPlatform::rtx2060();
    // Placement premise (paper Table VI: DLRM peaks at 320 MB of GPU
    // memory, i.e. the 1.22 GB uncompressed tables stay in host memory):
    // the uncompressed arm fetches its two big-table rows over PCIe per
    // request; Rec-AD's Eff-TT cores are device-resident (dispatch only).
    let per_request = if compressed {
        platform.cost.dispatch
    } else {
        platform.cost.dispatch
            + platform.cost.gather_time(2)
            + platform.cost.h2d_time(2 * 16 * 4)
    };
    let server = ServeSession::from_engine(engine).dispatch(per_request).start();
    let report = server.run_stream(&ds.samples[..STREAM_REQUESTS], deploy);
    (
        name.to_string(),
        report.mean_latency.as_secs_f64(),
        report.tps,
        peak,
        deploy,
    )
}

fn main() {
    let ds = generate(&DatasetCfg {
        n_normal: 4000,
        n_attack: 1000,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 100,
        noise_std: 0.005,
        seed: 6,
    });

    let dlrm = serve_arm("DLRM", false, &ds);
    let recad_arm = serve_arm("Rec-AD", true, &ds);

    let total_samples = (100e6 / SAMPLE_BYTES) as u64;
    let mut t = Table::new(
        "Table VI — streaming detection, batch size 1 (RTX-2060-class)",
        &["Metric", "DLRM", "Rec-AD", "Delta", "Paper delta"],
    );
    t.row(&[
        "Single-detection latency".into(),
        fmt_dur(dlrm.1),
        fmt_dur(recad_arm.1),
        format!("{:+.1}%", 100.0 * (recad_arm.1 - dlrm.1) / dlrm.1),
        "-14%".into(),
    ]);
    t.row(&[
        "Throughput (TPS)".into(),
        format!("{:.1}/s", dlrm.2),
        format!("{:.1}/s", recad_arm.2),
        format!("{:+.1}%", 100.0 * (recad_arm.2 - dlrm.2) / dlrm.2),
        "+16%".into(),
    ]);
    t.row(&[
        "Peak memory".into(),
        fmt_bytes(dlrm.3),
        fmt_bytes(recad_arm.3),
        format!("{:+.1}%", 100.0 * (recad_arm.3 as f64 - dlrm.3 as f64) / dlrm.3 as f64),
        "-34%".into(),
    ]);
    t.row(&[
        "Deployment size".into(),
        fmt_bytes(dlrm.4),
        fmt_bytes(recad_arm.4),
        format!("{:+.1}%", 100.0 * (recad_arm.4 as f64 - dlrm.4 as f64) / dlrm.4 as f64),
        "-47%".into(),
    ]);
    let total_d = total_samples as f64 / dlrm.2;
    let total_r = total_samples as f64 / recad_arm.2;
    t.row(&[
        "100MB total time".into(),
        format!("{:.2}h", total_d / 3600.0),
        format!("{:.2}h", total_r / 3600.0),
        format!("{:+.1}%", 100.0 * (total_r - total_d) / total_d),
        "-13.5%".into(),
    ]);
    t.print();
    println!("\nnote: vocab scale {SCALE} — absolute MB/ms shrink with it; the reproduced");
    println!("quantities are the DLRM→Rec-AD deltas (right columns).");

    // ---- sharded serving arm: 1 replica vs N, plan-affinity routing -----
    // (one detector clone per replica worker; the ServeSession builder
    // threads the planner + policy — the streaming analogue of Table VI
    // under load)
    let n = recad::bench_support::bench_workers();
    if n > 1 {
        let cfg = EngineCfg::ieee118(SCALE);
        let (_, engine) = train_ieee118(cfg, &ds, 2, 64, 3);
        let deploy = engine.model_bytes();
        let platform = SimPlatform::rtx2060();
        let session = ServeSession::from_engine(engine).dispatch(platform.cost.dispatch);

        let r1 = session
            .clone()
            .start()
            .run_stream(&ds.samples[..STREAM_REQUESTS], deploy);
        let rn = session
            .replicas(n)
            .policy(Policy::PlanAffinity)
            .start()
            .run_stream_concurrent(&ds.samples[..STREAM_REQUESTS], deploy, n * 2);

        let mut st = Table::new(
            "Sharded streaming serve (RECAD_WORKERS replicas)",
            &["Replicas", "Policy", "TPS", "p99 latency", "speedup"],
        );
        st.row(&[
            "1".into(),
            r1.policy.into(),
            format!("{:.1}/s", r1.tps),
            fmt_dur(r1.p99_latency.as_secs_f64()),
            "1.00x".into(),
        ]);
        st.row(&[
            format!("{n}"),
            rn.policy.into(),
            format!("{:.1}/s", rn.tps),
            fmt_dur(rn.p99_latency.as_secs_f64()),
            format!("{:.2}x", rn.tps / r1.tps),
        ]);
        st.print();
    }
}
