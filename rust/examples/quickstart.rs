//! Quickstart: the 60-second tour of the Rec-AD public API.
//!
//! 1. Plan a TT compression for an embedding table and look rows up.
//! 2. Build the index bijection from a workload sample.
//! 3. Train a tiny FDIA detector on synthetic IEEE-118 data.
//!
//! Run: `cargo run --release --example quickstart`

use recad::coordinator::engine::EngineCfg;
use recad::coordinator::trainer::train_ieee118;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::reorder::bijection::IndexBijection;
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::bench::fmt_bytes;
use recad::util::prng::Rng;

fn main() {
    // ---- 1. Eff-TT table: compress 1M x 16 to three small cores --------
    let shapes = TtShapes::plan(1_000_000, 16, 8);
    println!(
        "TT plan for 1M x 16: m={:?} n={:?} rank={} — {} vs plain {} ({:.1}x)",
        shapes.m,
        shapes.n,
        shapes.rank,
        fmt_bytes(shapes.tt_bytes()),
        fmt_bytes(shapes.plain_bytes()),
        shapes.compression_ratio()
    );
    let mut rng = Rng::new(42);
    let mut table = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    let mut scratch = TtScratch::default();

    // nn.EmbeddingBag(sum) contract: indices + offsets -> pooled rows
    let indices = [7u64, 9, 7, 123_456, 7];
    let offsets = [0usize, 3, 5]; // two bags
    let mut pooled = vec![0.0f32; 2 * 16];
    table.embedding_bag(&indices, &offsets, &mut pooled, &mut scratch);
    println!(
        "pooled 2 bags; reuse buffer saved {} of {} first-hop GEMMs",
        table.stats.reuse_hits,
        table.stats.reuse_hits + table.stats.prefix_gemms
    );

    // ---- 2. index bijection from a workload sample ----------------------
    let sample_batches: Vec<Vec<u64>> = (0..20)
        .map(|i| (0..32).map(|k| ((i * 13 + k * 7) % 500) as u64).collect())
        .collect();
    let refs: Vec<&[u64]> = sample_batches.iter().map(|b| b.as_slice()).collect();
    let bij = IndexBijection::build(1_000_000, &refs, 0.1);
    println!(
        "bijection: {} hot ids, {} communities (modularity {:.3})",
        bij.n_hot, bij.n_communities, bij.modularity
    );

    // ---- 3. train a small detector --------------------------------------
    let ds = generate(&DatasetCfg {
        n_normal: 1200,
        n_attack: 300,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 7,
    });
    let cfg = EngineCfg::ieee118(1.0 / 2000.0);
    let (report, _) = train_ieee118(cfg, &ds, 2, 64, 1);
    println!(
        "trained {} steps: accuracy {:.1}%, recall {:.1}%, F1 {:.1}%",
        report.steps,
        report.eval.accuracy * 100.0,
        report.eval.recall * 100.0,
        report.eval.f1 * 100.0
    );
}
