//! Streaming detection service demo (paper §V-M / Table VI): train a
//! detector, then drive the redesigned serving stack three ways —
//! closed-loop batch-1 (the Table VI row), plan-affinity sharded
//! serving, and an open-loop Poisson stream whose latency percentiles
//! ARE the attack window under load.
//!
//! Run: `cargo run --release --example streaming_serve`

use std::time::Duration;

use recad::coordinator::engine::EngineCfg;
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer::train_ieee118;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::serve::{run_open_loop, OpenLoopCfg, Policy, ServeSession};
use recad::util::bench::{fmt_bytes, fmt_dur};

const SCALE: f64 = 1.0 / 2000.0;

fn main() {
    let ds = generate(&DatasetCfg {
        n_normal: 3000,
        n_attack: 750,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 100,
        noise_std: 0.005,
        seed: 11,
    });

    println!("training detector…");
    let (report, engine) = train_ieee118(EngineCfg::ieee118(SCALE), &ds, 2, 64, 5);
    println!(
        "detector ready: accuracy {:.1}% / recall {:.1}%",
        report.eval.accuracy * 100.0,
        report.eval.recall * 100.0
    );
    let model_bytes = engine.model_bytes();

    // Table VI scenario: batch size 1, RTX-2060-class edge box.
    let platform = SimPlatform::rtx2060();
    let session = ServeSession::from_engine(engine).dispatch(platform.cost.dispatch);
    let stream = &ds.samples[..1000];

    println!("serving {} requests (batch size 1, closed loop)…", stream.len());
    let sr = session.clone().start().run_stream(stream, model_bytes);
    println!("\n=== Table VI row (streaming real-time detection) ===");
    println!("  requests served      : {} (lifetime {})", sr.served, sr.lifetime_served);
    println!("  throughput           : {:.1} samples/s", sr.tps);
    println!("  mean latency         : {}", fmt_dur(sr.mean_latency.as_secs_f64()));
    println!("  p99 latency          : {}", fmt_dur(sr.p99_latency.as_secs_f64()));
    println!("  model deployment size: {}", fmt_bytes(sr.model_bytes));

    // Plan-driven shard routing: requests hash through the planner's
    // bijection + TT-prefix map, so hot rows stay on warm replicas.
    let sharded = session
        .clone()
        .replicas(3)
        .policy(Policy::PlanAffinity)
        .start()
        .run_stream_concurrent(stream, model_bytes, 6);
    println!(
        "\nsharded [{} x{} replicas]: {:.1} TPS, p99 {}",
        sharded.policy,
        sharded.replicas,
        sharded.tps,
        fmt_dur(sharded.p99_latency.as_secs_f64())
    );

    // Open loop: Poisson arrivals measure what closed-loop clients
    // can't — the queueing share of the attack window.
    let rate = (sr.tps * 0.8).max(100.0);
    let ol = run_open_loop(
        session.replicas(2).policy(Policy::LeastQueued).start(),
        &ds.samples[..600],
        &OpenLoopCfg { rate_per_sec: rate, seed: 23 },
    );
    println!(
        "\nopen loop [{}]: offered {:.0}/s, achieved {:.0}/s over {} requests",
        ol.policy, ol.offered_rate, ol.achieved_rate, ol.served
    );
    println!(
        "attack window p50 {} / p99 {} (queue-delay p99 {}, service p99 {})",
        fmt_dur(ol.p50_window.as_secs_f64()),
        fmt_dur(ol.p99_window.as_secs_f64()),
        fmt_dur(ol.p99_queue_delay.as_secs_f64()),
        fmt_dur(ol.p99_service.as_secs_f64()),
    );

    // attack-window narrative from the intro: detection latency bounds
    // the attacker's undetected window
    let window = ol.p99_window + Duration::from_millis(1);
    println!(
        "\nattack window (p99 + ingest): {} — vs a 30 s dispatch cycle, \
         the attacker loses {:.0}x of their window",
        fmt_dur(window.as_secs_f64()),
        30.0 / window.as_secs_f64()
    );
}
