//! Streaming detection service demo (paper §V-M / Table VI): train a
//! detector, then serve a batch-1 closed-loop request stream and report
//! latency / TPS / memory — the edge-deployment scenario.
//!
//! Run: `cargo run --release --example streaming_serve`

use std::time::Duration;

use recad::coordinator::engine::EngineCfg;
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer::train_ieee118;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::serve::{Detector, StreamingServer};
use recad::util::bench::{fmt_bytes, fmt_dur};

const SCALE: f64 = 1.0 / 2000.0;

fn main() {
    let ds = generate(&DatasetCfg {
        n_normal: 3000,
        n_attack: 750,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 100,
        noise_std: 0.005,
        seed: 11,
    });

    println!("training detector…");
    let (report, engine) = train_ieee118(EngineCfg::ieee118(SCALE), &ds, 2, 64, 5);
    println!(
        "detector ready: accuracy {:.1}% / recall {:.1}%",
        report.eval.accuracy * 100.0,
        report.eval.recall * 100.0
    );
    let model_bytes = engine.model_bytes();

    // Table VI scenario: batch size 1, RTX-2060-class edge box.
    let platform = SimPlatform::rtx2060();
    let det = Detector::new(engine, 0.5);
    let server = StreamingServer::start(det, 1, platform.cost.dispatch);
    let stream = &ds.samples[..1000];
    println!("serving {} requests (batch size 1, closed loop)…", stream.len());
    let sr = server.run_stream(stream, model_bytes);

    println!("\n=== Table VI row (streaming real-time detection) ===");
    println!("  requests served      : {}", sr.served);
    println!("  throughput           : {:.1} samples/s", sr.tps);
    println!("  mean latency         : {}", fmt_dur(sr.mean_latency.as_secs_f64()));
    println!("  p99 latency          : {}", fmt_dur(sr.p99_latency.as_secs_f64()));
    println!("  model deployment size: {}", fmt_bytes(sr.model_bytes));

    // attack-window narrative from the intro: detection latency bounds
    // the attacker's undetected window
    let window = sr.p99_latency + Duration::from_millis(1);
    println!(
        "\nattack window (p99 + ingest): {} — vs a 30 s dispatch cycle, \
         the attacker loses {:.0}x of their window",
        fmt_dur(window.as_secs_f64()),
        30.0 / window.as_secs_f64()
    );
}
