//! Large-embedding-table walkthrough (paper §V-I / Fig. 13 premise): a
//! 40M-row × 128-dim table (~19 GB uncompressed) cannot fit a 16 GB V100,
//! forcing the baselines into model-parallel sharding — while Eff-TT
//! compresses it onto ONE device.  This example shows the footprint
//! arithmetic at full scale and exercises a scaled-down instantiation of
//! the same shape end to end.
//!
//! Run: `cargo run --release --example large_table`

use recad::baselines::multi_gpu::{
    dlrm_model_parallel_step, hugectr_step, recad_step, throughput, torchrec_step,
    MultiGpuWorkload,
};
use recad::coordinator::platform::SimPlatform;
use recad::data::ctr::Batch;
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::bench::fmt_bytes;
use recad::util::prng::Rng;
use std::time::Instant;

fn main() {
    // ---- full-scale footprint arithmetic (the Fig. 13 premise) ----------
    let full = TtShapes::plan(40_000_000, 128, 32);
    let platform = SimPlatform::v100(4);
    println!("=== 40M x 128 table (paper §V-I) ===");
    println!("  plain size : {}", fmt_bytes(full.plain_bytes()));
    println!("  Eff-TT size: {} (rank {})", fmt_bytes(full.tt_bytes()), full.rank);
    println!(
        "  fits one {} ({}): plain={}, Eff-TT={}",
        platform.name,
        fmt_bytes(platform.hbm_bytes),
        platform.fits_hbm(full.plain_bytes()),
        platform.fits_hbm(full.tt_bytes()),
    );
    assert!(!platform.fits_hbm(full.plain_bytes()));
    assert!(platform.fits_hbm(full.tt_bytes()));

    // ---- scaled instantiation: same shape, 1/100 rows --------------------
    println!("\n=== scaled instantiation (400k rows, dim 128) ===");
    let shapes = TtShapes::plan(400_000, 128, 16);
    let mut rng = Rng::new(1);
    let mut table = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    let mut scratch = TtScratch::default();
    let batch: Vec<u64> = (0..4096).map(|_| rng.below(400_000)).collect();
    let offsets: Vec<usize> = (0..=4096).collect();
    let mut out = vec![0.0f32; 4096 * 128];
    let t0 = Instant::now();
    table.embedding_bag(&batch, &offsets, &mut out, &mut scratch);
    let lookup_time = t0.elapsed();
    println!(
        "  batch-4096 lookup: {:?} ({} reuse hits / {} prefixes)",
        lookup_time, table.stats.reuse_hits, table.stats.prefix_gemms
    );

    // ---- multi-GPU throughput model (Fig. 13 shape) -----------------------
    println!("\n=== Fig. 13: throughput vs HugeCTR / TorchRec (modeled 4x V100) ===");
    let w = MultiGpuWorkload {
        compute: lookup_time * 3, // fwd + bwd ≈ 3x the lookup on this table
        batch_size: 4096,
        n_sparse: 1,
        emb_dim: 128,
        dp_grad_bytes: shapes.tt_bytes(),
    };
    let c = platform.cost;
    for n in [1usize, 2, 4] {
        let r = throughput(&w, recad_step(&w, &c, n), n);
        let h = throughput(&w, hugectr_step(&w, &c, n), n);
        let t = throughput(&w, torchrec_step(&w, &c, n), n);
        let d = throughput(&w, dlrm_model_parallel_step(&w, &c, n), n);
        println!(
            "  {n} GPU: Rec-AD {:>9.0}/s  HugeCTR {:>9.0}/s  TorchRec {:>9.0}/s  DLRM-MP {:>9.0}/s \
             (Rec-AD = {:.2}x HugeCTR, {:.2}x TorchRec)",
            r, h, t, d, r / h, r / t
        );
    }
    let _ = &mut Batch { dense: vec![], sparse: vec![], labels: vec![], batch_size: 0 };
}
