//! End-to-end FDIA detection driver (the repo's E2E validation run —
//! recorded in EXPERIMENTS.md).
//!
//! Reproduces the paper's core workflow on a real small workload:
//!  1. synthesize the IEEE-118 SCADA stream (power flow + stealthy FDIA),
//!  2. show the classical residual BDD misses stealthy attacks,
//!  3. train the Rec-AD detector (Eff-TT DLRM) for a few epochs, logging
//!     the loss curve,
//!  4. evaluate Accuracy/Recall/F1 on held-out data (Table III row),
//!  5. run the SAME model through the PJRT artifact path when artifacts
//!     are present (proving the three layers compose).
//!
//! Run: `cargo run --release --example fdia_detection`

use recad::coordinator::engine::EngineCfg;
use recad::coordinator::trainer::{evaluate_on, train_ieee118};
use recad::powersys::attack::AttackKind;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::runtime::{Artifacts, DlrmTrainStep};
use recad::util::bench::fmt_dur;
use recad::util::prng::Rng;

const SCALE: f64 = 1.0 / 2000.0;

fn main() {
    // ---- 1. dataset ------------------------------------------------------
    println!("=== IEEE-118 FDIA dataset (paper Table II shape) ===");
    let ds = generate(&DatasetCfg {
        n_normal: 5000,
        n_attack: 1200,
        vocab: SparseVocab::ieee118(SCALE),
        n_profiles: 120,
        noise_std: 0.005,
        seed: 0x5EED,
    });
    println!("samples: {} ({} attacked)", ds.samples.len(), 1200);

    // ---- 2. classical BDD baseline ---------------------------------------
    // dense[4] is the (normalized) residual norm; threshold at the clean
    // 99th percentile equivalent — stealthy attacks must slip through.
    let clean: Vec<f32> = ds
        .samples
        .iter()
        .filter(|s| s.label < 0.5)
        .map(|s| s.dense[4])
        .collect();
    let mut sorted = clean.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tau = sorted[(sorted.len() as f64 * 0.99) as usize];
    let mut caught = [0usize; 3];
    let mut total = [0usize; 3];
    for s in &ds.samples {
        if let Some(kind) = s.attack_kind {
            let k = match kind {
                AttackKind::Stealthy => 0,
                AttackKind::Scaling => 1,
                AttackKind::Random => 2,
            };
            total[k] += 1;
            if s.dense[4] > tau {
                caught[k] += 1;
            }
        }
    }
    println!("classical residual BDD recall by attack type:");
    for (name, k) in [("stealthy", 0), ("scaling", 1), ("random", 2)] {
        println!(
            "  {name:<9} {:>5.1}%  ({}/{})",
            100.0 * caught[k] as f64 / total[k].max(1) as f64,
            caught[k],
            total[k]
        );
    }

    // ---- 3. train Rec-AD --------------------------------------------------
    println!("\n=== training Rec-AD detector (Eff-TT DLRM) ===");
    let cfg = EngineCfg::ieee118(SCALE);
    let (report, mut engine) = train_ieee118(cfg, &ds, 3, 64, 1);
    println!(
        "{} steps in {} ({:.0} samples/s)",
        report.steps,
        fmt_dur(report.wall.as_secs_f64()),
        report.samples_per_sec
    );
    println!("loss curve:");
    let stride = (report.loss_curve.len() / 12).max(1);
    for (i, l) in report.loss_curve.iter().step_by(stride).enumerate() {
        let bar = "#".repeat((l * 60.0).min(60.0) as usize);
        println!("  step {:>4}  {l:.4}  {bar}", i * stride);
    }

    // ---- 4. evaluation (Table III) ----------------------------------------
    println!("\n=== held-out evaluation (paper Table III: Rec-AD 97.5/96.2/96.3) ===");
    let eval = evaluate_on(&mut engine, ds.split(0.8).1);
    println!(
        "accuracy {:.1}%  recall {:.1}%  precision {:.1}%  F1 {:.1}%",
        eval.accuracy * 100.0,
        eval.recall * 100.0,
        eval.precision * 100.0,
        eval.f1 * 100.0
    );

    // ---- 5. PJRT artifact path (L1+L2+L3 composed) -------------------------
    match Artifacts::load("artifacts") {
        Ok(arts) => {
            println!("\n=== PJRT artifact path (jax-lowered train step) ===");
            let m = arts.meta.clone();
            let mut rng = Rng::new(3);
            let mut step = DlrmTrainStep::new(&arts).expect("executor");
            let mut last = 0.0;
            for i in 0..5 {
                // batches straight from the dataset, padded to train_batch
                let mut dense = vec![0f32; m.train_batch * m.dense_dim];
                let mut idx = vec![0i32; m.train_batch * m.num_tables];
                let mut labels = vec![0f32; m.train_batch];
                for b in 0..m.train_batch {
                    let s = &ds.samples[(i * m.train_batch + b) % ds.samples.len()];
                    dense[b * m.dense_dim..(b + 1) * m.dense_dim]
                        .copy_from_slice(&s.dense);
                    for (t, &ix) in s.sparse.iter().enumerate() {
                        idx[b * m.num_tables + t] = (ix % m.table_rows[t]) as i32;
                    }
                    labels[b] = s.label;
                }
                let _ = &mut rng;
                last = step.step(&dense, &idx, &labels).expect("step");
                println!("  pjrt step {i}: loss {last:.4}");
            }
            assert!(last.is_finite());
            println!("three-layer composition OK (rust -> PJRT -> pallas HLO)");
        }
        Err(e) => {
            println!("\n(skipping PJRT path: {e}; run `make artifacts` first)");
        }
    }
}
