//! Multi-node serving probe: open-loop p99 attack window vs node count
//! over loopback TCP — the ISSUE 9 acceptance measurement — plus a
//! node-kill recovery arm (kill one of three nodes mid-run, respawn it
//! at a fresh port, assert zero dropped requests).
//!
//! Writes `BENCH_multinode.json` in `perf_probe`'s schema; arm extras
//! carry the ring/recovery accounting (`dropped`, `evictions`,
//! `rejoins`, `ring_epoch`).  `RECAD_SMOKE=1` shrinks the workload for
//! the CI smoke job.

use std::cell::RefCell;
use std::time::Duration;

use recad::access::AccessPlanner;
use recad::bench_support::{bench_workers, write_bench_json, BenchArm};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::net::{run_open_loop_net, NetClient, NetLoopReport, NodeServer};
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::runtime::FaultCfg;
use recad::serve::{OpenLoopCfg, ServeSession};
use recad::util::prng::Rng;

fn smoke() -> bool {
    std::env::var("RECAD_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn arm_from_report(name: String, nl: &NetLoopReport) -> BenchArm {
    let r = &nl.report;
    BenchArm {
        name,
        workers: nl.nodes,
        throughput: r.achieved_rate,
        p50_us: r.p50_window.as_secs_f64() * 1e6,
        p99_us: r.p99_window.as_secs_f64() * 1e6,
        n: r.served as usize,
        extra: Vec::new(),
    }
    .with_extra("dropped", r.dropped as f64)
    .with_extra("shed", r.shed as f64)
    .with_extra("evictions", nl.evictions as f64)
    .with_extra("rejoins", nl.rejoins as f64)
    .with_extra("ring_epoch", nl.ring_epoch as f64)
}

fn main() {
    let (requests, rate) = if smoke() { (160usize, 4000.0) } else { (600, 6000.0) };
    let ds = generate(&DatasetCfg {
        n_normal: requests,
        n_attack: requests / 4,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 10,
        noise_std: 0.005,
        seed: 2,
    });
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    let ecfg = EngineCfg::ieee118(1.0 / 2000.0);
    let engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(1));
    let affinity = AccessPlanner::for_engine_cfg(&ecfg).affinity_map();
    let base = ServeSession::from_engine(engine);
    let mut arms: Vec<BenchArm> = Vec::new();

    // ---- open-loop p99 attack window vs node count ---------------------
    for n in 1..=3usize {
        let nodes: Vec<NodeServer> = (0..n)
            .map(|i| {
                NodeServer::spawn(i as u64, 0, base.clone(), "127.0.0.1:0", None)
                    .expect("node spawn")
            })
            .collect();
        let addrs: Vec<String> = nodes.iter().map(|nd| nd.addr().to_string()).collect();
        let mut client = NetClient::connect(affinity.clone(), &addrs, 64, 128)
            .expect("router connect")
            .timeouts(Duration::from_millis(10), Duration::from_millis(250));
        let nl = run_open_loop_net(
            &mut client,
            stream,
            &OpenLoopCfg { rate_per_sec: rate, seed: 3 },
            None,
        );
        client.close();
        for nd in nodes {
            nd.shutdown();
        }
        let r = &nl.report;
        println!(
            "nodes_{n}: {}/{} served at {:.0}/s, window p50 {:.0} us / p99 {:.0} us \
             ({} dropped, {} shed)",
            r.served,
            r.offered,
            r.achieved_rate,
            r.p50_window.as_secs_f64() * 1e6,
            r.p99_window.as_secs_f64() * 1e6,
            r.dropped,
            r.shed,
        );
        assert_eq!(r.dropped, 0, "nodes_{n}: healthy run dropped requests");
        arms.push(arm_from_report(format!("nodes_{n}"), &nl));
    }

    // ---- node-kill recovery arm ----------------------------------------
    // Three nodes share a chaos plan that kills node 1 mid-stream (the
    // seeded verdict fires at generation 0 only); the router evicts it,
    // requeues its in-flight work onto the survivors, and the respawn
    // callback brings a generation-1 replacement up at a NEW port.
    let plan = FaultCfg {
        enabled: true,
        seed: 7,
        kill_node: Some(1),
        node_kill_after: if smoke() { 5 } else { 20 },
        ..FaultCfg::default()
    }
    .plan()
    .expect("enabled cfg builds a plan");
    let spawned: RefCell<Vec<NodeServer>> = RefCell::new(Vec::new());
    for i in 0..3u64 {
        let nd = NodeServer::spawn(i, 0, base.clone(), "127.0.0.1:0", Some(plan.clone()))
            .expect("node spawn");
        spawned.borrow_mut().push(nd);
    }
    let addrs: Vec<String> =
        spawned.borrow().iter().map(|nd| nd.addr().to_string()).collect();
    let mut client = NetClient::connect(affinity.clone(), &addrs, 64, 128)
        .expect("router connect")
        .timeouts(Duration::from_millis(10), Duration::from_millis(250));
    let mut respawn = |slot: usize| -> Option<String> {
        let nd = NodeServer::spawn(slot as u64, 1, base.clone(), "127.0.0.1:0", None).ok()?;
        let addr = nd.addr().to_string();
        spawned.borrow_mut().push(nd);
        Some(addr)
    };
    let nl = run_open_loop_net(
        &mut client,
        stream,
        &OpenLoopCfg { rate_per_sec: rate, seed: 3 },
        Some(&mut respawn),
    );
    client.close();
    for nd in spawned.into_inner() {
        nd.shutdown();
    }
    let r = &nl.report;
    println!(
        "node_kill_recovery: {}/{} served, {} dropped, {} eviction(s), {} rejoin(s), \
         ring epoch {}, post-recovery tail p99 {:.0} us",
        r.served,
        r.offered,
        r.dropped,
        nl.evictions,
        nl.rejoins,
        nl.ring_epoch,
        r.tail_p99_window.as_secs_f64() * 1e6,
    );
    assert_eq!(r.dropped, 0, "node kill dropped requests");
    assert!(nl.evictions >= 1, "router never evicted the killed node");
    assert!(nl.rejoins >= 1, "respawned node never rejoined the ring");
    assert!(plan.event_count("node_kill") >= 1, "node-kill fault never fired");
    arms.push(arm_from_report("node_kill_recovery".into(), &nl));

    let path = write_bench_json("multinode", bench_workers(), &arms);
    println!("wrote {path}");
}
