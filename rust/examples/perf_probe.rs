//! L3 perf probe: Eff-TT fwd+bwd at serving-relevant shapes, the engine
//! train-step arm (exec workers = 1 vs N), and the access-layer ingest
//! arm (planned-prefetch vs unplanned inline).
//!
//! Emits a machine-readable `BENCH_perf_probe.json` (throughput, p50/p99
//! per-iteration latency, workers arm; schema shared with
//! `BENCH_table3.json` / `BENCH_fig12.json`) so the perf trajectory can
//! be tracked across PRs.  Run: `cargo run --release --example perf_probe`
//! (`RECAD_WORKERS=N` overrides the parallel arm width; `RECAD_SMOKE=1`
//! shrinks the workload to CI-smoke size).  The JSON is re-parsed after
//! writing — malformed output fails the run, which is what the CI smoke
//! job asserts.

use std::time::Instant;

use recad::access::{replay_fill, run_prefetched_fill, AccessPlanner};
use recad::bench_support::{bench_workers, write_bench_json, BenchArm};
use recad::coordinator::engine::NativeDlrm;
use recad::data::batcher::EpochIter;
use recad::exec::ExecCfg;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::prng::Rng;

fn smoke() -> bool {
    std::env::var("RECAD_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Time `f` for `reps` iterations x `rounds` rounds; per-iter seconds.
fn time_iters(mut f: impl FnMut(), reps: usize, rounds: usize) -> Vec<f64> {
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / reps as f64);
    }
    samples
}

fn tt_arm(rows: u64, rank: usize, batch: usize, workers: usize) -> (BenchArm, BenchArm) {
    let shapes = TtShapes::plan(rows, 16, rank);
    let mut rng = Rng::new(1);
    let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    t.set_pool(recad::exec::ExecPool::new(ExecCfg::with_workers(workers)));
    let zipf = recad::data::zipf::Zipf::new(rows, 1.2);
    let idx: Vec<u64> = (0..batch).map(|_| zipf.sample(&mut rng)).collect();
    let offsets: Vec<usize> = (0..=batch).collect();
    let mut out = vec![0.0f32; batch * 16];
    let g = vec![0.05f32; batch * 16];
    let mut scratch = TtScratch::default();
    // warmup
    t.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
    t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch);

    let (reps, rounds) = if smoke() { (2, 2) } else { (20, 5) };
    let fwd =
        time_iters(|| t.embedding_bag(&idx, &offsets, &mut out, &mut scratch), reps, rounds);
    let bwd = time_iters(|| t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch), reps, rounds);
    let mk = |tag: &str, iters: &[f64]| {
        BenchArm::from_iters(
            format!("tt_{tag}_rows{rows}_rank{rank}_batch{batch}"),
            workers,
            iters,
            batch,
        )
    };
    (mk("fwd", &fwd), mk("bwd", &bwd))
}

fn ieee118_batches(batch: usize, n: usize) -> Vec<recad::data::ctr::Batch> {
    let scale = 1.0 / 2000.0;
    let (n_normal, n_attack) = if smoke() { (600, 150) } else { (3000, 750) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(scale),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 7,
    });
    let mut rng = Rng::new(9);
    EpochIter::new(&ds.samples, batch, &mut rng).take(n).collect()
}

fn engine_cfg(workers: usize) -> recad::coordinator::engine::EngineCfg {
    let mut cfg = recad::coordinator::engine::EngineCfg::ieee118(1.0 / 2000.0);
    cfg.exec = ExecCfg::with_workers(workers);
    cfg
}

fn engine_arm(workers: usize) -> BenchArm {
    let (batch, n_batches, rounds) = if smoke() { (64, 3, 2) } else { (512, 6, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let mut engine = NativeDlrm::new(engine_cfg(workers), &mut Rng::new(1));
    engine.train_step(&batches[0]); // warmup
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        for b in &batches {
            engine.train_step(b);
        }
        // per-step latency so every arm shares per-iteration units
        samples.push(t0.elapsed().as_secs_f64() / steps);
    }
    BenchArm::from_iters(format!("engine_train_step_batch{batch}"), workers, &samples, per_step)
}

/// Access-layer arm: full-epoch training throughput with ingest either
/// inline-unplanned (legacy wrappers: plan built on the training thread)
/// or prefetch-planned (`plan_ahead = 2`: assembled + planned on the
/// ingest worker, plan shared by fwd+bwd).  Identical math both ways —
/// the acceptance gate is planned >= unplanned throughput.
fn ingest_arm(planned: bool) -> BenchArm {
    let (batch, n_batches, rounds) = if smoke() { (64, 4, 2) } else { (256, 16, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let cfg = engine_cfg(1);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    engine.train_step(&batches[0]); // warmup
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        if planned {
            run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                engine.train_step_planned(b, p);
            });
        } else {
            for b in &batches {
                engine.train_step(b);
            }
        }
        // per-step latency so every arm shares per-iteration units
        samples.push(t0.elapsed().as_secs_f64() / steps);
    }
    let tag = if planned { "planned" } else { "unplanned" };
    BenchArm::from_iters(format!("ingest_{tag}_batch{batch}x{n_batches}"), 1, &samples, per_step)
}

fn main() {
    let par = bench_workers();
    let worker_arms: Vec<usize> = if par > 1 { vec![1, par] } else { vec![1] };
    let mut arms: Vec<BenchArm> = Vec::new();

    let tt_shapes: &[(u64, usize, usize)] = if smoke() {
        &[(10_000, 8, 512)]
    } else {
        &[(100_000, 8, 4096), (100_000, 16, 4096), (1_000_000, 16, 4096)]
    };
    for &w in &worker_arms {
        for &(rows, rank, batch) in tt_shapes {
            let (f, b) = tt_arm(rows, rank, batch, w);
            println!(
                "workers={w} rows={rows:>8} rank={rank:>2} batch={batch}: \
                 fwd {:.0}µs ({:.1} Mlookup/s)  bwd {:.0}µs",
                f.p50_us,
                f.throughput / 1e6,
                b.p50_us
            );
            arms.push(f);
            arms.push(b);
        }
        let e = engine_arm(w);
        println!(
            "workers={w} engine train_step: {:.0} samples/s (p50 {:.0}µs per step)",
            e.throughput, e.p50_us
        );
        arms.push(e);
    }

    // speedup headline: engine arm parallel vs serial
    if worker_arms.len() > 1 {
        let t1 = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == 1)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        let tn = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == par)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        if t1 > 0.0 {
            println!("engine speedup workers={par} vs 1: {:.2}x", tn / t1);
        }
    }

    // access-layer arm: planned prefetch ingest vs unplanned inline
    let unplanned = ingest_arm(false);
    let planned = ingest_arm(true);
    println!(
        "ingest unplanned {:.0} samples/s | planned(prefetch=2) {:.0} samples/s ({:.2}x)",
        unplanned.throughput,
        planned.throughput,
        planned.throughput / unplanned.throughput
    );
    arms.push(unplanned);
    arms.push(planned);

    let path = write_bench_json("perf_probe", par, &arms);
    println!("wrote {path} ({} arms, JSON round-trip checked)", arms.len());
}
