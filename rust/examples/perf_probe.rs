//! L3 perf probe: Eff-TT fwd+bwd at serving-relevant shapes, the engine
//! train-step arm (exec workers = 1 vs N), and the access-layer ingest
//! arm (planned-prefetch vs unplanned inline).
//!
//! Emits a machine-readable `BENCH_perf_probe.json` (throughput, p50/p99
//! per-iteration latency, workers arm; schema shared with
//! `BENCH_table3.json` / `BENCH_fig12.json`) so the perf trajectory can
//! be tracked across PRs.  Run: `cargo run --release --example perf_probe`
//! (`RECAD_WORKERS=N` overrides the parallel arm width; `RECAD_SMOKE=1`
//! shrinks the workload to CI-smoke size).  The JSON is re-parsed after
//! writing — malformed output fails the run, which is what the CI smoke
//! job asserts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use recad::access::{replay_fill, run_prefetched_fill, AccessCfg, AccessPlanner, BatchPlan};
use recad::bench_support::{arm_extra, bench_workers, write_bench_json, BenchArm};
use recad::runtime::{AutotuneCfg, FaultCfg, FaultPlan};
use recad::util::clock::Ewma;
use recad::coordinator::data_parallel::{
    train_data_parallel_faulted, train_data_parallel_placed, DpCfg, Placement,
};
use recad::coordinator::engine::{EngineCfg, NativeDlrm};
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer::train_ieee118_full;
use recad::serve::{run_open_loop, OpenLoopCfg, Policy, ServeSession};
use recad::data::batcher::EpochIter;
use recad::data::ctr::Batch;
use recad::data::zipf::{GradualDriftZipf, GrowingVocabZipf, Zipf};
use recad::exec::ExecCfg;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, QuantizeMode, TtScratch};
use recad::util::prng::Rng;

fn smoke() -> bool {
    std::env::var("RECAD_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Time `f` for `reps` iterations x `rounds` rounds; per-iter seconds.
fn time_iters(mut f: impl FnMut(), reps: usize, rounds: usize) -> Vec<f64> {
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / reps as f64);
    }
    samples
}

fn tt_arm(rows: u64, rank: usize, batch: usize, workers: usize) -> (BenchArm, BenchArm) {
    let shapes = TtShapes::plan(rows, 16, rank);
    let mut rng = Rng::new(1);
    let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    t.set_pool(recad::exec::ExecPool::new(ExecCfg::with_workers(workers)));
    let zipf = recad::data::zipf::Zipf::new(rows, 1.2);
    let idx: Vec<u64> = (0..batch).map(|_| zipf.sample(&mut rng)).collect();
    let offsets: Vec<usize> = (0..=batch).collect();
    let mut out = vec![0.0f32; batch * 16];
    let g = vec![0.05f32; batch * 16];
    let mut scratch = TtScratch::default();
    // warmup
    t.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
    t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch);

    let (reps, rounds) = if smoke() { (2, 2) } else { (20, 5) };
    let fwd =
        time_iters(|| t.embedding_bag(&idx, &offsets, &mut out, &mut scratch), reps, rounds);
    let bwd = time_iters(|| t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch), reps, rounds);
    let mk = |tag: &str, iters: &[f64]| {
        BenchArm::from_iters(
            format!("tt_{tag}_rows{rows}_rank{rank}_batch{batch}"),
            workers,
            iters,
            batch,
        )
    };
    (mk("fwd", &fwd), mk("bwd", &bwd))
}

fn ieee118_batches(batch: usize, n: usize) -> Vec<recad::data::ctr::Batch> {
    let scale = 1.0 / 2000.0;
    let (n_normal, n_attack) = if smoke() { (600, 150) } else { (3000, 750) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(scale),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 7,
    });
    let mut rng = Rng::new(9);
    EpochIter::new(&ds.samples, batch, &mut rng).take(n).collect()
}

fn engine_cfg(workers: usize) -> recad::coordinator::engine::EngineCfg {
    let mut cfg = recad::coordinator::engine::EngineCfg::ieee118(1.0 / 2000.0);
    cfg.exec = ExecCfg::with_workers(workers);
    cfg
}

fn engine_arm(workers: usize) -> BenchArm {
    let (batch, n_batches, rounds) = if smoke() { (64, 3, 2) } else { (512, 6, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let mut engine = NativeDlrm::new(engine_cfg(workers), &mut Rng::new(1));
    engine.train_step(&batches[0]); // warmup
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        for b in &batches {
            engine.train_step(b);
        }
        // per-step latency so every arm shares per-iteration units
        samples.push(t0.elapsed().as_secs_f64() / steps);
    }
    BenchArm::from_iters(format!("engine_train_step_batch{batch}"), workers, &samples, per_step)
}

/// Access-layer arm: full-epoch training throughput with ingest either
/// inline-unplanned (legacy wrappers: plan built on the training thread)
/// or prefetch-planned (`plan_ahead = 2`: assembled + planned on the
/// ingest worker, plan shared by fwd+bwd).  Identical math both ways —
/// the acceptance gate is planned >= unplanned throughput.
fn ingest_arm(planned: bool) -> BenchArm {
    let (batch, n_batches, rounds) = if smoke() { (64, 4, 2) } else { (256, 16, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let cfg = engine_cfg(1);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    // pin PR-2 planning semantics (no tiled layout) so this arm's
    // cross-PR trajectory keeps measuring what it always measured;
    // tiled-vs-planned lives in BENCH_cache_layout.json
    planner.set_layout_policy(0, false);
    engine.train_step(&batches[0]); // warmup
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        if planned {
            run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                engine.train_step_planned(b, p);
            });
        } else {
            for b in &batches {
                engine.train_step(b);
            }
        }
        // per-step latency so every arm shares per-iteration units
        samples.push(t0.elapsed().as_secs_f64() / steps);
    }
    let tag = if planned { "planned" } else { "unplanned" };
    BenchArm::from_iters(format!("ingest_{tag}_batch{batch}x{n_batches}"), 1, &samples, per_step)
}

/// Training-throughput arm at the IEEE-118 scale: ingest-planned
/// execution with the plan layout at `cache_kb` (0 = the PR-2 planned
/// baseline, >0 = hottest-first tiled).  Identical math either way — the
/// acceptance gate is tiled ≥ planned throughput.
fn cache_layout_train_arm(cache_kb: usize, tag: &str) -> BenchArm {
    let (batch, n_batches, rounds) = if smoke() { (64, 4, 2) } else { (256, 16, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let cfg = engine_cfg(1);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.set_layout_policy(cache_kb, false);
    engine.train_step(&batches[0]); // warmup
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
            engine.train_step_planned(b, p);
        });
        samples.push(t0.elapsed().as_secs_f64() / steps);
    }
    BenchArm::from_iters(format!("train_{tag}_ieee118_batch{batch}"), 1, &samples, per_step)
}

/// Planning-throughput arm on a shared-vocabulary workload: three sparse
/// features drawing from ONE id space (plus a small host slot), planned
/// per-slot vs through the fused cross-table sweep.
fn fused_plan_arm(fuse: bool) -> BenchArm {
    let (vocab, b, n, rounds) = if smoke() {
        (4000u64, 128usize, 6usize, 2usize)
    } else {
        (60_000, 1024, 12, 4)
    };
    let mut tables = vec![(vocab, true); 3];
    tables.push((40, false));
    let cfg = EngineCfg {
        dense_dim: 2,
        emb_dim: 16,
        tables,
        tt_rank: 8,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let z = Zipf::new(vocab, 1.2);
    let mut rng = Rng::new(5);
    let batches: Vec<Batch> = (0..n)
        .map(|_| {
            let sparse: Vec<u64> = (0..b)
                .flat_map(|_| {
                    [z.sample(&mut rng), z.sample(&mut rng), z.sample(&mut rng), rng.below(40)]
                })
                .collect();
            Batch { dense: vec![0.0; b * 2], sparse, labels: vec![0.0; b], batch_size: b }
        })
        .collect();
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.set_layout_policy(256, fuse);
    let mut plan = BatchPlan::default();
    planner.plan_into(&batches[0], &mut plan); // warmup
    let mut samples = Vec::new();
    for _ in 0..rounds {
        let t0 = Instant::now();
        for batch in &batches {
            planner.plan_into(batch, &mut plan);
        }
        samples.push(t0.elapsed().as_secs_f64() / n as f64);
    }
    let tag = if fuse { "fused" } else { "unfused" };
    BenchArm::from_iters(format!("plan_{tag}_3x{vocab}v_batch{b}"), 1, &samples, b * 3)
}

/// The online-reorder recovery workload: a gradually drifting Zipf
/// stream (mixture interpolation) for the first half, vocabulary growth
/// for the second — both scenarios where only periodic refresh keeps the
/// bijection useful.  Built once so the sync and background arms replay
/// IDENTICAL batches.
fn drift_batches(vocab: u64, n: usize, b: usize) -> Vec<Batch> {
    let mut rng = Rng::new(11);
    let mut gd = GradualDriftZipf::new(vocab, 1.2, 7);
    gd.begin_drift(vocab / 2);
    let mut gv = GrowingVocabZipf::new(vocab, vocab / 3, 1.2, 9);
    (0..n)
        .map(|i| {
            let from_growth = i >= n / 2;
            if from_growth {
                gv.grow(vocab / n as u64);
            } else {
                gd.advance(2.0 / n as f64);
            }
            let sparse: Vec<u64> = (0..b)
                .flat_map(|_| {
                    let id = if from_growth { gv.sample(&mut rng) } else { gd.sample(&mut rng) };
                    [id, rng.below(40)]
                })
                .collect();
            Batch { dense: vec![0.0; b * 4], sparse, labels: vec![0.0; b], batch_size: b }
        })
        .collect()
}

/// Train over the drift workload with scheduled online reordering and
/// report the per-refresh ingest-thread stall samples.  `background`
/// arms vs the synchronous-compute twin are bit-identical in loss (the
/// caller asserts it); only the stall profile differs.
fn reorder_stall_arm(
    cfg: &EngineCfg,
    batches: &[Batch],
    refresh_every: usize,
    window: usize,
    background: bool,
) -> (BenchArm, Vec<f32>) {
    let access = AccessCfg {
        refresh_every,
        window,
        hot_ratio: 0.1,
        ..AccessCfg::default()
    };
    let mut planner = AccessPlanner::for_engine_cfg(cfg);
    planner.enable_scheduled_online(cfg, &access, background);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(3));
    let mut losses = Vec::new();
    run_prefetched_fill(replay_fill(batches), &mut planner, 0, |b, p| {
        losses.push(engine.train_step_planned(b, p));
    });
    let stalls = planner.reorder_stall_samples();
    assert!(
        !stalls.is_empty(),
        "no online refresh fired — the stall arm measured nothing"
    );
    let tag = if background { "background" } else { "sync" };
    let arm = BenchArm::from_iters(format!("reorder_stall_{tag}"), 1, &stalls, 1);
    (arm, losses)
}

/// Device-placement arms (BENCH_device_placement.json): real data-
/// parallel training, replicated vs plan-placed, at workers 1/2/4, on a
/// two-TT-table Zipf workload big enough that a shard touches a strict
/// subset of the TT cores.  Each arm reports throughput plus the total
/// logical all-reduce payload (`payload_bytes` extra key); the probe
/// asserts plan-placed payload strictly below replicated at workers ≥ 2
/// — the communication win plan-driven placement exists for.
fn placement_arms() -> Vec<BenchArm> {
    let (vocab, batch, n_batches, rounds) = if smoke() {
        (30_000u64, 64usize, 6usize, 2usize)
    } else {
        (200_000, 256, 12, 3)
    };
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(vocab, true), (vocab * 5 / 8, true), (118, false)],
        tt_rank: 8,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let z1 = Zipf::new(vocab, 1.2);
    let z2 = Zipf::new(vocab * 5 / 8, 1.2);
    let mut rng = Rng::new(23);
    let batches: Vec<Batch> = (0..n_batches)
        .map(|_| {
            let mut dense = vec![0.0f32; batch * 4];
            rng.fill_normal(&mut dense, 0.0, 1.0);
            let sparse: Vec<u64> = (0..batch)
                .flat_map(|_| [z1.sample(&mut rng), z2.sample(&mut rng), rng.below(118)])
                .collect();
            let labels: Vec<f32> =
                (0..batch).map(|_| if rng.coin(0.3) { 1.0 } else { 0.0 }).collect();
            Batch { dense, sparse, labels, batch_size: batch }
        })
        .collect();
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let cost = SimPlatform::v100(4).cost;
    let mut arms = Vec::new();
    // (arm tag, placement, quantized exchange): replicated + plan f32,
    // plus the int8 sparse exchange arm on top of plan placement
    let configs = [
        ("replicated", Placement::Replicated, false),
        ("plan", Placement::Plan, false),
        ("plan_q8", Placement::Plan, true),
    ];
    for (tag, placement, quantize_comm) in configs {
        for workers in [1usize, 2, 4] {
            let dp = DpCfg { workers, placement, cost, seed: 5, quantize_comm };
            let mut iters = Vec::new();
            let mut payload = 0u64;
            for _ in 0..rounds {
                let (r, _) =
                    train_data_parallel_placed(cfg.clone(), &planner, &batches, &dp);
                iters.push(r.wall.as_secs_f64() / r.steps as f64);
                payload = r.payload_bytes;
            }
            arms.push(
                BenchArm::from_iters(format!("dp_{tag}_w{workers}"), workers, &iters, batch)
                    .with_extra("payload_bytes", payload as f64),
            );
        }
    }
    let payload_of = |name: &str| arm_extra(&arms, name, "payload_bytes").unwrap_or(-1.0);
    for workers in [2usize, 4] {
        let rep = payload_of(&format!("dp_replicated_w{workers}"));
        let plan = payload_of(&format!("dp_plan_w{workers}"));
        let q8 = payload_of(&format!("dp_plan_q8_w{workers}"));
        assert!(
            plan > 0.0 && rep > 0.0 && plan < rep,
            "plan-placed payload must be strictly below replicated at \
             workers={workers}: plan {plan} vs replicated {rep}"
        );
        assert!(
            q8 > 0.0 && q8 < plan,
            "int8 sparse exchange must be strictly below f32 sparse at \
             workers={workers}: q8 {q8} vs plan {plan}"
        );
    }
    arms
}

/// Serving-router arms (BENCH_serving.json): every route policy at
/// replicas 1/2/4, measured both closed-loop (TPS: per-request wall over
/// a concurrent stream) and open-loop (attack window: per-request
/// latency percentiles under Poisson load).  Replicas are clones, so the
/// arms measure ROUTING, not model variance.
fn serving_arms() -> Vec<BenchArm> {
    let (requests, rounds, rate) = if smoke() { (48, 2, 800.0) } else { (300, 3, 2500.0) };
    let (n_normal, n_attack, epochs) = if smoke() { (400, 100, 1) } else { (1500, 375, 2) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 13,
    });
    let (_, engine, planner) =
        train_ieee118_full(engine_cfg(1), &AccessCfg::default(), &ds, epochs, 64, 5);
    let base = ServeSession::from_trained(engine, planner);
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    let mut arms = Vec::new();
    for policy in [Policy::RoundRobin, Policy::PlanAffinity, Policy::LeastQueued] {
        for replicas in [1usize, 2, 4] {
            // closed loop: per-request wall time; throughput = TPS
            let mut iters = Vec::new();
            for _ in 0..rounds {
                let server = base.clone().replicas(replicas).policy(policy).start();
                let r = server.run_stream_concurrent(stream, 0, replicas * 2);
                iters.push(r.wall.as_secs_f64() / r.served.max(1) as f64);
            }
            arms.push(BenchArm::from_iters(
                format!("serve_closed_{}_r{replicas}", policy.as_str()),
                replicas,
                &iters,
                1,
            ));
            // open loop: per-request attack windows under Poisson load;
            // p99_us of this arm IS the p99 attack window
            let server = base.clone().replicas(replicas).policy(policy).start();
            let ol = run_open_loop(
                server,
                stream,
                &OpenLoopCfg { rate_per_sec: rate, seed: 17 },
            );
            arms.push(BenchArm::from_iters(
                format!("serve_open_{}_r{replicas}", policy.as_str()),
                replicas,
                &ol.window_samples,
                1,
            ));
        }
    }
    arms
}

/// Quantized-fast-path arms (BENCH_quantized_path.json): serving at the
/// IEEE-118 scale with f32 vs f16 vs int8 frozen cores — closed-loop TPS
/// and open-loop attack-window percentiles — each carrying its frozen
/// `model_bytes`, plus the training exchange twins: the f32 sparse
/// all-reduce vs the int8+error-feedback one at 2 workers, each carrying
/// `payload_bytes`.  The probe asserts the byte orderings the fast path
/// exists for (int8 < f16 < f32 model bytes; q8 < f32 exchange payload).
fn quantized_path_arms() -> Vec<BenchArm> {
    let (requests, rounds, rate) = if smoke() { (48, 2, 800.0) } else { (300, 3, 2500.0) };
    let (n_normal, n_attack, epochs) = if smoke() { (400, 100, 1) } else { (1500, 375, 2) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 29,
    });
    let (_, engine, planner) =
        train_ieee118_full(engine_cfg(1), &AccessCfg::default(), &ds, epochs, 64, 5);
    let base = ServeSession::from_trained(engine.clone(), planner);
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    let replicas = 2usize;
    let mut arms = Vec::new();
    let mut model_bytes_of = Vec::new();
    for mode in [QuantizeMode::Off, QuantizeMode::F16, QuantizeMode::Int8] {
        let model_bytes = {
            let mut frozen = engine.clone();
            frozen.freeze_quantized(mode);
            frozen.model_bytes() as f64
        };
        model_bytes_of.push(model_bytes);
        let mut iters = Vec::new();
        for _ in 0..rounds {
            let server = base.clone().replicas(replicas).quantize(mode).start();
            let r = server.run_stream_concurrent(stream, 0, replicas * 2);
            iters.push(r.wall.as_secs_f64() / r.served.max(1) as f64);
        }
        arms.push(
            BenchArm::from_iters(
                format!("serve_closed_{}_r{replicas}", mode.as_str()),
                replicas,
                &iters,
                1,
            )
            .with_extra("model_bytes", model_bytes),
        );
        let server = base.clone().replicas(replicas).quantize(mode).start();
        let ol = run_open_loop(server, stream, &OpenLoopCfg { rate_per_sec: rate, seed: 17 });
        arms.push(
            BenchArm::from_iters(
                format!("serve_open_{}_r{replicas}", mode.as_str()),
                replicas,
                &ol.window_samples,
                1,
            )
            .with_extra("model_bytes", model_bytes),
        );
    }
    assert!(
        model_bytes_of[2] < model_bytes_of[1] && model_bytes_of[1] < model_bytes_of[0],
        "frozen model bytes must order int8 < f16 < f32: {model_bytes_of:?}"
    );

    // training exchange twins: plan-placed sparse all-reduce, f32 vs int8
    // with error feedback, on a TT workload at 2 workers
    let (vocab, batch, n_batches) = if smoke() {
        (10_000u64, 64usize, 4usize)
    } else {
        (60_000, 256, 8)
    };
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(vocab, true), (118, false)],
        tt_rank: 8,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let z = Zipf::new(vocab, 1.2);
    let mut rng = Rng::new(31);
    let batches: Vec<Batch> = (0..n_batches)
        .map(|_| {
            let mut dense = vec![0.0f32; batch * 4];
            rng.fill_normal(&mut dense, 0.0, 1.0);
            let sparse: Vec<u64> =
                (0..batch).flat_map(|_| [z.sample(&mut rng), rng.below(118)]).collect();
            let labels: Vec<f32> =
                (0..batch).map(|_| if rng.coin(0.3) { 1.0 } else { 0.0 }).collect();
            Batch { dense, sparse, labels, batch_size: batch }
        })
        .collect();
    let dp_planner = AccessPlanner::for_engine_cfg(&cfg);
    let cost = SimPlatform::v100(2).cost;
    for (tag, quantize_comm) in [("f32", false), ("q8", true)] {
        let dp = DpCfg {
            workers: 2,
            placement: Placement::Plan,
            cost,
            seed: 5,
            quantize_comm,
        };
        let mut iters = Vec::new();
        let mut payload = 0u64;
        for _ in 0..rounds {
            let (r, _) = train_data_parallel_placed(cfg.clone(), &dp_planner, &batches, &dp);
            iters.push(r.wall.as_secs_f64() / r.steps as f64);
            payload = r.payload_bytes;
        }
        arms.push(
            BenchArm::from_iters(format!("allreduce_sparse_{tag}_w2"), 2, &iters, batch)
                .with_extra("payload_bytes", payload as f64),
        );
    }
    let f32_payload = arm_extra(&arms, "allreduce_sparse_f32_w2", "payload_bytes").unwrap();
    let q8_payload = arm_extra(&arms, "allreduce_sparse_q8_w2", "payload_bytes").unwrap();
    assert!(
        q8_payload > 0.0 && q8_payload < f32_payload,
        "q8 exchange payload {q8_payload} must be strictly below f32 {f32_payload}"
    );
    arms
}

/// Self-tuning runtime arms (BENCH_autotune.json): every static cache
/// ladder rung vs the feedback tuner (training throughput), a static
/// (max_batch, deadline) serve grid vs the per-replica batching tuner
/// (open-loop p99 attack window), and the cadence controller on a
/// drifting stream.  The acceptance comparisons the CI smoke re-checks
/// from the JSON are asserted here first: each autotuned arm must be at
/// least as good as the median static arm (5% noise slack) and within
/// 10% of the best static arm.
fn autotune_arms() -> Vec<BenchArm> {
    let mut arms = Vec::new();

    // ---- cache-budget ladder: static rungs vs the feedback tuner ----
    let ladder = [64usize, 128, 256, 512];
    let (batch, n_batches, rounds) = if smoke() { (64, 4, 2) } else { (256, 16, 3) };
    let batches = ieee118_batches(batch, n_batches);
    let cfg = engine_cfg(1);
    let per_step: usize =
        batches.iter().map(|b| b.batch_size).sum::<usize>() / batches.len();
    let steps = batches.len() as f64;
    let mut static_tp = Vec::new();
    for &kb in &ladder {
        let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.set_layout_policy(kb, false);
        engine.train_step(&batches[0]); // warmup
        let mut samples = Vec::new();
        for _ in 0..rounds {
            let t0 = Instant::now();
            run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                engine.train_step_planned(b, p);
            });
            samples.push(t0.elapsed().as_secs_f64() / steps);
        }
        let arm =
            BenchArm::from_iters(format!("tune_train_static_{kb}kb"), 1, &samples, per_step);
        static_tp.push(arm.throughput);
        arms.push(arm);
    }
    let (auto_train, committed_kb) = {
        let autotune = AutotuneCfg {
            enabled: true,
            reorder: false,
            serve: false,
            cache_ladder: ladder.to_vec(),
            probe_batches: 1,
            ..AutotuneCfg::default()
        };
        let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        planner.set_layout_policy(ladder[0], false);
        planner.enable_autotune(&autotune);
        let feedback = planner.cache_feedback().expect("cache loop installed");
        engine.train_step(&batches[0]); // warmup
        // unmeasured warmup rounds until the ladder commits, so the
        // measured rounds run at the converged budget
        let mut warmup_rounds = 0usize;
        while planner.cache_tuner().unwrap().committed_kb().is_none() && warmup_rounds < 32 {
            run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                let ts = Instant::now();
                engine.train_step_planned(b, p);
                feedback.push(ts.elapsed().as_secs_f64());
            });
            warmup_rounds += 1;
        }
        let committed = planner
            .cache_tuner()
            .unwrap()
            .committed_kb()
            .expect("cache ladder failed to commit during warmup");
        let mut samples = Vec::new();
        for _ in 0..rounds {
            let t0 = Instant::now();
            run_prefetched_fill(replay_fill(&batches), &mut planner, 2, |b, p| {
                let ts = Instant::now();
                engine.train_step_planned(b, p);
                feedback.push(ts.elapsed().as_secs_f64());
            });
            samples.push(t0.elapsed().as_secs_f64() / steps);
        }
        let arm = BenchArm::from_iters("tune_train_auto".to_string(), 1, &samples, per_step)
            .with_extra("committed_kb", committed as f64)
            .with_extra("warmup_rounds", warmup_rounds as f64);
        (arm, committed)
    };
    let mut sorted_tp = static_tp.clone();
    sorted_tp.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_tp = sorted_tp[(sorted_tp.len() - 1) / 2];
    let best_tp = *sorted_tp.last().unwrap();
    assert!(
        auto_train.throughput * 1.05 >= median_tp && auto_train.throughput * 1.1 >= best_tp,
        "autotuned training must reach the median static rung (5% slack) and \
         come within 10% of the best: auto {:.0} vs median {median_tp:.0} / \
         best {best_tp:.0} samples/s",
        auto_train.throughput
    );
    println!(
        "tune[cache]: auto {:.0} samples/s (committed {committed_kb} KiB) vs \
         static ladder median {median_tp:.0} / best {best_tp:.0}",
        auto_train.throughput,
    );
    arms.push(auto_train);

    // ---- serve batching: static (max_batch, deadline) grid vs tuner ----
    let (requests, rate) = if smoke() { (256usize, 800.0) } else { (512, 2500.0) };
    let (n_normal, n_attack, epochs) = if smoke() { (400, 100, 1) } else { (1500, 375, 2) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 41,
    });
    let (_, engine, planner) =
        train_ieee118_full(engine_cfg(1), &AccessCfg::default(), &ds, epochs, 64, 5);
    let base = ServeSession::from_trained(engine, planner);
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    let grid = [(1usize, 0u64), (4, 200), (8, 1_000)];
    let mut static_p99 = Vec::new();
    for &(b, d) in &grid {
        let server =
            base.clone().max_batch(b).deadline(Duration::from_micros(d)).start();
        let ol = run_open_loop(server, stream, &OpenLoopCfg { rate_per_sec: rate, seed: 17 });
        let arm = BenchArm::from_iters(
            format!("tune_serve_static_b{b}_d{d}us"),
            1,
            &ol.window_samples,
            1,
        );
        static_p99.push(arm.p99_us);
        arms.push(arm);
    }
    let mut sorted_p99 = static_p99.clone();
    sorted_p99.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let best_p99 = sorted_p99[0];
    let median_p99 = sorted_p99[sorted_p99.len() / 2];
    let auto_serve = {
        // the tuner's SLO is the best measured static p99: over it the
        // controller stops waiting for fill, under it growth is bounded
        let autotune = AutotuneCfg {
            enabled: true,
            cache: false,
            reorder: false,
            target_p99_us: (best_p99.ceil() as u64).max(1),
            ..AutotuneCfg::default()
        };
        // start from the MIDDLE static config and let the loop walk in
        let server = base
            .clone()
            .max_batch(4)
            .deadline(Duration::from_micros(200))
            .autotune(&autotune)
            .start();
        // hand-rolled Poisson submit loop (same arrival process as
        // run_open_loop, same seed) — the report's window_samples come
        // back SORTED, which would bury the controller's transient, and
        // here we need replies in submission order to cut a temporal tail
        let mut arrivals = Rng::new(17);
        let mut receivers = Vec::with_capacity(stream.len());
        let mut due = Duration::ZERO;
        let t0 = Instant::now();
        for s in stream {
            let gap = -(1.0 - arrivals.f64()).ln() / rate;
            due += Duration::from_secs_f64(gap);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
            }
            receivers.push(server.submit(s));
        }
        let windows: Vec<f64> = receivers
            .into_iter()
            .map(|rx| rx.recv().expect("open-loop reply").latency.as_secs_f64())
            .collect();
        let _ = server.shutdown();
        // score the converged temporal tail: the first half covers the
        // controller's walk from the mid config toward the knee
        let tail_at = windows.len() / 2;
        BenchArm::from_iters("tune_serve_auto".to_string(), 1, &windows[tail_at..], 1)
            .with_extra("target_p99_us", autotune.target_p99_us as f64)
            .with_extra("warmup_dropped", tail_at as f64)
    };
    assert!(
        auto_serve.p99_us <= median_p99 * 1.05 && auto_serve.p99_us <= best_p99 * 1.1,
        "autotuned serving must reach the median static arm's p99 (5% slack) \
         and come within 10% of the best: auto {:.0}µs vs median \
         {median_p99:.0}µs / best {best_p99:.0}µs",
        auto_serve.p99_us
    );
    println!(
        "tune[serve]: auto p99 {:.0}µs vs static grid median {median_p99:.0}µs / \
         best {best_p99:.0}µs",
        auto_serve.p99_us
    );
    arms.push(auto_serve);

    // ---- reorder cadence on a drifting stream ----
    arms.push(cadence_drift_arm());
    arms
}

/// Cadence-controller arm: a stationary Zipf warmup adapts the online
/// bijection (the cadence may legitimately RELAX during it), then the
/// hot set drifts — the decaying reuse rate must drive `refresh_every`
/// below whatever cadence the controller held at drift onset.  Extras
/// record the trajectory endpoints (`initial_every` is the drift-onset
/// value the CI assertion compares against).
fn cadence_drift_arm() -> BenchArm {
    let (vocab, b, n_warm, n_drift) = if smoke() {
        (6_000u64, 128usize, 24usize, 24usize)
    } else {
        (60_000, 256, 48, 48)
    };
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(vocab, true), (40, false)],
        tt_rank: 8,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let mut rng = Rng::new(43);
    let mut z = GradualDriftZipf::new(vocab, 1.2, 7);
    let batch_at = |z: &GradualDriftZipf, rng: &mut Rng| {
        let sparse: Vec<u64> =
            (0..b).flat_map(|_| [z.sample(rng), rng.below(40)]).collect();
        Batch { dense: vec![0.0; b * 4], sparse, labels: vec![0.0; b], batch_size: b }
    };
    let warm: Vec<Batch> = (0..n_warm).map(|_| batch_at(&z, &mut rng)).collect();
    let mut drift = Vec::with_capacity(n_drift);
    z.begin_drift(vocab / 2);
    for _ in 0..n_drift {
        // full drift by ~2/3 of the phase, then a stationary tail
        z.advance(1.5 / n_drift as f64);
        drift.push(batch_at(&z, &mut rng));
    }
    // a short starting cadence so the bijection adapts during warmup —
    // the drifting-stream ids are a scrambled permutation, so reuse (and
    // with it the tuner's decay signal) only exists once refresh has run
    let access = AccessCfg {
        refresh_every: 8,
        window: 8,
        hot_ratio: 0.1,
        ..AccessCfg::default()
    };
    let mut planner = AccessPlanner::for_engine_cfg(&cfg);
    planner.enable_scheduled_online(&cfg, &access, false);
    let autotune =
        AutotuneCfg { enabled: true, cache: false, serve: false, ..AutotuneCfg::default() };
    planner.enable_autotune(&autotune);
    let mut engine = NativeDlrm::new(cfg.clone(), &mut Rng::new(3));
    let mut steps = 0usize;
    let t0 = Instant::now();
    run_prefetched_fill(replay_fill(&warm), &mut planner, 0, |bt, p| {
        engine.train_step_planned(bt, p);
        steps += 1;
    });
    let onset_every = planner.online_refresh_every(0).expect("slot 0 is online");
    let onset_shortens = planner.cadence_tuner(0).map(|c| c.shortens).unwrap_or(0);
    run_prefetched_fill(replay_fill(&drift), &mut planner, 0, |bt, p| {
        engine.train_step_planned(bt, p);
        steps += 1;
    });
    let per_step = t0.elapsed().as_secs_f64() / steps.max(1) as f64;
    let final_every = planner.online_refresh_every(0).expect("slot 0 is online");
    let shortens = planner.cadence_tuner(0).map(|c| c.shortens).unwrap_or(0);
    assert!(
        shortens > onset_shortens && final_every < onset_every,
        "hot-set drift must shorten the refresh cadence: \
         {onset_every} -> {final_every} ({onset_shortens} -> {shortens} shortens)"
    );
    println!(
        "tune[reorder]: refresh_every {onset_every} -> {final_every} under drift \
         ({} shorten(s))",
        shortens - onset_shortens
    );
    BenchArm::from_iters("tune_cadence_drift".to_string(), 1, &[per_step], b)
        .with_extra("initial_every", onset_every as f64)
        .with_extra("final_every", final_every as f64)
        .with_extra("shortens", (shortens - onset_shortens) as f64)
}

/// Recovery-latency curve (BENCH_reorder_recovery.json): how many
/// post-drift batches the smoothed reuse rate needs to climb back to 90%
/// of the worst arm's post-drift plateau, as a function of
/// `refresh_every` x `window`, under gradual hot-set drift and under
/// vocabulary growth.  Planner-only replay (bijections never depend on
/// training), so the recovery figure is deterministic in batches.
fn reorder_recovery_arms() -> Vec<BenchArm> {
    let (vocab, b, n_warm, n_drift) = if smoke() {
        (6_000u64, 128usize, 24usize, 32usize)
    } else {
        (60_000, 256, 48, 64)
    };
    let refreshes = [2usize, 8];
    let windows = [4usize, 16];
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(vocab, true), (40, false)],
        tt_rank: 8,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let make_batch = |ids: Vec<u64>, rng: &mut Rng| {
        let sparse: Vec<u64> =
            ids.into_iter().flat_map(|id| [id, rng.below(40)]).collect();
        Batch { dense: vec![0.0; b * 4], sparse, labels: vec![0.0; b], batch_size: b }
    };
    let mut scenarios: Vec<(&str, Vec<Batch>)> = Vec::new();
    {
        let mut rng = Rng::new(47);
        let mut z = GradualDriftZipf::new(vocab, 1.2, 7);
        let mut batches = Vec::new();
        for i in 0..(n_warm + n_drift) {
            if i == n_warm {
                z.begin_drift(vocab / 2);
            }
            if i >= n_warm {
                z.advance(1.5 / n_drift as f64);
            }
            let ids: Vec<u64> = (0..b).map(|_| z.sample(&mut rng)).collect();
            batches.push(make_batch(ids, &mut rng));
        }
        scenarios.push(("gradual", batches));
    }
    {
        let mut rng = Rng::new(53);
        let mut z = GrowingVocabZipf::new(vocab, vocab / 3, 1.2, 9);
        let mut batches = Vec::new();
        for i in 0..(n_warm + n_drift) {
            if i >= n_warm {
                // active vocabulary roughly doubles over the drift phase
                z.grow(vocab / 3 / n_drift as u64);
            }
            let ids: Vec<u64> = (0..b).map(|_| z.sample(&mut rng)).collect();
            batches.push(make_batch(ids, &mut rng));
        }
        scenarios.push(("growing", batches));
    }
    let mut arms = Vec::new();
    for (scenario, batches) in &scenarios {
        // (trace, plan-time samples) per (refresh, window) combination
        let mut runs = Vec::new();
        for &refresh in &refreshes {
            for &window in &windows {
                let access = AccessCfg {
                    refresh_every: refresh,
                    window,
                    hot_ratio: 0.1,
                    ..AccessCfg::default()
                };
                let mut planner = AccessPlanner::for_engine_cfg(&cfg);
                planner.enable_scheduled_online(&cfg, &access, false);
                let mut plan = BatchPlan::default();
                let mut ewma = Ewma::new(0.3);
                let mut trace = Vec::with_capacity(batches.len());
                let mut iters = Vec::with_capacity(batches.len());
                for bt in batches {
                    let t0 = Instant::now();
                    planner.plan_into(bt, &mut plan);
                    iters.push(t0.elapsed().as_secs_f64());
                    let r = plan.tt_plan(0).map(|tp| tp.reuse_rate()).unwrap_or(0.0);
                    trace.push(ewma.observe(r));
                }
                runs.push((refresh, window, trace, iters));
            }
        }
        // shared recovery bar: 90% of the worst arm's post-drift plateau,
        // so every arm is measured against the same achievable level
        let plateau = runs
            .iter()
            .map(|(_, _, trace, _)| *trace.last().unwrap())
            .fold(f64::INFINITY, f64::min);
        let thr = 0.9 * plateau;
        for (refresh, window, trace, iters) in runs {
            let last_below =
                (n_warm..trace.len()).rev().find(|&i| trace[i] < thr);
            let recovery = match last_below {
                Some(i) => (i + 1 - n_warm).min(n_drift),
                None => 0,
            };
            println!(
                "recover[{scenario}] refresh={refresh} window={window}: \
                 {recovery} batches to 90% of plateau ({plateau:.3})"
            );
            arms.push(
                BenchArm::from_iters(
                    format!("recover_{scenario}_r{refresh}_w{window}"),
                    1,
                    &iters,
                    b,
                )
                .with_extra("recovery_batches", recovery as f64)
                .with_extra("refresh_every", refresh as f64)
                .with_extra("window", window as f64)
                .with_extra("drift_batches", n_drift as f64)
                .with_extra("plateau_reuse", plateau),
            );
        }
        // faster refresh must not recover later (small slack for EWMA
        // threshold-crossing ties)
        for &window in &windows {
            let rb = |r: usize| {
                arm_extra(
                    &arms,
                    &format!("recover_{scenario}_r{r}_w{window}"),
                    "recovery_batches",
                )
                .unwrap()
            };
            assert!(
                rb(refreshes[0]) <= rb(*refreshes.last().unwrap()) + 4.0,
                "refresh={} must not recover later than refresh={} \
                 (window {window}, scenario {scenario}): {} vs {}",
                refreshes[0],
                refreshes.last().unwrap(),
                rb(refreshes[0]),
                rb(*refreshes.last().unwrap()),
            );
        }
    }
    arms
}

/// Fault-tolerance arms (BENCH_fault_tolerance.json): the open-loop
/// serving stream fault-free vs with a replica kill + supervised respawn
/// — each arm's window percentiles come from `run_open_loop`, and each
/// carries `served`/`shed`/`dropped`/`respawns` plus the post-recovery
/// `tail_p99_us` — and the straggler-exclusion training twins (full
/// participation vs straggle_rate 0.3 with error-feedback carry), each
/// carrying `final_loss_e6`.  The acceptance bounds are asserted
/// in-process before the JSON is written: the kill arm respawns and
/// keeps serving with zero silent drops, its post-recovery tail p99
/// stays within 25% (+ scheduling slack) of the fault-free twin, and
/// the straggler twin's final loss lands within 0.1 of full
/// participation.
fn fault_tolerance_arms() -> Vec<BenchArm> {
    let (requests, rate) = if smoke() { (60usize, 1200.0) } else { (300, 2500.0) };
    let (n_normal, n_attack) = if smoke() { (200, 50) } else { (600, 150) };
    let ds = generate(&DatasetCfg {
        n_normal,
        n_attack,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 31,
    });
    let base = ServeSession::from_engine(NativeDlrm::new(engine_cfg(1), &mut Rng::new(3)))
        .replicas(2)
        .heartbeat(Duration::from_millis(2));
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    let mut arms = Vec::new();

    let open = |name: &str, plan: Option<Arc<FaultPlan>>| {
        let server = base.clone().fault(plan).start();
        let ol = run_open_loop(server, stream, &OpenLoopCfg { rate_per_sec: rate, seed: 17 });
        let arm =
            BenchArm::from_iters(format!("serve_open_{name}_r2"), 2, &ol.window_samples, 1)
                .with_extra("served", ol.served as f64)
                .with_extra("shed", ol.shed as f64)
                .with_extra("dropped", ol.dropped as f64)
                .with_extra("respawns", ol.respawns as f64)
                .with_extra("tail_p99_us", ol.tail_p99_window.as_secs_f64() * 1e6);
        (arm, ol)
    };
    let (free_arm, free) = open("fault_free", None);
    let plan = FaultCfg {
        enabled: true,
        seed: 7,
        kill_replica: Some(0),
        kill_after: (requests / 8) as u64,
        ..FaultCfg::default()
    }
    .plan()
    .unwrap();
    let (kill_arm, kill) = open("replica_kill", Some(plan.clone()));
    assert_eq!(kill.dropped, 0, "replica kill silently dropped requests");
    assert!(
        kill.served > 0 && kill.served as usize + kill.shed == kill.offered,
        "kill arm accounting leaked: {} served + {} shed != {} offered",
        kill.served,
        kill.shed,
        kill.offered
    );
    assert!(
        kill.respawns >= 1 && plan.event_count("respawn") >= 1,
        "supervisor never respawned the killed replica"
    );
    let free_tail = free.tail_p99_window.as_secs_f64();
    let kill_tail = kill.tail_p99_window.as_secs_f64();
    assert!(
        kill_tail <= free_tail * 1.25 + 500e-6,
        "post-recovery tail p99 {:.0}µs exceeds fault-free {:.0}µs by more than 25% (+slack)",
        kill_tail * 1e6,
        free_tail * 1e6
    );
    arms.push(free_arm);
    arms.push(kill_arm);

    // straggler-exclusion training twins: full participation vs rate 0.3
    let (vocab, batch, n_batches) =
        if smoke() { (3_000u64, 32usize, 8usize) } else { (20_000, 64, 16) };
    let cfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 8,
        tables: vec![(vocab, true), (60, false)],
        tt_rank: 4,
        bot_hidden: vec![16],
        top_hidden: vec![16],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let z = Zipf::new(vocab, 1.2);
    let mut rng = Rng::new(37);
    let batches: Vec<Batch> = (0..n_batches)
        .map(|_| {
            let mut dense = vec![0.0f32; batch * 4];
            rng.fill_normal(&mut dense, 0.0, 1.0);
            let sparse: Vec<u64> =
                (0..batch).flat_map(|_| [z.sample(&mut rng), rng.below(60)]).collect();
            let labels: Vec<f32> =
                (0..batch).map(|_| if rng.coin(0.3) { 1.0 } else { 0.0 }).collect();
            Batch { dense, sparse, labels, batch_size: batch }
        })
        .collect();
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let cost = SimPlatform::v100(3).cost;
    let dp = DpCfg {
        workers: 3,
        placement: Placement::Replicated,
        cost,
        seed: 5,
        quantize_comm: false,
    };
    let run_train = |tag: &str, fplan: Option<&Arc<FaultPlan>>| {
        let (r, _) = train_data_parallel_faulted(cfg.clone(), &planner, &batches, &dp, fplan);
        let per_step = [r.wall.as_secs_f64() / r.steps as f64];
        let last = *r.losses.last().unwrap();
        let arm = BenchArm::from_iters(format!("train_{tag}_w3"), 3, &per_step, batch)
            .with_extra("final_loss_e6", f64::from(last) * 1e6);
        (arm, last)
    };
    let (full_arm, full_loss) = run_train("full_participation", None);
    let splan = FaultCfg {
        enabled: true,
        seed: 13,
        straggle_rate: 0.3,
        straggle_ms: 0,
        ..FaultCfg::default()
    }
    .plan()
    .unwrap();
    let (strag_arm, strag_loss) = run_train("straggler_0p3", Some(&splan));
    assert!(splan.event_count("straggle") > 0, "straggle rate 0.3 never fired");
    assert!(
        (strag_loss - full_loss).abs() < 0.1,
        "straggler-excluded final loss {strag_loss} drifted from full participation {full_loss}"
    );
    arms.push(full_arm);
    arms.push(strag_arm);
    arms
}

fn main() {
    let par = bench_workers();
    let worker_arms: Vec<usize> = if par > 1 { vec![1, par] } else { vec![1] };
    let mut arms: Vec<BenchArm> = Vec::new();

    let tt_shapes: &[(u64, usize, usize)] = if smoke() {
        &[(10_000, 8, 512)]
    } else {
        &[(100_000, 8, 4096), (100_000, 16, 4096), (1_000_000, 16, 4096)]
    };
    for &w in &worker_arms {
        for &(rows, rank, batch) in tt_shapes {
            let (f, b) = tt_arm(rows, rank, batch, w);
            println!(
                "workers={w} rows={rows:>8} rank={rank:>2} batch={batch}: \
                 fwd {:.0}µs ({:.1} Mlookup/s)  bwd {:.0}µs",
                f.p50_us,
                f.throughput / 1e6,
                b.p50_us
            );
            arms.push(f);
            arms.push(b);
        }
        let e = engine_arm(w);
        println!(
            "workers={w} engine train_step: {:.0} samples/s (p50 {:.0}µs per step)",
            e.throughput, e.p50_us
        );
        arms.push(e);
    }

    // speedup headline: engine arm parallel vs serial
    if worker_arms.len() > 1 {
        let t1 = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == 1)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        let tn = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == par)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        if t1 > 0.0 {
            println!("engine speedup workers={par} vs 1: {:.2}x", tn / t1);
        }
    }

    // access-layer arm: planned prefetch ingest vs unplanned inline
    let unplanned = ingest_arm(false);
    let planned = ingest_arm(true);
    println!(
        "ingest unplanned {:.0} samples/s | planned(prefetch=2) {:.0} samples/s ({:.2}x)",
        unplanned.throughput,
        planned.throughput,
        planned.throughput / unplanned.throughput
    );
    arms.push(unplanned);
    arms.push(planned);

    let path = write_bench_json("perf_probe", par, &arms);
    println!("wrote {path} ({} arms, JSON round-trip checked)", arms.len());

    // ---- cache-resident plan execution (BENCH_cache_layout.json) --------
    let mut cl_arms: Vec<BenchArm> = Vec::new();
    let planned_pr2 = cache_layout_train_arm(0, "planned_pr2");
    let tiled = cache_layout_train_arm(256, "tiled");
    println!(
        "train planned(PR2) {:.0} samples/s | tiled hottest-first {:.0} samples/s ({:.2}x)",
        planned_pr2.throughput,
        tiled.throughput,
        tiled.throughput / planned_pr2.throughput
    );
    cl_arms.push(planned_pr2);
    cl_arms.push(tiled);

    let unfused = fused_plan_arm(false);
    let fused = fused_plan_arm(true);
    println!(
        "plan 3-table sweep unfused {:.0}µs/batch | fused {:.0}µs/batch ({:.2}x)",
        unfused.p50_us,
        fused.p50_us,
        unfused.p50_us / fused.p50_us
    );
    cl_arms.push(unfused);
    cl_arms.push(fused);

    let (vocab, n_drift, b_drift, refresh, window) = if smoke() {
        (6_000u64, 14usize, 128usize, 4usize, 8usize)
    } else {
        (60_000, 48, 512, 8, 16)
    };
    let dcfg = EngineCfg {
        dense_dim: 4,
        emb_dim: 16,
        tables: vec![(vocab, true), (40, false)],
        tt_rank: 8,
        bot_hidden: vec![32],
        top_hidden: vec![32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    };
    let drift = drift_batches(vocab, n_drift, b_drift);
    let (sync_arm, sync_losses) = reorder_stall_arm(&dcfg, &drift, refresh, window, false);
    let (bg_arm, bg_losses) = reorder_stall_arm(&dcfg, &drift, refresh, window, true);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&sync_losses),
        bits(&bg_losses),
        "background refresh diverged from its synchronous twin"
    );
    println!(
        "reorder ingest stall (per refresh, n={}): sync p50 {:.0}µs p99 {:.0}µs | \
         background p50 {:.0}µs p99 {:.0}µs (losses bit-identical)",
        sync_arm.n, sync_arm.p50_us, sync_arm.p99_us, bg_arm.p50_us, bg_arm.p99_us
    );
    cl_arms.push(sync_arm);
    cl_arms.push(bg_arm);

    let cl_path = write_bench_json("cache_layout", par, &cl_arms);
    println!("wrote {cl_path} ({} arms, JSON round-trip checked)", cl_arms.len());

    // ---- serving router arms (BENCH_serving.json) -----------------------
    let sv_arms = serving_arms();
    let tps = |name: &str| {
        sv_arms
            .iter()
            .find(|a| a.name == name)
            .map(|a| a.throughput)
            .unwrap_or(0.0)
    };
    let p99 = |name: &str| {
        sv_arms
            .iter()
            .find(|a| a.name == name)
            .map(|a| a.p99_us)
            .unwrap_or(0.0)
    };
    println!(
        "serve closed r4: round_robin {:.0} TPS | plan_affinity {:.0} TPS | \
         least_queued {:.0} TPS",
        tps("serve_closed_round_robin_r4"),
        tps("serve_closed_plan_affinity_r4"),
        tps("serve_closed_least_queued_r4"),
    );
    println!(
        "serve open-loop p99 attack window r4: round_robin {:.0}µs | \
         plan_affinity {:.0}µs | least_queued {:.0}µs",
        p99("serve_open_round_robin_r4"),
        p99("serve_open_plan_affinity_r4"),
        p99("serve_open_least_queued_r4"),
    );
    let sv_path = write_bench_json("serving", par, &sv_arms);
    println!("wrote {sv_path} ({} arms, JSON round-trip checked)", sv_arms.len());

    // ---- device-placement arms (BENCH_device_placement.json) ------------
    let dp_arms = placement_arms();
    let stat = |name: &str| {
        dp_arms
            .iter()
            .find(|a| a.name == name)
            .map(|a| {
                let pb = a
                    .extra
                    .iter()
                    .find(|(k, _)| k == "payload_bytes")
                    .map(|(_, v)| *v)
                    .unwrap_or(0.0);
                (a.throughput, pb)
            })
            .unwrap_or((0.0, 0.0))
    };
    for workers in [2usize, 4] {
        let (rt, rp) = stat(&format!("dp_replicated_w{workers}"));
        let (pt, pp) = stat(&format!("dp_plan_w{workers}"));
        println!(
            "dp w{workers}: replicated {rt:.0} samples/s @ {:.1} KB payload | \
             plan-placed {pt:.0} samples/s @ {:.1} KB payload ({:.2}x less traffic)",
            rp / 1e3,
            pp / 1e3,
            rp / pp.max(1.0),
        );
    }
    for workers in [2usize, 4] {
        let (_, fp) = stat(&format!("dp_plan_w{workers}"));
        let (_, qp) = stat(&format!("dp_plan_q8_w{workers}"));
        println!(
            "dp w{workers}: q8 exchange {:.1} KB vs f32 sparse {:.1} KB \
             ({:.2}x less traffic)",
            qp / 1e3,
            fp / 1e3,
            fp / qp.max(1.0),
        );
    }
    let dp_path = write_bench_json("device_placement", par, &dp_arms);
    println!("wrote {dp_path} ({} arms, JSON round-trip checked)", dp_arms.len());

    // ---- quantized fast path (BENCH_quantized_path.json) ----------------
    let qp_arms = quantized_path_arms();
    let qtps = |name: &str| {
        qp_arms.iter().find(|a| a.name == name).map(|a| a.throughput).unwrap_or(0.0)
    };
    println!(
        "serve closed r2: f32 {:.0} TPS | f16 {:.0} TPS | int8 {:.0} TPS",
        qtps("serve_closed_off_r2"),
        qtps("serve_closed_f16_r2"),
        qtps("serve_closed_int8_r2"),
    );
    let qp99 = |name: &str| {
        qp_arms.iter().find(|a| a.name == name).map(|a| a.p99_us).unwrap_or(0.0)
    };
    println!(
        "serve open-loop p99 attack window r2: f32 {:.0}µs | f16 {:.0}µs | int8 {:.0}µs",
        qp99("serve_open_off_r2"),
        qp99("serve_open_f16_r2"),
        qp99("serve_open_int8_r2"),
    );
    let qp_path = write_bench_json("quantized_path", par, &qp_arms);
    println!("wrote {qp_path} ({} arms, JSON round-trip checked)", qp_arms.len());

    // ---- self-tuning runtime (BENCH_autotune.json) ----------------------
    let at_arms = autotune_arms();
    let at_path = write_bench_json("autotune", par, &at_arms);
    println!("wrote {at_path} ({} arms, JSON round-trip checked)", at_arms.len());

    // ---- reorder recovery curve (BENCH_reorder_recovery.json) -----------
    let rr_arms = reorder_recovery_arms();
    let rr_path = write_bench_json("reorder_recovery", par, &rr_arms);
    println!("wrote {rr_path} ({} arms, JSON round-trip checked)", rr_arms.len());

    // ---- fault tolerance (BENCH_fault_tolerance.json) -------------------
    let ft_arms = fault_tolerance_arms();
    let fx = |name: &str, key: &str| arm_extra(&ft_arms, name, key).unwrap_or(0.0);
    println!(
        "serve open-loop r2 replica-kill: {:.0} served / {:.0} shed / {:.0} dropped, \
         {:.0} respawn(s); post-recovery tail p99 {:.0}µs vs fault-free {:.0}µs",
        fx("serve_open_replica_kill_r2", "served"),
        fx("serve_open_replica_kill_r2", "shed"),
        fx("serve_open_replica_kill_r2", "dropped"),
        fx("serve_open_replica_kill_r2", "respawns"),
        fx("serve_open_replica_kill_r2", "tail_p99_us"),
        fx("serve_open_fault_free_r2", "tail_p99_us"),
    );
    println!(
        "train w3 straggler exclusion (rate 0.3): final loss {:.4} vs full \
         participation {:.4}",
        fx("train_straggler_0p3_w3", "final_loss_e6") / 1e6,
        fx("train_full_participation_w3", "final_loss_e6") / 1e6,
    );
    let ft_path = write_bench_json("fault_tolerance", par, &ft_arms);
    println!("wrote {ft_path} ({} arms, JSON round-trip checked)", ft_arms.len());

    // ---- self-lint pass (BENCH_lint.json) -------------------------------
    let ln_arms = lint_arms();
    let lx = |key: &str| arm_extra(&ln_arms, "lint_full_crate", key).unwrap_or(-1.0);
    println!(
        "recad lint self-run: {:.0} rules over {:.0} files — {:.0} raw site(s), \
         {:.0} pragma-suppressed, {:.0} surviving (CI gates this to 0)",
        lx("rules"),
        lx("files"),
        lx("findings_raw"),
        lx("suppressed"),
        lx("findings_after"),
    );
    let ln_path = write_bench_json("lint", par, &ln_arms);
    println!("wrote {ln_path} ({} arms, JSON round-trip checked)", ln_arms.len());
}

/// Self-lint arm (BENCH_lint.json): run the `recad lint` determinism &
/// robustness pass over the crate's own source and report the burn-down
/// ratchet — sites the rules fired on pre-pragma (`findings_raw`) vs
/// findings that survive suppression (`findings_after`, gated to zero
/// by the CI smoke job).  Throughput is files linted per second.
fn lint_arms() -> Vec<BenchArm> {
    use recad::analysis::{run_lint, rules::RULES, LintCfg};
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintCfg::default();
    let reps = if smoke() { 3 } else { 7 };
    let mut iters = Vec::with_capacity(reps);
    let mut last = None;
    for _ in 0..reps {
        let t = Instant::now();
        let run = run_lint(root, &cfg, None).expect("lint walk over crate source");
        iters.push(t.elapsed().as_secs_f64());
        last = Some(run);
    }
    let run = last.expect("at least one lint rep");
    vec![
        BenchArm::from_iters("lint_full_crate".into(), 1, &iters, run.files)
            .with_extra("files", run.files as f64)
            .with_extra("rules", RULES.len() as f64)
            .with_extra("findings_raw", run.findings_raw as f64)
            .with_extra("findings_after", run.findings.len() as f64)
            .with_extra("suppressed", run.suppressed as f64),
    ]
}
