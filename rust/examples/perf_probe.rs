//! L3 perf probe: Eff-TT fwd+bwd at serving-relevant shapes, plus the
//! engine train-step arm, each at exec workers = 1 vs N.
//!
//! Emits a machine-readable `BENCH_perf_probe.json` (throughput, p50/p99
//! per-iteration latency, workers arm) so the perf trajectory can be
//! tracked across PRs.  Run: `cargo run --release --example perf_probe`
//! (`RECAD_WORKERS=N` overrides the parallel arm width).

use std::time::Instant;

use recad::bench_support::bench_workers;
use recad::coordinator::engine::NativeDlrm;
use recad::data::batcher::EpochIter;
use recad::exec::ExecCfg;
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::tt::shapes::TtShapes;
use recad::tt::table::{EffTtOptions, EffTtTable, TtScratch};
use recad::util::prng::Rng;
use recad::util::stats::summarize;

struct Arm {
    name: String,
    workers: usize,
    /// items (lookups or samples) per second
    throughput: f64,
    p50_us: f64,
    p99_us: f64,
}

fn arm_json(a: &Arm) -> String {
    format!(
        "{{\"name\": \"{}\", \"workers\": {}, \"throughput_per_sec\": {:.1}, \
         \"p50_us\": {:.1}, \"p99_us\": {:.1}}}",
        a.name, a.workers, a.throughput, a.p50_us, a.p99_us
    )
}

/// Time `f` for `reps` iterations x 5 rounds; returns per-iter seconds.
fn time_iters(mut f: impl FnMut(), reps: usize) -> Vec<f64> {
    let mut samples = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / reps as f64);
    }
    samples
}

fn tt_arm(rows: u64, rank: usize, batch: usize, workers: usize) -> (Arm, Arm) {
    let shapes = TtShapes::plan(rows, 16, rank);
    let mut rng = Rng::new(1);
    let mut t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
    t.set_pool(recad::exec::ExecPool::new(ExecCfg::with_workers(workers)));
    let zipf = recad::data::zipf::Zipf::new(rows, 1.2);
    let idx: Vec<u64> = (0..batch).map(|_| zipf.sample(&mut rng)).collect();
    let offsets: Vec<usize> = (0..=batch).collect();
    let mut out = vec![0.0f32; batch * 16];
    let g = vec![0.05f32; batch * 16];
    let mut scratch = TtScratch::default();
    // warmup
    t.embedding_bag(&idx, &offsets, &mut out, &mut scratch);
    t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch);

    let fwd = time_iters(|| t.embedding_bag(&idx, &offsets, &mut out, &mut scratch), 20);
    let bwd = time_iters(|| t.backward_sgd(&idx, &offsets, &g, 0.01, &mut scratch), 20);
    let fs = summarize(&fwd);
    let bs = summarize(&bwd);
    let mk = |tag: &str, s: &recad::util::stats::Summary| Arm {
        name: format!("tt_{tag}_rows{rows}_rank{rank}_batch{batch}"),
        workers,
        throughput: batch as f64 / s.p50,
        p50_us: s.p50 * 1e6,
        p99_us: s.p99 * 1e6,
    };
    (mk("fwd", &fs), mk("bwd", &bs))
}

fn engine_arm(workers: usize) -> Arm {
    let scale = 1.0 / 2000.0;
    let ds = generate(&DatasetCfg {
        n_normal: 3000,
        n_attack: 750,
        vocab: SparseVocab::ieee118(scale),
        n_profiles: 50,
        noise_std: 0.005,
        seed: 7,
    });
    let mut cfg = recad::coordinator::engine::EngineCfg::ieee118(scale);
    cfg.exec = ExecCfg::with_workers(workers);
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(1));
    let mut rng = Rng::new(9);
    let batches: Vec<_> = EpochIter::new(&ds.samples, 512, &mut rng).take(6).collect();
    engine.train_step(&batches[0]); // warmup
    let n: usize = batches.iter().map(|b| b.batch_size).sum();
    let mut samples = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        for b in &batches {
            engine.train_step(b);
        }
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = summarize(&samples);
    // samples time a whole pass over `batches`; report per-step latency so
    // every arm in the JSON shares per-iteration units
    let steps = batches.len() as f64;
    Arm {
        name: "engine_train_step_batch512".into(),
        workers,
        throughput: n as f64 / s.p50,
        p50_us: s.p50 * 1e6 / steps,
        p99_us: s.p99 * 1e6 / steps,
    }
}

fn main() {
    let par = bench_workers();
    let worker_arms: Vec<usize> = if par > 1 { vec![1, par] } else { vec![1] };
    let mut arms: Vec<Arm> = Vec::new();

    for &w in &worker_arms {
        for (rows, rank, batch) in
            [(100_000u64, 8usize, 4096usize), (100_000, 16, 4096), (1_000_000, 16, 4096)]
        {
            let (f, b) = tt_arm(rows, rank, batch, w);
            println!(
                "workers={w} rows={rows:>8} rank={rank:>2} batch={batch}: \
                 fwd {:.0}µs ({:.1} Mlookup/s)  bwd {:.0}µs",
                f.p50_us,
                f.throughput / 1e6,
                b.p50_us
            );
            arms.push(f);
            arms.push(b);
        }
        let e = engine_arm(w);
        println!(
            "workers={w} engine train_step: {:.0} samples/s (p50 {:.0}µs per step)",
            e.throughput, e.p50_us
        );
        arms.push(e);
    }

    // speedup headline: engine arm parallel vs serial
    if worker_arms.len() > 1 {
        let t1 = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == 1)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        let tn = arms
            .iter()
            .find(|a| a.name.starts_with("engine") && a.workers == par)
            .map(|a| a.throughput)
            .unwrap_or(0.0);
        if t1 > 0.0 {
            println!("engine speedup workers={par} vs 1: {:.2}x", tn / t1);
        }
    }

    let body: Vec<String> = arms.iter().map(arm_json).collect();
    let json = format!(
        "{{\"bench\": \"perf_probe\", \"parallel_workers\": {par}, \"arms\": [\n  {}\n]}}\n",
        body.join(",\n  ")
    );
    std::fs::write("BENCH_perf_probe.json", &json).expect("write BENCH_perf_probe.json");
    println!("wrote BENCH_perf_probe.json ({} arms)", arms.len());
}
