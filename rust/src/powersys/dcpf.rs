//! DC power flow: susceptance matrix assembly + dense LU solver.
//!
//! Under the DC approximation branch flow is `f = (θ_from − θ_to)/x` and
//! bus injections satisfy `P = B'·θ` with the slack angle fixed at 0.  The
//! reduced B' (slack row/col removed) is SPD for a connected grid, so a
//! plain partial-pivot LU is ample at 117×117.

use crate::powersys::ieee118::{Grid, N_BUS, SLACK};

/// Dense row-major matrix with an LU solver (no external BLAS offline).
#[derive(Clone)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub a: Vec<f64>,
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> DMat {
        DMat { rows, cols, a: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.a[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.a[r * self.cols + c]
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.a[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    /// y = Aᵀ·x.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = &self.a[r * self.cols..(r + 1) * self.cols];
            for (yc, &a) in y.iter_mut().zip(row) {
                *yc += a * x[r];
            }
        }
        y
    }

    /// C = AᵀA (normal-equation assembly for WLS).
    pub fn gram(&self) -> DMat {
        let n = self.cols;
        let mut c = DMat::zeros(n, n);
        for r in 0..self.rows {
            let row = &self.a[r * self.cols..(r + 1) * self.cols];
            for i in 0..n {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let crow = &mut c.a[i * n..(i + 1) * n];
                for (cv, &aj) in crow.iter_mut().zip(row) {
                    *cv += ai * aj;
                }
            }
        }
        c
    }
}

/// LU factorization with partial pivoting (in place).
pub struct Lu {
    lu: DMat,
    piv: Vec<usize>,
}

impl Lu {
    pub fn factor(mut m: DMat) -> Result<Lu, &'static str> {
        assert_eq!(m.rows, m.cols);
        let n = m.rows;
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // pivot
            let (mut pmax, mut prow) = (m.at(k, k).abs(), k);
            for r in k + 1..n {
                let v = m.at(r, k).abs();
                if v > pmax {
                    pmax = v;
                    prow = r;
                }
            }
            if pmax < 1e-12 {
                return Err("singular matrix in LU");
            }
            if prow != k {
                for c in 0..n {
                    let t = m.at(k, c);
                    *m.at_mut(k, c) = m.at(prow, c);
                    *m.at_mut(prow, c) = t;
                }
                piv.swap(k, prow);
            }
            let inv = 1.0 / m.at(k, k);
            for r in k + 1..n {
                let f = m.at(r, k) * inv;
                *m.at_mut(r, k) = f;
                if f != 0.0 {
                    for c in k + 1..n {
                        *m.at_mut(r, c) -= f * m.at(k, c);
                    }
                }
            }
        }
        Ok(Lu { lu: m, piv })
    }

    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        // apply permutation
        let mut y: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward substitution (unit lower)
        for r in 1..n {
            let mut s = y[r];
            for c in 0..r {
                s -= self.lu.at(r, c) * y[c];
            }
            y[r] = s;
        }
        // back substitution
        for r in (0..n).rev() {
            let mut s = y[r];
            for c in r + 1..n {
                s -= self.lu.at(r, c) * y[c];
            }
            y[r] = s / self.lu.at(r, r);
        }
        y
    }
}

/// DC power-flow model for a grid: reduced susceptance matrix + factor.
pub struct DcPowerFlow {
    pub grid: Grid,
    /// Reduced B' [n-1, n-1] (slack removed), prefactored.
    lu: Lu,
}

impl DcPowerFlow {
    pub fn new(grid: Grid) -> DcPowerFlow {
        let n = N_BUS;
        let mut b = DMat::zeros(n - 1, n - 1);
        for br in &grid.branches {
            let w = 1.0 / br.x;
            let (f, t) = (br.from, br.to);
            for &(i, j, s) in &[(f, f, w), (t, t, w), (f, t, -w), (t, f, -w)] {
                if i == SLACK || j == SLACK {
                    continue;
                }
                *b.at_mut(red(i), red(j)) += s;
            }
        }
        let lu = Lu::factor(b).expect("connected grid ⇒ B' nonsingular");
        DcPowerFlow { grid, lu }
    }

    /// Solve angles θ (full length, θ[slack]=0) from injections P.
    pub fn solve_angles(&self, injections: &[f64]) -> Vec<f64> {
        assert_eq!(injections.len(), N_BUS);
        let reduced: Vec<f64> = (0..N_BUS)
            .filter(|&i| i != SLACK)
            .map(|i| injections[i])
            .collect();
        let th_red = self.lu.solve(&reduced);
        let mut theta = vec![0.0; N_BUS];
        for i in 0..N_BUS {
            if i != SLACK {
                theta[i] = th_red[red(i)];
            }
        }
        theta
    }

    /// Branch flows from angles.
    pub fn flows(&self, theta: &[f64]) -> Vec<f64> {
        self.grid
            .branches
            .iter()
            .map(|br| (theta[br.from] - theta[br.to]) / br.x)
            .collect()
    }

    /// Bus injections implied by angles (B·θ over the full matrix).
    pub fn injections(&self, theta: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; N_BUS];
        for br in &self.grid.branches {
            let f = (theta[br.from] - theta[br.to]) / br.x;
            p[br.from] += f;
            p[br.to] -= f;
        }
        p
    }

    /// Measurement Jacobian H [n_meas, n-1] over reduced angles:
    /// rows = branch flows then bus injections.
    pub fn jacobian(&self) -> DMat {
        let nb = self.grid.branches.len();
        let mut h = DMat::zeros(nb + N_BUS, N_BUS - 1);
        for (r, br) in self.grid.branches.iter().enumerate() {
            let w = 1.0 / br.x;
            if br.from != SLACK {
                *h.at_mut(r, red(br.from)) += w;
            }
            if br.to != SLACK {
                *h.at_mut(r, red(br.to)) -= w;
            }
        }
        for br in self.grid.branches.iter() {
            let w = 1.0 / br.x;
            let row_from = nb + br.from;
            let row_to = nb + br.to;
            if br.from != SLACK {
                *h.at_mut(row_from, red(br.from)) += w;
                *h.at_mut(row_to, red(br.from)) -= w;
            }
            if br.to != SLACK {
                *h.at_mut(row_from, red(br.to)) -= w;
                *h.at_mut(row_to, red(br.to)) += w;
            }
        }
        h
    }
}

#[inline]
fn red(bus: usize) -> usize {
    // index into the reduced (slack-removed) vector
    if bus > SLACK {
        bus - 1
    } else {
        bus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::ieee118::Grid;
    use crate::util::prng::Rng;

    #[test]
    fn lu_solves_random_system() {
        let mut rng = Rng::new(4);
        let n = 20;
        let mut m = DMat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                *m.at_mut(r, c) = rng.normal();
            }
            *m.at_mut(r, r) += 5.0; // diagonally dominant
        }
        let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.3 - 2.0).collect();
        let b = m.matvec(&x);
        let lu = Lu::factor(m).unwrap();
        let xhat = lu.solve(&b);
        for (a, e) in xhat.iter().zip(&x) {
            assert!((a - e).abs() < 1e-8, "{a} vs {e}");
        }
    }

    #[test]
    fn power_flow_balances() {
        let grid = Grid::ieee118(1);
        let pf = DcPowerFlow::new(grid);
        // balanced injections: generators cover total load
        let mut inj: Vec<f64> = pf.grid.base_load.iter().map(|&l| -l).collect();
        let total: f64 = pf.grid.base_load.iter().sum();
        let per_gen = total / pf.grid.gen_buses.len() as f64;
        for &g in &pf.grid.gen_buses.clone() {
            inj[g] += per_gen;
        }
        let theta = pf.solve_angles(&inj);
        let implied = pf.injections(&theta);
        // implied injections must match everywhere except slack (absorbs
        // imbalance; here balance is exact so slack matches too)
        for i in 0..N_BUS {
            assert!(
                (implied[i] - inj[i]).abs() < 1e-6,
                "bus {i}: {} vs {}",
                implied[i],
                inj[i]
            );
        }
    }

    #[test]
    fn jacobian_linearizes_measurements() {
        let grid = Grid::ieee118(2);
        let pf = DcPowerFlow::new(grid);
        let mut rng = Rng::new(9);
        let theta_red: Vec<f64> = (0..N_BUS - 1).map(|_| rng.normal() * 0.1).collect();
        let mut theta = vec![0.0; N_BUS];
        for i in 1..N_BUS {
            theta[i] = theta_red[i - 1];
        }
        let h = pf.jacobian();
        let z = h.matvec(&theta_red);
        let flows = pf.flows(&theta);
        let inj = pf.injections(&theta);
        for (i, f) in flows.iter().enumerate() {
            assert!((z[i] - f).abs() < 1e-9);
        }
        for (i, p) in inj.iter().enumerate() {
            assert!((z[pf.grid.branches.len() + i] - p).abs() < 1e-9);
        }
    }
}
