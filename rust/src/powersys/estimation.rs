//! WLS state estimation + residual-based bad-data detection (the classical
//! BDD that stealthy FDIAs evade — the security premise of the paper).

use crate::powersys::dcpf::{DMat, DcPowerFlow, Lu};

pub struct Estimator {
    /// Measurement Jacobian H [n_meas, n_state].
    pub h: DMat,
    /// Prefactored normal-equation matrix (HᵀH; unit weights).
    gain: Lu,
}

/// Result of one estimation pass.
pub struct Estimate {
    /// Estimated reduced angle state.
    pub state: Vec<f64>,
    /// Residual vector r = z − H·x̂.
    pub residual: Vec<f64>,
    /// L2 norm of the residual (the BDD statistic).
    pub residual_norm: f64,
    pub max_abs_residual: f64,
}

impl Estimator {
    pub fn new(pf: &DcPowerFlow) -> Estimator {
        let h = pf.jacobian();
        let gain = Lu::factor(h.gram()).expect("observable system");
        Estimator { h, gain }
    }

    /// WLS estimate (unit weights): x̂ = (HᵀH)⁻¹ Hᵀ z.
    pub fn estimate(&self, z: &[f64]) -> Estimate {
        assert_eq!(z.len(), self.h.rows);
        let rhs = self.h.tmatvec(z);
        let state = self.gain.solve(&rhs);
        let zhat = self.h.matvec(&state);
        let residual: Vec<f64> = z.iter().zip(&zhat).map(|(a, b)| a - b).collect();
        let residual_norm = residual.iter().map(|r| r * r).sum::<f64>().sqrt();
        let max_abs_residual = residual.iter().fold(0.0f64, |m, r| m.max(r.abs()));
        Estimate { state, residual, residual_norm, max_abs_residual }
    }

    /// Classical BDD: flag when the residual norm exceeds `tau`.
    pub fn bad_data(&self, z: &[f64], tau: f64) -> bool {
        self.estimate(z).residual_norm > tau
    }

    /// Calibrate tau as `k`× the clean-measurement residual norm level.
    /// (Callers estimate the clean level by sampling.)
    pub fn calibrate_tau(clean_norms: &[f64], k: f64) -> f64 {
        let mut s = clean_norms.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = s[((s.len() - 1) as f64 * 0.99) as usize];
        p99 * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::ieee118::{Grid, N_BUS};
    use crate::util::prng::Rng;

    fn setup() -> (DcPowerFlow, Estimator) {
        let pf = DcPowerFlow::new(Grid::ieee118(5));
        let est = Estimator::new(&pf);
        (pf, est)
    }

    #[test]
    fn noiseless_measurements_zero_residual() {
        let (pf, est) = setup();
        let mut rng = Rng::new(1);
        let inj: Vec<f64> = (0..N_BUS).map(|_| rng.normal() * 0.1).collect();
        let theta = pf.solve_angles(&inj);
        let mut z = pf.flows(&theta);
        z.extend(pf.injections(&theta));
        let e = est.estimate(&z);
        assert!(e.residual_norm < 1e-6, "residual {}", e.residual_norm);
    }

    #[test]
    fn noise_gives_small_residual_and_state_recovers() {
        let (pf, est) = setup();
        let mut rng = Rng::new(2);
        let inj: Vec<f64> = (0..N_BUS).map(|_| rng.normal() * 0.1).collect();
        let theta = pf.solve_angles(&inj);
        let mut z = pf.flows(&theta);
        z.extend(pf.injections(&theta));
        for v in z.iter_mut() {
            *v += rng.normal() * 0.01;
        }
        let e = est.estimate(&z);
        assert!(e.residual_norm > 0.0);
        // state ≈ true reduced angles
        for i in 1..N_BUS {
            assert!((e.state[i - 1] - theta[i]).abs() < 0.05);
        }
    }

    #[test]
    fn gross_error_trips_bdd() {
        let (pf, est) = setup();
        let mut rng = Rng::new(3);
        let inj: Vec<f64> = (0..N_BUS).map(|_| rng.normal() * 0.1).collect();
        let theta = pf.solve_angles(&inj);
        let mut z = pf.flows(&theta);
        z.extend(pf.injections(&theta));
        let clean = est.estimate(&z).residual_norm;
        z[7] += 50.0; // gross bad datum
        let attacked = est.estimate(&z).residual_norm;
        assert!(attacked > clean + 1.0);
    }
}
