//! Power-system substrate: grid topology, DC power flow, WLS state
//! estimation with residual BDD, FDIA attack construction, and the
//! IEEE-118 detection dataset generator (paper §V-B).

pub mod attack;
pub mod dataset;
pub mod dcpf;
pub mod estimation;
pub mod ieee118;

pub use attack::{Attack, AttackGen, AttackKind};
pub use dataset::{generate, DatasetCfg, Ieee118Dataset, Sample, SparseVocab};
pub use dcpf::DcPowerFlow;
pub use estimation::Estimator;
pub use ieee118::Grid;
