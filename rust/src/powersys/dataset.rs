//! IEEE-118 FDIA detection dataset synthesis (paper Table II row 4:
//! 24,800 samples, 6 dense + 7 sparse features, 20,000 normal / 4,800
//! attacked).
//!
//! Each sample is one SCADA snapshot: a DC power-flow solution under a
//! time-varying load pattern, optionally perturbed by an FDIA, summarized
//! into the DLRM feature layout:
//!
//! dense (6): [mean|flow|, max|flow|, std(flow), mean(inj), residual-norm,
//!            max-normalized-residual]
//! sparse (7): [topo-pair id (large, hashed), load-profile id (large,
//!            hashed), argmax-|inj| bus, argmax-|flow| branch, dominant
//!            generator, hour-of-day, dominant measurement type]
//!
//! The two large vocabularies are produced by hashing structured state, so
//! their index distribution inherits the power-law skew of real telemetry
//! (a small set of load archetypes dominates) — exactly the skew the
//! Eff-TT reuse buffer and the index reordering exploit.

use crate::powersys::attack::{apply, Attack, AttackGen, AttackKind};
use crate::powersys::dcpf::DcPowerFlow;
use crate::powersys::estimation::Estimator;
use crate::powersys::ieee118::{Grid, N_BRANCH, N_BUS, N_GEN};
use crate::util::prng::Rng;

pub const N_DENSE: usize = 6;
pub const N_SPARSE: usize = 7;

/// One DLRM-ready sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub dense: [f32; N_DENSE],
    pub sparse: [u64; N_SPARSE],
    /// 1.0 = attacked, 0.0 = clean.
    pub label: f32,
    pub attack_kind: Option<AttackKind>,
}

/// Vocabulary sizes per sparse feature (must match the model config).
#[derive(Clone, Copy, Debug)]
pub struct SparseVocab(pub [u64; N_SPARSE]);

impl SparseVocab {
    /// Paper-shape vocabularies scaled by `scale` on the two large tables
    /// (12M and 7.5M rows at scale 1.0; Σ ≈ 19.53M ≈ Table II).
    pub fn ieee118(scale: f64) -> SparseVocab {
        let s = |r: f64| ((r * scale) as u64).max(32);
        SparseVocab([
            s(12_000_000.0),
            s(7_500_000.0),
            N_BUS as u64,
            N_BRANCH as u64,
            N_GEN as u64,
            24,
            91,
        ])
    }
}

pub struct DatasetCfg {
    pub n_normal: usize,
    pub n_attack: usize,
    pub vocab: SparseVocab,
    /// Number of load archetypes (drives the power-law on table 1).
    pub n_profiles: usize,
    pub noise_std: f64,
    pub seed: u64,
}

impl Default for DatasetCfg {
    fn default() -> Self {
        DatasetCfg {
            n_normal: 20_000,
            n_attack: 4_800,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 200,
            noise_std: 0.005,
            seed: 0x5EED,
        }
    }
}

pub struct Ieee118Dataset {
    pub samples: Vec<Sample>,
    pub vocab: SparseVocab,
    /// Calibrated BDD threshold (for baseline comparison).
    pub bdd_tau: f64,
}

/// FNV-1a for stable feature hashing (shared with the plan-affinity
/// router; the implementation lives in `util::hash`).
pub use crate::util::hash::fnv1a;

pub fn generate(cfg: &DatasetCfg) -> Ieee118Dataset {
    let grid = Grid::ieee118(cfg.seed);
    let pf = DcPowerFlow::new(grid);
    let est = Estimator::new(&pf);
    let gen = AttackGen::new(&pf);
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);

    // Load archetypes with Zipf popularity: profile p is chosen with
    // weight ∝ 1/(p+1)^1.1 — telemetry skew.
    let profiles: Vec<Vec<f64>> = (0..cfg.n_profiles)
        .map(|_| (0..N_BUS).map(|_| 0.3 + 0.7 * rng.f64()).collect())
        .collect();
    let weights: Vec<f64> = (0..cfg.n_profiles)
        .map(|p| 1.0 / ((p + 1) as f64).powf(1.1))
        .collect();
    let wsum: f64 = weights.iter().sum();

    let pick_profile = |rng: &mut Rng| -> usize {
        let mut x = rng.f64() * wsum;
        for (p, &w) in weights.iter().enumerate() {
            if x < w {
                return p;
            }
            x -= w;
        }
        cfg.n_profiles - 1
    };

    let total = cfg.n_normal + cfg.n_attack;
    let mut order: Vec<bool> = (0..total).map(|i| i < cfg.n_attack).collect();
    rng.shuffle(&mut order);

    let mut samples = Vec::with_capacity(total);
    let mut clean_norms = Vec::new();
    for (si, &attacked) in order.iter().enumerate() {
        let hour = (si % 24) as u64;
        let day_factor = 0.8 + 0.4 * ((hour as f64 / 24.0) * std::f64::consts::TAU).sin().abs();
        let p_id = pick_profile(&mut rng);

        // injections: generators cover the scaled profile load
        let mut inj: Vec<f64> = profiles[p_id]
            .iter()
            .map(|&l| -l * day_factor * (1.0 + 0.05 * rng.normal()))
            .collect();
        let total_load: f64 = -inj.iter().sum::<f64>();
        let per_gen = total_load / pf.grid.gen_buses.len() as f64;
        let gen_jitter: Vec<f64> = pf
            .grid
            .gen_buses
            .iter()
            .map(|_| per_gen * (1.0 + 0.1 * rng.normal()))
            .collect();
        let jsum: f64 = gen_jitter.iter().sum();
        let scale = total_load / jsum;
        for (gi, &g) in pf.grid.gen_buses.iter().enumerate() {
            inj[g] += gen_jitter[gi] * scale;
        }

        let theta = pf.solve_angles(&inj);
        let mut z = pf.flows(&theta);
        z.extend(pf.injections(&theta));
        for v in z.iter_mut() {
            *v += rng.normal() * cfg.noise_std;
        }

        let (z, attack): (Vec<f64>, Option<Attack>) = if attacked {
            // paper's threat model: mostly stealthy, some crude attacks
            let pick = rng.usize_below(10);
            let atk = match pick {
                0..=6 => {
                    let k = 2 + rng.usize_below(6);
                    let mag = 0.3 + 0.7 * rng.f64();
                    gen.stealthy(&mut rng, k, mag)
                }
                7..=8 => {
                    let frac = 0.05 + 0.1 * rng.f64();
                    let factor = 1.2 + rng.f64();
                    gen.scaling(&mut rng, &z, frac, factor)
                }
                _ => {
                    let k = 3 + rng.usize_below(5);
                    let mag = 1.0 + 2.0 * rng.f64();
                    gen.random(&mut rng, k, mag)
                }
            };
            (apply(&z, &atk), Some(atk))
        } else {
            (z, None)
        };

        let e = est.estimate(&z);
        if !attacked {
            clean_norms.push(e.residual_norm);
        }

        // ---- dense features -------------------------------------------
        let nb = pf.grid.branches.len();
        let flows = &z[..nb];
        let injm = &z[nb..];
        let mean_f = flows.iter().map(|f| f.abs()).sum::<f64>() / nb as f64;
        let max_f = flows.iter().fold(0.0f64, |m, f| m.max(f.abs()));
        let var_f = flows.iter().map(|f| (f.abs() - mean_f) * (f.abs() - mean_f)).sum::<f64>() / nb as f64;
        let mean_i = injm.iter().sum::<f64>() / injm.len() as f64;
        let dense = [
            mean_f as f32,
            max_f as f32,
            var_f.sqrt() as f32,
            mean_i as f32,
            e.residual_norm as f32,
            e.max_abs_residual as f32,
        ];

        // ---- sparse features -------------------------------------------
        let vocab = cfg.vocab.0;
        let argmax_flow = flows
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let argmax_inj = injm
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let dominant_gen = pf
            .grid
            .gen_buses
            .iter()
            .enumerate()
            .max_by(|a, b| inj[*a.1].partial_cmp(&inj[*b.1]).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let max_res_row = e
            .residual
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        // large-vocab hashes: structured state → skewed id space
        let topo_pair = fnv1a(&[argmax_flow as u64, argmax_inj as u64, hour]) % vocab[0];
        let quant: Vec<u64> = profiles[p_id].iter().take(16).map(|l| (l * 8.0) as u64).collect();
        let profile_id = fnv1a(&quant) % vocab[1];
        let sparse = [
            topo_pair,
            profile_id,
            argmax_inj as u64 % vocab[2],
            argmax_flow as u64 % vocab[3],
            dominant_gen as u64 % vocab[4],
            hour % vocab[5],
            (max_res_row as u64) % vocab[6],
        ];

        samples.push(Sample {
            dense,
            sparse,
            label: if attacked { 1.0 } else { 0.0 },
            attack_kind: attack.map(|a| a.kind),
        });
    }

    // normalize dense features to zero-mean/unit-std (paper: max-min /
    // normalization preprocessing; z-score is the variance-preserving kin)
    let mut mean = [0.0f64; N_DENSE];
    let mut var = [0.0f64; N_DENSE];
    for s in &samples {
        for d in 0..N_DENSE {
            mean[d] += s.dense[d] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= samples.len() as f64;
    }
    for s in &samples {
        for d in 0..N_DENSE {
            let x = s.dense[d] as f64 - mean[d];
            var[d] += x * x;
        }
    }
    for v in var.iter_mut() {
        *v = (*v / samples.len() as f64).sqrt().max(1e-9);
    }
    for s in samples.iter_mut() {
        for d in 0..N_DENSE {
            s.dense[d] = ((s.dense[d] as f64 - mean[d]) / var[d]) as f32;
        }
    }

    let bdd_tau = Estimator::calibrate_tau(&clean_norms, 1.05);
    Ieee118Dataset { samples, vocab: cfg.vocab, bdd_tau }
}

impl Ieee118Dataset {
    /// Split into (train, test) preserving order randomization.
    pub fn split(&self, train_frac: f64) -> (&[Sample], &[Sample]) {
        let n = (self.samples.len() as f64 * train_frac) as usize;
        (&self.samples[..n], &self.samples[n..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DatasetCfg {
        DatasetCfg {
            n_normal: 400,
            n_attack: 100,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 40,
            noise_std: 0.005,
            seed: 42,
        }
    }

    #[test]
    fn generates_requested_counts() {
        let ds = generate(&small_cfg());
        assert_eq!(ds.samples.len(), 500);
        let attacked = ds.samples.iter().filter(|s| s.label > 0.5).count();
        assert_eq!(attacked, 100);
    }

    #[test]
    fn sparse_indices_in_vocab() {
        let ds = generate(&small_cfg());
        for s in &ds.samples {
            for (f, &idx) in s.sparse.iter().enumerate() {
                assert!(idx < ds.vocab.0[f], "feature {f}: {idx} >= {}", ds.vocab.0[f]);
            }
        }
    }

    #[test]
    fn dense_normalized() {
        let ds = generate(&small_cfg());
        for d in 0..N_DENSE {
            let mean: f64 = ds.samples.iter().map(|s| s.dense[d] as f64).sum::<f64>()
                / ds.samples.len() as f64;
            assert!(mean.abs() < 0.1, "feature {d} mean {mean}");
        }
    }

    #[test]
    fn profile_ids_are_skewed() {
        // power-law premise: top profile id must dominate
        let ds = generate(&small_cfg());
        let mut counts = std::collections::HashMap::new();
        for s in &ds.samples {
            *counts.entry(s.sparse[1]).or_insert(0usize) += 1;
        }
        // lint:allow(D1) max over all values is commutative — order-free
        let max = counts.values().copied().max().unwrap();
        assert!(
            max as f64 > 2.0 * ds.samples.len() as f64 / counts.len() as f64,
            "no skew: max {max} over {} ids", counts.len()
        );
    }

    #[test]
    fn bdd_misses_stealthy_catches_random() {
        let ds = generate(&small_cfg());
        // recompute BDD verdicts from stored dense[4] (residual norm)
        let mut stealthy_caught = 0;
        let mut stealthy_total = 0;
        let mut random_caught = 0;
        let mut random_total = 0;
        // NOTE: dense was normalized; use kind + stored residual ordering
        // instead: stealthy residuals must look like clean ones.
        let clean_mean: f32 = {
            let c: Vec<f32> = ds.samples.iter().filter(|s| s.label < 0.5).map(|s| s.dense[4]).collect();
            c.iter().sum::<f32>() / c.len() as f32
        };
        for s in &ds.samples {
            match s.attack_kind {
                Some(AttackKind::Stealthy) => {
                    stealthy_total += 1;
                    if s.dense[4] > clean_mean + 3.0 {
                        stealthy_caught += 1;
                    }
                }
                Some(AttackKind::Random) => {
                    random_total += 1;
                    if s.dense[4] > clean_mean + 3.0 {
                        random_caught += 1;
                    }
                }
                _ => {}
            }
        }
        assert!(stealthy_total > 0 && random_total > 0);
        assert!(
            (stealthy_caught as f64) < 0.2 * stealthy_total as f64,
            "stealthy attacks should evade the residual test: {stealthy_caught}/{stealthy_total}"
        );
        assert!(
            (random_caught as f64) > 0.5 * random_total as f64,
            "random attacks should trip the residual test: {random_caught}/{random_total}"
        );
    }
}
