//! IEEE-118-scale grid topology.
//!
//! Substitution note (DESIGN.md §4): the authoritative MATPOWER case file
//! is not available offline, so we synthesize a 118-bus / 186-branch /
//! 54-generator network with the same dimensions and a power-grid-like
//! degree distribution (connected spanning tree + locality-biased chords).
//! Everything downstream (DC power flow, WLS estimation, stealthy FDIA
//! construction) depends only on these dimensions and on B-matrix
//! structure, not on the exact IEEE parameter values.

use crate::util::prng::Rng;

pub const N_BUS: usize = 118;
pub const N_BRANCH: usize = 186;
pub const N_GEN: usize = 54;
/// Slack/reference bus (angle fixed to 0).
pub const SLACK: usize = 0;

#[derive(Clone, Copy, Debug)]
pub struct Branch {
    pub from: usize,
    pub to: usize,
    /// Series reactance (p.u.); DC susceptance is 1/x.
    pub x: f64,
}

#[derive(Clone, Debug)]
pub struct Grid {
    pub branches: Vec<Branch>,
    /// Generator bus ids (first `N_GEN` by convention).
    pub gen_buses: Vec<usize>,
    /// Base-case load at each bus (p.u., positive = consumption).
    pub base_load: Vec<f64>,
}

impl Grid {
    /// Deterministic synthetic IEEE-118-scale grid.
    pub fn ieee118(seed: u64) -> Grid {
        let mut rng = Rng::new(seed ^ 0x118_118);
        // Spanning tree with locality: bus i attaches to a nearby earlier
        // bus — yields the chain-of-regions structure of real grids.
        let mut branches = Vec::with_capacity(N_BRANCH);
        let mut seen = std::collections::HashSet::new();
        for i in 1..N_BUS {
            let lo = i.saturating_sub(8);
            let to = lo + rng.usize_below(i - lo);
            branches.push(Branch { from: i, to, x: sample_x(&mut rng) });
            seen.insert(key(i, to));
        }
        // Locality-biased chords up to N_BRANCH.
        while branches.len() < N_BRANCH {
            let a = rng.usize_below(N_BUS);
            let span = 2 + rng.usize_below(20);
            let b = (a + span) % N_BUS;
            if a == b || seen.contains(&key(a, b)) {
                continue;
            }
            seen.insert(key(a, b));
            branches.push(Branch { from: a, to: b, x: sample_x(&mut rng) });
        }
        // Generators spread across the grid.
        let gen_buses: Vec<usize> = (0..N_GEN).map(|g| (g * N_BUS) / N_GEN).collect();
        // Base loads: every non-generator bus consumes; generators net-inject.
        let mut base_load = vec![0.0; N_BUS];
        for b in 0..N_BUS {
            base_load[b] = 0.2 + 0.8 * rng.f64(); // p.u.
        }
        Grid { branches, gen_buses, base_load }
    }

    /// Bus degree (for feature synthesis + sanity checks).
    pub fn degrees(&self) -> Vec<usize> {
        let mut d = vec![0; N_BUS];
        for br in &self.branches {
            d[br.from] += 1;
            d[br.to] += 1;
        }
        d
    }

    /// Total measurement count of the standard DC sensor suite:
    /// one flow per branch + one injection per bus.
    pub fn n_measurements(&self) -> usize {
        self.branches.len() + N_BUS
    }

    /// Check the grid is a single connected component.
    pub fn is_connected(&self) -> bool {
        let mut adj = vec![Vec::new(); N_BUS];
        for br in &self.branches {
            adj[br.from].push(br.to);
            adj[br.to].push(br.from);
        }
        let mut seen = vec![false; N_BUS];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == N_BUS
    }
}

fn sample_x(rng: &mut Rng) -> f64 {
    // log-uniform reactance in [0.02, 0.2] p.u.
    0.02 * (10.0f64).powf(rng.f64())
}

fn key(a: usize, b: usize) -> (usize, usize) {
    (a.min(b), a.max(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_match_ieee118() {
        let g = Grid::ieee118(0);
        assert_eq!(g.branches.len(), N_BRANCH);
        assert_eq!(g.gen_buses.len(), N_GEN);
        assert_eq!(g.base_load.len(), N_BUS);
        assert_eq!(g.n_measurements(), N_BRANCH + N_BUS);
    }

    #[test]
    fn connected_and_deterministic() {
        let g1 = Grid::ieee118(7);
        let g2 = Grid::ieee118(7);
        assert!(g1.is_connected());
        assert_eq!(g1.branches.len(), g2.branches.len());
        for (a, b) in g1.branches.iter().zip(&g2.branches) {
            assert_eq!((a.from, a.to), (b.from, b.to));
            assert_eq!(a.x, b.x);
        }
    }

    #[test]
    fn no_self_loops_or_duplicates() {
        let g = Grid::ieee118(3);
        let mut seen = std::collections::HashSet::new();
        for br in &g.branches {
            assert_ne!(br.from, br.to);
            assert!(seen.insert(key(br.from, br.to)), "dup branch");
            assert!(br.x >= 0.02 && br.x <= 0.2 + 1e-9);
        }
    }
}
