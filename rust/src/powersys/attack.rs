//! FDIA construction: stealthy attacks `a = H·c` (invisible to residual
//! BDD — Liu, Ning & Reiter's classical result) and naive random attacks
//! (which BDD catches).  The detector the paper trains must catch what BDD
//! cannot.

use crate::powersys::dcpf::DcPowerFlow;
use crate::powersys::ieee118::N_BUS;
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackKind {
    /// a = H·c with sparse c: bypasses BDD entirely.
    Stealthy,
    /// Random measurement corruption: detectable by BDD.
    Random,
    /// Proportional scaling of a measurement subset (load-altering flavor).
    Scaling,
}

#[derive(Clone, Debug)]
pub struct Attack {
    pub kind: AttackKind,
    /// Additive perturbation on the measurement vector.
    pub delta: Vec<f64>,
    /// Buses whose state the attacker targets (c-support for stealthy).
    pub target_buses: Vec<usize>,
    pub magnitude: f64,
}

pub struct AttackGen<'a> {
    pf: &'a DcPowerFlow,
    h_rows: usize,
}

impl<'a> AttackGen<'a> {
    pub fn new(pf: &'a DcPowerFlow) -> AttackGen<'a> {
        let h_rows = pf.grid.n_measurements();
        AttackGen { pf, h_rows }
    }

    /// Stealthy FDIA: pick `k` target buses, draw attack state shift c,
    /// inject a = H·c.  The estimator absorbs c into the state, so the
    /// residual is **unchanged** — this is the attack class the DLRM must
    /// learn to catch.
    pub fn stealthy(&self, rng: &mut Rng, k: usize, magnitude: f64) -> Attack {
        let targets = rng.sample_distinct(N_BUS - 1, k.max(1));
        let mut c = vec![0.0; N_BUS - 1];
        for &t in &targets {
            c[t] = magnitude * (rng.normal() * 0.5 + (if rng.coin(0.5) { 1.0 } else { -1.0 }));
        }
        let h = self.pf.jacobian();
        let delta = h.matvec(&c);
        Attack {
            kind: AttackKind::Stealthy,
            delta,
            target_buses: targets.iter().map(|&t| t + 1).collect(),
            magnitude,
        }
    }

    /// Random corruption of `k` measurements — BDD-detectable.
    pub fn random(&self, rng: &mut Rng, k: usize, magnitude: f64) -> Attack {
        let rows = rng.sample_distinct(self.h_rows, k.max(1));
        let mut delta = vec![0.0; self.h_rows];
        for &r in &rows {
            delta[r] = magnitude * rng.normal();
        }
        Attack {
            kind: AttackKind::Random,
            delta,
            target_buses: vec![],
            magnitude,
        }
    }

    /// Scale a contiguous measurement window (mimics coordinated load
    /// falsification) — partially detectable.
    pub fn scaling(&self, rng: &mut Rng, z: &[f64], frac: f64, factor: f64) -> Attack {
        let span = ((self.h_rows as f64) * frac) as usize;
        let start = rng.usize_below(self.h_rows - span.max(1));
        let mut delta = vec![0.0; self.h_rows];
        for i in start..start + span {
            delta[i] = z[i] * (factor - 1.0);
        }
        Attack {
            kind: AttackKind::Scaling,
            delta,
            target_buses: vec![],
            magnitude: factor,
        }
    }
}

/// Apply an attack to a measurement vector.
pub fn apply(z: &[f64], attack: &Attack) -> Vec<f64> {
    z.iter().zip(&attack.delta).map(|(a, b)| a + b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::estimation::Estimator;
    use crate::powersys::ieee118::Grid;
    use crate::util::check::check_cases;

    fn clean_measurements(pf: &DcPowerFlow, rng: &mut Rng) -> Vec<f64> {
        let inj: Vec<f64> = (0..N_BUS).map(|_| rng.normal() * 0.1).collect();
        let theta = pf.solve_angles(&inj);
        let mut z = pf.flows(&theta);
        z.extend(pf.injections(&theta));
        for v in z.iter_mut() {
            *v += rng.normal() * 0.005; // sensor noise
        }
        z
    }

    #[test]
    fn stealthy_attack_preserves_residual() {
        let pf = DcPowerFlow::new(Grid::ieee118(8));
        let est = Estimator::new(&pf);
        let gen = AttackGen::new(&pf);
        check_cases("stealthy", 10, |rng, _| {
            let z = clean_measurements(&pf, rng);
            let r0 = est.estimate(&z).residual_norm;
            let atk = gen.stealthy(rng, 4, 0.5);
            let za = apply(&z, &atk);
            let r1 = est.estimate(&za).residual_norm;
            assert!(
                (r1 - r0).abs() < 1e-6 * r0.max(1.0),
                "stealthy attack changed residual: {r0} -> {r1}"
            );
            // ... but it does move the measurements substantially
            let shift: f64 = atk.delta.iter().map(|d| d * d).sum::<f64>().sqrt();
            assert!(shift > 0.1, "attack too small to matter: {shift}");
        });
    }

    #[test]
    fn random_attack_trips_bdd() {
        let pf = DcPowerFlow::new(Grid::ieee118(8));
        let est = Estimator::new(&pf);
        let gen = AttackGen::new(&pf);
        check_cases("random-detectable", 10, |rng, _| {
            let z = clean_measurements(&pf, rng);
            let r0 = est.estimate(&z).residual_norm;
            let atk = gen.random(rng, 6, 5.0);
            let za = apply(&z, &atk);
            let r1 = est.estimate(&za).residual_norm;
            assert!(r1 > 2.0 * r0, "random attack invisible: {r0} -> {r1}");
        });
    }

    #[test]
    fn scaling_attack_shapes() {
        let pf = DcPowerFlow::new(Grid::ieee118(8));
        let gen = AttackGen::new(&pf);
        let mut rng = Rng::new(3);
        let z = clean_measurements(&pf, &mut rng);
        let atk = gen.scaling(&mut rng, &z, 0.1, 1.5);
        let touched = atk.delta.iter().filter(|d| d.abs() > 0.0).count();
        assert!(touched > 0 && touched <= z.len() / 5);
    }
}
