//! Modularity-based community detection (paper Eq. 10, refs [40–42]) —
//! a single-level Louvain pass with deterministic scan order, plus a
//! hierarchical coarsening loop.
//!
//! Quality target: the paper only requires "dense intra-community,
//! sparse inter-community" clusters to feed the index bijection, so we
//! implement the standard greedy modularity ascent: repeatedly move nodes
//! to the neighboring community with the largest positive ΔQ until a full
//! sweep makes no move, then contract communities and repeat.

use std::collections::HashMap;

use crate::reorder::graph::IndexGraph;

pub struct Communities {
    /// community id per dense node (contiguous ids 0..n_comms)
    pub assign: Vec<usize>,
    pub n_comms: usize,
    pub modularity: f64,
}

/// Greedy modularity ascent on the index graph.
pub fn louvain(g: &IndexGraph) -> Communities {
    let n = g.num_nodes();
    if n == 0 {
        return Communities { assign: vec![], n_comms: 0, modularity: 0.0 };
    }
    // current adjacency, neighbors already in ascending id order —
    // IndexGraph stores sorted neighbor lists (not hash maps) precisely
    // so the f64 degree sums and the ΔQ tie-breaks below are pure
    // functions of the graph (the online-reorder engines are asserted
    // bit-identical across rebuild invocations)
    let mut adj: Vec<Vec<(usize, f64)>> = g.adj.clone();
    // node -> original nodes it represents (for unfolding)
    let mut members: Vec<Vec<usize>> = (0..n).map(|v| vec![v]).collect();
    let mut final_assign = vec![0usize; n];
    let two_m = (2.0 * g.total_weight).max(1e-12);

    loop {
        let nn = adj.len();
        let degree: Vec<f64> = adj.iter().map(|a| a.iter().map(|&(_, w)| w).sum()).collect();
        let mut comm: Vec<usize> = (0..nn).collect();
        let mut comm_deg = degree.clone();

        // local moving phase.  `w_to`/`cand` are hoisted: this loop runs
        // per node per sweep per level, and it sits exactly on the
        // rebuild path whose latency the access layer instruments.
        let mut moved = true;
        let mut rounds = 0;
        let mut w_to: HashMap<usize, f64> = HashMap::new();
        let mut cand: Vec<(usize, f64)> = Vec::new();
        while moved && rounds < 32 {
            moved = false;
            rounds += 1;
            for v in 0..nn {
                let cur = comm[v];
                // weights from v into each neighboring community
                w_to.clear();
                for &(u, w) in &adj[v] {
                    if u != v {
                        *w_to.entry(comm[u]).or_insert(0.0) += w;
                    }
                }
                comm_deg[cur] -= degree[v];
                let base = w_to.get(&cur).copied().unwrap_or(0.0)
                    - comm_deg[cur] * degree[v] / two_m;
                // candidates in ascending community id: near-ties (within
                // the 1e-12 deadband) resolve to the lowest id instead of
                // whatever the map yields first — deterministic rebuilds
                cand.clear();
                // lint:allow(D1) drained into cand and id-sorted on the next line before any use
                cand.extend(w_to.iter().map(|(&c, &w)| (c, w)));
                cand.sort_unstable_by_key(|&(c, _)| c);
                let (mut best_c, mut best_gain) = (cur, 0.0f64);
                for &(c, w) in &cand {
                    if c == cur {
                        continue;
                    }
                    let gain = (w - comm_deg[c] * degree[v] / two_m) - base;
                    if gain > best_gain + 1e-12 {
                        best_gain = gain;
                        best_c = c;
                    }
                }
                comm[v] = best_c;
                comm_deg[best_c] += degree[v];
                if best_c != cur {
                    moved = true;
                }
            }
        }

        // compact community ids
        let mut remap: HashMap<usize, usize> = HashMap::new();
        for c in comm.iter_mut() {
            let next = remap.len();
            *c = *remap.entry(*c).or_insert(next);
        }
        let n_comms = remap.len();

        // write through to original nodes
        for v in 0..nn {
            for &orig in &members[v] {
                final_assign[orig] = comm[v];
            }
        }
        if n_comms == nn {
            // converged: no contraction possible
            let q = modularity(g, &final_assign);
            return Communities { assign: final_assign, n_comms, modularity: q };
        }

        // contraction phase: build the community graph
        let mut new_members: Vec<Vec<usize>> = vec![Vec::new(); n_comms];
        for v in 0..nn {
            new_members[comm[v]].append(&mut members[v].clone());
        }
        let mut new_adj_maps: Vec<HashMap<usize, f64>> = vec![HashMap::new(); n_comms];
        for v in 0..nn {
            for &(u, w) in &adj[v] {
                let (cv, cu) = (comm[v], comm[u]);
                // keep self-loops: intra-community mass must survive the
                // contraction or the next level over-merges (k_v would
                // under-count and every ΔQ toward a neighbor looks good)
                *new_adj_maps[cv].entry(cu).or_insert(0.0) += w;
            }
        }
        adj = new_adj_maps
            .into_iter()
            .map(|m| {
                let mut a: Vec<(usize, f64)> = m.into_iter().collect();
                a.sort_unstable_by_key(|&(v, _)| v);
                a
            })
            .collect();
        members = new_members;
    }
}

/// Newman modularity Q of an assignment on the original graph (Eq. 10).
pub fn modularity(g: &IndexGraph, assign: &[usize]) -> f64 {
    let m = g.total_weight;
    if m <= 0.0 {
        return 0.0;
    }
    let n_comms = assign.iter().copied().max().map(|c| c + 1).unwrap_or(0);
    let mut intra = vec![0.0; n_comms]; // e_ii (sum of intra edge weights)
    let mut deg = vec![0.0; n_comms]; // Σ k_i per community
    for v in 0..g.num_nodes() {
        deg[assign[v]] += g.degree(v);
        for &(u, w) in &g.adj[v] {
            if assign[u] == assign[v] && u > v {
                intra[assign[v]] += w;
            }
        }
    }
    (0..n_comms)
        .map(|c| intra[c] / m - (deg[c] / (2.0 * m)).powi(2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::graph::GraphBuilder;

    /// Two dense cliques with one weak bridge must split into two
    /// communities with positive modularity.
    #[test]
    fn separates_two_cliques() {
        let mut gb = GraphBuilder::new(&[]);
        for _ in 0..5 {
            gb.observe_batch(&[0, 1, 2, 3]); // clique A
            gb.observe_batch(&[10, 11, 12, 13]); // clique B
        }
        gb.observe_batch(&[3, 10]); // weak bridge
        let g = gb.build();
        let c = louvain(&g);
        assert!(c.modularity > 0.3, "Q = {}", c.modularity);
        let ca = c.assign[g.node_of[&0]];
        for i in [1u64, 2, 3] {
            assert_eq!(c.assign[g.node_of[&i]], ca);
        }
        let cb = c.assign[g.node_of[&10]];
        assert_ne!(ca, cb);
        for i in [11u64, 12, 13] {
            assert_eq!(c.assign[g.node_of[&i]], cb);
        }
    }

    #[test]
    fn modularity_of_trivial_assignment_is_nonpositive() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[0, 1, 2]);
        let g = gb.build();
        // all in one community: Q = e/m - 1 = 0... strictly: 1 - 1 = 0
        let q = modularity(&g, &vec![0; g.num_nodes()]);
        assert!(q.abs() < 1e-9, "{q}");
    }

    #[test]
    fn louvain_never_worse_than_singletons() {
        let mut gb = GraphBuilder::new(&[]);
        for b in 0..20u64 {
            gb.observe_batch(&[b % 7, (b + 1) % 7, 7 + b % 5]);
        }
        let g = gb.build();
        let singles: Vec<usize> = (0..g.num_nodes()).collect();
        let q0 = modularity(&g, &singles);
        let c = louvain(&g);
        assert!(c.modularity >= q0 - 1e-9, "{} < {}", c.modularity, q0);
        assert!(c.n_comms >= 1 && c.n_comms <= g.num_nodes());
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(&[]).build();
        let c = louvain(&g);
        assert_eq!(c.n_comms, 0);
    }

    #[test]
    fn assignment_ids_contiguous() {
        let mut gb = GraphBuilder::new(&[]);
        for _ in 0..3 {
            gb.observe_batch(&[0, 1]);
            gb.observe_batch(&[5, 6]);
            gb.observe_batch(&[9, 12]);
        }
        let g = gb.build();
        let c = louvain(&g);
        let max = c.assign.iter().copied().max().unwrap();
        assert_eq!(max + 1, c.n_comms);
    }
}
