//! Local-information pass: the batch co-occurrence index graph
//! (paper Algorithm 2, Fig. 7 step 1).
//!
//! Nodes are (non-hot) embedding indices; an edge connects two indices
//! each time they co-occur in the same mini-batch.  Edge weights feed the
//! modularity clustering in `louvain.rs`.

use std::collections::HashMap;

/// Compressed index graph: adjacency with accumulated co-occurrence
/// weights, nodes remapped to dense ids.
pub struct IndexGraph {
    /// dense node id -> original embedding index
    pub nodes: Vec<u64>,
    /// original embedding index -> dense node id
    pub node_of: HashMap<u64, usize>,
    /// adjacency: per node, (neighbor dense id, weight)
    pub adj: Vec<HashMap<usize, f64>>,
    pub total_weight: f64,
}

pub struct GraphBuilder {
    hot: std::collections::HashSet<u64>,
    /// Cap on pairs per batch — co-occurrence is quadratic in batch size,
    /// so like Rabbit-Order-style preprocessing we subsample long batches.
    max_pairs_per_batch: usize,
    pairs: HashMap<(u64, u64), f64>,
}

impl GraphBuilder {
    pub fn new(hot: &[u64]) -> GraphBuilder {
        GraphBuilder {
            hot: hot.iter().copied().collect(),
            max_pairs_per_batch: 4096,
            pairs: HashMap::new(),
        }
    }

    /// Add one batch's indices (Algorithm 2 `self_combinations`): every
    /// unordered pair of distinct, non-hot indices gains weight 1.
    pub fn observe_batch(&mut self, batch: &[u64]) {
        // dedup within batch first: co-occurrence is a set property
        let mut uniq: Vec<u64> = batch
            .iter()
            .copied()
            .filter(|i| !self.hot.contains(i))
            .collect();
        uniq.sort_unstable();
        uniq.dedup();
        let n = uniq.len();
        if n < 2 {
            return;
        }
        // bound quadratic blowup: stride over pairs if needed
        let all_pairs = n * (n - 1) / 2;
        let stride = (all_pairs / self.max_pairs_per_batch).max(1);
        let mut c = 0usize;
        for a in 0..n {
            for b in a + 1..n {
                if c % stride == 0 {
                    let key = (uniq[a], uniq[b]);
                    *self.pairs.entry(key).or_insert(0.0) += stride as f64;
                }
                c += 1;
            }
        }
    }

    pub fn build(self) -> IndexGraph {
        let mut node_of: HashMap<u64, usize> = HashMap::new();
        let mut nodes = Vec::new();
        let intern = |i: u64, nodes: &mut Vec<u64>, node_of: &mut HashMap<u64, usize>| {
            *node_of.entry(i).or_insert_with(|| {
                nodes.push(i);
                nodes.len() - 1
            })
        };
        // Canonical edge order: HashMap iteration order varies PER
        // INSTANCE, so interning in it would assign different dense node
        // ids (and different f64 accumulation orders) to identical
        // inputs on every build — and the whole reorder stack must be a
        // pure function of its inputs (the background refresh engine is
        // asserted bit-identical to its synchronous twin, and pipeline ==
        // sequential replays rebuilds).  Sorting by the (a, b) key
        // restores that.
        let mut pairs: Vec<((u64, u64), f64)> = self.pairs.into_iter().collect();
        pairs.sort_unstable_by_key(|&(k, _)| k);
        let mut edges: Vec<(usize, usize, f64)> = Vec::with_capacity(pairs.len());
        for ((a, b), w) in pairs {
            let ia = intern(a, &mut nodes, &mut node_of);
            let ib = intern(b, &mut nodes, &mut node_of);
            edges.push((ia, ib, w));
        }
        let mut adj = vec![HashMap::new(); nodes.len()];
        let mut total = 0.0;
        for (a, b, w) in edges {
            *adj[a].entry(b).or_insert(0.0) += w;
            *adj[b].entry(a).or_insert(0.0) += w;
            total += w;
        }
        IndexGraph { nodes, node_of, adj, total_weight: total }
    }
}

impl IndexGraph {
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Weighted degree of a node.
    pub fn degree(&self, v: usize) -> f64 {
        self.adj[v].values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooccurrence_weights() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[1, 2, 3]);
        gb.observe_batch(&[1, 2]);
        let g = gb.build();
        assert_eq!(g.num_nodes(), 3);
        let a = g.node_of[&1];
        let b = g.node_of[&2];
        let c = g.node_of[&3];
        assert_eq!(g.adj[a][&b], 2.0); // co-occurred twice
        assert_eq!(g.adj[a][&c], 1.0);
        assert_eq!(g.total_weight, 4.0); // edges (1,2)x2 (1,3) (2,3)
    }

    #[test]
    fn hot_indices_excluded() {
        let mut gb = GraphBuilder::new(&[7]);
        gb.observe_batch(&[7, 1, 2]);
        let g = gb.build();
        assert!(!g.node_of.contains_key(&7));
        assert_eq!(g.num_nodes(), 2);
    }

    #[test]
    fn duplicate_in_batch_counts_once() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[4, 4, 9]);
        let g = gb.build();
        let a = g.node_of[&4];
        let b = g.node_of[&9];
        assert_eq!(g.adj[a][&b], 1.0);
    }

    #[test]
    fn large_batch_subsampled_but_connected() {
        let mut gb = GraphBuilder::new(&[]);
        let batch: Vec<u64> = (0..500).collect();
        gb.observe_batch(&batch);
        let g = gb.build();
        assert!(g.num_nodes() > 0);
        // subsampling keeps total weight ≈ all pairs
        let expect = 500.0 * 499.0 / 2.0;
        assert!((g.total_weight - expect).abs() / expect < 0.1);
    }
}
