//! Local-information pass: the batch co-occurrence index graph
//! (paper Algorithm 2, Fig. 7 step 1).
//!
//! Nodes are (non-hot) embedding indices; an edge connects two indices
//! each time they co-occur in the same mini-batch.  Edge weights feed the
//! modularity clustering in `louvain.rs`.

use std::collections::HashMap;

/// Compressed index graph: adjacency with accumulated co-occurrence
/// weights, nodes remapped to dense ids.
///
/// The adjacency is stored as *sorted* neighbor lists, not hash maps:
/// `degree()` and the Louvain modularity sums accumulate f64 edge
/// weights in neighbor order, and hash iteration order varies per
/// process — with hash adjacency two builds of the same graph could
/// disagree in the last ulp and flip a ΔQ tie-break (the exact class
/// of bug PR 3 fixed in the edge-interning order; `recad lint` rule D1
/// now bans the pattern outright).
pub struct IndexGraph {
    /// dense node id -> original embedding index
    pub nodes: Vec<u64>,
    /// original embedding index -> dense node id
    pub node_of: HashMap<u64, usize>,
    /// adjacency: per node, (neighbor dense id, weight), sorted by
    /// neighbor id with one entry per neighbor
    pub adj: Vec<Vec<(usize, f64)>>,
    pub total_weight: f64,
}

pub struct GraphBuilder {
    hot: std::collections::HashSet<u64>,
    /// Cap on pairs per batch — co-occurrence is quadratic in batch size,
    /// so like Rabbit-Order-style preprocessing we subsample long batches.
    max_pairs_per_batch: usize,
    pairs: HashMap<(u64, u64), f64>,
}

impl GraphBuilder {
    pub fn new(hot_ids: &[u64]) -> GraphBuilder {
        GraphBuilder {
            hot: hot_ids.iter().copied().collect(),
            max_pairs_per_batch: 4096,
            pairs: HashMap::new(),
        }
    }

    /// Add one batch's indices (Algorithm 2 `self_combinations`): every
    /// unordered pair of distinct, non-hot indices gains weight 1.
    pub fn observe_batch(&mut self, batch: &[u64]) {
        // dedup within batch first: co-occurrence is a set property
        let mut uniq: Vec<u64> = batch
            .iter()
            .copied()
            .filter(|i| !self.hot.contains(i))
            .collect();
        uniq.sort_unstable();
        uniq.dedup();
        let n = uniq.len();
        if n < 2 {
            return;
        }
        // bound quadratic blowup: stride over pairs if needed
        let all_pairs = n * (n - 1) / 2;
        let stride = (all_pairs / self.max_pairs_per_batch).max(1);
        let mut c = 0usize;
        for a in 0..n {
            for b in a + 1..n {
                if c % stride == 0 {
                    let key = (uniq[a], uniq[b]);
                    *self.pairs.entry(key).or_insert(0.0) += stride as f64;
                }
                c += 1;
            }
        }
    }

    pub fn build(self) -> IndexGraph {
        let mut node_of: HashMap<u64, usize> = HashMap::new();
        let mut nodes = Vec::new();
        let intern = |i: u64, nodes: &mut Vec<u64>, node_of: &mut HashMap<u64, usize>| {
            *node_of.entry(i).or_insert_with(|| {
                nodes.push(i);
                nodes.len() - 1
            })
        };
        // Canonical edge order: HashMap iteration order varies PER
        // INSTANCE, so interning in it would assign different dense node
        // ids (and different f64 accumulation orders) to identical
        // inputs on every build — and the whole reorder stack must be a
        // pure function of its inputs (the background refresh engine is
        // asserted bit-identical to its synchronous twin, and pipeline ==
        // sequential replays rebuilds).  Sorting by the (a, b) key
        // restores that.
        // lint:allow(D1) pair accumulator is drained once and key-sorted on the next line
        let mut sorted_pairs: Vec<((u64, u64), f64)> = self.pairs.into_iter().collect();
        sorted_pairs.sort_unstable_by_key(|&(k, _)| k);
        let mut edges: Vec<(usize, usize, f64)> = Vec::with_capacity(sorted_pairs.len());
        for ((a, b), w) in sorted_pairs {
            let ia = intern(a, &mut nodes, &mut node_of);
            let ib = intern(b, &mut nodes, &mut node_of);
            edges.push((ia, ib, w));
        }
        let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); nodes.len()];
        let mut total = 0.0;
        for (a, b, w) in edges {
            adj[a].push((b, w));
            adj[b].push((a, w));
            total += w;
        }
        // neighbor lists in ascending id order; the (a, b) keys were
        // unique so no neighbor repeats and no merge is needed
        for list in adj.iter_mut() {
            list.sort_unstable_by_key(|&(v, _)| v);
        }
        IndexGraph { nodes, node_of, adj, total_weight: total }
    }
}

impl IndexGraph {
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Weighted degree of a node (neighbor-order f64 sum — stable, the
    /// adjacency is canonically sorted).
    pub fn degree(&self, v: usize) -> f64 {
        self.adj[v].iter().map(|&(_, w)| w).sum()
    }

    /// Weight of the edge `(a, b)`, 0.0 when absent.
    pub fn weight(&self, a: usize, b: usize) -> f64 {
        match self.adj[a].binary_search_by_key(&b, |&(v, _)| v) {
            Ok(i) => self.adj[a][i].1,
            Err(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooccurrence_weights() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[1, 2, 3]);
        gb.observe_batch(&[1, 2]);
        let g = gb.build();
        assert_eq!(g.num_nodes(), 3);
        let a = g.node_of[&1];
        let b = g.node_of[&2];
        let c = g.node_of[&3];
        assert_eq!(g.weight(a, b), 2.0); // co-occurred twice
        assert_eq!(g.weight(a, c), 1.0);
        assert_eq!(g.total_weight, 4.0); // edges (1,2)x2 (1,3) (2,3)
    }

    #[test]
    fn adjacency_is_sorted_and_symmetric() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[5, 1, 9, 3]);
        let g = gb.build();
        for v in 0..g.num_nodes() {
            let ids: Vec<usize> = g.adj[v].iter().map(|&(u, _)| u).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(ids, sorted, "node {v} adjacency not sorted/unique");
            for &(u, w) in &g.adj[v] {
                assert_eq!(g.weight(u, v), w, "asymmetric edge ({v},{u})");
            }
        }
    }

    #[test]
    fn hot_indices_excluded() {
        let mut gb = GraphBuilder::new(&[7]);
        gb.observe_batch(&[7, 1, 2]);
        let g = gb.build();
        assert!(!g.node_of.contains_key(&7));
        assert_eq!(g.num_nodes(), 2);
    }

    #[test]
    fn duplicate_in_batch_counts_once() {
        let mut gb = GraphBuilder::new(&[]);
        gb.observe_batch(&[4, 4, 9]);
        let g = gb.build();
        let a = g.node_of[&4];
        let b = g.node_of[&9];
        assert_eq!(g.weight(a, b), 1.0);
    }

    #[test]
    fn large_batch_subsampled_but_connected() {
        let mut gb = GraphBuilder::new(&[]);
        let batch: Vec<u64> = (0..500).collect();
        gb.observe_batch(&batch);
        let g = gb.build();
        assert!(g.num_nodes() > 0);
        // subsampling keeps total weight ≈ all pairs
        let expect = 500.0 * 499.0 / 2.0;
        assert!((g.total_weight - expect).abs() / expect < 0.1);
    }
}
