//! The dual-projection index bijection (paper §III-H, Fig. 7, Eq. 9).
//!
//! Combines global information (frequency order; hot indices pinned to the
//! lowest new ids) with local information (community members get adjacent
//! new ids) into a permutation `f_index` over the table's row space.
//! Adjacent new ids share TT prefixes (`i // m3`), so a well-clustered
//! bijection directly raises the Eff-TT reuse-buffer hit rate — the link
//! the Fig. 12 ablation measures.
//!
//! The bijection is built **offline** from a training-batch sample (paper:
//! "hot index identification and community detection can be performed
//! offline") and applied per batch with an O(1) array lookup.

use std::collections::HashMap;

use crate::reorder::freq::FreqCounter;
use crate::reorder::graph::GraphBuilder;
use crate::reorder::louvain::louvain;

/// A permutation over [0, rows) applied to embedding indices before
/// lookup.
///
/// Profiled ids get the curated layout (hot block, then community
/// blocks); all remaining ids fill the remaining new-id slots *in
/// ascending original order*, so any locality already present in the
/// unprofiled tail survives the remap.  For tables small enough to
/// materialize (≤ `DENSE_LIMIT` rows) the permutation is a flat array —
/// O(1) lookup on the hot path; larger tables keep the sparse map and
/// fall back to identity for unprofiled ids.
#[derive(Clone)]
pub struct IndexBijection {
    /// old index -> new index (sparse: only remapped ids stored)
    map: HashMap<u64, u64>,
    /// total permutation (old -> new) when rows <= DENSE_LIMIT
    dense: Option<Vec<u64>>,
    pub rows: u64,
    pub n_hot: usize,
    pub n_communities: usize,
    pub modularity: f64,
}

/// Materialization threshold: 32M rows ⇒ 256 MB of u64 — the same order
/// as the embedding cache itself; beyond that the sparse map suffices
/// because unprofiled ids are by definition cold.
const DENSE_LIMIT: u64 = 32_000_000;

/// Materialize the total permutation for a curated sparse map:
/// unprofiled ids fill the remaining new-id slots in ascending original
/// order.  Deterministic given `(rows, map)` — shared by the offline
/// builder and the snapshot deserializer ([`IndexBijection::from_entries`])
/// so a bijection shipped to another node applies bit-identically.
fn totalize(rows: u64, map: &HashMap<u64, u64>) -> Option<Vec<u64>> {
    if rows > DENSE_LIMIT {
        return None;
    }
    let mut d = vec![u64::MAX; rows as usize];
    // lint:allow(D1) each entry writes its own d[old] slot — order-free
    for (&old, &new) in map {
        d[old as usize] = new;
    }
    let mut slot = 0u64;
    // lint:allow(D1) collected into a membership set; no order survives
    let taken: std::collections::HashSet<u64> = map.values().copied().collect();
    for old in 0..rows {
        if d[old as usize] == u64::MAX {
            while taken.contains(&slot) {
                slot += 1;
            }
            d[old as usize] = slot;
            slot += 1;
        }
    }
    Some(d)
}

impl IndexBijection {
    /// Identity bijection (reordering disabled — the ablation arm).
    pub fn identity(rows: u64) -> IndexBijection {
        IndexBijection {
            map: HashMap::new(),
            dense: None,
            rows,
            n_hot: 0,
            n_communities: 0,
            modularity: 0.0,
        }
    }

    /// Build from a sample of training batches (Fig. 7 pipeline):
    /// 1. frequency pass → hot set pinned to new ids [0, n_hot)
    /// 2. co-occurrence graph over the rest → Louvain communities
    /// 3. communities laid out contiguously, members ordered by frequency
    pub fn build(rows: u64, batches: &[&[u64]], hot_ratio: f64) -> IndexBijection {
        let mut freq = FreqCounter::new();
        for b in batches {
            freq.observe(b);
        }
        Self::build_with_freq(rows, &freq, batches, hot_ratio)
    }

    /// Like [`IndexBijection::build`], but with the frequency statistics
    /// supplied by the caller — the online reorderer maintains them
    /// incrementally (with decay) across a longer horizon than the
    /// co-occurrence `batches` window.
    pub fn build_with_freq(
        rows: u64,
        freq: &FreqCounter,
        batches: &[&[u64]],
        hot_ratio: f64,
    ) -> IndexBijection {
        let hot = freq.hot_set(hot_ratio);

        let mut gb = GraphBuilder::new(&hot);
        for b in batches {
            gb.observe_batch(b);
        }
        let g = gb.build();
        let comms = louvain(&g);

        let mut map = HashMap::new();
        let mut next: u64 = 0;
        // 1) hot indices first: most-frequent get smallest ids => they all
        //    share the low TT prefixes and stay cache-resident
        for &h in &hot {
            map.insert(h, next);
            next += 1;
        }
        // 2) communities: larger (by access mass) first, members by freq
        let mut by_comm: Vec<Vec<usize>> = vec![Vec::new(); comms.n_comms];
        for v in 0..g.num_nodes() {
            by_comm[comms.assign[v]].push(v);
        }
        let mass = |vs: &Vec<usize>| -> u64 {
            vs.iter().map(|&v| freq.count_of(g.nodes[v])).sum()
        };
        let mut order: Vec<usize> = (0..comms.n_comms).collect();
        order.sort_by_key(|&c| std::cmp::Reverse(mass(&by_comm[c])));
        for c in order {
            let mut vs = by_comm[c].clone();
            vs.sort_by_key(|&v| std::cmp::Reverse(freq.count_of(g.nodes[v])));
            for v in vs {
                let old = g.nodes[v];
                if !map.contains_key(&old) {
                    map.insert(old, next);
                    next += 1;
                }
            }
        }
        // 3) any remaining profiled ids (singletons not in graph)
        for old in freq.freq_order() {
            if !map.contains_key(&old) {
                map.insert(old, next);
                next += 1;
            }
        }
        // 4) totalize: unprofiled ids fill the remaining slots in
        //    ascending order (locality-preserving tail)
        let dense = totalize(rows, &map);
        IndexBijection {
            map,
            dense,
            rows,
            n_hot: hot.len(),
            n_communities: comms.n_comms,
            modularity: comms.modularity,
        }
    }

    /// Apply `f_index` (Eq. 9): O(1) array lookup for materialized
    /// permutations; sparse-map-or-identity for huge tables (unprofiled
    /// ids there are cold by definition and collisions with curated slots
    /// are statistically negligible at that scale).
    #[inline]
    pub fn apply(&self, old: u64) -> u64 {
        if let Some(d) = &self.dense {
            return d[old as usize];
        }
        self.map.get(&old).copied().unwrap_or(old)
    }

    pub fn apply_batch(&self, indices: &mut [u64]) {
        for i in indices.iter_mut() {
            *i = self.apply(*i);
        }
    }

    /// Number of explicitly remapped ids.
    pub fn mapped(&self) -> usize {
        self.map.len()
    }

    /// The curated `(old, new)` pairs, sorted by old id — a canonical
    /// order despite the backing `HashMap`, so serialized snapshots are
    /// byte-stable across runs.
    pub fn entries(&self) -> Vec<(u64, u64)> {
        // lint:allow(D1) drained to a Vec and fully sorted on the next line
        let mut e: Vec<(u64, u64)> = self.map.iter().map(|(&o, &n)| (o, n)).collect();
        e.sort_unstable();
        e
    }

    /// Rebuild a bijection from a serialized snapshot
    /// ([`entries`](Self::entries) plus the summary stats).  The dense
    /// materialization is re-derived with the same `totalize` pass the
    /// builder uses, so `apply` is bit-identical to the original.
    pub fn from_entries(
        rows: u64,
        n_hot: usize,
        n_communities: usize,
        modularity: f64,
        entries: &[(u64, u64)],
    ) -> IndexBijection {
        let map: HashMap<u64, u64> = entries.iter().copied().collect();
        let dense = totalize(rows, &map);
        IndexBijection { map, dense, rows, n_hot, n_communities, modularity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::Zipf;
    use crate::tt::shapes::TtShapes;
    use crate::util::prng::Rng;

    fn sample_batches(rng: &mut Rng, n: usize, bs: usize, vocab: u64) -> Vec<Vec<u64>> {
        // Co-occurrence structure: batches draw from one of 4 "themes";
        // ids are then scrambled by a fixed permutation, mimicking how
        // production systems assign sparse ids by hashing — raw indices
        // carry NO spatial locality (the paper's §III-G premise).
        let mut perm: Vec<u64> = (0..vocab).collect();
        let mut prng = Rng::new(0xBEEF);
        prng.shuffle(&mut perm);
        let z = Zipf::new(vocab / 4, 1.1);
        (0..n)
            .map(|i| {
                let theme = (i % 4) as u64 * (vocab / 4);
                (0..bs).map(|_| perm[(theme + z.sample(rng)) as usize]).collect()
            })
            .collect()
    }

    #[test]
    fn bijection_is_injective_on_profiled_ids() {
        let mut rng = Rng::new(1);
        let batches = sample_batches(&mut rng, 30, 32, 4000);
        let refs: Vec<&[u64]> = batches.iter().map(|b| b.as_slice()).collect();
        let bij = IndexBijection::build(4000, &refs, 0.2);
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            for &i in b {
                let n = bij.apply(i);
                assert!(n < 4000);
                // same old id must always map to same new id
                let again = bij.apply(i);
                assert_eq!(n, again);
            }
        }
        // distinct profiled olds -> distinct news
        // lint:allow(D1) injectivity is a ∀-check over all entries — order-free
        for (&old, &new) in bij.map.iter() {
            assert!(seen.insert(new), "collision at old={old} new={new}");
        }
    }

    #[test]
    fn hot_ids_get_smallest_new_ids() {
        let mut rng = Rng::new(2);
        let batches = sample_batches(&mut rng, 30, 32, 4000);
        let refs: Vec<&[u64]> = batches.iter().map(|b| b.as_slice()).collect();
        let bij = IndexBijection::build(4000, &refs, 0.3);
        assert!(bij.n_hot > 0);
        // the most frequent id maps below n_hot
        let mut freq = FreqCounter::new();
        for b in &batches {
            freq.observe(b);
        }
        let top = freq.freq_order()[0];
        assert!(bij.apply(top) < bij.n_hot as u64);
    }

    /// The headline claim of §III-G: reordering must RAISE the number of
    /// shared TT prefixes within a batch.
    #[test]
    fn reordering_improves_prefix_sharing() {
        let mut rng = Rng::new(3);
        let vocab = 8000u64;
        let shapes = TtShapes::plan(vocab, 16, 8);
        let batches = sample_batches(&mut rng, 50, 64, vocab);
        let refs: Vec<&[u64]> = batches.iter().map(|b| b.as_slice()).collect();
        let bij = IndexBijection::build(vocab, &refs, 0.1);

        let distinct_prefixes = |batch: &[u64]| -> usize {
            let s: std::collections::HashSet<u64> =
                batch.iter().map(|&i| shapes.prefix_of(i)).collect();
            s.len()
        };
        let mut before = 0usize;
        let mut after = 0usize;
        // fresh batches from the same distribution (test generalization)
        let eval = sample_batches(&mut rng, 30, 64, vocab);
        for b in &eval {
            before += distinct_prefixes(b);
            let mut nb = b.clone();
            bij.apply_batch(&mut nb);
            after += distinct_prefixes(&nb);
        }
        assert!(
            after < before,
            "reordering did not improve prefix sharing: {after} !< {before}"
        );
    }

    #[test]
    fn identity_is_noop() {
        let bij = IndexBijection::identity(100);
        for i in 0..100 {
            assert_eq!(bij.apply(i), i);
        }
    }

    #[test]
    fn entries_snapshot_rebuilds_bit_identically() {
        let mut rng = Rng::new(4);
        let batches = sample_batches(&mut rng, 30, 32, 4000);
        let refs: Vec<&[u64]> = batches.iter().map(|b| b.as_slice()).collect();
        let bij = IndexBijection::build(4000, &refs, 0.2);
        let back = IndexBijection::from_entries(
            bij.rows,
            bij.n_hot,
            bij.n_communities,
            bij.modularity,
            &bij.entries(),
        );
        for old in 0..4000 {
            assert_eq!(bij.apply(old), back.apply(old), "remap drifted at {old}");
        }
        assert_eq!(bij.entries(), back.entries(), "entries not canonical");
        // an identity snapshot stays identity
        let id = IndexBijection::identity(64);
        let id2 = IndexBijection::from_entries(64, 0, 0, 0.0, &id.entries());
        for old in 0..64 {
            assert_eq!(id2.apply(old), old);
        }
    }
}
