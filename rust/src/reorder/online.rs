//! Online index reordering: an incremental frequency tracker that
//! refreshes the dual-projection bijection every K batches, so the
//! reuse-buffer hit rate tracks *drifting* index distributions instead of
//! being pinned to an offline profiling sample (paper §III-H builds the
//! bijection offline; this is the streaming extension the access layer
//! enables).
//!
//! Two refresh engines share the window/decay semantics:
//!
//! * [`OnlineReorderer`] — the PR-2 inline engine: the O(window·Louvain)
//!   rebuild runs ON the ingest thread at the trigger batch (full stall).
//! * [`BackgroundReorderer`] — the rebuild runs on a worker thread and
//!   lands through an epoch-tagged double-buffer swap; the ingest thread
//!   adopts the new bijection at a FIXED batch lag after the trigger
//!   (blocking only if the worker hasn't finished by then).  Because the
//!   adoption point is a function of the batch index — never of timing —
//!   background refresh is **bit-identical** to its synchronous-compute
//!   twin (`synchronous = true`, same lag) while its per-batch ingest
//!   stall shrinks from the full rebuild to the residual join wait.
//!
//! Semantics note: refreshing the bijection mid-training re-assigns
//! embedding rows to entities that moved (the standard re-bucketing
//! trade-off of hot/cold systems like FAE); it is a *systems*
//! optimization — the drift test in `tests/plan_equivalence.rs` measures
//! its effect on prefix sharing, not on model accuracy.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::reorder::bijection::IndexBijection;
use crate::reorder::freq::FreqCounter;

/// Per-table online reorder state.
#[derive(Clone)]
pub struct OnlineReorderer {
    rows: u64,
    hot_ratio: f64,
    refresh_every: usize,
    window_cap: usize,
    /// Incremental frequency counts, exponentially decayed at each
    /// refresh so stale mass ages out under drift.
    freq: FreqCounter,
    /// Recent raw index batches — the co-occurrence sample the next
    /// refresh builds its community graph from.
    window: VecDeque<Vec<u64>>,
    since_refresh: usize,
    /// Current bijection (identity until the first refresh).
    pub bijection: IndexBijection,
    /// Number of rebuilds performed.
    pub refreshes: u64,
}

impl OnlineReorderer {
    /// `refresh_every`: batches between bijection rebuilds (K).
    /// `window_cap`: co-occurrence sample size kept for the rebuild.
    pub fn new(rows: u64, hot_ratio: f64, refresh_every: usize, window_cap: usize) -> Self {
        assert!(refresh_every >= 1, "refresh interval must be >= 1");
        OnlineReorderer {
            rows,
            hot_ratio,
            refresh_every,
            window_cap: window_cap.max(1),
            freq: FreqCounter::new(),
            window: VecDeque::new(),
            since_refresh: 0,
            bijection: IndexBijection::identity(rows),
            refreshes: 0,
        }
    }

    /// Feed one RAW (pre-remap) index column; returns `true` when this
    /// call triggered a bijection refresh.
    pub fn observe(&mut self, col: &[u64]) -> bool {
        self.freq.observe(col);
        if self.window.len() == self.window_cap {
            self.window.pop_front();
        }
        self.window.push_back(col.to_vec());
        self.since_refresh += 1;
        if self.since_refresh < self.refresh_every {
            return false;
        }
        self.since_refresh = 0;
        let refs: Vec<&[u64]> = self.window.iter().map(|v| v.as_slice()).collect();
        self.bijection =
            IndexBijection::build_with_freq(self.rows, &self.freq, &refs, self.hot_ratio);
        // half-life = one refresh interval: old hot sets fade instead of
        // anchoring the layout forever
        self.freq.decay(0.5);
        self.refreshes += 1;
        true
    }

    /// Current refresh interval (batches between rebuilds).
    pub fn refresh_every(&self) -> usize {
        self.refresh_every
    }

    /// Retune the refresh interval (autotune cadence controller).  Takes
    /// effect at the next trigger check; clamped to >= 1.
    pub fn set_refresh_every(&mut self, every: usize) {
        self.refresh_every = every.max(1);
    }
}

/// Default adoption lag of the scheduled refresh engines: the rebuild
/// overlaps one training batch before its result is required.
pub const DEFAULT_ADOPT_LAG: usize = 1;

/// One rebuild request shipped to the background worker (behind an
/// `Arc`: the ingest thread keeps a second handle for crash recovery
/// without a second deep copy of freq + window).
struct RefreshJob {
    epoch: u64,
    rows: u64,
    hot_ratio: f64,
    freq: FreqCounter,
    window: Vec<Vec<u64>>,
}

/// The epoch-tagged double buffer the worker publishes into (hand-rolled
/// arc-swap over `std::sync`): worker overwrites under the mutex and
/// notifies; the ingest thread reads — or waits, at the adoption point —
/// for the epoch it scheduled.
struct SwapSlot {
    slot: Mutex<Option<(u64, IndexBijection)>>,
    ready: Condvar,
}

impl Default for SwapSlot {
    fn default() -> Self {
        SwapSlot { slot: Mutex::new(None), ready: Condvar::new() }
    }
}

/// A scheduled (not yet adopted) refresh.
struct PendingRefresh {
    epoch: u64,
    /// batches until adoption (0 = adopt on the current batch).
    countdown: usize,
    /// synchronous twin: the bijection computed inline at the trigger.
    done: Option<IndexBijection>,
    /// background engine: the snapshot that was shipped to the worker,
    /// kept so a worker that dies MID-rebuild (panic in the Louvain
    /// stack) can be recovered from at the adoption point by rebuilding
    /// inline from the identical inputs — same bijection, training
    /// survives.
    job: Option<Arc<RefreshJob>>,
    /// ingest-thread seconds already spent on this refresh (inline
    /// rebuild for the synchronous twin, snapshot+dispatch otherwise).
    stall_so_far: f64,
}

/// Per-table scheduled online-reorder state (see module docs).
pub struct BackgroundReorderer {
    rows: u64,
    hot_ratio: f64,
    refresh_every: usize,
    window_cap: usize,
    adopt_lag: usize,
    /// true = compute inline at the trigger (the stall BASELINE with the
    /// same adoption schedule); false = compute on the worker thread.
    synchronous: bool,
    /// The background worker died (send failed): rebuilds fall back to
    /// the ingest thread — training survives, the stall advantage is
    /// gone.  Logged once when first detected.
    worker_lost: bool,
    freq: FreqCounter,
    window: VecDeque<Vec<u64>>,
    since_refresh: usize,
    epoch: u64,
    pending: Option<PendingRefresh>,
    tx: Option<mpsc::Sender<Arc<RefreshJob>>>,
    worker: Option<std::thread::JoinHandle<()>>,
    swap: Arc<SwapSlot>,
    /// Current bijection (identity until the first adoption).
    pub bijection: IndexBijection,
    /// Number of adoptions performed.
    pub refreshes: u64,
    /// Per-refresh ingest-thread stall seconds (trigger + adoption work).
    /// Bounded telemetry: when it reaches [`STALL_SAMPLE_CAP`] the oldest
    /// half is dropped, so steady-state memory stays flat on long runs.
    pub stall_samples: Vec<f64>,
    /// Running maximum over ALL stall samples ever recorded — tracked
    /// independently of the drained ring so [`Self::max_stall`] stays
    /// exact after the cap evicts old samples.
    stall_max: f64,
}

/// Cap on retained stall samples (halved when reached).
const STALL_SAMPLE_CAP: usize = 8192;

impl BackgroundReorderer {
    /// `background = false` builds the synchronous-compute twin: same
    /// trigger points, same adoption schedule (so outputs are
    /// bit-identical to `background = true`), but the rebuild stalls the
    /// ingest thread at the trigger batch — the baseline the stall
    /// comparison in `BENCH_cache_layout.json` measures against.
    pub fn new(
        rows: u64,
        hot_ratio: f64,
        refresh_every: usize,
        window_cap: usize,
        adopt_lag: usize,
        background: bool,
    ) -> Self {
        assert!(refresh_every >= 1, "refresh interval must be >= 1");
        BackgroundReorderer {
            rows,
            hot_ratio,
            refresh_every,
            window_cap: window_cap.max(1),
            adopt_lag,
            synchronous: !background,
            worker_lost: false,
            freq: FreqCounter::new(),
            window: VecDeque::new(),
            since_refresh: 0,
            epoch: 0,
            pending: None,
            tx: None,
            worker: None,
            swap: Arc::new(SwapSlot::default()),
            bijection: IndexBijection::identity(rows),
            refreshes: 0,
            stall_samples: Vec::new(),
            stall_max: 0.0,
        }
    }

    /// Feed one RAW (pre-remap) index column; returns `true` when this
    /// call ADOPTED a refreshed bijection.  Triggers fire every
    /// `refresh_every` observed batches (skipped while a refresh is in
    /// flight); adoption happens exactly `adopt_lag` batches later —
    /// a pure function of the batch index, so streams replayed through
    /// background and synchronous engines see identical bijections on
    /// identical batches.
    pub fn observe(&mut self, col: &[u64]) -> bool {
        self.freq.observe(col);
        if self.window.len() == self.window_cap {
            self.window.pop_front();
        }
        self.window.push_back(col.to_vec());
        self.since_refresh += 1;
        if self.since_refresh >= self.refresh_every && self.pending.is_none() {
            self.since_refresh = 0;
            self.epoch += 1;
            // lint:allow(D2) stall instrumentation: times the real rebuild on the ingest path
            let t0 = Instant::now();
            let (done, job) = if self.synchronous {
                let refs: Vec<&[u64]> = self.window.iter().map(|v| v.as_slice()).collect();
                let bij = IndexBijection::build_with_freq(
                    self.rows,
                    &self.freq,
                    &refs,
                    self.hot_ratio,
                );
                (Some(bij), None)
            } else {
                // ONE deep snapshot; the Arc is shared between the
                // worker and the crash-recovery slot
                let job = Arc::new(self.make_job());
                match self.dispatch(Arc::clone(&job)) {
                    // worker already gone: the rebuild ran inline as a
                    // fallback (same inputs => same bijection)
                    Some(bij) => (Some(bij), None),
                    // in flight; keep the snapshot so a worker that dies
                    // mid-rebuild can be recovered from at adoption
                    None => (None, Some(job)),
                }
            };
            let stall_so_far = t0.elapsed().as_secs_f64();
            // half-life = one refresh interval, same as the inline engine
            self.freq.decay(0.5);
            self.pending = Some(PendingRefresh {
                epoch: self.epoch,
                countdown: self.adopt_lag,
                done,
                job,
                stall_so_far,
            });
        }
        let adopt_now = matches!(self.pending.as_ref(), Some(p) if p.countdown == 0);
        if adopt_now {
            let mut p = self.pending.take().unwrap();
            // lint:allow(D2) stall instrumentation: times the real rebuild on the ingest path
            let t0 = Instant::now();
            let bij = match p.done.take() {
                Some(b) => b,
                None => self.await_epoch(p.epoch, p.job.take()),
            };
            self.record_stall(p.stall_so_far + t0.elapsed().as_secs_f64());
            self.bijection = bij;
            self.refreshes += 1;
            return true;
        }
        if let Some(p) = self.pending.as_mut() {
            p.countdown -= 1;
        }
        false
    }

    /// Record one per-refresh stall sample: the ring is halved at its cap
    /// (bounded memory), but the running maximum is updated first so
    /// `max_stall` never under-reports a drained sample.
    fn record_stall(&mut self, secs: f64) {
        self.stall_max = self.stall_max.max(secs);
        if self.stall_samples.len() >= STALL_SAMPLE_CAP {
            self.stall_samples.drain(..STALL_SAMPLE_CAP / 2);
        }
        self.stall_samples.push(secs);
    }

    /// Maximum per-refresh ingest stall observed so far (seconds) — over
    /// the engine's whole lifetime, not just the retained ring.
    pub fn max_stall(&self) -> f64 {
        self.stall_max
    }

    /// Current refresh interval (batches between rebuild triggers).
    pub fn refresh_every(&self) -> usize {
        self.refresh_every
    }

    /// Retune the refresh interval (autotune cadence controller).  Takes
    /// effect at the next trigger check; clamped to >= 1.  The adoption
    /// lag of an in-flight refresh is untouched, so retuning never
    /// perturbs the fixed batch-indexed adoption schedule.
    pub fn set_refresh_every(&mut self, every: usize) {
        self.refresh_every = every.max(1);
    }

    /// Snapshot the rebuild inputs at the trigger point.
    fn make_job(&self) -> RefreshJob {
        RefreshJob {
            epoch: self.epoch,
            rows: self.rows,
            hot_ratio: self.hot_ratio,
            freq: self.freq.clone(),
            window: self.window.iter().cloned().collect(),
        }
    }

    /// Ship the rebuild to the background worker.  If the worker is gone
    /// (its thread panicked, so the channel is closed), compute the
    /// bijection inline instead and return it — a dead worker degrades
    /// to synchronous-twin behavior (identical outputs, full stall)
    /// rather than panicking the ingest thread.
    fn dispatch(&mut self, job: Arc<RefreshJob>) -> Option<IndexBijection> {
        if self.tx.is_none() && !self.worker_lost {
            let (tx, rx) = mpsc::channel::<Arc<RefreshJob>>();
            let swap = self.swap.clone();
            let handle = std::thread::spawn(move || {
                for job in rx {
                    let refs: Vec<&[u64]> = job.window.iter().map(|v| v.as_slice()).collect();
                    let bij = IndexBijection::build_with_freq(
                        job.rows,
                        &job.freq,
                        &refs,
                        job.hot_ratio,
                    );
                    let mut slot = swap.slot.lock().unwrap();
                    *slot = Some((job.epoch, bij));
                    swap.ready.notify_all();
                }
            });
            self.tx = Some(tx);
            self.worker = Some(handle);
        }
        let undelivered = match self.tx.as_ref() {
            Some(tx) => match tx.send(job) {
                Ok(()) => None,
                Err(e) => Some(e.0), // channel closed: the job comes back
            },
            None => Some(job),
        };
        let job = undelivered?;
        self.tx = None; // stop trying; rebuild inline from now on
        Some(self.rebuild_inline(&job))
    }

    /// Synchronous fallback rebuild from a job snapshot (dead worker).
    fn rebuild_inline(&mut self, job: &RefreshJob) -> IndexBijection {
        if !self.worker_lost {
            self.worker_lost = true;
            eprintln!(
                "recad: background reorder worker died; falling back to \
                 synchronous rebuilds (bijections unchanged, stalls grow)"
            );
        }
        let refs: Vec<&[u64]> = job.window.iter().map(|v| v.as_slice()).collect();
        IndexBijection::build_with_freq(job.rows, &job.freq, &refs, job.hot_ratio)
    }

    /// Adoption-point wait: block until the worker has published `epoch`
    /// (or newer) and read the bijection WITHOUT consuming the slot
    /// (clones keep it valid).  A worker that died MID-rebuild (panic in
    /// the Louvain stack, unwind on OOM) is detected via the timed wait;
    /// the refresh is then rebuilt inline from the `job` snapshot — the
    /// identical inputs the worker had, so the adopted bijection is
    /// unchanged and training survives.
    fn await_epoch(&mut self, epoch: u64, job: Option<Arc<RefreshJob>>) -> IndexBijection {
        {
            let mut slot = self.swap.slot.lock().unwrap();
            loop {
                if let Some((e, bij)) = slot.as_ref() {
                    if *e >= epoch {
                        return bij.clone();
                    }
                }
                if !self.worker.as_ref().is_some_and(|h| !h.is_finished()) {
                    // worker thread is gone; one last slot check below
                    // catches a publish that raced its exit
                    break;
                }
                let (guard, _timed_out) = self
                    .swap
                    .ready
                    .wait_timeout(slot, std::time::Duration::from_millis(20))
                    .unwrap();
                slot = guard;
            }
            if let Some((e, bij)) = slot.as_ref() {
                if *e >= epoch {
                    return bij.clone();
                }
            }
        }
        // the worker died before publishing this epoch
        let job = job
            .unwrap_or_else(|| panic!("reorder worker died before epoch {epoch}, no snapshot"));
        self.rebuild_inline(&job)
    }

    /// Block until the worker publishes `epoch` — the clone path, which
    /// has no `&mut self` to fall back with; a dead worker panics here
    /// (cloning an engine whose worker crashed mid-rebuild).
    fn wait_for(&self, epoch: u64) -> IndexBijection {
        let mut slot = self.swap.slot.lock().unwrap();
        loop {
            if let Some((e, bij)) = slot.as_ref() {
                if *e >= epoch {
                    return bij.clone();
                }
            }
            assert!(
                self.worker.as_ref().is_some_and(|h| !h.is_finished()),
                "background reorder worker died before publishing epoch {epoch}"
            );
            let (guard, _timed_out) = self
                .swap
                .ready
                .wait_timeout(slot, std::time::Duration::from_millis(20))
                .unwrap();
            slot = guard;
        }
    }
}

impl Clone for BackgroundReorderer {
    /// Clones carry the full deterministic state but no worker thread
    /// (it respawns lazily).  An in-flight background rebuild is resolved
    /// (briefly blocking) so the clone starts from a settled pending.
    fn clone(&self) -> Self {
        let pending = self.pending.as_ref().map(|p| PendingRefresh {
            epoch: p.epoch,
            countdown: p.countdown,
            stall_so_far: p.stall_so_far,
            // resolved to a concrete bijection, so no snapshot needed
            job: None,
            done: Some(match &p.done {
                Some(b) => b.clone(),
                None => self.wait_for(p.epoch),
            }),
        });
        BackgroundReorderer {
            rows: self.rows,
            hot_ratio: self.hot_ratio,
            refresh_every: self.refresh_every,
            window_cap: self.window_cap,
            adopt_lag: self.adopt_lag,
            synchronous: self.synchronous,
            worker_lost: self.worker_lost,
            freq: self.freq.clone(),
            window: self.window.clone(),
            since_refresh: self.since_refresh,
            epoch: self.epoch,
            pending,
            tx: None,
            worker: None,
            swap: Arc::new(SwapSlot::default()),
            bijection: self.bijection.clone(),
            refreshes: self.refreshes,
            stall_samples: self.stall_samples.clone(),
            stall_max: self.stall_max,
        }
    }
}

impl Drop for BackgroundReorderer {
    fn drop(&mut self) {
        // closing the channel ends the worker loop; join so no rebuild
        // outlives the owning planner
        self.tx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
impl BackgroundReorderer {
    /// Simulate a crashed worker: end the real thread, then install a
    /// channel whose receiver is already gone so every send fails the
    /// way a panicked worker's does.
    fn sever_worker_for_test(&mut self) {
        self.tx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        let (tx, rx) = mpsc::channel::<Arc<RefreshJob>>();
        drop(rx);
        self.tx = Some(tx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::Zipf;
    use crate::tt::shapes::TtShapes;
    use crate::util::prng::Rng;

    fn distinct_prefixes(shapes: &TtShapes, batch: &[u64]) -> usize {
        let s: std::collections::HashSet<u64> =
            batch.iter().map(|&i| shapes.prefix_of(i)).collect();
        s.len()
    }

    #[test]
    fn identity_until_first_refresh() {
        let mut o = OnlineReorderer::new(1000, 0.1, 4, 8);
        assert!(!o.observe(&[1, 2, 3]));
        assert_eq!(o.refreshes, 0);
        for i in 0..1000 {
            assert_eq!(o.bijection.apply(i), i);
        }
    }

    #[test]
    fn refresh_fires_every_k_batches() {
        let mut o = OnlineReorderer::new(4000, 0.1, 3, 8);
        let mut rng = Rng::new(1);
        let z = Zipf::new(4000, 1.2);
        let mut fired = Vec::new();
        for step in 0..9 {
            let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
            if o.observe(&col) {
                fired.push(step);
            }
        }
        assert_eq!(fired, vec![2, 5, 8]);
        assert_eq!(o.refreshes, 3);
    }

    /// The background engine's whole contract: identical bijections on
    /// identical batches vs its synchronous-compute twin, regardless of
    /// worker timing.
    #[test]
    fn background_matches_synchronous_twin_bitwise() {
        let vocab = 3000u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(9);
        let batches: Vec<Vec<u64>> = (0..20)
            .map(|_| (0..96).map(|_| z.sample(&mut rng)).collect())
            .collect();
        let run = |background: bool| -> (Vec<(usize, Vec<u64>)>, u64) {
            let mut r = BackgroundReorderer::new(vocab, 0.1, 4, 8, 1, background);
            let mut adoptions = Vec::new();
            for (step, col) in batches.iter().enumerate() {
                if r.observe(col) {
                    let snap: Vec<u64> = (0..vocab).map(|i| r.bijection.apply(i)).collect();
                    adoptions.push((step, snap));
                }
            }
            (adoptions, r.refreshes)
        };
        let (sync_adopt, sync_n) = run(false);
        let (bg_adopt, bg_n) = run(true);
        assert!(sync_n >= 2, "not enough refreshes to be interesting");
        assert_eq!(sync_n, bg_n, "refresh counts diverged");
        assert_eq!(sync_adopt.len(), bg_adopt.len());
        for ((ss, sb), (bs, bb)) in sync_adopt.iter().zip(&bg_adopt) {
            assert_eq!(ss, bs, "adoption batch diverged");
            assert_eq!(sb, bb, "bijection diverged at step {ss}");
        }
    }

    #[test]
    fn background_adoption_lags_trigger_by_fixed_batches() {
        let vocab = 2000u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(11);
        let mut r = BackgroundReorderer::new(vocab, 0.1, 3, 6, 1, true);
        let mut adopted_at = Vec::new();
        for step in 0..10 {
            let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
            if r.observe(&col) {
                adopted_at.push(step);
            }
        }
        // triggers fire at steps 2, 5, 8 (the inline engine's schedule);
        // adoption lands exactly one batch later
        assert_eq!(adopted_at, vec![3, 6, 9]);
        assert_eq!(r.stall_samples.len(), 3, "every adoption must record a stall sample");
        assert!(r.max_stall() >= 0.0);
    }

    /// A dead background worker must degrade to inline rebuilds (same
    /// bijections as the synchronous twin), not panic the ingest thread.
    #[test]
    fn dead_worker_falls_back_to_synchronous_rebuild() {
        let vocab = 2500u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(17);
        let batches: Vec<Vec<u64>> = (0..12)
            .map(|_| (0..96).map(|_| z.sample(&mut rng)).collect())
            .collect();
        // reference: the synchronous-compute twin over the same stream
        let mut sync = BackgroundReorderer::new(vocab, 0.1, 3, 6, 1, false);
        let mut sync_adopt = Vec::new();
        for (step, col) in batches.iter().enumerate() {
            if sync.observe(col) {
                sync_adopt.push(step);
            }
        }
        // background engine whose worker dies before the first trigger
        let mut bg = BackgroundReorderer::new(vocab, 0.1, 3, 6, 1, true);
        bg.sever_worker_for_test();
        let mut bg_adopt = Vec::new();
        for (step, col) in batches.iter().enumerate() {
            if bg.observe(col) {
                bg_adopt.push(step);
            }
        }
        assert!(bg.worker_lost, "severed worker must be detected");
        assert_eq!(sync_adopt, bg_adopt, "adoption schedule diverged");
        assert!(bg.refreshes >= 2, "fallback must keep refreshing");
        for i in 0..vocab {
            assert_eq!(
                sync.bijection.apply(i),
                bg.bijection.apply(i),
                "fallback bijection diverged at {i}"
            );
        }
        // stall samples keep flowing (they now measure the inline cost)
        assert_eq!(bg.stall_samples.len(), bg.refreshes as usize);
    }

    /// The realistic death mode: the worker accepts a job and then dies
    /// WITHOUT publishing (panic mid-rebuild).  The adoption point must
    /// rebuild inline from the kept snapshot — identical bijections to
    /// the synchronous twin, no ingest panic.
    #[test]
    fn mid_rebuild_worker_death_recovers_inline() {
        let vocab = 2200u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(29);
        let batches: Vec<Vec<u64>> = (0..8)
            .map(|_| (0..96).map(|_| z.sample(&mut rng)).collect())
            .collect();
        let mut sync = BackgroundReorderer::new(vocab, 0.1, 3, 6, 1, false);
        let mut sync_adopt = Vec::new();
        for (step, col) in batches.iter().enumerate() {
            if sync.observe(col) {
                sync_adopt.push(step);
            }
        }
        let mut bg = BackgroundReorderer::new(vocab, 0.1, 3, 6, 1, true);
        let mut bg_adopt = Vec::new();
        for (step, col) in batches.iter().enumerate() {
            if step == 3 {
                // the trigger at step 2 dispatched a job; simulate the
                // worker dying mid-rebuild: a finished thread handle and
                // a swap slot that will never be published
                assert!(
                    matches!(bg.pending.as_ref(), Some(p) if p.done.is_none()),
                    "test premise: a background rebuild is in flight"
                );
                bg.swap = Arc::new(SwapSlot::default());
                bg.worker = Some(std::thread::spawn(|| {}));
            }
            if bg.observe(col) {
                bg_adopt.push(step);
            }
        }
        assert_eq!(sync_adopt, bg_adopt, "adoption schedule diverged after crash");
        for i in 0..vocab {
            assert_eq!(
                sync.bijection.apply(i),
                bg.bijection.apply(i),
                "crash-recovered bijection diverged at {i}"
            );
        }
    }

    #[test]
    fn background_clone_resolves_pending_and_stays_deterministic() {
        let vocab = 1500u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(13);
        let mut r = BackgroundReorderer::new(vocab, 0.1, 2, 4, 1, true);
        // two batches: trigger fires on the second, adoption still pending
        for _ in 0..2 {
            let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
            r.observe(&col);
        }
        let mut c = r.clone();
        let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
        let a = r.observe(&col);
        let b = c.observe(&col);
        assert!(a && b, "both must adopt on the lagged batch");
        for i in 0..vocab {
            assert_eq!(r.bijection.apply(i), c.bijection.apply(i), "clone diverged at {i}");
        }
    }

    /// Regression: the stall-sample ring halves itself at its cap, which
    /// used to silently discard the largest sample — `max_stall()` then
    /// under-reported.  The running max must survive the drain.
    #[test]
    fn max_stall_survives_sample_ring_drain() {
        let mut r = BackgroundReorderer::new(100, 0.1, 1, 1, 0, false);
        r.record_stall(9.0); // the lifetime maximum, recorded early
        for _ in 0..(STALL_SAMPLE_CAP + 10) {
            r.record_stall(0.001); // enough traffic to drain the ring twice
        }
        assert!(
            !r.stall_samples.contains(&9.0),
            "test premise: the big sample must have been drained"
        );
        assert!(r.stall_samples.len() <= STALL_SAMPLE_CAP, "ring must stay bounded");
        assert_eq!(r.max_stall(), 9.0, "running max must survive the drain");
    }

    #[test]
    fn retuned_refresh_interval_takes_effect_next_trigger() {
        let vocab = 1000u64;
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(5);
        let mut r = BackgroundReorderer::new(vocab, 0.1, 8, 8, 0, false);
        assert_eq!(r.refresh_every(), 8);
        r.set_refresh_every(2);
        assert_eq!(r.refresh_every(), 2);
        let mut adopted_at = Vec::new();
        for step in 0..6 {
            let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
            if r.observe(&col) {
                adopted_at.push(step);
            }
        }
        // lag 0: triggers and adoptions land on the same batch, every 2
        assert_eq!(adopted_at, vec![1, 3, 5]);
        let mut o = OnlineReorderer::new(vocab, 0.1, 8, 8);
        o.set_refresh_every(1);
        assert_eq!(o.refresh_every(), 1);
        let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
        assert!(o.observe(&col), "interval 1 must refresh on every batch");
    }

    #[test]
    fn refreshed_bijection_improves_prefix_sharing_on_scrambled_stream() {
        // scrambled ids (hash realism): raw adjacency carries no locality
        let vocab = 6000u64;
        let shapes = TtShapes::plan(vocab, 16, 8);
        let mut perm: Vec<u64> = (0..vocab).collect();
        Rng::new(0xD15C).shuffle(&mut perm);
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(2);
        let mut o = OnlineReorderer::new(vocab, 0.1, 16, 16);
        for _ in 0..16 {
            let col: Vec<u64> =
                (0..128).map(|_| perm[z.sample(&mut rng) as usize]).collect();
            o.observe(&col);
        }
        assert_eq!(o.refreshes, 1);
        // fresh batches from the same distribution
        let mut before = 0usize;
        let mut after = 0usize;
        for _ in 0..8 {
            let col: Vec<u64> =
                (0..128).map(|_| perm[z.sample(&mut rng) as usize]).collect();
            before += distinct_prefixes(&shapes, &col);
            let remapped: Vec<u64> = col.iter().map(|&i| o.bijection.apply(i)).collect();
            after += distinct_prefixes(&shapes, &remapped);
        }
        assert!(after < before, "online bijection did not help: {after} !< {before}");
    }
}
