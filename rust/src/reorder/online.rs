//! Online index reordering: an incremental frequency tracker that
//! refreshes the dual-projection bijection every K batches, so the
//! reuse-buffer hit rate tracks *drifting* index distributions instead of
//! being pinned to an offline profiling sample (paper §III-H builds the
//! bijection offline; this is the streaming extension the access layer
//! enables).
//!
//! Semantics note: refreshing the bijection mid-training re-assigns
//! embedding rows to entities that moved (the standard re-bucketing
//! trade-off of hot/cold systems like FAE); it is a *systems*
//! optimization — the drift test in `tests/plan_equivalence.rs` measures
//! its effect on prefix sharing, not on model accuracy.

use std::collections::VecDeque;

use crate::reorder::bijection::IndexBijection;
use crate::reorder::freq::FreqCounter;

/// Per-table online reorder state.
#[derive(Clone)]
pub struct OnlineReorderer {
    rows: u64,
    hot_ratio: f64,
    refresh_every: usize,
    window_cap: usize,
    /// Incremental frequency counts, exponentially decayed at each
    /// refresh so stale mass ages out under drift.
    freq: FreqCounter,
    /// Recent raw index batches — the co-occurrence sample the next
    /// refresh builds its community graph from.
    window: VecDeque<Vec<u64>>,
    since_refresh: usize,
    /// Current bijection (identity until the first refresh).
    pub bijection: IndexBijection,
    /// Number of rebuilds performed.
    pub refreshes: u64,
}

impl OnlineReorderer {
    /// `refresh_every`: batches between bijection rebuilds (K).
    /// `window_cap`: co-occurrence sample size kept for the rebuild.
    pub fn new(rows: u64, hot_ratio: f64, refresh_every: usize, window_cap: usize) -> Self {
        assert!(refresh_every >= 1, "refresh interval must be >= 1");
        OnlineReorderer {
            rows,
            hot_ratio,
            refresh_every,
            window_cap: window_cap.max(1),
            freq: FreqCounter::new(),
            window: VecDeque::new(),
            since_refresh: 0,
            bijection: IndexBijection::identity(rows),
            refreshes: 0,
        }
    }

    /// Feed one RAW (pre-remap) index column; returns `true` when this
    /// call triggered a bijection refresh.
    pub fn observe(&mut self, col: &[u64]) -> bool {
        self.freq.observe(col);
        if self.window.len() == self.window_cap {
            self.window.pop_front();
        }
        self.window.push_back(col.to_vec());
        self.since_refresh += 1;
        if self.since_refresh < self.refresh_every {
            return false;
        }
        self.since_refresh = 0;
        let refs: Vec<&[u64]> = self.window.iter().map(|v| v.as_slice()).collect();
        self.bijection =
            IndexBijection::build_with_freq(self.rows, &self.freq, &refs, self.hot_ratio);
        // half-life = one refresh interval: old hot sets fade instead of
        // anchoring the layout forever
        self.freq.decay(0.5);
        self.refreshes += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::Zipf;
    use crate::tt::shapes::TtShapes;
    use crate::util::prng::Rng;

    fn distinct_prefixes(shapes: &TtShapes, batch: &[u64]) -> usize {
        let s: std::collections::HashSet<u64> =
            batch.iter().map(|&i| shapes.prefix_of(i)).collect();
        s.len()
    }

    #[test]
    fn identity_until_first_refresh() {
        let mut o = OnlineReorderer::new(1000, 0.1, 4, 8);
        assert!(!o.observe(&[1, 2, 3]));
        assert_eq!(o.refreshes, 0);
        for i in 0..1000 {
            assert_eq!(o.bijection.apply(i), i);
        }
    }

    #[test]
    fn refresh_fires_every_k_batches() {
        let mut o = OnlineReorderer::new(4000, 0.1, 3, 8);
        let mut rng = Rng::new(1);
        let z = Zipf::new(4000, 1.2);
        let mut fired = Vec::new();
        for step in 0..9 {
            let col: Vec<u64> = (0..64).map(|_| z.sample(&mut rng)).collect();
            if o.observe(&col) {
                fired.push(step);
            }
        }
        assert_eq!(fired, vec![2, 5, 8]);
        assert_eq!(o.refreshes, 3);
    }

    #[test]
    fn refreshed_bijection_improves_prefix_sharing_on_scrambled_stream() {
        // scrambled ids (hash realism): raw adjacency carries no locality
        let vocab = 6000u64;
        let shapes = TtShapes::plan(vocab, 16, 8);
        let mut perm: Vec<u64> = (0..vocab).collect();
        Rng::new(0xD15C).shuffle(&mut perm);
        let z = Zipf::new(vocab, 1.2);
        let mut rng = Rng::new(2);
        let mut o = OnlineReorderer::new(vocab, 0.1, 16, 16);
        for _ in 0..16 {
            let col: Vec<u64> =
                (0..128).map(|_| perm[z.sample(&mut rng) as usize]).collect();
            o.observe(&col);
        }
        assert_eq!(o.refreshes, 1);
        // fresh batches from the same distribution
        let mut before = 0usize;
        let mut after = 0usize;
        for _ in 0..8 {
            let col: Vec<u64> =
                (0..128).map(|_| perm[z.sample(&mut rng) as usize]).collect();
            before += distinct_prefixes(&shapes, &col);
            let remapped: Vec<u64> = col.iter().map(|&i| o.bijection.apply(i)).collect();
            after += distinct_prefixes(&shapes, &remapped);
        }
        assert!(after < before, "online bijection did not help: {after} !< {before}");
    }
}
