//! Global-information pass: access-frequency ordering + hot-set selection
//! (paper Algorithm 2, lines 1–4).
//!
//! Indices are ranked by access frequency over a sample of training
//! batches; the top `hot_ratio` fraction are "hot embeddings" — they are
//! pinned (exempt from community reordering) and are the FAE/cache
//! residency candidates at the system level.

use std::collections::HashMap;

/// Frequency statistics over a stream of index batches.
#[derive(Clone, Default)]
pub struct FreqCounter {
    counts: HashMap<u64, u64>,
    total: u64,
}

impl FreqCounter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, indices: &[u64]) {
        for &i in indices {
            *self.counts.entry(i).or_insert(0) += 1;
            self.total += 1;
        }
    }

    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count_of(&self, idx: u64) -> u64 {
        self.counts.get(&idx).copied().unwrap_or(0)
    }

    /// Indices sorted by descending frequency (ties by index for
    /// determinism) — Algorithm 2's `Freq_order`.
    pub fn freq_order(&self) -> Vec<u64> {
        // lint:allow(D1) drained to a Vec and fully sorted on the next line
        let mut v: Vec<(u64, u64)> = self.counts.iter().map(|(&i, &c)| (i, c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.into_iter().map(|(i, _)| i).collect()
    }

    /// The hot set: smallest prefix of `freq_order` covering `hot_ratio`
    /// of all accesses (access-mass definition, robust to vocab size).
    pub fn hot_set(&self, hot_ratio: f64) -> Vec<u64> {
        let order = self.freq_order();
        let target = (self.total as f64 * hot_ratio.clamp(0.0, 1.0)) as u64;
        let mut acc = 0;
        let mut out = Vec::new();
        for i in order {
            if acc >= target {
                break;
            }
            acc += self.count_of(i);
            out.push(i);
        }
        out
    }

    /// Exponentially age the counts (online reordering: stale access mass
    /// must fade under drift).  Counts are scaled by `factor` with floor
    /// division; ids decayed to zero are dropped.
    pub fn decay(&mut self, factor: f64) {
        let factor = factor.clamp(0.0, 1.0);
        self.total = 0;
        // lint:allow(D1) per-entry integer decay is independent of visit order
        self.counts.retain(|_, c| {
            *c = (*c as f64 * factor) as u64;
            *c > 0
        });
        // lint:allow(D1) u64 sum is commutative — no fp accumulation order
        self.total = self.counts.values().sum();
    }

    /// Fraction of total accesses covered by the `k` most frequent ids
    /// (the power-law diagnostic the paper cites).
    pub fn coverage_topk(&self, k: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let order = self.freq_order();
        let cov: u64 = order.iter().take(k).map(|&i| self.count_of(i)).sum();
        cov as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::zipf::Zipf;
    use crate::util::prng::Rng;

    #[test]
    fn order_is_by_frequency() {
        let mut f = FreqCounter::new();
        f.observe(&[5, 5, 5, 2, 2, 9]);
        assert_eq!(f.freq_order(), vec![5, 2, 9]);
        assert_eq!(f.count_of(5), 3);
        assert_eq!(f.distinct(), 3);
    }

    #[test]
    fn hot_set_covers_mass() {
        let mut f = FreqCounter::new();
        // 10 accesses: id 1 has 6, id 2 has 3, id 3 has 1
        f.observe(&[1, 1, 1, 1, 1, 1, 2, 2, 2, 3]);
        let hot = f.hot_set(0.6);
        assert_eq!(hot, vec![1]);
        let hot = f.hot_set(0.9);
        assert_eq!(hot, vec![1, 2]);
    }

    #[test]
    fn decay_halves_and_drops_zeros() {
        let mut f = FreqCounter::new();
        f.observe(&[1, 1, 1, 1, 2, 2, 3]);
        f.decay(0.5);
        assert_eq!(f.count_of(1), 2);
        assert_eq!(f.count_of(2), 1);
        assert_eq!(f.count_of(3), 0, "count 1 must floor-decay to zero");
        assert_eq!(f.distinct(), 2);
        assert_eq!(f.total(), 3);
    }

    #[test]
    fn zipf_stream_concentrates() {
        let z = Zipf::new(100_000, 1.2);
        let mut rng = Rng::new(1);
        let mut f = FreqCounter::new();
        let mut buf = vec![0u64; 512];
        for _ in 0..40 {
            z.sample_many(&mut rng, &mut buf);
            f.observe(&buf);
        }
        // power law: tiny hot set covers most accesses
        assert!(f.coverage_topk(100) > 0.5);
        assert!(f.hot_set(0.75).len() < f.distinct() / 2);
    }
}
