//! Input-level optimization (paper §III-G/H): the dual-projection index
//! bijection built from global frequency + local co-occurrence structure.

pub mod bijection;
pub mod freq;
pub mod graph;
pub mod louvain;
pub mod online;

pub use bijection::IndexBijection;
pub use freq::FreqCounter;
pub use online::{BackgroundReorderer, OnlineReorderer, DEFAULT_ADOPT_LAG};
pub use graph::{GraphBuilder, IndexGraph};
pub use louvain::{louvain, modularity, Communities};
