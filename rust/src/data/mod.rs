//! Workload synthesis: dataset schemas (Table II), Zipf index sampler,
//! synthetic CTR generator, and batch assembly.

pub mod batcher;
pub mod ctr;
pub mod schema;
pub mod zipf;

pub use ctr::{Batch, CtrGenerator};
pub use schema::DatasetSchema;
pub use zipf::{DriftingZipf, Zipf};
