//! Mini-batch assembly from the IEEE118 dataset + EmbeddingBag layout
//! helpers shared by the trainers.  Assembly writes straight into
//! caller-owned `Batch` scratch (`fill_batch` / `EpochIter::next_into`)
//! so the ingest stage can recycle buffers instead of cloning samples
//! twice per batch.

use crate::access::plan::UnitOffsets;
use crate::data::ctr::Batch;
use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};
use crate::util::prng::Rng;

/// Assemble samples into `out` (reused scratch: clears, never shrinks).
pub fn fill_batch<'a, I: IntoIterator<Item = &'a Sample>>(samples: I, out: &mut Batch) {
    out.dense.clear();
    out.sparse.clear();
    out.labels.clear();
    for s in samples {
        out.dense.extend_from_slice(&s.dense);
        out.sparse.extend_from_slice(&s.sparse);
        out.labels.push(s.label);
    }
    out.batch_size = out.labels.len();
    debug_assert_eq!(out.dense.len(), out.batch_size * N_DENSE);
    debug_assert_eq!(out.sparse.len(), out.batch_size * N_SPARSE);
}

/// Convert a window of IEEE118 samples into the DLRM batch layout.
pub fn to_batch(samples: &[Sample]) -> Batch {
    let mut b = Batch::default();
    fill_batch(samples, &mut b);
    b
}

/// Epoch iterator: shuffled fixed-size batches over a sample slice.
pub struct EpochIter<'a> {
    samples: &'a [Sample],
    order: Vec<usize>,
    batch_size: usize,
    cursor: usize,
}

impl<'a> EpochIter<'a> {
    pub fn new(samples: &'a [Sample], batch_size: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..samples.len()).collect();
        rng.shuffle(&mut order);
        EpochIter { samples, order, batch_size, cursor: 0 }
    }

    pub fn num_batches(&self) -> usize {
        self.samples.len() / self.batch_size
    }

    /// Assemble the next batch directly into reusable scratch (no
    /// intermediate `Vec<&Sample>` / owned clone per batch); returns
    /// `false` when the epoch is exhausted.  This is the ingest stage's
    /// `fill` entry point (`access::ingest::run_prefetched_fill`).
    pub fn next_into(&mut self, out: &mut Batch) -> bool {
        if self.cursor + self.batch_size > self.order.len() {
            return false;
        }
        let sel = &self.order[self.cursor..self.cursor + self.batch_size];
        self.cursor += self.batch_size;
        fill_batch(sel.iter().map(|&i| &self.samples[i]), out);
        true
    }
}

impl<'a> Iterator for EpochIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let mut b = Batch::default();
        if self.next_into(&mut b) {
            Some(b)
        } else {
            None
        }
    }
}

/// Extract one sparse column of a batch as (indices, unit-bag offsets) —
/// the EmbeddingBag calling convention for per-feature tables.
/// Allocates both vectors; hot paths should use `column_bags_into` (or a
/// `BatchPlan`, which caches the unit offsets and the dedup work too).
pub fn column_bags(batch: &Batch, table: usize, n_sparse: usize) -> (Vec<u64>, Vec<usize>) {
    let mut indices = Vec::new();
    let mut offsets = UnitOffsets::default();
    column_bags_into(batch, table, n_sparse, &mut indices, &mut offsets);
    let off = offsets.get(indices.len()).to_vec();
    (indices, off)
}

/// Reusable-scratch variant of [`column_bags`]: the index column lands in
/// `indices` and the `0..=len` unit-offset vector comes from the shared
/// grow-only [`UnitOffsets`] cache instead of being rebuilt per call.
pub fn column_bags_into<'a>(
    batch: &Batch,
    table: usize,
    n_sparse: usize,
    indices: &mut Vec<u64>,
    offsets: &'a mut UnitOffsets,
) -> &'a [usize] {
    indices.clear();
    indices.extend(batch.sparse_col(table, n_sparse));
    offsets.get(indices.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};

    fn tiny_ds() -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: 80,
            n_attack: 20,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 1,
        })
        .samples
    }

    #[test]
    fn to_batch_layout() {
        let ds = tiny_ds();
        let b = to_batch(&ds[..4]);
        assert_eq!(b.batch_size, 4);
        assert_eq!(b.dense.len(), 4 * N_DENSE);
        assert_eq!(b.sparse.len(), 4 * N_SPARSE);
        assert_eq!(b.dense[0], ds[0].dense[0]);
        assert_eq!(b.sparse[N_SPARSE], ds[1].sparse[0]);
    }

    #[test]
    fn epoch_covers_all_full_batches() {
        let ds = tiny_ds();
        let mut rng = Rng::new(0);
        let it = EpochIter::new(&ds, 16, &mut rng);
        assert_eq!(it.num_batches(), 100 / 16);
        let batches: Vec<_> = it.collect();
        assert_eq!(batches.len(), 6);
        for b in &batches {
            assert_eq!(b.batch_size, 16);
        }
    }

    #[test]
    fn next_into_reuses_scratch_and_matches_iterator() {
        let ds = tiny_ds();
        let mut rng_a = Rng::new(3);
        let mut rng_b = Rng::new(3);
        let mut a = EpochIter::new(&ds, 16, &mut rng_a);
        let mut b = EpochIter::new(&ds, 16, &mut rng_b);
        let mut scratch = Batch::default();
        let mut seen = 0;
        while b.next_into(&mut scratch) {
            let owned = a.next().expect("iterator ended early");
            assert_eq!(owned.dense, scratch.dense);
            assert_eq!(owned.sparse, scratch.sparse);
            assert_eq!(owned.labels, scratch.labels);
            assert_eq!(owned.batch_size, scratch.batch_size);
            seen += 1;
        }
        assert!(a.next().is_none());
        assert_eq!(seen, 100 / 16);
    }

    #[test]
    fn column_bags_into_uses_cached_offsets() {
        let ds = tiny_ds();
        let b = to_batch(&ds[..8]);
        let mut idx = Vec::new();
        let mut cache = crate::access::plan::UnitOffsets::default();
        let off = column_bags_into(&b, 2, N_SPARSE, &mut idx, &mut cache).to_vec();
        assert_eq!(off, (0..=8).collect::<Vec<_>>());
        // second call on a smaller batch reuses the same backing store
        let b2 = to_batch(&ds[..4]);
        let off2 = column_bags_into(&b2, 0, N_SPARSE, &mut idx, &mut cache);
        assert_eq!(off2, &[0, 1, 2, 3, 4]);
        assert_eq!(idx.len(), 4);
    }

    #[test]
    fn column_bags_unit_offsets() {
        let ds = tiny_ds();
        let b = to_batch(&ds[..8]);
        let (idx, off) = column_bags(&b, 2, N_SPARSE);
        assert_eq!(idx.len(), 8);
        assert_eq!(off, (0..=8).collect::<Vec<_>>());
        for (i, &v) in idx.iter().enumerate() {
            assert_eq!(v, ds[i].sparse[2]);
        }
    }
}
