//! Mini-batch assembly from the IEEE118 dataset + EmbeddingBag layout
//! helpers shared by the trainers.

use crate::data::ctr::Batch;
use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};
use crate::util::prng::Rng;

/// Convert a window of IEEE118 samples into the DLRM batch layout.
pub fn to_batch(samples: &[Sample]) -> Batch {
    let b = samples.len();
    let mut dense = Vec::with_capacity(b * N_DENSE);
    let mut sparse = Vec::with_capacity(b * N_SPARSE);
    let mut labels = Vec::with_capacity(b);
    for s in samples {
        dense.extend_from_slice(&s.dense);
        sparse.extend_from_slice(&s.sparse);
        labels.push(s.label);
    }
    Batch { dense, sparse, labels, batch_size: b }
}

/// Epoch iterator: shuffled fixed-size batches over a sample slice.
pub struct EpochIter<'a> {
    samples: &'a [Sample],
    order: Vec<usize>,
    batch_size: usize,
    cursor: usize,
}

impl<'a> EpochIter<'a> {
    pub fn new(samples: &'a [Sample], batch_size: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..samples.len()).collect();
        rng.shuffle(&mut order);
        EpochIter { samples, order, batch_size, cursor: 0 }
    }

    pub fn num_batches(&self) -> usize {
        self.samples.len() / self.batch_size
    }
}

impl<'a> Iterator for EpochIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor + self.batch_size > self.order.len() {
            return None;
        }
        let sel: Vec<&Sample> = self.order[self.cursor..self.cursor + self.batch_size]
            .iter()
            .map(|&i| &self.samples[i])
            .collect();
        self.cursor += self.batch_size;
        let owned: Vec<Sample> = sel.into_iter().cloned().collect();
        Some(to_batch(&owned))
    }
}

/// Extract one sparse column of a batch as (indices, unit-bag offsets) —
/// the EmbeddingBag calling convention for per-feature tables.
pub fn column_bags(batch: &Batch, table: usize, n_sparse: usize) -> (Vec<u64>, Vec<usize>) {
    let indices: Vec<u64> = batch.sparse_col(table, n_sparse).collect();
    let offsets: Vec<usize> = (0..=indices.len()).collect();
    (indices, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};

    fn tiny_ds() -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: 80,
            n_attack: 20,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 1,
        })
        .samples
    }

    #[test]
    fn to_batch_layout() {
        let ds = tiny_ds();
        let b = to_batch(&ds[..4]);
        assert_eq!(b.batch_size, 4);
        assert_eq!(b.dense.len(), 4 * N_DENSE);
        assert_eq!(b.sparse.len(), 4 * N_SPARSE);
        assert_eq!(b.dense[0], ds[0].dense[0]);
        assert_eq!(b.sparse[N_SPARSE], ds[1].sparse[0]);
    }

    #[test]
    fn epoch_covers_all_full_batches() {
        let ds = tiny_ds();
        let mut rng = Rng::new(0);
        let it = EpochIter::new(&ds, 16, &mut rng);
        assert_eq!(it.num_batches(), 100 / 16);
        let batches: Vec<_> = it.collect();
        assert_eq!(batches.len(), 6);
        for b in &batches {
            assert_eq!(b.batch_size, 16);
        }
    }

    #[test]
    fn column_bags_unit_offsets() {
        let ds = tiny_ds();
        let b = to_batch(&ds[..8]);
        let (idx, off) = column_bags(&b, 2, N_SPARSE);
        assert_eq!(idx.len(), 8);
        assert_eq!(off, (0..=8).collect::<Vec<_>>());
        for (i, &v) in idx.iter().enumerate() {
            assert_eq!(v, ds[i].sparse[2]);
        }
    }
}
