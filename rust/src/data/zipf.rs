//! Zipf / power-law index sampler — the access-pattern model behind every
//! skew-dependent optimization in the paper (reuse buffer, FAE hot set,
//! embedding cache, index reordering).
//!
//! Rejection-inversion sampling (W. Hörmann & G. Derflinger) gives O(1)
//! draws for arbitrary n and exponent s > 0 without materializing the
//! harmonic table — required for Criteo-scale vocabularies (242M rows).

use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    // precomputed constants of the rejection-inversion scheme
    h_x1: f64,
    h_n: f64,
    dd: f64,
}

impl Zipf {
    /// Distribution over {0, …, n−1} with P(k) ∝ 1/(k+1)^s.
    pub fn new(n: u64, s: f64) -> Zipf {
        assert!(n > 0 && s > 0.0);
        let h_x1 = h(1.5, s) - 1.0;
        let h_n = h(n as f64 + 0.5, s);
        let dd = 2.0f64.powf(-s); // h⁻¹ shortcut threshold helper
        Zipf { n, s, h_x1, h_n, dd }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        // Special-case n == 1.
        if self.n == 1 {
            return 0;
        }
        loop {
            let u = self.h_n + rng.f64() * (self.h_x1 - self.h_n);
            let x = h_inv(u, self.s);
            let k64 = (x + 0.5).floor().max(1.0);
            let k = if k64 as u64 > self.n { self.n } else { k64 as u64 };
            // accept-reject
            if u >= h(k as f64 + 0.5, self.s) - (k as f64).powf(-self.s) {
                return k - 1;
            }
            let _ = self.dd; // constants kept for clarity
        }
    }

    /// Fill a batch.
    pub fn sample_many(&self, rng: &mut Rng, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }
}

/// Drifting Zipf stream — the online-reordering scenario generator.
///
/// Ids are Zipf-skewed, scrambled through a fixed random permutation
/// (production realism: sparse ids are hash-assigned, so raw adjacency
/// carries no locality — the §III-G premise), and the hot head can be
/// **rotated** mid-stream: after `drift(delta)` the access mass moves to
/// a previously cold region of the id space.  An offline-built bijection
/// goes stale at that point; the online reorderer's periodic refresh is
/// what recovers the reuse-hit rate (see `tests/plan_equivalence.rs`).
///
/// The permutation is materialized (8 bytes/row), so this is a
/// test/bench-scale generator — not for Criteo-scale vocabularies.
#[derive(Clone, Debug)]
pub struct DriftingZipf {
    z: Zipf,
    perm: Vec<u64>,
    n: u64,
    rotation: u64,
}

impl DriftingZipf {
    pub fn new(n: u64, s: f64, seed: u64) -> DriftingZipf {
        assert!(n > 0);
        let mut perm: Vec<u64> = (0..n).collect();
        Rng::new(seed).shuffle(&mut perm);
        DriftingZipf { z: Zipf::new(n, s), perm, n, rotation: 0 }
    }

    /// Shift the distribution: rank r now lands where rank r−delta used
    /// to — the old hot set goes cold and a scrambled cold region heats.
    pub fn drift(&mut self, delta: u64) {
        self.rotation = (self.rotation + delta) % self.n;
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        self.perm[((self.z.sample(rng) + self.rotation) % self.n) as usize]
    }

    pub fn sample_many(&self, rng: &mut Rng, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }
}

/// Gradually drifting Zipf stream — mixture interpolation between two
/// rotations of the same scrambled Zipf.
///
/// Where [`DriftingZipf::drift`] SNAPS the hot set to a cold region,
/// real workloads usually migrate: at mixing weight `alpha` a sample
/// comes from the new rotation with probability `alpha` and the old one
/// otherwise, so `P_t = (1-α)·P_old + α·P_new` sweeps smoothly from the
/// old distribution to the new as the caller advances `alpha`.  This is
/// the scenario where refresh cadence matters most: every intermediate
/// mixture is a distribution no offline profile ever saw.
#[derive(Clone, Debug)]
pub struct GradualDriftZipf {
    z: Zipf,
    perm: Vec<u64>,
    n: u64,
    from_rot: u64,
    to_rot: u64,
    alpha: f64,
}

impl GradualDriftZipf {
    pub fn new(n: u64, s: f64, seed: u64) -> GradualDriftZipf {
        assert!(n > 0);
        let mut perm: Vec<u64> = (0..n).collect();
        Rng::new(seed).shuffle(&mut perm);
        GradualDriftZipf { z: Zipf::new(n, s), perm, n, from_rot: 0, to_rot: 0, alpha: 0.0 }
    }

    /// Start a new drift episode: the target distribution is the current
    /// target rotated by `delta`; mixing restarts at `alpha = 0`.  An
    /// in-progress episode is committed first (its target becomes the
    /// new base) — chaining episodes therefore never snaps BACK to a
    /// stale base; finish an episode with `advance` up to 1.0 first if
    /// the jump-forward matters to the scenario.
    pub fn begin_drift(&mut self, delta: u64) {
        self.from_rot = self.to_rot;
        self.to_rot = (self.from_rot + delta) % self.n;
        self.alpha = 0.0;
    }

    /// Advance the mixture by `d_alpha` (clamped to 1; at 1 the target
    /// becomes the new base so a later `begin_drift` chains episodes).
    pub fn advance(&mut self, d_alpha: f64) {
        self.alpha = (self.alpha + d_alpha).min(1.0);
        if self.alpha >= 1.0 {
            self.from_rot = self.to_rot;
        }
    }

    /// Current mixing weight of the target distribution.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let rot = if self.alpha > 0.0 && rng.f64() < self.alpha {
            self.to_rot
        } else {
            self.from_rot
        };
        self.perm[((self.z.sample(rng) + rot) % self.n) as usize]
    }

    pub fn sample_many(&self, rng: &mut Rng, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }
}

/// Vocabulary-growth stream — the active id set expands over time (new
/// users/entities appearing), Zipf-skewed over the currently active
/// prefix of a scrambled id space.  Newly activated ids join at the TAIL
/// of the rank order, but the scramble means they land anywhere in the
/// raw id space — so a stale bijection has never seen them at all, the
/// second failure mode (besides drift) that online refresh covers.
#[derive(Clone, Debug)]
pub struct GrowingVocabZipf {
    s: f64,
    perm: Vec<u64>,
    n_max: u64,
    active: u64,
    z: Zipf,
}

impl GrowingVocabZipf {
    /// Stream over `n_max` total ids, of which `active0` are live at t=0.
    pub fn new(n_max: u64, active0: u64, s: f64, seed: u64) -> GrowingVocabZipf {
        assert!(n_max > 0 && active0 > 0 && active0 <= n_max);
        let mut perm: Vec<u64> = (0..n_max).collect();
        Rng::new(seed).shuffle(&mut perm);
        GrowingVocabZipf { s, perm, n_max, active: active0, z: Zipf::new(active0, s) }
    }

    /// Activate `delta` more ids (clamped to the full vocabulary).
    pub fn grow(&mut self, delta: u64) {
        let next = (self.active + delta).min(self.n_max);
        if next != self.active {
            self.active = next;
            self.z = Zipf::new(next, self.s);
        }
    }

    pub fn active(&self) -> u64 {
        self.active
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        self.perm[self.z.sample(rng) as usize]
    }

    pub fn sample_many(&self, rng: &mut Rng, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }
}

/// H(x) = ∫ x^-s dx antiderivative (s ≠ 1 branch handled via expm1).
fn h(x: f64, s: f64) -> f64 {
    let log_x = x.ln();
    if (1.0 - s).abs() < 1e-9 {
        log_x
    } else {
        ((1.0 - s) * log_x).exp_m1() / (1.0 - s)
    }
}

fn h_inv(u: f64, s: f64) -> f64 {
    if (1.0 - s).abs() < 1e-9 {
        u.exp()
    } else {
        (1.0 + u * (1.0 - s)).ln().exp_2_div(1.0 - s)
    }
}

/// helper: exp(a / b) written as a trait-ish function for clarity
trait Exp2Div {
    fn exp_2_div(self, d: f64) -> f64;
}

impl Exp2Div for f64 {
    fn exp_2_div(self, d: f64) -> f64 {
        (self / d).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(1000, 1.2);
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            assert!(z.sample(&mut rng) < 1000);
        }
    }

    #[test]
    fn head_heavier_than_tail() {
        let z = Zipf::new(10_000, 1.1);
        let mut rng = Rng::new(2);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 100 {
                head += 1;
            }
        }
        // top-1% of ids should carry far more than 1% of mass
        assert!(head as f64 > 0.3 * n as f64, "head fraction {}", head as f64 / n as f64);
    }

    #[test]
    fn rank_frequencies_decrease() {
        let z = Zipf::new(50, 1.5);
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; 50];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[20]);
    }

    #[test]
    fn huge_n_does_not_overflow() {
        let z = Zipf::new(242_500_000, 1.05);
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 242_500_000);
        }
    }

    #[test]
    fn n_equals_one() {
        let z = Zipf::new(1, 1.0);
        let mut rng = Rng::new(5);
        assert_eq!(z.sample(&mut rng), 0);
    }

    fn hot20(samples: impl Fn(&mut Rng) -> u64, rng: &mut Rng) -> std::collections::HashSet<u64> {
        let mut counts = std::collections::HashMap::new();
        for _ in 0..8000 {
            *counts.entry(samples(rng)).or_insert(0u64) += 1;
        }
        // lint:allow(D1) drained to a Vec and fully sorted on the next line
        let mut v: Vec<(u64, u64)> = counts.into_iter().collect();
        v.sort_by_key(|&(id, c)| (std::cmp::Reverse(c), id));
        v.into_iter().take(20).map(|(id, _)| id).collect()
    }

    #[test]
    fn gradual_drift_interpolates_between_endpoints() {
        let mut gz = GradualDriftZipf::new(5000, 1.3, 17);
        let mut rng = Rng::new(18);
        let start = hot20(|r| gz.sample(r), &mut rng);
        gz.begin_drift(2500);
        assert_eq!(gz.alpha(), 0.0);
        // alpha = 0: still the old distribution
        let at0 = hot20(|r| gz.sample(r), &mut rng);
        assert!(start.intersection(&at0).count() >= 12, "alpha=0 already drifted");
        // alpha = 0.5: genuinely mixed — hot ids from BOTH endpoints
        gz.advance(0.5);
        let mid = hot20(|r| gz.sample(r), &mut rng);
        gz.advance(0.5);
        assert_eq!(gz.alpha(), 1.0);
        let end = hot20(|r| gz.sample(r), &mut rng);
        assert!(
            start.intersection(&end).count() <= 2,
            "endpoints barely moved: {}",
            start.intersection(&end).count()
        );
        assert!(mid.intersection(&start).count() >= 3, "mid lost the old mode");
        assert!(mid.intersection(&end).count() >= 3, "mid never gained the new mode");
        for _ in 0..2000 {
            assert!(gz.sample(&mut rng) < 5000);
        }
    }

    #[test]
    fn vocab_growth_activates_new_ids() {
        let mut gv = GrowingVocabZipf::new(10_000, 500, 1.2, 23);
        let mut rng = Rng::new(24);
        let before: std::collections::HashSet<u64> =
            (0..4000).map(|_| gv.sample(&mut rng)).collect();
        assert!(before.len() <= 500, "sampled outside the active set");
        gv.grow(4500);
        assert_eq!(gv.active(), 5000);
        let after: std::collections::HashSet<u64> =
            (0..40_000).map(|_| gv.sample(&mut rng)).collect();
        let novel = after.difference(&before).count();
        assert!(novel > 100, "growth produced almost no new ids: {novel}");
        // clamped at the vocabulary ceiling
        gv.grow(1 << 40);
        assert_eq!(gv.active(), 10_000);
        for _ in 0..2000 {
            assert!(gv.sample(&mut rng) < 10_000);
        }
    }

    #[test]
    fn drift_moves_the_hot_set() {
        let mut dz = DriftingZipf::new(5000, 1.3, 7);
        let mut rng = Rng::new(6);
        let hot_ids = |dz: &DriftingZipf, rng: &mut Rng| -> std::collections::HashSet<u64> {
            let mut counts = std::collections::HashMap::new();
            for _ in 0..8000 {
                *counts.entry(dz.sample(rng)).or_insert(0u64) += 1;
            }
            // lint:allow(D1) drained to a Vec and fully sorted on the next line
            let mut v: Vec<(u64, u64)> = counts.into_iter().collect();
            v.sort_by_key(|&(id, c)| (std::cmp::Reverse(c), id));
            v.into_iter().take(20).map(|(id, _)| id).collect()
        };
        let before = hot_ids(&dz, &mut rng);
        dz.drift(2500);
        let after = hot_ids(&dz, &mut rng);
        let overlap = before.intersection(&after).count();
        assert!(overlap <= 2, "hot set barely moved: overlap {overlap}/20");
        // samples stay in range after drift
        for _ in 0..2000 {
            assert!(dz.sample(&mut rng) < 5000);
        }
    }
}
