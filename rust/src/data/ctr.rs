//! Synthetic CTR workload generator (Avazu / Criteo shape).
//!
//! Substitution (DESIGN.md §4): the real click logs are unavailable
//! offline, so batches are synthesized with (a) Zipf-skewed sparse indices
//! per table, (b) N(0,1) dense features, and (c) labels from a *planted*
//! logistic model over a random projection of the features — giving the
//! trainers a real signal so Table V accuracy parity is measurable, while
//! the index skew drives the same systems behaviour as the real logs.

use crate::data::schema::DatasetSchema;
use crate::data::zipf::Zipf;
use crate::util::prng::Rng;

/// A mini-batch in DLRM layout (bag size 1 per table, the CTR standard).
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub dense: Vec<f32>,    // [b, n_dense] row-major
    pub sparse: Vec<u64>,   // [b, n_sparse] row-major
    pub labels: Vec<f32>,   // [b]
    pub batch_size: usize,
}

impl Batch {
    pub fn sparse_col(&self, t: usize, n_sparse: usize) -> impl Iterator<Item = u64> + '_ {
        self.sparse.iter().skip(t).step_by(n_sparse).copied()
    }
}

pub struct CtrGenerator {
    pub schema: DatasetSchema,
    zipfs: Vec<Zipf>,
    /// Planted model: weight per (table, bucketized id) + dense weights.
    dense_w: Vec<f32>,
    sparse_w: Vec<Vec<f32>>, // [table][id % W]
    rng: Rng,
}

const PLANTED_BUCKETS: usize = 1024;

impl CtrGenerator {
    pub fn new(schema: DatasetSchema, seed: u64) -> CtrGenerator {
        let mut rng = Rng::new(seed);
        let zipfs = schema
            .vocabs
            .iter()
            .map(|&v| Zipf::new(v, schema.zipf_s))
            .collect();
        let dense_w: Vec<f32> = (0..schema.n_dense).map(|_| rng.normal_f32(0.0, 0.7)).collect();
        let sparse_w: Vec<Vec<f32>> = (0..schema.vocabs.len())
            .map(|_| (0..PLANTED_BUCKETS).map(|_| rng.normal_f32(0.0, 0.5)).collect())
            .collect();
        CtrGenerator { schema, zipfs, dense_w, sparse_w, rng }
    }

    /// Draw one batch; deterministic given construction seed + call order.
    pub fn next_batch(&mut self, batch_size: usize) -> Batch {
        let nd = self.schema.n_dense;
        let ns = self.schema.vocabs.len();
        let mut dense = vec![0.0f32; batch_size * nd];
        let mut sparse = vec![0u64; batch_size * ns];
        let mut labels = vec![0.0f32; batch_size];
        for b in 0..batch_size {
            let mut logit = -0.3f32; // base rate < 0.5, CTR-like
            for d in 0..nd {
                let x = self.rng.normal_f32(0.0, 1.0);
                dense[b * nd + d] = x;
                logit += self.dense_w[d] * x;
            }
            for t in 0..ns {
                let idx = self.zipfs[t].sample(&mut self.rng);
                sparse[b * ns + t] = idx;
                logit += self.sparse_w[t][(idx as usize) % PLANTED_BUCKETS]
                    / (ns as f32).sqrt();
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            labels[b] = if self.rng.coin(p as f64) { 1.0 } else { 0.0 };
        }
        Batch { dense, sparse, labels, batch_size }
    }

    /// Sample a stream of `n` batches (for epochs over synthetic data).
    pub fn batches(&mut self, n: usize, batch_size: usize) -> Vec<Batch> {
        (0..n).map(|_| self.next_batch(batch_size)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema;

    #[test]
    fn batch_shapes() {
        let mut g = CtrGenerator::new(schema::avazu(), 1);
        let b = g.next_batch(64);
        assert_eq!(b.dense.len(), 64 * 1);
        assert_eq!(b.sparse.len(), 64 * 20);
        assert_eq!(b.labels.len(), 64);
    }

    #[test]
    fn indices_within_vocab() {
        let s = schema::criteo_kaggle();
        let vocabs = s.vocabs.clone();
        let mut g = CtrGenerator::new(s, 2);
        let b = g.next_batch(128);
        for (i, &idx) in b.sparse.iter().enumerate() {
            let t = i % vocabs.len();
            assert!(idx < vocabs[t]);
        }
    }

    #[test]
    fn labels_are_binary_and_mixed() {
        let mut g = CtrGenerator::new(schema::avazu(), 3);
        let b = g.next_batch(2000);
        let pos: usize = b.labels.iter().filter(|&&l| l > 0.5).count();
        assert!(b.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        assert!(pos > 100 && pos < 1900, "degenerate label rate {pos}/2000");
    }

    #[test]
    fn planted_signal_learnable() {
        // logistic signal exists: label rate conditioned on dense_w·x sign
        // must differ from base rate
        let mut g = CtrGenerator::new(schema::avazu(), 4);
        let b = g.next_batch(4000);
        let w = g.dense_w[0];
        let (mut hi, mut hi_n, mut lo, mut lo_n) = (0.0, 0, 0.0, 0);
        for i in 0..b.batch_size {
            let x = b.dense[i]; // n_dense == 1 for avazu
            if w * x > 0.5 {
                hi += b.labels[i];
                hi_n += 1;
            } else if w * x < -0.5 {
                lo += b.labels[i];
                lo_n += 1;
            }
        }
        let hi_rate = hi / hi_n.max(1) as f32;
        let lo_rate = lo / lo_n.max(1) as f32;
        assert!(hi_rate > lo_rate + 0.1, "hi {hi_rate} lo {lo_rate}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CtrGenerator::new(schema::avazu(), 9);
        let mut b = CtrGenerator::new(schema::avazu(), 9);
        let ba = a.next_batch(32);
        let bb = b.next_batch(32);
        assert_eq!(ba.sparse, bb.sparse);
        assert_eq!(ba.labels, bb.labels);
    }
}
