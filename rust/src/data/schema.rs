//! Dataset descriptors matching the paper's Table II, used by the
//! footprint accounting (Table IV) and the workload generators.

use crate::tt::shapes::TtShapes;

#[derive(Clone, Debug)]
pub struct DatasetSchema {
    pub name: &'static str,
    pub n_dense: usize,
    /// Per-sparse-feature vocabulary sizes.
    pub vocabs: Vec<u64>,
    pub emb_dim: usize,
    /// Zipf exponent of the index skew.
    pub zipf_s: f64,
    /// TT rank used for the *footprint* accounting (Table IV).  Calibrated
    /// per dataset so the compression factor lands near the paper's
    /// reported value; compute benches use smaller ranks.
    pub ft_rank: usize,
}

impl DatasetSchema {
    pub fn n_sparse(&self) -> usize {
        self.vocabs.len()
    }

    pub fn total_rows(&self) -> u64 {
        self.vocabs.iter().sum()
    }

    /// Plain embedding bytes (Table II "Size" / Table IV "DLRM" column).
    pub fn plain_bytes(&self) -> u64 {
        self.total_rows() * self.emb_dim as u64 * 4
    }

    /// Eff-TT bytes at `rank`, compressing tables above `threshold` rows
    /// (paper §V-C policy: >1M rows ⇒ compressed).
    pub fn tt_bytes(&self, rank: usize, threshold: u64) -> u64 {
        self.vocabs
            .iter()
            .map(|&rows| {
                if rows > threshold {
                    TtShapes::plan(rows, self.emb_dim, rank).tt_bytes()
                } else {
                    rows * self.emb_dim as u64 * 4
                }
            })
            .sum()
    }

    pub fn compression_ratio(&self, rank: usize, threshold: u64) -> f64 {
        self.plain_bytes() as f64 / self.tt_bytes(rank, threshold) as f64
    }
}

/// Avazu (Table II): 1 dense + 20 sparse, 8.9M rows, dim 16, 0.55 GB.
pub fn avazu() -> DatasetSchema {
    // vocab split: a few large id-spaces dominate (device/site ids), the
    // rest are small categoricals — matches the published cardinalities.
    let mut vocabs = vec![
        4_000_000u64, 2_500_000, 1_500_000, 500_000, 250_000, 100_000,
        30_000, 10_000, 5_000, 2_000,
    ];
    vocabs.extend([1000u64, 500, 300, 100, 50, 30, 20, 10, 8, 4]);
    DatasetSchema { name: "Avazu", n_dense: 1, vocabs, emb_dim: 16, zipf_s: 1.1, ft_rank: 96 }
}

/// Criteo Terabyte (Table II): 13 dense + 26 sparse, 242.5M rows, dim 64.
pub fn criteo_terabyte() -> DatasetSchema {
    let mut vocabs = vec![
        100_000_000u64, 60_000_000, 40_000_000, 20_000_000, 10_000_000,
        6_000_000, 3_000_000, 1_500_000, 800_000, 400_000,
    ];
    vocabs.extend([
        200_000u64, 100_000, 50_000, 20_000, 10_000, 5_000, 2_000, 1_000,
        500, 300, 200, 100, 50, 20, 10, 5,
    ]);
    DatasetSchema { name: "Criteo Terabyte", n_dense: 13, vocabs, emb_dim: 64, zipf_s: 1.05, ft_rank: 96 }
}

/// Criteo Kaggle (Table II): 13 dense + 26 sparse, 30.8M rows, dim 16.
pub fn criteo_kaggle() -> DatasetSchema {
    let mut vocabs = vec![
        12_000_000u64, 8_000_000, 5_000_000, 2_500_000, 1_500_000, 800_000,
        400_000, 200_000, 100_000, 50_000,
    ];
    vocabs.extend([
        20_000u64, 10_000, 5_000, 2_500, 1_200, 600, 300, 150, 80, 40, 20,
        10, 8, 6, 4, 2,
    ]);
    DatasetSchema { name: "Criteo Kaggle", n_dense: 13, vocabs, emb_dim: 16, zipf_s: 1.1, ft_rank: 160 }
}

/// IEEE 118-Bus (Table II): 6 dense + 7 sparse, 19.53M rows, dim 16.
pub fn ieee118() -> DatasetSchema {
    DatasetSchema {
        name: "IEEE118-Bus",
        n_dense: 6,
        vocabs: vec![12_000_000, 7_500_000, 118, 186, 54, 24, 91],
        emb_dim: 16,
        zipf_s: 1.2,
        ft_rank: 256,
    }
}

pub fn all_schemas() -> Vec<DatasetSchema> {
    vec![avazu(), criteo_terabyte(), criteo_kaggle(), ieee118()]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II row checks: row counts and plain sizes within tolerance of
    /// the published numbers.
    #[test]
    fn table2_row_counts() {
        let close = |got: f64, want: f64, tol: f64| (got - want).abs() / want < tol;
        let a = avazu();
        assert!(close(a.total_rows() as f64, 8.9e6, 0.02), "{}", a.total_rows());
        assert!(close(a.plain_bytes() as f64, 0.55e9, 0.08));
        let t = criteo_terabyte();
        assert!(close(t.total_rows() as f64, 242.5e6, 0.02), "{}", t.total_rows());
        assert!(close(t.plain_bytes() as f64, 59.2e9, 0.08));
        let k = criteo_kaggle();
        assert!(close(k.total_rows() as f64, 30.8e6, 0.02), "{}", k.total_rows());
        assert!(close(k.plain_bytes() as f64, 1.9e9, 0.08));
        let i = ieee118();
        assert!(close(i.total_rows() as f64, 19.53e6, 0.02), "{}", i.total_rows());
        assert!(close(i.plain_bytes() as f64, 1.22e9, 0.08));
    }

    #[test]
    fn feature_counts_match_table2() {
        assert_eq!(avazu().n_dense, 1);
        assert_eq!(avazu().n_sparse(), 20);
        assert_eq!(criteo_terabyte().n_dense, 13);
        assert_eq!(criteo_terabyte().n_sparse(), 26);
        assert_eq!(criteo_kaggle().n_sparse(), 26);
        assert_eq!(ieee118().n_dense, 6);
        assert_eq!(ieee118().n_sparse(), 7);
    }

    /// Table IV: per-dataset compression factors at the calibrated ranks
    /// must land near the paper's reported values (6.22x / 74.19x / 7.29x
    /// / 5.33x) and Terabyte must lead by an order of magnitude.
    #[test]
    fn table4_compression_factors() {
        let thr = 1_000_000;
        let paper = [6.22, 74.19, 7.29, 5.33];
        let ratios: Vec<(f64, &str)> = all_schemas()
            .iter()
            .map(|s| (s.compression_ratio(s.ft_rank, thr), s.name))
            .collect();
        for (&(r, name), &want) in ratios.iter().zip(&paper) {
            assert!(
                r > want * 0.5 && r < want * 2.0,
                "{name}: measured {r:.2} vs paper {want}"
            );
        }
        assert!(ratios[1].0 > 5.0 * ratios[0].0, "terabyte must dominate");
    }
}
