//! Access-plan types: the per-batch, per-table index preprocessing
//! artifact (dedup, prefix-group layout, scatter map, backward
//! aggregation order) computed ONCE during ingest and consumed by the
//! Eff-TT forward/backward, the engine, the pipeline and the server.
//!
//! The plan builders replicate the exact sweeps the Eff-TT hot path used
//! to run inline (same sorts, same sentinel logic), so planned execution
//! is bit-identical to the pre-refactor unplanned path — pinned by
//! `tests/plan_equivalence.rs`.

use std::collections::HashMap;
use std::ops::Range;

use crate::data::ctr::Batch;
use crate::reorder::bijection::IndexBijection;
use crate::tt::shapes::TtShapes;

/// Bag layout of an EmbeddingBag call.  `Unit(n)` is the CTR-standard
/// one-index-per-bag case (bag b == position b); it exists so consumers
/// never materialize the `0..=n` offset vector on the hot path.
#[derive(Clone, Copy, Debug)]
pub enum BagLayout<'a> {
    /// `n` bags of exactly one index each (offsets would be `0..=n`).
    Unit(usize),
    /// Explicit offsets: bag b covers `indices[offsets[b]..offsets[b+1]]`.
    Offsets(&'a [usize]),
}

impl<'a> BagLayout<'a> {
    #[inline]
    pub fn num_bags(&self) -> usize {
        match self {
            BagLayout::Unit(n) => *n,
            BagLayout::Offsets(o) => o.len() - 1,
        }
    }

    /// Total number of indices covered.
    #[inline]
    pub fn total(&self) -> usize {
        match self {
            BagLayout::Unit(n) => *n,
            BagLayout::Offsets(o) => *o.last().unwrap(),
        }
    }

    /// Index range of bag `b`.
    #[inline]
    pub fn range(&self, b: usize) -> Range<usize> {
        match self {
            BagLayout::Unit(_) => b..b + 1,
            BagLayout::Offsets(o) => o[b]..o[b + 1],
        }
    }
}

/// Per-table TT access plan for one batch: everything the Eff-TT
/// forward/backward needs that depends only on the index stream, not on
/// values or gradients.
///
/// Built once per (batch, table); the forward path consumes the
/// distinct-row set + scatter map, the backward path the sorted
/// occurrence list.  All buffers are reused across batches
/// (`build*` clears, never reallocates in steady state).
#[derive(Clone, Default)]
pub struct TtPlan {
    shapes: Option<TtShapes>,
    n_indices: usize,
    n_bags: usize,
    unit_bags: bool,
    fwd_ready: bool,
    bwd_ready: bool,
    /// backward reads `order` instead of `occ` (unit bags: bag == pos).
    bwd_via_order: bool,
    /// sorted (row, original position) pairs — the forward dedup sweep.
    order: Vec<(u64, u32)>,
    /// per-position slot into `uniq_rows` (the scatter map).
    pub index_slot: Vec<u32>,
    /// ascending distinct rows of the batch.
    pub uniq_rows: Vec<u64>,
    /// indices into `uniq_rows` where a new TT prefix begins.
    pub group_starts: Vec<u32>,
    /// sorted (row, bag) pairs — the backward aggregation order
    /// (empty when `bwd_via_order`).
    occ: Vec<(u64, u32)>,
    // ---- cache-resident execution layout (optional; `build_layout`) ----
    layout_ready: bool,
    /// hottest-first schedule: `sched[p]` is the slot into `uniq_rows`
    /// materialized at scheduled position p.  Prefix groups are ordered
    /// by descending size (ties by ascending first slot — deterministic),
    /// rows stay ascending within a group, so every scheduled group is a
    /// contiguous run with a distinct prefix.
    sched: Vec<u32>,
    /// inverse of `sched`: scheduled position of each distinct-row slot
    /// (the scatter map indirection of the tiled walk).
    pub slot_pos: Vec<u32>,
    /// scheduled positions where an L2 tile begins (first element 0; the
    /// final tile ends at `uniq_rows.len()`).  Tiles are whole groups, so
    /// sharding at tile boundaries preserves the compute-each-prefix-once
    /// invariant.
    tile_starts: Vec<u32>,
    /// scheduled positions where each group begins (the schedule's
    /// equivalent of `group_starts`) — the fine-grained shard cuts when
    /// there are fewer tiles than workers.
    sched_group_starts: Vec<u32>,
}

impl TtPlan {
    fn reset(&mut self, shapes: TtShapes, indices: usize, bags: BagLayout) {
        self.shapes = Some(shapes);
        self.n_indices = indices;
        self.n_bags = bags.num_bags();
        self.unit_bags = matches!(bags, BagLayout::Unit(_));
        self.fwd_ready = false;
        self.bwd_ready = false;
        self.bwd_via_order = false;
        self.layout_ready = false;
    }

    /// Forward section: sorted dedup of rows + prefix-group boundaries +
    /// scatter map.  Exactly the sweep `EffTtTable::embedding_bag` ran
    /// inline pre-refactor (same sort, same `u64::MAX` sentinels), so
    /// consuming it is bit-identical.
    pub fn build_forward(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        debug_assert_eq!(bags.total(), indices.len());
        self.reset(shapes, indices.len(), bags);
        self.order.clear();
        self.order
            .extend(indices.iter().enumerate().map(|(k, &i)| (i, k as u32)));
        self.order.sort_unstable();
        self.finish_forward(shapes);
    }

    /// Forward section from an ALREADY-SORTED (row, position) pair list —
    /// the fused cross-table sweep's entry point: one concatenated sort
    /// across all same-vocabulary slots replaces the per-slot sorts, and
    /// each slot's (row, pos)-ordered subsequence lands here.  The sweep
    /// after the sort is byte-for-byte `build_forward`'s, so the
    /// resulting plan is bitwise identical to an independently built one.
    pub(crate) fn build_forward_sorted(
        &mut self,
        shapes: TtShapes,
        sorted: &[(u64, u32)],
        bags: BagLayout,
    ) {
        debug_assert_eq!(bags.total(), sorted.len());
        debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "pairs must be sorted");
        self.reset(shapes, sorted.len(), bags);
        self.order.clear();
        self.order.extend_from_slice(sorted);
        self.finish_forward(shapes);
    }

    /// The post-sort dedup sweep shared by [`TtPlan::build_forward`] and
    /// the fused path: prefix-group boundaries + scatter map over the
    /// sorted `order` pairs.
    fn finish_forward(&mut self, shapes: TtShapes) {
        self.index_slot.clear();
        self.index_slot.resize(self.n_indices, 0);
        self.uniq_rows.clear();
        self.group_starts.clear();
        let mut last_row = u64::MAX;
        let mut last_pref = u64::MAX;
        for &(idx, pos) in self.order.iter() {
            if idx != last_row {
                let pf = shapes.prefix_of(idx);
                if pf != last_pref {
                    self.group_starts.push(self.uniq_rows.len() as u32);
                    last_pref = pf;
                }
                self.uniq_rows.push(idx);
                last_row = idx;
            }
            self.index_slot[pos as usize] = (self.uniq_rows.len() - 1) as u32;
        }
        self.fwd_ready = true;
        if self.unit_bags {
            // (row, pos) == (row, bag) when every bag holds one index, so
            // the forward sort doubles as the backward aggregation order.
            self.bwd_ready = true;
            self.bwd_via_order = true;
            self.occ.clear();
        }
    }

    /// Backward section: the sorted (row, bag) occurrence list gradient
    /// aggregation sweeps over.  Construction + sort match
    /// `EffTtTable::backward_sgd`'s inline version exactly.
    pub fn build_backward(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        debug_assert_eq!(bags.total(), indices.len());
        if !self.fwd_ready {
            self.reset(shapes, indices.len(), bags);
        }
        self.occ.clear();
        for b in 0..bags.num_bags() {
            for k in bags.range(b) {
                self.occ.push((indices[k], b as u32));
            }
        }
        self.occ.sort_unstable();
        self.bwd_ready = true;
        self.bwd_via_order = false;
    }

    /// Build both sections.  For unit bags this is a single sort (the
    /// forward order serves backward aggregation too).
    pub fn build(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        self.build_forward(shapes, indices, bags);
        if !self.unit_bags {
            self.build_backward(shapes, indices, bags);
        }
    }

    /// Build the cache-resident execution layout over a ready forward
    /// section: prefix groups scheduled hottest-first (descending size,
    /// ties by ascending slot) and cut into L2-sized tiles.  `cache_kb`
    /// is the per-core cache budget in KiB (0 disables the layout); the
    /// rows-per-tile bound keeps one prefix partial product plus each
    /// row's output and third-core slice resident while the tile is
    /// walked.
    ///
    /// Pure scheduling metadata: consumers that walk the schedule produce
    /// bit-identical outputs to the unscheduled walk (rows are
    /// materialized independently and the scatter/apply orders are
    /// unchanged) — pinned by `tests/plan_equivalence.rs`.
    pub fn build_layout(&mut self, cache_kb: usize) {
        self.build_layout_elem(cache_kb, 4);
    }

    /// [`TtPlan::build_layout`] with an explicit core element width in
    /// bytes (4 = f32, 2 = f16, 1 = int8).  Quantized cores shrink the
    /// per-row D3 slice, so more rows fit one L2 tile; the partial
    /// product and the output row stay f32 (dequantize-in-microkernel
    /// accumulates in f32).  `elem_bytes = 4` is exactly the historical
    /// budget — `build_layout` delegates here.
    pub fn build_layout_elem(&mut self, cache_kb: usize, elem_bytes: usize) {
        self.build_layout_ordered(cache_kb, elem_bytes, None);
    }

    /// [`TtPlan::build_layout`] with the group schedule ranked by a
    /// class-wide prefix-heat map instead of this table's private group
    /// sizes.  `heat` maps TT prefix → summed distinct-row count across
    /// every slot of a fused class, so all members of the class walk
    /// their (shared-vocabulary) prefix groups in ONE order: forward
    /// materializations and backward chunk sweeps of fused tables
    /// interleave on the same prefixes instead of each table pulling the
    /// partial-product cache in its own direction.  Prefixes absent from
    /// the map rank coldest.  Pure scheduling metadata, like every other
    /// layout: bit-identical outputs, pinned by `tests/plan_equivalence.rs`.
    pub fn build_layout_ranked(&mut self, cache_kb: usize, heat: &HashMap<u64, u64>) {
        self.build_layout_ordered(cache_kb, 4, Some(heat));
    }

    fn build_layout_ordered(
        &mut self,
        cache_kb: usize,
        elem_bytes: usize,
        heat: Option<&HashMap<u64, u64>>,
    ) {
        self.layout_ready = false;
        self.sched.clear();
        self.slot_pos.clear();
        self.tile_starts.clear();
        self.sched_group_starts.clear();
        if cache_kb == 0 || !self.fwd_ready {
            return;
        }
        let Some(s) = self.shapes else { return };
        let n_rows = self.uniq_rows.len();
        if n_rows == 0 {
            return;
        }
        let n_groups = self.group_starts.len();
        let starts = &self.group_starts;
        let size_of = |gi: usize| -> usize {
            let lo = starts[gi] as usize;
            let hi = starts.get(gi + 1).map(|&x| x as usize).unwrap_or(n_rows);
            hi - lo
        };
        let mut order: Vec<u32> = (0..n_groups as u32).collect();
        match heat {
            None => order.sort_by(|&x, &y| {
                size_of(y as usize).cmp(&size_of(x as usize)).then(x.cmp(&y))
            }),
            Some(heat) => {
                // class-wide ranking: (heat desc, prefix asc) is a total
                // order on prefixes, hence SHARED by every class member
                // regardless of which groups each table actually has
                let prefix_of =
                    |gi: usize| -> u64 { s.prefix_of(self.uniq_rows[starts[gi] as usize]) };
                let rank = |gi: usize| -> (std::cmp::Reverse<u64>, u64) {
                    let p = prefix_of(gi);
                    (std::cmp::Reverse(heat.get(&p).copied().unwrap_or(0)), p)
                };
                order.sort_by_key(|&x| rank(x as usize));
            }
        }
        // rows per tile: cache_kb minus the shared partial product (f32),
        // spread over the per-row working set — f32 output row plus the
        // D3 slice at the storage width — in bytes
        let plen = s.n[0] * s.n[1] * s.rank;
        let per_row = s.dim * 4 + s.rank * s.n[2] * elem_bytes;
        let budget_rows =
            ((cache_kb * 1024).saturating_sub(plen * 4) / per_row.max(1)).max(8);
        self.sched.reserve(n_rows);
        self.tile_starts.push(0);
        let mut in_tile = 0usize;
        for &gi in &order {
            let lo = starts[gi as usize] as usize;
            let sz = size_of(gi as usize);
            if in_tile > 0 && in_tile + sz > budget_rows {
                self.tile_starts.push(self.sched.len() as u32);
                in_tile = 0;
            }
            self.sched_group_starts.push(self.sched.len() as u32);
            self.sched.extend((lo..lo + sz).map(|r| r as u32));
            in_tile += sz;
        }
        debug_assert_eq!(self.sched.len(), n_rows);
        self.slot_pos.resize(n_rows, 0);
        for (p, &slot) in self.sched.iter().enumerate() {
            self.slot_pos[slot as usize] = p as u32;
        }
        self.layout_ready = true;
    }

    /// Whether a cache-resident layout is attached (tiled execution).
    #[inline]
    pub fn tiled(&self) -> bool {
        self.layout_ready
    }

    /// The hottest-first schedule (slots into `uniq_rows` per position).
    #[inline]
    pub fn sched(&self) -> &[u32] {
        &self.sched
    }

    /// Scheduled positions where each L2 tile begins (first is 0).
    #[inline]
    pub fn tile_starts(&self) -> &[u32] {
        &self.tile_starts
    }

    /// Scheduled positions where each prefix group begins — the valid
    /// fine-grained shard cuts of the tiled walk.
    #[inline]
    pub fn sched_group_starts(&self) -> &[u32] {
        &self.sched_group_starts
    }

    /// Number of L2 tiles in the attached layout (0 when untiled).
    #[inline]
    pub fn num_tiles(&self) -> usize {
        if self.layout_ready {
            self.tile_starts.len()
        } else {
            0
        }
    }

    /// The distinct-row slots (indices into `uniq_rows`) scheduled into
    /// tile `i`, in schedule order; empty when the plan is untiled or
    /// `i >= num_tiles()`.  Tiles are the ready-made routing units of
    /// plan-driven sharding: a tile's row set is exactly what stays
    /// cache-resident while the tile is walked, so a router that keeps a
    /// tile's rows on one replica keeps that replica warm.
    pub fn tile_slots(&self, i: usize) -> &[u32] {
        if !self.layout_ready || i >= self.tile_starts.len() {
            return &[];
        }
        let lo = self.tile_starts[i] as usize;
        let hi = self
            .tile_starts
            .get(i + 1)
            .map(|&x| x as usize)
            .unwrap_or(self.sched.len());
        &self.sched[lo..hi]
    }

    /// The rows of tile `i` (its slots resolved through `uniq_rows`).
    pub fn tile_rows(&self, i: usize) -> impl Iterator<Item = u64> + '_ {
        self.tile_slots(i).iter().map(move |&s| self.uniq_rows[s as usize])
    }

    #[inline]
    pub fn shapes(&self) -> Option<TtShapes> {
        self.shapes
    }

    #[inline]
    pub fn n_indices(&self) -> usize {
        self.n_indices
    }

    #[inline]
    pub fn num_bags(&self) -> usize {
        self.n_bags
    }

    #[inline]
    pub fn forward_ready(&self) -> bool {
        self.fwd_ready
    }

    #[inline]
    pub fn backward_ready(&self) -> bool {
        self.bwd_ready
    }

    /// The sorted (row, bag) occurrence list (gradient-aggregation order).
    #[inline]
    pub fn occ_sorted(&self) -> &[(u64, u32)] {
        if self.bwd_via_order {
            &self.order
        } else {
            &self.occ
        }
    }

    /// Distinct rows in the batch (hop-2 GEMM count under reuse).
    pub fn distinct_rows(&self) -> usize {
        self.uniq_rows.len()
    }

    /// Distinct TT prefixes in the batch (first-hop GEMM count under
    /// reuse); the quantity index reordering minimizes (§III-G).
    pub fn distinct_prefixes(&self) -> usize {
        self.group_starts.len()
    }

    /// Fraction of first-hop GEMMs saved by the Reuse Buffer on this
    /// batch: `1 - distinct_prefixes / indices`.
    pub fn reuse_rate(&self) -> f64 {
        if self.n_indices == 0 {
            return 0.0;
        }
        1.0 - self.distinct_prefixes() as f64 / self.n_indices as f64
    }
}

/// Grow-only cache of the `[0, 1, …, n]` unit-bag offset vector, so
/// consumers that still need a materialized `&[usize]` (plain tables)
/// never rebuild it per call.
#[derive(Clone, Default)]
pub struct UnitOffsets {
    buf: Vec<usize>,
}

impl UnitOffsets {
    /// `&[0, 1, …, n]` (length n+1), extending the backing store only
    /// when `n` grows past every previous request.
    pub fn get(&mut self, n: usize) -> &[usize] {
        if self.buf.len() < n + 1 {
            let start = self.buf.len();
            self.buf.extend(start..=n);
        }
        &self.buf[..n + 1]
    }
}

/// Whole-batch access plan: per-table remapped index columns plus the
/// TT plan for every compressed slot.  The engine, pipeline and server
/// consume this instead of re-slicing `Batch::sparse` per table per pass.
#[derive(Clone, Default)]
pub struct BatchPlan {
    batch_size: usize,
    /// Per-table index column, already passed through the table's
    /// bijection (identity when reordering is off).
    cols: Vec<Vec<u64>>,
    /// Per-table TT access plan; `None` for plain (uncompressed) slots.
    tt: Vec<Option<TtPlan>>,
    unit_offsets: UnitOffsets,
    /// L2 budget (KiB) for hottest-first tiled layouts; 0 = untiled.
    cache_kb: usize,
    /// Dedup across same-vocabulary TT slots in one fused sorted sweep.
    fuse_tables: bool,
    fused: crate::access::fused::FusedSweep,
    /// Counters from the fused sweep (zeroed per build).
    pub fused_stats: crate::access::fused::FusedStats,
}

impl BatchPlan {
    /// Set the execution policy applied by subsequent builds: `cache_kb`
    /// attaches hottest-first tiled layouts to every TT plan (0 = off),
    /// `fuse_tables` plans same-vocabulary TT slots through one fused
    /// prefix-sorted sweep.  Both are bit-identity-preserving; they only
    /// change how (and how fast) the same plans are built and walked.
    pub fn set_policy(&mut self, cache_kb: usize, fuse_tables: bool) {
        self.cache_kb = cache_kb;
        self.fuse_tables = fuse_tables;
    }

    /// Plan one batch: extract + remap every sparse column, build the TT
    /// plan for each compressed slot (`shapes[t] = Some(..)`), refresh
    /// the unit-offset cache.  `bijections` may be shorter than `shapes`
    /// (missing/`None` entries mean identity).  All buffers are reused.
    pub fn build_into(
        &mut self,
        batch: &Batch,
        shapes: &[Option<TtShapes>],
        bijections: &[Option<IndexBijection>],
    ) {
        let ns = shapes.len();
        let b = batch.batch_size;
        debug_assert_eq!(batch.sparse.len(), b * ns);
        self.batch_size = b;
        self.cols.resize_with(ns, Vec::new);
        self.tt.resize_with(ns, || None);
        for t in 0..ns {
            let col = &mut self.cols[t];
            col.clear();
            col.extend(batch.sparse_col(t, ns));
            if let Some(Some(bij)) = bijections.get(t).map(|b| b.as_ref()) {
                for v in col.iter_mut() {
                    *v = bij.apply(*v);
                }
            }
            if shapes[t].is_none() {
                self.tt[t] = None;
            }
        }
        self.fused_stats = Default::default();
        if self.fuse_tables {
            // one prefix-sorted sweep per same-shapes class (plans are
            // bitwise identical to the per-slot builds below)
            let mut fused = std::mem::take(&mut self.fused);
            fused.build_classes(
                shapes,
                &self.cols,
                &mut self.tt,
                b,
                &mut self.fused_stats,
            );
            self.fused = fused;
        } else {
            for t in 0..ns {
                if let Some(sh) = shapes[t] {
                    let plan = self.tt[t].get_or_insert_with(TtPlan::default);
                    plan.build(sh, &self.cols[t], BagLayout::Unit(b));
                }
            }
        }
        if self.cache_kb > 0 {
            for plan in self.tt.iter_mut().flatten() {
                plan.build_layout(self.cache_kb);
            }
            if self.fuse_tables {
                // Fused classes get a class-wide RANKED layout on top:
                // sum each TT prefix's distinct-row count across every
                // member, then rebuild each member's schedule in that
                // shared heat order so the fused tables' core-slice
                // walks interleave on the same prefixes.  Overrides the
                // generic layout above (ranked build clears first);
                // bit-identity is untouched either way.
                let fused = std::mem::take(&mut self.fused);
                let mut heat: HashMap<u64, u64> = HashMap::new();
                for members in fused.multi_classes() {
                    heat.clear();
                    for &t in members {
                        let (Some(sh), Some(plan)) = (&shapes[t], &self.tt[t]) else {
                            continue;
                        };
                        let n_rows = plan.uniq_rows.len();
                        let starts = &plan.group_starts;
                        for (gi, &lo) in starts.iter().enumerate() {
                            let hi = starts
                                .get(gi + 1)
                                .map(|&x| x as usize)
                                .unwrap_or(n_rows);
                            let prefix = sh.prefix_of(plan.uniq_rows[lo as usize]);
                            *heat.entry(prefix).or_insert(0) += (hi - lo as usize) as u64;
                        }
                    }
                    for &t in members {
                        if let Some(plan) = self.tt[t].as_mut() {
                            plan.build_layout_ranked(self.cache_kb, &heat);
                        }
                    }
                }
                self.fused = fused;
            }
        }
        self.unit_offsets.get(b);
    }

    #[inline]
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    #[inline]
    pub fn n_tables(&self) -> usize {
        self.cols.len()
    }

    /// The (remapped) index column of table `t`.
    #[inline]
    pub fn col(&self, t: usize) -> &[u64] {
        &self.cols[t]
    }

    /// The TT plan of table `t` (`None` for plain slots).
    #[inline]
    pub fn tt_plan(&self, t: usize) -> Option<&TtPlan> {
        self.tt[t].as_ref()
    }

    /// Cached unit-bag offsets `[0, 1, …, batch_size]` for consumers that
    /// need a materialized slice (plain tables).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.unit_offsets.buf[..self.batch_size + 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn unit_offsets_grow_only() {
        let mut u = UnitOffsets::default();
        assert_eq!(u.get(3), &[0, 1, 2, 3]);
        let cap_after_big = {
            u.get(100);
            u.buf.capacity()
        };
        assert_eq!(u.get(5), &[0, 1, 2, 3, 4, 5]);
        assert_eq!(u.buf.capacity(), cap_after_big, "shrank instead of caching");
        assert_eq!(u.get(100).len(), 101);
        for (i, &v) in u.get(100).iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn tt_plan_dedups_rows_and_prefixes() {
        let shapes = TtShapes::plan(1000, 8, 4);
        let m3 = shapes.m[2];
        // 4 indices, 3 distinct rows, 2 distinct prefixes
        let idx = vec![5 * m3, 5 * m3 + 1, 7 * m3 + 2, 7 * m3 + 2];
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Unit(4));
        assert_eq!(plan.distinct_rows(), 3);
        assert_eq!(plan.distinct_prefixes(), 2);
        assert!(plan.forward_ready() && plan.backward_ready());
        // scatter map points every position at its distinct row
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(plan.uniq_rows[plan.index_slot[k] as usize], i);
        }
        // unit bags: backward order is the forward order
        assert_eq!(plan.occ_sorted().len(), 4);
        assert!(plan.occ_sorted().windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn layout_schedules_hottest_first_in_valid_tiles() {
        let shapes = TtShapes::plan(5000, 16, 8);
        let mut rng = Rng::new(7);
        // skewed: many repeats => groups of very different sizes
        let idx: Vec<u64> = (0..2048).map(|_| rng.below(300)).collect();
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Unit(idx.len()));
        assert!(!plan.tiled());
        plan.build_layout(1); // 1 KiB => many small tiles
        assert!(plan.tiled());
        let n = plan.uniq_rows.len();
        // sched is a permutation of 0..n and slot_pos its inverse
        let mut seen = vec![false; n];
        for (p, &slot) in plan.sched().iter().enumerate() {
            assert!(!seen[slot as usize], "slot {slot} scheduled twice");
            seen[slot as usize] = true;
            assert_eq!(plan.slot_pos[slot as usize] as usize, p);
        }
        assert!(seen.iter().all(|&s| s));
        // group sizes are non-increasing along the schedule
        let group_of = |slot: u32| {
            plan.group_starts.partition_point(|&g| g <= slot) - 1
        };
        let size_of = |g: usize| {
            let lo = plan.group_starts[g] as usize;
            let hi =
                plan.group_starts.get(g + 1).map(|&x| x as usize).unwrap_or(n);
            hi - lo
        };
        let mut sizes = Vec::new();
        let mut last_group = usize::MAX;
        for &slot in plan.sched() {
            let g = group_of(slot);
            if g != last_group {
                sizes.push(size_of(g));
                last_group = g;
            }
        }
        assert_eq!(sizes.len(), plan.group_starts.len());
        assert!(sizes.windows(2).all(|w| w[0] >= w[1]), "not hottest-first: {sizes:?}");
        // tile boundaries are scheduled-group boundaries
        assert!(plan.tile_starts().len() > 1, "1 KiB budget must emit several tiles");
        for &t in plan.tile_starts() {
            assert!(
                plan.sched_group_starts().contains(&t),
                "tile start {t} not at a group boundary"
            );
        }
        // disabling the layout clears it
        plan.build_layout(0);
        assert!(!plan.tiled());
        assert!(plan.sched().is_empty() && plan.tile_starts().is_empty());
    }

    #[test]
    fn elem_width_aware_layout_packs_wider_tiles() {
        let shapes = TtShapes::plan(5000, 16, 8);
        let mut rng = Rng::new(21);
        let idx: Vec<u64> = (0..2048).map(|_| rng.below(600)).collect();
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Unit(idx.len()));
        // build_layout == build_layout_elem at 4 bytes, exactly
        plan.build_layout(2);
        let f32_tiles: Vec<u32> = plan.tile_starts().to_vec();
        let f32_sched: Vec<u32> = plan.sched().to_vec();
        plan.build_layout_elem(2, 4);
        assert_eq!(plan.tile_starts(), &f32_tiles[..]);
        assert_eq!(plan.sched(), &f32_sched[..]);
        // shrinking the D3 slice never cuts MORE tiles, and the schedule
        // (hottest-first order) is width-independent
        for eb in [2usize, 1] {
            plan.build_layout_elem(2, eb);
            assert!(plan.tile_starts().len() <= f32_tiles.len());
            assert_eq!(plan.sched(), &f32_sched[..]);
        }
    }

    #[test]
    fn ranked_layout_shares_one_prefix_order_across_plans() {
        let shapes = TtShapes::plan(5000, 16, 8);
        let mut rng = Rng::new(11);
        let idx_a: Vec<u64> = (0..1024).map(|_| rng.below(400)).collect();
        let idx_b: Vec<u64> = (0..1024).map(|_| rng.below(700)).collect();
        let mut a = TtPlan::default();
        let mut b = TtPlan::default();
        a.build(shapes, &idx_a, BagLayout::Unit(idx_a.len()));
        b.build(shapes, &idx_b, BagLayout::Unit(idx_b.len()));
        // class-wide heat: summed distinct-row counts per prefix
        let mut heat: HashMap<u64, u64> = HashMap::new();
        for plan in [&a, &b] {
            let n = plan.uniq_rows.len();
            for (gi, &lo) in plan.group_starts.iter().enumerate() {
                let hi = plan
                    .group_starts
                    .get(gi + 1)
                    .map(|&x| x as usize)
                    .unwrap_or(n);
                let p = shapes.prefix_of(plan.uniq_rows[lo as usize]);
                *heat.entry(p).or_insert(0) += (hi - lo as usize) as u64;
            }
        }
        a.build_layout_ranked(1, &heat);
        b.build_layout_ranked(1, &heat);
        // scheduled prefix sequence of one plan, in walk order
        let prefixes_of = |plan: &TtPlan| -> Vec<u64> {
            plan.sched_group_starts()
                .iter()
                .map(|&p| {
                    let slot = plan.sched()[p as usize] as usize;
                    shapes.prefix_of(plan.uniq_rows[slot])
                })
                .collect()
        };
        for plan in [&a, &b] {
            assert!(plan.tiled());
            // sched is still a permutation of the distinct-row slots
            let n = plan.uniq_rows.len();
            let mut seen = vec![false; n];
            for &slot in plan.sched() {
                assert!(!seen[slot as usize]);
                seen[slot as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
            // walk order follows (heat desc, prefix asc) — the shared rank
            let ps = prefixes_of(plan);
            assert!(ps.windows(2).all(|w| {
                let ka = (std::cmp::Reverse(heat[&w[0]]), w[0]);
                let kb = (std::cmp::Reverse(heat[&w[1]]), w[1]);
                ka < kb
            }));
            // tile boundaries remain group boundaries
            for &t in plan.tile_starts() {
                assert!(plan.sched_group_starts().contains(&t));
            }
        }
        // both plans walk their (shared-vocabulary) prefixes in ONE order:
        // the common prefixes appear in the same relative order
        let pa = prefixes_of(&a);
        let pb = prefixes_of(&b);
        let common: Vec<u64> =
            pa.iter().copied().filter(|p| pb.contains(p)).collect();
        let pb_common: Vec<u64> =
            pb.iter().copied().filter(|p| pa.contains(p)).collect();
        assert!(!common.is_empty(), "test needs overlapping prefixes");
        assert_eq!(common, pb_common, "class members disagree on walk order");
    }

    #[test]
    fn tile_row_sets_partition_the_distinct_rows() {
        let shapes = TtShapes::plan(5000, 16, 8);
        let mut rng = Rng::new(13);
        let idx: Vec<u64> = (0..1024).map(|_| rng.below(400)).collect();
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Unit(idx.len()));
        assert_eq!(plan.num_tiles(), 0, "untiled plan exposes no tiles");
        assert!(plan.tile_slots(0).is_empty(), "untiled tile_slots must be empty");
        plan.build_layout(1);
        let n_tiles = plan.num_tiles();
        assert!(n_tiles > 1, "1 KiB budget must cut several tiles");
        // every distinct-row slot appears in exactly one tile
        let n = plan.uniq_rows.len();
        let mut seen = vec![false; n];
        for t in 0..n_tiles {
            for &slot in plan.tile_slots(t) {
                assert!(!seen[slot as usize], "slot {slot} in two tiles");
                seen[slot as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "a slot is in no tile");
        assert!(plan.tile_slots(n_tiles).is_empty(), "out-of-range tile must be empty");
        // tile_rows resolves slots through uniq_rows
        for t in 0..n_tiles {
            for (row, &slot) in plan.tile_rows(t).zip(plan.tile_slots(t)) {
                assert_eq!(row, plan.uniq_rows[slot as usize]);
            }
        }
    }

    #[test]
    fn tt_plan_multibag_occ_matches_manual_sort(){
        let shapes = TtShapes::plan(500, 8, 4);
        let mut rng = Rng::new(3);
        let idx: Vec<u64> = (0..32).map(|_| rng.below(500)).collect();
        let offsets: Vec<usize> = vec![0, 5, 5, 20, 32];
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Offsets(&offsets[..]));
        let mut manual: Vec<(u64, u32)> = Vec::new();
        for b in 0..offsets.len() - 1 {
            for k in offsets[b]..offsets[b + 1] {
                manual.push((idx[k], b as u32));
            }
        }
        manual.sort_unstable();
        assert_eq!(plan.occ_sorted(), &manual[..]);
    }

    #[test]
    fn bag_layout_unit_equivalent_to_offsets() {
        let offsets: Vec<usize> = (0..=6).collect();
        let unit = BagLayout::Unit(6);
        let off = BagLayout::Offsets(&offsets[..]);
        assert_eq!(unit.num_bags(), off.num_bags());
        assert_eq!(unit.total(), off.total());
        for b in 0..6 {
            assert_eq!(unit.range(b), off.range(b));
        }
    }
}
