//! Access-plan types: the per-batch, per-table index preprocessing
//! artifact (dedup, prefix-group layout, scatter map, backward
//! aggregation order) computed ONCE during ingest and consumed by the
//! Eff-TT forward/backward, the engine, the pipeline and the server.
//!
//! The plan builders replicate the exact sweeps the Eff-TT hot path used
//! to run inline (same sorts, same sentinel logic), so planned execution
//! is bit-identical to the pre-refactor unplanned path — pinned by
//! `tests/plan_equivalence.rs`.

use std::ops::Range;

use crate::data::ctr::Batch;
use crate::reorder::bijection::IndexBijection;
use crate::tt::shapes::TtShapes;

/// Bag layout of an EmbeddingBag call.  `Unit(n)` is the CTR-standard
/// one-index-per-bag case (bag b == position b); it exists so consumers
/// never materialize the `0..=n` offset vector on the hot path.
#[derive(Clone, Copy, Debug)]
pub enum BagLayout<'a> {
    /// `n` bags of exactly one index each (offsets would be `0..=n`).
    Unit(usize),
    /// Explicit offsets: bag b covers `indices[offsets[b]..offsets[b+1]]`.
    Offsets(&'a [usize]),
}

impl<'a> BagLayout<'a> {
    #[inline]
    pub fn num_bags(&self) -> usize {
        match self {
            BagLayout::Unit(n) => *n,
            BagLayout::Offsets(o) => o.len() - 1,
        }
    }

    /// Total number of indices covered.
    #[inline]
    pub fn total(&self) -> usize {
        match self {
            BagLayout::Unit(n) => *n,
            BagLayout::Offsets(o) => *o.last().unwrap(),
        }
    }

    /// Index range of bag `b`.
    #[inline]
    pub fn range(&self, b: usize) -> Range<usize> {
        match self {
            BagLayout::Unit(_) => b..b + 1,
            BagLayout::Offsets(o) => o[b]..o[b + 1],
        }
    }
}

/// Per-table TT access plan for one batch: everything the Eff-TT
/// forward/backward needs that depends only on the index stream, not on
/// values or gradients.
///
/// Built once per (batch, table); the forward path consumes the
/// distinct-row set + scatter map, the backward path the sorted
/// occurrence list.  All buffers are reused across batches
/// (`build*` clears, never reallocates in steady state).
#[derive(Clone, Default)]
pub struct TtPlan {
    shapes: Option<TtShapes>,
    n_indices: usize,
    n_bags: usize,
    unit_bags: bool,
    fwd_ready: bool,
    bwd_ready: bool,
    /// backward reads `order` instead of `occ` (unit bags: bag == pos).
    bwd_via_order: bool,
    /// sorted (row, original position) pairs — the forward dedup sweep.
    order: Vec<(u64, u32)>,
    /// per-position slot into `uniq_rows` (the scatter map).
    pub index_slot: Vec<u32>,
    /// ascending distinct rows of the batch.
    pub uniq_rows: Vec<u64>,
    /// indices into `uniq_rows` where a new TT prefix begins.
    pub group_starts: Vec<u32>,
    /// sorted (row, bag) pairs — the backward aggregation order
    /// (empty when `bwd_via_order`).
    occ: Vec<(u64, u32)>,
}

impl TtPlan {
    fn reset(&mut self, shapes: TtShapes, indices: usize, bags: BagLayout) {
        self.shapes = Some(shapes);
        self.n_indices = indices;
        self.n_bags = bags.num_bags();
        self.unit_bags = matches!(bags, BagLayout::Unit(_));
        self.fwd_ready = false;
        self.bwd_ready = false;
        self.bwd_via_order = false;
    }

    /// Forward section: sorted dedup of rows + prefix-group boundaries +
    /// scatter map.  Exactly the sweep `EffTtTable::embedding_bag` ran
    /// inline pre-refactor (same sort, same `u64::MAX` sentinels), so
    /// consuming it is bit-identical.
    pub fn build_forward(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        debug_assert_eq!(bags.total(), indices.len());
        self.reset(shapes, indices.len(), bags);
        self.order.clear();
        self.order
            .extend(indices.iter().enumerate().map(|(k, &i)| (i, k as u32)));
        self.order.sort_unstable();
        self.index_slot.clear();
        self.index_slot.resize(indices.len(), 0);
        self.uniq_rows.clear();
        self.group_starts.clear();
        let mut last_row = u64::MAX;
        let mut last_pref = u64::MAX;
        for &(idx, pos) in self.order.iter() {
            if idx != last_row {
                let pf = shapes.prefix_of(idx);
                if pf != last_pref {
                    self.group_starts.push(self.uniq_rows.len() as u32);
                    last_pref = pf;
                }
                self.uniq_rows.push(idx);
                last_row = idx;
            }
            self.index_slot[pos as usize] = (self.uniq_rows.len() - 1) as u32;
        }
        self.fwd_ready = true;
        if self.unit_bags {
            // (row, pos) == (row, bag) when every bag holds one index, so
            // the forward sort doubles as the backward aggregation order.
            self.bwd_ready = true;
            self.bwd_via_order = true;
            self.occ.clear();
        }
    }

    /// Backward section: the sorted (row, bag) occurrence list gradient
    /// aggregation sweeps over.  Construction + sort match
    /// `EffTtTable::backward_sgd`'s inline version exactly.
    pub fn build_backward(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        debug_assert_eq!(bags.total(), indices.len());
        if !self.fwd_ready {
            self.reset(shapes, indices.len(), bags);
        }
        self.occ.clear();
        for b in 0..bags.num_bags() {
            for k in bags.range(b) {
                self.occ.push((indices[k], b as u32));
            }
        }
        self.occ.sort_unstable();
        self.bwd_ready = true;
        self.bwd_via_order = false;
    }

    /// Build both sections.  For unit bags this is a single sort (the
    /// forward order serves backward aggregation too).
    pub fn build(&mut self, shapes: TtShapes, indices: &[u64], bags: BagLayout) {
        self.build_forward(shapes, indices, bags);
        if !self.unit_bags {
            self.build_backward(shapes, indices, bags);
        }
    }

    #[inline]
    pub fn shapes(&self) -> Option<TtShapes> {
        self.shapes
    }

    #[inline]
    pub fn n_indices(&self) -> usize {
        self.n_indices
    }

    #[inline]
    pub fn num_bags(&self) -> usize {
        self.n_bags
    }

    #[inline]
    pub fn forward_ready(&self) -> bool {
        self.fwd_ready
    }

    #[inline]
    pub fn backward_ready(&self) -> bool {
        self.bwd_ready
    }

    /// The sorted (row, bag) occurrence list (gradient-aggregation order).
    #[inline]
    pub fn occ_sorted(&self) -> &[(u64, u32)] {
        if self.bwd_via_order {
            &self.order
        } else {
            &self.occ
        }
    }

    /// Distinct rows in the batch (hop-2 GEMM count under reuse).
    pub fn distinct_rows(&self) -> usize {
        self.uniq_rows.len()
    }

    /// Distinct TT prefixes in the batch (first-hop GEMM count under
    /// reuse); the quantity index reordering minimizes (§III-G).
    pub fn distinct_prefixes(&self) -> usize {
        self.group_starts.len()
    }

    /// Fraction of first-hop GEMMs saved by the Reuse Buffer on this
    /// batch: `1 - distinct_prefixes / indices`.
    pub fn reuse_rate(&self) -> f64 {
        if self.n_indices == 0 {
            return 0.0;
        }
        1.0 - self.distinct_prefixes() as f64 / self.n_indices as f64
    }
}

/// Grow-only cache of the `[0, 1, …, n]` unit-bag offset vector, so
/// consumers that still need a materialized `&[usize]` (plain tables)
/// never rebuild it per call.
#[derive(Clone, Default)]
pub struct UnitOffsets {
    buf: Vec<usize>,
}

impl UnitOffsets {
    /// `&[0, 1, …, n]` (length n+1), extending the backing store only
    /// when `n` grows past every previous request.
    pub fn get(&mut self, n: usize) -> &[usize] {
        if self.buf.len() < n + 1 {
            let start = self.buf.len();
            self.buf.extend(start..=n);
        }
        &self.buf[..n + 1]
    }
}

/// Whole-batch access plan: per-table remapped index columns plus the
/// TT plan for every compressed slot.  The engine, pipeline and server
/// consume this instead of re-slicing `Batch::sparse` per table per pass.
#[derive(Clone, Default)]
pub struct BatchPlan {
    batch_size: usize,
    /// Per-table index column, already passed through the table's
    /// bijection (identity when reordering is off).
    cols: Vec<Vec<u64>>,
    /// Per-table TT access plan; `None` for plain (uncompressed) slots.
    tt: Vec<Option<TtPlan>>,
    unit_offsets: UnitOffsets,
}

impl BatchPlan {
    /// Plan one batch: extract + remap every sparse column, build the TT
    /// plan for each compressed slot (`shapes[t] = Some(..)`), refresh
    /// the unit-offset cache.  `bijections` may be shorter than `shapes`
    /// (missing/`None` entries mean identity).  All buffers are reused.
    pub fn build_into(
        &mut self,
        batch: &Batch,
        shapes: &[Option<TtShapes>],
        bijections: &[Option<IndexBijection>],
    ) {
        let ns = shapes.len();
        let b = batch.batch_size;
        debug_assert_eq!(batch.sparse.len(), b * ns);
        self.batch_size = b;
        self.cols.resize_with(ns, Vec::new);
        self.tt.resize_with(ns, || None);
        for t in 0..ns {
            let col = &mut self.cols[t];
            col.clear();
            col.extend(batch.sparse_col(t, ns));
            if let Some(Some(bij)) = bijections.get(t).map(|b| b.as_ref()) {
                for v in col.iter_mut() {
                    *v = bij.apply(*v);
                }
            }
            match shapes[t] {
                Some(sh) => {
                    let plan = self.tt[t].get_or_insert_with(TtPlan::default);
                    plan.build(sh, col, BagLayout::Unit(b));
                }
                None => self.tt[t] = None,
            }
        }
        self.unit_offsets.get(b);
    }

    #[inline]
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    #[inline]
    pub fn n_tables(&self) -> usize {
        self.cols.len()
    }

    /// The (remapped) index column of table `t`.
    #[inline]
    pub fn col(&self, t: usize) -> &[u64] {
        &self.cols[t]
    }

    /// The TT plan of table `t` (`None` for plain slots).
    #[inline]
    pub fn tt_plan(&self, t: usize) -> Option<&TtPlan> {
        self.tt[t].as_ref()
    }

    /// Cached unit-bag offsets `[0, 1, …, batch_size]` for consumers that
    /// need a materialized slice (plain tables).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.unit_offsets.buf[..self.batch_size + 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn unit_offsets_grow_only() {
        let mut u = UnitOffsets::default();
        assert_eq!(u.get(3), &[0, 1, 2, 3]);
        let cap_after_big = {
            u.get(100);
            u.buf.capacity()
        };
        assert_eq!(u.get(5), &[0, 1, 2, 3, 4, 5]);
        assert_eq!(u.buf.capacity(), cap_after_big, "shrank instead of caching");
        assert_eq!(u.get(100).len(), 101);
        for (i, &v) in u.get(100).iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn tt_plan_dedups_rows_and_prefixes() {
        let shapes = TtShapes::plan(1000, 8, 4);
        let m3 = shapes.m[2];
        // 4 indices, 3 distinct rows, 2 distinct prefixes
        let idx = vec![5 * m3, 5 * m3 + 1, 7 * m3 + 2, 7 * m3 + 2];
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Unit(4));
        assert_eq!(plan.distinct_rows(), 3);
        assert_eq!(plan.distinct_prefixes(), 2);
        assert!(plan.forward_ready() && plan.backward_ready());
        // scatter map points every position at its distinct row
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(plan.uniq_rows[plan.index_slot[k] as usize], i);
        }
        // unit bags: backward order is the forward order
        assert_eq!(plan.occ_sorted().len(), 4);
        assert!(plan.occ_sorted().windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn tt_plan_multibag_occ_matches_manual_sort(){
        let shapes = TtShapes::plan(500, 8, 4);
        let mut rng = Rng::new(3);
        let idx: Vec<u64> = (0..32).map(|_| rng.below(500)).collect();
        let offsets: Vec<usize> = vec![0, 5, 5, 20, 32];
        let mut plan = TtPlan::default();
        plan.build(shapes, &idx, BagLayout::Offsets(&offsets[..]));
        let mut manual: Vec<(u64, u32)> = Vec::new();
        for b in 0..offsets.len() - 1 {
            for k in offsets[b]..offsets[b + 1] {
                manual.push((idx[k], b as u32));
            }
        }
        manual.sort_unstable();
        assert_eq!(plan.occ_sorted(), &manual[..]);
    }

    #[test]
    fn bag_layout_unit_equivalent_to_offsets() {
        let offsets: Vec<usize> = (0..=6).collect();
        let unit = BagLayout::Unit(6);
        let off = BagLayout::Offsets(&offsets[..]);
        assert_eq!(unit.num_bags(), off.num_bags());
        assert_eq!(unit.total(), off.total());
        for b in 0..6 {
            assert_eq!(unit.range(b), off.range(b));
        }
    }
}
