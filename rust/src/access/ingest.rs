//! Prefetch-overlapped ingest: assemble + remap + plan batch N+1 on a
//! worker thread while batch N trains — the paper's pipeline mechanism
//! (§IV) applied to *data access* rather than embedding/MLP overlap.
//!
//! Determinism: batches and plans are computed by pure functions of the
//! source stream and planner state, and the consumer sees them in source
//! order, so `plan_ahead = N` is bit-identical to `plan_ahead = 0`
//! (pinned by `tests/plan_equivalence.rs`).  Buffer shells circulate
//! through a recycle channel, so the steady state is allocation-free:
//! with `plan_ahead = 1` exactly the classic double buffer.

use std::sync::mpsc;
use std::time::Instant;

use crate::access::plan::BatchPlan;
use crate::access::planner::AccessPlanner;
use crate::data::ctr::Batch;

/// One assembled batch plus its access plan (the queue item).
#[derive(Clone, Default)]
pub struct PlannedBatch {
    pub batch: Batch,
    pub plan: BatchPlan,
}

/// What a run of the ingest stage did.
#[derive(Clone, Copy, Debug)]
pub struct IngestReport {
    pub batches: u64,
    /// Whether an overlap thread ran (`plan_ahead > 0`).
    pub overlapped: bool,
    /// Longest single `plan_into` call on the ingest thread (seconds) —
    /// dominated by inline bijection rebuilds when online reordering is
    /// on; the background refresh engine exists to bound this.
    pub plan_stall_max_s: f64,
    /// Total ingest-thread planning seconds across the run.
    pub plan_time_total_s: f64,
}

/// Drive `consume` over a refillable batch source with `plan_ahead`
/// batches of lookahead.
///
/// `fill` writes the next batch into reusable scratch and returns `false`
/// when the stream is exhausted (e.g. `EpochIter::next_into`); it runs on
/// the ingest worker when `plan_ahead > 0`.  `consume` always runs on the
/// calling thread.
pub fn run_prefetched_fill<F, C>(
    mut fill: F,
    planner: &mut AccessPlanner,
    plan_ahead: usize,
    mut consume: C,
) -> IngestReport
where
    F: FnMut(&mut Batch) -> bool + Send,
    C: FnMut(&Batch, &BatchPlan),
{
    let mut n = 0u64;
    if plan_ahead == 0 {
        // inline mode: one reusable shell, no threads
        let mut pb = PlannedBatch::default();
        let (mut stall_max, mut total) = (0.0f64, 0.0f64);
        while fill(&mut pb.batch) {
            // lint:allow(D2) plan-stall instrumentation times the real planning call
            let t0 = Instant::now();
            planner.plan_into(&pb.batch, &mut pb.plan);
            let dt = t0.elapsed().as_secs_f64();
            stall_max = stall_max.max(dt);
            total += dt;
            consume(&pb.batch, &pb.plan);
            n += 1;
        }
        return IngestReport {
            batches: n,
            overlapped: false,
            plan_stall_max_s: stall_max,
            plan_time_total_s: total,
        };
    }
    let (tx, rx) = mpsc::sync_channel::<PlannedBatch>(plan_ahead);
    let (recycle_tx, recycle_rx) = mpsc::channel::<PlannedBatch>();
    let (stall_max, total) = std::thread::scope(|sc| {
        let planner = &mut *planner;
        let ingest = sc.spawn(move || {
            let (mut stall_max, mut total) = (0.0f64, 0.0f64);
            loop {
                // reuse a spent shell when one has come back
                let mut pb = recycle_rx.try_recv().unwrap_or_default();
                if !fill(&mut pb.batch) {
                    break;
                }
                // lint:allow(D2) plan-stall instrumentation times the real planning call
                let t0 = Instant::now();
                planner.plan_into(&pb.batch, &mut pb.plan);
                let dt = t0.elapsed().as_secs_f64();
                stall_max = stall_max.max(dt);
                total += dt;
                if tx.send(pb).is_err() {
                    break;
                }
            }
            // tx drops here; rx.iter() below then terminates
            (stall_max, total)
        });
        for pb in rx.iter() {
            consume(&pb.batch, &pb.plan);
            n += 1;
            let _ = recycle_tx.send(pb);
        }
        ingest.join().expect("ingest worker panicked")
    });
    IngestReport {
        batches: n,
        overlapped: true,
        plan_stall_max_s: stall_max,
        plan_time_total_s: total,
    }
}

/// A `fill` source that replays a pre-built batch slice via `clone_from`
/// (recycled shells keep their allocations) — the benches' standard way
/// to drive [`run_prefetched_fill`] over a fixed workload repeatedly.
pub fn replay_fill(batches: &[Batch]) -> impl FnMut(&mut Batch) -> bool + Send + '_ {
    let mut cursor = 0usize;
    move |out| {
        if cursor >= batches.len() {
            return false;
        }
        out.clone_from(&batches[cursor]);
        cursor += 1;
        true
    }
}

/// Iterator-source convenience wrapper around [`run_prefetched_fill`].
pub fn run_prefetched<I, C>(
    mut source: I,
    planner: &mut AccessPlanner,
    plan_ahead: usize,
    consume: C,
) -> IngestReport
where
    I: Iterator<Item = Batch> + Send,
    C: FnMut(&Batch, &BatchPlan),
{
    run_prefetched_fill(
        move |out| match source.next() {
            Some(b) => {
                *out = b;
                true
            }
            None => false,
        },
        planner,
        plan_ahead,
        consume,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::data::ctr::CtrGenerator;
    use crate::data::schema::DatasetSchema;

    fn tiny_cfg_and_batches() -> (EngineCfg, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(2000, true), (40, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let schema = DatasetSchema {
            name: "ingest-test",
            n_dense: 2,
            vocabs: vec![2000, 40],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 11);
        let batches = gen.batches(12, 32);
        (cfg, batches)
    }

    #[test]
    fn overlapped_stream_matches_inline_order_and_content() {
        let (cfg, batches) = tiny_cfg_and_batches();
        let collect = |plan_ahead: usize| -> (Vec<Vec<u64>>, Vec<usize>, u64) {
            let mut planner = AccessPlanner::for_engine_cfg(&cfg);
            let mut cols = Vec::new();
            let mut prefixes = Vec::new();
            let report = run_prefetched(
                batches.iter().cloned(),
                &mut planner,
                plan_ahead,
                |b, p| {
                    assert_eq!(p.batch_size(), b.batch_size);
                    cols.push(p.col(0).to_vec());
                    prefixes.push(p.tt_plan(0).unwrap().distinct_prefixes());
                },
            );
            (cols, prefixes, report.batches)
        };
        let (c0, p0, n0) = collect(0);
        for ahead in [1usize, 3] {
            let (c, p, n) = collect(ahead);
            assert_eq!(n, n0);
            assert_eq!(c, c0, "plan_ahead={ahead} changed column content/order");
            assert_eq!(p, p0, "plan_ahead={ahead} changed plans");
        }
        assert_eq!(n0 as usize, batches.len());
    }

    #[test]
    fn empty_source_is_fine() {
        let (cfg, _) = tiny_cfg_and_batches();
        let mut planner = AccessPlanner::for_engine_cfg(&cfg);
        let report =
            run_prefetched(std::iter::empty(), &mut planner, 2, |_, _| panic!("no batches"));
        assert_eq!(report.batches, 0);
        assert!(report.overlapped);
    }
}
