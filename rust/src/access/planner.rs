//! The access planner: per-table bijections (offline-profiled and/or
//! online-refreshed) + plan construction for whole batches.  This is the
//! single owner of "index preprocessing" — the Rec-AD baseline arm, the
//! trainer, the pipeline and the server all configure one of these
//! instead of hand-rolling remap/dedup on their hot paths.

use std::collections::BTreeMap;

use crate::access::plan::{BatchPlan, TtPlan};
use crate::coordinator::engine::EngineCfg;
use crate::data::ctr::Batch;
use crate::reorder::bijection::IndexBijection;
use crate::reorder::online::{BackgroundReorderer, OnlineReorderer, DEFAULT_ADOPT_LAG};
use crate::runtime::autotune::{AutotuneCfg, CacheBudgetTuner, CacheFeedback, ReorderCadenceTuner};
use crate::tt::shapes::TtShapes;
use crate::util::clock::Clock;
use crate::util::json::Json;

/// `[access]` section of the run config.
#[derive(Clone, Copy, Debug)]
pub struct AccessCfg {
    /// Ingest lookahead: how many batches may be assembled + planned
    /// ahead of training on the ingest worker.  0 = plan inline on the
    /// training thread (no overlap thread).
    pub plan_ahead: usize,
    /// Refresh the bijection online every `refresh_every` batches.
    pub online_reorder: bool,
    /// Batches between online bijection rebuilds (K).
    pub refresh_every: usize,
    /// Hot-set access-mass ratio for (re)built bijections.
    pub hot_ratio: f64,
    /// Co-occurrence window kept for online rebuilds, in batches.
    pub window: usize,
    /// L2 budget (KiB) for hottest-first tiled plan layouts; 0 disables
    /// tiling.  Bit-identity-preserving — tiles only reorder independent
    /// row materializations and chain computations.
    pub cache_kb: usize,
    /// Plan same-vocabulary TT slots through one fused prefix-sorted
    /// sweep (per-slot plans stay bitwise identical).
    pub fuse_tables: bool,
    /// Run online bijection rebuilds on a background worker with an
    /// epoch-tagged swap (adopted at a fixed one-batch lag) instead of
    /// inline on the ingest thread.
    pub background_reorder: bool,
}

impl Default for AccessCfg {
    fn default() -> Self {
        AccessCfg {
            plan_ahead: 1,
            online_reorder: false,
            refresh_every: 64,
            hot_ratio: 0.05,
            window: 32,
            cache_kb: 256,
            fuse_tables: false,
            background_reorder: false,
        }
    }
}

/// Per-slot online refresh engine (see `reorder::online` module docs).
#[derive(Clone)]
enum OnlineSlot {
    /// PR-2 inline engine: rebuild on the ingest thread at the trigger.
    Inline(OnlineReorderer),
    /// Scheduled engine: background worker (or its synchronous-compute
    /// twin) with a fixed adoption lag and stall accounting.
    Scheduled(BackgroundReorderer),
}

impl OnlineSlot {
    /// Feed one raw column; `Some(bijection)` when this call refreshed.
    fn observe(&mut self, col: &[u64]) -> Option<&IndexBijection> {
        match self {
            OnlineSlot::Inline(o) => o.observe(col).then(|| &o.bijection),
            OnlineSlot::Scheduled(b) => b.observe(col).then(|| &b.bijection),
        }
    }

    fn refresh_every(&self) -> usize {
        match self {
            OnlineSlot::Inline(o) => o.refresh_every(),
            OnlineSlot::Scheduled(b) => b.refresh_every(),
        }
    }

    fn set_refresh_every(&mut self, every: usize) {
        match self {
            OnlineSlot::Inline(o) => o.set_refresh_every(every),
            OnlineSlot::Scheduled(b) => b.set_refresh_every(every),
        }
    }
}

/// Plans batches for one engine configuration.
#[derive(Clone)]
pub struct AccessPlanner {
    /// Per-slot TT shapes (`None` = plain table).
    shapes: Vec<Option<TtShapes>>,
    /// Per-slot remap (`None` = identity).
    bijections: Vec<Option<IndexBijection>>,
    /// Per-slot online refresh state (TT slots only, when enabled).
    online: Vec<Option<OnlineSlot>>,
    /// Scratch for online observation of raw columns.
    obs: Vec<u64>,
    /// L2 tile budget (KiB) stamped onto every plan built (0 = untiled).
    cache_kb: usize,
    /// Fused cross-table sweep policy stamped onto every plan built.
    fuse_tables: bool,
    /// Cache-budget autotune loop (`None` = static `cache_kb`).
    cache_tuner: Option<CacheBudgetTuner>,
    /// Per-slot reorder-cadence autotune loops (online slots only).
    cadence: Vec<Option<ReorderCadenceTuner>>,
    /// Scratch: per-slot "adopted a refreshed bijection this batch".
    adopted: Vec<bool>,
    /// Batches planned so far.
    pub batches_planned: u64,
    /// Online bijection refreshes across all slots.
    pub refreshes: u64,
}

impl std::fmt::Debug for AccessPlanner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AccessPlanner")
            .field("slots", &self.shapes.len())
            .field("remapped", &self.bijections.iter().filter(|b| b.is_some()).count())
            .field("online", &self.online.iter().filter(|o| o.is_some()).count())
            .field("cache_kb", &self.cache_kb)
            .field("fuse_tables", &self.fuse_tables)
            .field("cache_tuner", &self.cache_tuner)
            .field("cadence", &self.cadence.iter().filter(|c| c.is_some()).count())
            .field("batches_planned", &self.batches_planned)
            .field("refreshes", &self.refreshes)
            .finish()
    }
}

/// TT shapes per engine table slot, straight from the config (must match
/// `NativeDlrm::new`, which calls the same `TtShapes::plan`).  Slots whose
/// configuration never consults a plan (TT-Rec baseline: reuse AND
/// gradient aggregation both off) come back `None`, so the baseline arms
/// don't pay for sorts they would ignore — the engine falls back to the
/// per-occurrence path for plan-less TT slots.
pub fn table_shapes(cfg: &EngineCfg) -> Vec<Option<TtShapes>> {
    let plan_useful = cfg.tt_opts.reuse || cfg.tt_opts.grad_aggregation;
    cfg.tables
        .iter()
        .map(|&(rows, compressed)| {
            (compressed && plan_useful)
                .then(|| TtShapes::plan(rows, cfg.emb_dim, cfg.tt_rank))
        })
        .collect()
}

impl AccessPlanner {
    /// Identity planner (no reordering) for an engine config.  Plans are
    /// tiled at the default cache budget (bit-identity-preserving).
    pub fn for_engine_cfg(cfg: &EngineCfg) -> AccessPlanner {
        let shapes = table_shapes(cfg);
        let n = shapes.len();
        AccessPlanner {
            shapes,
            bijections: (0..n).map(|_| None).collect(),
            online: (0..n).map(|_| None).collect(),
            obs: Vec::new(),
            cache_kb: AccessCfg::default().cache_kb,
            fuse_tables: false,
            cache_tuner: None,
            cadence: (0..n).map(|_| None).collect(),
            adopted: vec![false; n],
            batches_planned: 0,
            refreshes: 0,
        }
    }

    /// Override the plan-layout policy (tile budget + fused sweeps).
    pub fn set_layout_policy(&mut self, cache_kb: usize, fuse_tables: bool) {
        self.cache_kb = cache_kb;
        self.fuse_tables = fuse_tables;
    }

    /// Offline profiling construction (paper §III-H): build a bijection
    /// per compressed slot from a sample of training batches.  This is
    /// what the Rec-AD baseline arm used to own privately.
    pub fn with_profile(
        cfg: &EngineCfg,
        profile: &[Batch],
        hot_ratio: f64,
    ) -> AccessPlanner {
        let mut p = Self::for_engine_cfg(cfg);
        let ns = cfg.tables.len();
        for (slot, &(rows, compressed)) in cfg.tables.iter().enumerate() {
            if !compressed {
                continue; // reordering pays off on the TT tables
            }
            let cols: Vec<Vec<u64>> = profile
                .iter()
                .map(|b| b.sparse_col(slot, ns).collect())
                .collect();
            let refs: Vec<&[u64]> = cols.iter().map(|c| c.as_slice()).collect();
            p.bijections[slot] = Some(IndexBijection::build(rows, &refs, hot_ratio));
        }
        p
    }

    /// Enable online bijection refresh on every compressed slot: the
    /// inline (PR-2) engine by default, the background engine when
    /// `access.background_reorder` is set.
    pub fn enable_online(&mut self, cfg: &EngineCfg, access: &AccessCfg) {
        if access.background_reorder {
            self.enable_scheduled_online(cfg, access, true);
            return;
        }
        for (slot, &(rows, compressed)) in cfg.tables.iter().enumerate() {
            if compressed {
                self.online[slot] = Some(OnlineSlot::Inline(OnlineReorderer::new(
                    rows,
                    access.hot_ratio,
                    access.refresh_every.max(1),
                    access.window,
                )));
            }
        }
    }

    /// Enable the SCHEDULED refresh engine on every compressed slot:
    /// `background = true` rebuilds on a worker thread, `false` is its
    /// synchronous-compute twin (identical trigger/adoption schedule ⇒
    /// bit-identical outputs; it exists as the stall baseline).
    pub fn enable_scheduled_online(
        &mut self,
        cfg: &EngineCfg,
        access: &AccessCfg,
        background: bool,
    ) {
        for (slot, &(rows, compressed)) in cfg.tables.iter().enumerate() {
            if compressed {
                self.online[slot] = Some(OnlineSlot::Scheduled(BackgroundReorderer::new(
                    rows,
                    access.hot_ratio,
                    access.refresh_every.max(1),
                    access.window,
                    DEFAULT_ADOPT_LAG,
                    background,
                )));
            }
        }
    }

    /// Apply [`AccessCfg`] policy: plan-layout knobs always, online
    /// refresh when requested (`background_reorder` alone implies it —
    /// a background engine with nothing to refresh would be inert).
    pub fn configure(&mut self, cfg: &EngineCfg, access: &AccessCfg) {
        self.set_layout_policy(access.cache_kb, access.fuse_tables);
        if access.online_reorder || access.background_reorder {
            self.enable_online(cfg, access);
        }
    }

    /// Install the autotune feedback loops this planner participates in
    /// (call AFTER `configure`/`enable_online`, so cadence tuners attach
    /// to the online slots that exist):
    ///
    /// * cache-budget: the planner asks the tuner for each batch's
    ///   `cache_kb` and reports the built plan's distinct-row count; the
    ///   training loop must push measured step seconds through
    ///   [`Self::cache_feedback`] to close the loop.
    /// * reorder cadence: each online slot gets a peak-decay controller
    ///   fed from its plan's `reuse_rate()`; interval changes are applied
    ///   to the slot's refresh engine.
    ///
    /// No-op for loops the config disables — a planner without tuners
    /// plans bit-identically to one that never saw this call.  Cloned
    /// planners share the cache-feedback bus, so install the cache loop
    /// only on the planner whose steps are actually timed.
    pub fn enable_autotune(&mut self, autotune: &AutotuneCfg) {
        if autotune.cache_on() {
            self.cache_tuner = Some(CacheBudgetTuner::new(autotune, Clock::real()));
        }
        if autotune.reorder_on() {
            for (t, slot) in self.online.iter().enumerate() {
                if let Some(s) = slot {
                    self.cadence[t] =
                        Some(ReorderCadenceTuner::new(s.refresh_every(), autotune));
                }
            }
        }
    }

    /// Step-time feedback producer for the cache-budget loop (`None`
    /// when that loop is off).
    pub fn cache_feedback(&self) -> Option<CacheFeedback> {
        self.cache_tuner.as_ref().map(|t| t.feedback())
    }

    /// The cache-budget tuner's state (telemetry/tests).
    pub fn cache_tuner(&self) -> Option<&CacheBudgetTuner> {
        self.cache_tuner.as_ref()
    }

    /// Slot `t`'s cadence tuner (telemetry/tests).
    pub fn cadence_tuner(&self, t: usize) -> Option<&ReorderCadenceTuner> {
        self.cadence[t].as_ref()
    }

    /// Slot `t`'s current online refresh interval (`None` = not online).
    pub fn online_refresh_every(&self, t: usize) -> Option<usize> {
        self.online[t].as_ref().map(|s| s.refresh_every())
    }

    /// Number of sparse table slots this planner plans for.
    pub fn num_tables(&self) -> usize {
        self.online.len()
    }

    /// Stable signature of the planner's table shapes — the cache
    /// tuner's re-probe trigger (a different model ⇒ stale cost curves).
    fn shape_sig(&self) -> u64 {
        use crate::util::hash::{fnv1a_step, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        for sh in self.shapes.iter().flatten() {
            h = fnv1a_step(h, sh.rows);
            for &m in &sh.m {
                h = fnv1a_step(h, m);
            }
        }
        h
    }

    /// Per-refresh ingest-thread stall samples (seconds) accumulated by
    /// the scheduled online engines across all slots (empty for the
    /// inline engine, which has no stall accounting).
    pub fn reorder_stall_samples(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for slot in self.online.iter().flatten() {
            if let OnlineSlot::Scheduled(b) = slot {
                out.extend_from_slice(&b.stall_samples);
            }
        }
        out
    }

    /// The bijection currently applied to slot `t` (`None` = identity).
    pub fn bijection(&self, t: usize) -> Option<&IndexBijection> {
        self.bijections[t].as_ref()
    }

    /// Snapshot this planner's routing view: per-slot shapes + CURRENT
    /// bijections, enough to compute a request's plan-affinity key
    /// without building a plan.  Hand it to
    /// [`PlanAffinity`](crate::serve::PlanAffinity) so serving routes
    /// requests to the replica whose plan scratch already holds their
    /// prefix groups.
    pub fn affinity_map(&self) -> AffinityMap {
        AffinityMap {
            shapes: self.shapes.clone(),
            bijections: self.bijections.clone(),
        }
    }

    /// Snapshot this planner's routing view as a training-side
    /// [`PlacementMap`]: the affinity keys that route serving requests to
    /// warm replicas, reduced modulo `workers`, assign each TT prefix
    /// group (and therefore each tile row-set a plan cuts from it) to the
    /// data-parallel worker that owns those rows.
    pub fn placement_map(&self, workers: usize) -> PlacementMap {
        PlacementMap::new(self.affinity_map(), workers)
    }

    /// Plan one batch into reusable scratch: observe raw columns (online
    /// mode), maybe refresh bijections, then remap + dedup + group into
    /// `out`.
    pub fn plan_into(&mut self, batch: &Batch, out: &mut BatchPlan) {
        let ns = self.shapes.len();
        for t in 0..ns {
            self.adopted[t] = false;
            let Some(online) = self.online[t].as_mut() else { continue };
            self.obs.clear();
            self.obs.extend(batch.sparse_col(t, ns));
            if let Some(bij) = online.observe(&self.obs) {
                self.bijections[t] = Some(bij.clone());
                self.refreshes += 1;
                self.adopted[t] = true;
            }
        }
        if let Some(tuner) = self.cache_tuner.as_mut() {
            self.cache_kb = tuner.budget_now();
        }
        out.set_policy(self.cache_kb, self.fuse_tables);
        out.build_into(batch, &self.shapes, &self.bijections);
        self.batches_planned += 1;
        if self.cache_tuner.is_some() || self.cadence.iter().any(|c| c.is_some()) {
            self.autotune_post_build(out);
        }
    }

    /// Close the autotune loops on a just-built plan: complete the cache
    /// tuner's issued probe with the plan's distinct-row count, and feed
    /// each cadence tuner its slot's reuse rate (applying any interval
    /// change to the slot's refresh engine).
    fn autotune_post_build(&mut self, out: &BatchPlan) {
        let sig = self.shape_sig();
        if let Some(tuner) = self.cache_tuner.as_mut() {
            let mut rows = 0usize;
            for t in 0..self.shapes.len() {
                if let Some(tp) = out.tt_plan(t) {
                    rows += tp.distinct_rows();
                }
            }
            tuner.note_rows(sig, rows);
        }
        for t in 0..self.cadence.len() {
            let Some(c) = self.cadence[t].as_mut() else { continue };
            let Some(tp) = out.tt_plan(t) else { continue };
            if let Some(new_every) = c.observe(tp.reuse_rate(), self.adopted[t]) {
                if let Some(slot) = self.online[t].as_mut() {
                    slot.set_refresh_every(new_every);
                }
            }
        }
    }

    /// Plan with the CURRENT bijections, without observing or refreshing
    /// — the evaluation/serving path: a model trained under a (possibly
    /// online-refreshed) remap must be read back through the same remap,
    /// and read-only traffic must not advance the online state.
    pub fn plan_frozen_into(&self, batch: &Batch, out: &mut BatchPlan) {
        out.set_policy(self.cache_kb, self.fuse_tables);
        out.build_into(batch, &self.shapes, &self.bijections);
    }
}

/// Frozen routing view of a planner: per-slot TT shapes and bijections.
/// [`AffinityMap::key`] reduces one request's sparse indices to the mixed
/// hash of its post-bijection TT prefixes — the exact quantity
/// `TtPlan::finish_forward` groups distinct rows by — so equal keys mean
/// the requests' plans share prefix groups (warm reuse-buffer partial
/// products and warm `TtPlan::tile_slots` row sets on whichever serving
/// replica saw them last).
#[derive(Clone)]
pub struct AffinityMap {
    shapes: Vec<Option<TtShapes>>,
    bijections: Vec<Option<IndexBijection>>,
}

impl AffinityMap {
    /// FNV-1a mix of every compressed slot's post-bijection TT prefix.
    /// Falls back to hashing the raw indices when no slot is compressed,
    /// so routing still spreads load on plain-table configurations.
    pub fn key(&self, sparse: &[u64]) -> u64 {
        use crate::util::hash::{fnv1a_step, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        let mut any = false;
        for (t, sh) in self.shapes.iter().enumerate() {
            let Some(sh) = sh else { continue };
            let Some(&raw) = sparse.get(t) else { continue };
            let row = match self.bijections[t].as_ref() {
                Some(b) => b.apply(raw),
                None => raw,
            };
            h = fnv1a_step(h, sh.prefix_of(row));
            any = true;
        }
        if !any {
            for &v in sparse {
                h = fnv1a_step(h, v);
            }
        }
        h
    }

    /// Serialize the routing view so a router can ship it to a joining
    /// node (`net::Frame::Join`).  Bijections travel as their curated
    /// `(old, new)` entries in canonical order; the dense materialization
    /// is re-derived on parse, so `key()` is bit-identical after a
    /// round-trip.
    pub fn to_json(&self) -> Json {
        let slots: Vec<Json> = self
            .shapes
            .iter()
            .zip(self.bijections.iter())
            .map(|(sh, bij)| {
                let mut m = BTreeMap::new();
                let shapes = match sh {
                    None => Json::Null,
                    Some(s) => {
                        let mut sm = BTreeMap::new();
                        sm.insert("rows".into(), Json::Num(s.rows as f64));
                        sm.insert("dim".into(), Json::Num(s.dim as f64));
                        sm.insert("rank".into(), Json::Num(s.rank as f64));
                        sm.insert(
                            "m".into(),
                            Json::Arr(s.m.iter().map(|&v| Json::Num(v as f64)).collect()),
                        );
                        sm.insert(
                            "n".into(),
                            Json::Arr(s.n.iter().map(|&v| Json::Num(v as f64)).collect()),
                        );
                        Json::Obj(sm)
                    }
                };
                let bijection = match bij {
                    None => Json::Null,
                    Some(b) => {
                        let mut bm = BTreeMap::new();
                        bm.insert("rows".into(), Json::Num(b.rows as f64));
                        bm.insert("n_hot".into(), Json::Num(b.n_hot as f64));
                        bm.insert("n_communities".into(), Json::Num(b.n_communities as f64));
                        bm.insert("modularity".into(), Json::Num(b.modularity));
                        bm.insert(
                            "entries".into(),
                            Json::Arr(
                                b.entries()
                                    .iter()
                                    .map(|&(o, n)| {
                                        Json::Arr(vec![
                                            Json::Num(o as f64),
                                            Json::Num(n as f64),
                                        ])
                                    })
                                    .collect(),
                            ),
                        );
                        Json::Obj(bm)
                    }
                };
                m.insert("shapes".into(), shapes);
                m.insert("bijection".into(), bijection);
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("slots".into(), Json::Arr(slots));
        Json::Obj(root)
    }

    /// Parse a snapshot serialized by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> anyhow::Result<AffinityMap> {
        use anyhow::Context;
        let slots = j.get("slots").and_then(Json::as_arr).context("missing slots")?;
        let mut shapes = Vec::with_capacity(slots.len());
        let mut bijections = Vec::with_capacity(slots.len());
        for (t, slot) in slots.iter().enumerate() {
            let sh = match slot.get("shapes") {
                None | Some(Json::Null) => None,
                Some(s) => {
                    let u = |k: &str| {
                        s.get(k).and_then(Json::as_u64).context(format!("slot {t}: missing shapes.{k}"))
                    };
                    let arr_u = |k: &str| -> anyhow::Result<Vec<u64>> {
                        s.get(k)
                            .and_then(Json::as_arr)
                            .context(format!("slot {t}: missing shapes.{k}"))?
                            .iter()
                            .map(|v| v.as_u64().context(format!("slot {t}: bad shapes.{k}")))
                            .collect()
                    };
                    let m = arr_u("m")?;
                    let n = arr_u("n")?;
                    anyhow::ensure!(m.len() == 3 && n.len() == 3, "slot {t}: shapes arity");
                    Some(TtShapes {
                        rows: u("rows")?,
                        dim: u("dim")? as usize,
                        m: [m[0], m[1], m[2]],
                        n: [n[0] as usize, n[1] as usize, n[2] as usize],
                        rank: u("rank")? as usize,
                    })
                }
            };
            let bij = match slot.get("bijection") {
                None | Some(Json::Null) => None,
                Some(b) => {
                    let u = |k: &str| {
                        b.get(k)
                            .and_then(Json::as_u64)
                            .context(format!("slot {t}: missing bijection.{k}"))
                    };
                    let entries = b
                        .get("entries")
                        .and_then(Json::as_arr)
                        .context(format!("slot {t}: missing bijection.entries"))?
                        .iter()
                        .map(|e| {
                            let o = e.idx(0).and_then(Json::as_u64);
                            let n = e.idx(1).and_then(Json::as_u64);
                            match (o, n) {
                                (Some(o), Some(n)) => Ok((o, n)),
                                _ => anyhow::bail!("slot {t}: bad bijection entry"),
                            }
                        })
                        .collect::<anyhow::Result<Vec<(u64, u64)>>>()?;
                    Some(IndexBijection::from_entries(
                        u("rows")?,
                        u("n_hot")? as usize,
                        u("n_communities")? as usize,
                        b.get("modularity")
                            .and_then(Json::as_f64)
                            .context(format!("slot {t}: missing bijection.modularity"))?,
                        &entries,
                    ))
                }
            };
            shapes.push(sh);
            bijections.push(bij);
        }
        Ok(AffinityMap { shapes, bijections })
    }
}

/// Assigns TT prefix groups — and whole samples — to data-parallel
/// training workers, reusing the serving-side FNV prefix key
/// ([`AffinityMap::key`]).  Samples whose compressed slots share ALL
/// their post-bijection TT prefixes hash to the same worker.  With one
/// compressed slot that makes every prefix group's owner exclusive, so
/// the sparse TT-core all-reduce ships each owned core slice from one
/// worker (only core coordinates shared between distinct prefixes
/// repeat); with several compressed slots the mixed key can split one
/// table's prefix group across workers when the other tables' prefixes
/// differ — duplication is reduced, not eliminated.
#[derive(Clone)]
pub struct PlacementMap {
    map: AffinityMap,
    workers: usize,
}

impl PlacementMap {
    pub fn new(map: AffinityMap, workers: usize) -> PlacementMap {
        assert!(workers >= 1, "placement needs at least one worker");
        PlacementMap { map, workers }
    }

    #[inline]
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Owning worker of one sample: its mixed affinity key (every
    /// compressed slot's post-bijection TT prefix) modulo the worker
    /// count.  Samples sharing all their TT prefixes always co-locate.
    #[inline]
    pub fn owner_of(&self, sparse: &[u64]) -> usize {
        (self.map.key(sparse) % self.workers as u64) as usize
    }

    /// Owning worker of a single RAW row of slot `t` under the per-slot
    /// prefix key (`None` for plain slots).  For configurations with
    /// exactly one compressed slot this agrees with [`Self::owner_of`];
    /// with several, [`Self::owner_of`] mixes all slots' prefixes while
    /// this view answers "which worker owns this table row".
    pub fn row_owner(&self, t: usize, raw_row: u64) -> Option<usize> {
        use crate::util::hash::{fnv1a_step, FNV_OFFSET};
        let sh = self.map.shapes.get(t)?.as_ref()?;
        let row = match self.map.bijections.get(t).and_then(|b| b.as_ref()) {
            Some(b) => b.apply(raw_row),
            None => raw_row,
        };
        Some((fnv1a_step(FNV_OFFSET, sh.prefix_of(row)) % self.workers as u64) as usize)
    }

    /// Primary owner of one tile row-set of a built plan
    /// ([`TtPlan::tile_rows`]): the owner of the tile's first (hottest)
    /// scheduled row's prefix group.  Plan rows are already
    /// post-bijection, so the prefix is hashed directly.  `None` when the
    /// plan is untiled or the tile is out of range.
    pub fn tile_owner(&self, plan: &TtPlan, tile: usize) -> Option<usize> {
        use crate::util::hash::{fnv1a_step, FNV_OFFSET};
        let sh = plan.shapes()?;
        let row = plan.tile_rows(tile).next()?;
        Some((fnv1a_step(FNV_OFFSET, sh.prefix_of(row)) % self.workers as u64) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ctr::CtrGenerator;
    use crate::data::schema::DatasetSchema;
    use crate::tt::table::EffTtOptions;

    fn cfg() -> EngineCfg {
        EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(4000, true), (40, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: EffTtOptions::default(),
            exec: crate::exec::ExecCfg::default(),
        }
    }

    fn gen() -> CtrGenerator {
        CtrGenerator::new(
            DatasetSchema {
                name: "planner-test",
                n_dense: 2,
                vocabs: vec![4000, 40],
                emb_dim: 8,
                zipf_s: 1.2,
                ft_rank: 8,
            },
            5,
        )
    }

    #[test]
    fn identity_planner_plans_tt_slots_only() {
        let cfg = cfg();
        let mut g = gen();
        let batch = g.next_batch(16);
        let mut p = AccessPlanner::for_engine_cfg(&cfg);
        let mut plan = BatchPlan::default();
        p.plan_into(&batch, &mut plan);
        assert_eq!(plan.n_tables(), 2);
        assert!(plan.tt_plan(0).is_some());
        assert!(plan.tt_plan(1).is_none());
        // identity: columns equal the raw batch slices
        let raw: Vec<u64> = batch.sparse_col(0, 2).collect();
        assert_eq!(plan.col(0), &raw[..]);
        assert_eq!(plan.offsets().len(), 17);
    }

    #[test]
    fn profiled_planner_remaps_compressed_slot_in_vocab() {
        let cfg = cfg();
        let mut g = gen();
        let profile = g.batches(15, 32);
        let mut p = AccessPlanner::with_profile(&cfg, &profile, 0.05);
        assert!(p.bijection(0).is_some());
        assert!(p.bijection(1).is_none());
        let batch = g.next_batch(16);
        let mut plan = BatchPlan::default();
        p.plan_into(&batch, &mut plan);
        let raw0: Vec<u64> = batch.sparse_col(0, 2).collect();
        let raw1: Vec<u64> = batch.sparse_col(1, 2).collect();
        for (&mapped, &old) in plan.col(0).iter().zip(&raw0) {
            assert!(mapped < 4000);
            assert_eq!(mapped, p.bijection(0).unwrap().apply(old));
        }
        assert_eq!(plan.col(1), &raw1[..], "plain slot must stay untouched");
    }

    #[test]
    fn background_reorder_alone_enables_refresh() {
        // `[access] background_reorder = true` without `online_reorder`
        // must still enable the (background) refresh engine
        let cfg = cfg();
        let mut p = AccessPlanner::for_engine_cfg(&cfg);
        let access = AccessCfg {
            background_reorder: true,
            refresh_every: 2,
            window: 4,
            ..Default::default()
        };
        p.configure(&cfg, &access);
        let mut g = gen();
        let mut plan = BatchPlan::default();
        for _ in 0..6 {
            let b = g.next_batch(64);
            p.plan_into(&b, &mut plan);
        }
        assert!(p.refreshes >= 1, "background_reorder alone was inert");
        assert!(
            !p.reorder_stall_samples().is_empty(),
            "scheduled engine must record stall samples"
        );
    }

    #[test]
    fn affinity_key_follows_prefix_groups() {
        let cfg = cfg(); // tables: (4000, compressed), (40, plain)
        let p = AccessPlanner::for_engine_cfg(&cfg);
        let map = p.affinity_map();
        let shapes = table_shapes(&cfg)[0].unwrap();
        let m3 = shapes.m[2];
        assert!(m3 >= 2, "test premise: >1 row per prefix");
        // same TT prefix on the compressed slot => same key, regardless of
        // the plain slot (which never enters a TtPlan)
        let a = map.key(&[5 * m3, 7]);
        let b = map.key(&[5 * m3 + 1, 23]);
        assert_eq!(a, b, "same-prefix requests must share an affinity key");
        // a different prefix changes the key
        let c = map.key(&[9 * m3, 7]);
        assert_ne!(a, c);
    }

    #[test]
    fn placement_keeps_prefix_groups_on_one_worker() {
        let cfg = cfg(); // tables: (4000, compressed), (40, plain)
        let p = AccessPlanner::for_engine_cfg(&cfg);
        let pm = p.placement_map(4);
        assert_eq!(pm.workers(), 4);
        let shapes = table_shapes(&cfg)[0].unwrap();
        let m3 = shapes.m[2];
        assert!(m3 >= 2, "test premise: >1 row per prefix");
        // rows sharing a TT prefix share a row owner…
        assert_eq!(pm.row_owner(0, 5 * m3), pm.row_owner(0, 5 * m3 + 1));
        // …and the plain slot has no owner
        assert_eq!(pm.row_owner(1, 7), None);
        // one compressed slot => sample owner == that slot's row owner
        for row in [0u64, 3 * m3, 5 * m3 + 1, 9 * m3] {
            assert_eq!(Some(pm.owner_of(&[row, 23])), pm.row_owner(0, row));
        }
        // owners stay in range and more than one worker gets work
        let owners: std::collections::HashSet<usize> =
            (0..64u64).map(|g| pm.owner_of(&[g * m3, 0])).collect();
        // lint:allow(D1) range bound is a ∀-check over all members — order-free
        assert!(owners.iter().all(|&w| w < 4));
        assert!(owners.len() > 1, "64 prefix groups all hashed to one worker");
    }

    #[test]
    fn placement_assigns_plan_tiles() {
        let cfg = cfg();
        let mut p = AccessPlanner::for_engine_cfg(&cfg);
        p.set_layout_policy(1, false); // 1 KiB budget => several tiles
        let mut g = gen();
        let batch = g.next_batch(256);
        let mut plan = BatchPlan::default();
        p.plan_into(&batch, &mut plan);
        let tp = plan.tt_plan(0).unwrap();
        assert!(tp.num_tiles() > 1, "tiny budget must cut tiles");
        let pm = p.placement_map(3);
        for t in 0..tp.num_tiles() {
            let owner = pm.tile_owner(tp, t).expect("tiled plan has owners");
            assert!(owner < 3);
            // the tile's primary owner is its first row's prefix owner —
            // and plan rows are post-bijection (identity here), so the
            // row-owner view must agree
            let first = tp.tile_rows(t).next().unwrap();
            assert_eq!(Some(owner), pm.row_owner(0, first));
        }
        assert_eq!(pm.tile_owner(tp, tp.num_tiles()), None);
    }

    #[test]
    fn online_refresh_updates_bijection() {
        let cfg = cfg();
        let mut g = gen();
        let mut p = AccessPlanner::for_engine_cfg(&cfg);
        let access = AccessCfg { refresh_every: 4, window: 8, ..Default::default() };
        p.enable_online(&cfg, &access);
        let mut plan = BatchPlan::default();
        for _ in 0..8 {
            let b = g.next_batch(64);
            p.plan_into(&b, &mut plan);
        }
        assert_eq!(p.refreshes, 2);
        assert!(p.bijection(0).is_some());
        assert!(p.bijection(1).is_none(), "plain slots never reorder");
    }
}
