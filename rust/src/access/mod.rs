//! `access` — the unified access-planning layer (paper §III-G/H lifted to
//! a pipeline stage).
//!
//! The Rec-AD paper's second pillar, "optimized data access via index
//! reordering", used to live as an offline bijection wired into one
//! baseline arm, while the engine, pipeline trainer, TT table and
//! streaming server each re-derived per-batch index work (dedup,
//! prefix-group sort, scatter map, remap) on the compute hot path.  This
//! module makes that work a first-class, reusable artifact:
//!
//! * [`TtPlan`] / [`BatchPlan`] (`plan`) — the per-batch, per-table index
//!   plan: distinct-row set, prefix-group layout, scatter map, backward
//!   aggregation order, remapped columns, cached unit-bag offsets.  Built
//!   once per batch; consumed by `EffTtTable::{embedding_bag,
//!   backward_sgd}_planned` and `NativeDlrm::{forward, train_step,
//!   predict}_planned`.  `TtPlan::build_layout` additionally attaches a
//!   **cache-resident execution schedule**: prefix groups ordered
//!   hottest-first and cut into L2-sized tiles (`[access] cache_kb`)
//!   that the TT walks shard and iterate.
//! * [`fused::FusedSweep`] (`fused`) — **cross-table fused planning**:
//!   TT slots sharing a vocabulary are planned through ONE concatenated
//!   `(row, slot, pos)` sort (`[access] fuse_tables`); per-slot plans
//!   are bitwise identical to private builds.
//! * [`AccessPlanner`] (`planner`) — owns the per-table bijections
//!   (offline-profiled and/or online-refreshed via
//!   `reorder::OnlineReorderer`, or non-blockingly via
//!   `reorder::BackgroundReorderer` with `[access] background_reorder`)
//!   and turns raw batches into plans.
//! * [`run_prefetched`] / [`run_prefetched_fill`] (`ingest`) — the
//!   double-buffered ingest stage: batch N+1 is assembled + remapped +
//!   planned on a worker thread while batch N trains; per-batch planning
//!   stall is reported (`IngestReport::plan_stall_max_s`).
//!
//! Invariant: the planned path is **bit-identical** to the pre-refactor
//! unplanned path (the unplanned APIs are now thin wrappers that build a
//! plan inline), for any worker count, any `plan_ahead` depth, tiled or
//! untiled, fused or per-slot, background or synchronous refresh —
//! pinned by `tests/plan_equivalence.rs`.

pub mod fused;
pub mod ingest;
pub mod plan;
pub mod planner;

pub use fused::FusedStats;
pub use ingest::{replay_fill, run_prefetched, run_prefetched_fill, IngestReport, PlannedBatch};
pub use plan::{BagLayout, BatchPlan, TtPlan, UnitOffsets};
pub use planner::{table_shapes, AccessCfg, AccessPlanner, AffinityMap, PlacementMap};
