//! Cross-table fused plan sweeps.
//!
//! DLRM feature columns frequently share an id space (user/item ids
//! appearing in several sparse features), so their TT slots share
//! `TtShapes` — yet PR 2 planned every slot in isolation: one sort, one
//! dedup sweep and one set of scratch buffers per slot per batch.  The
//! fused sweep concatenates all same-shapes columns into a single
//! `(row, slot, pos)` stream, sorts it ONCE, and peels the per-slot plans
//! off the shared sorted order.  Each slot's subsequence is ordered by
//! `(row, pos)` — exactly what its private sort would have produced — so
//! the per-slot plans are **bitwise identical** to independently built
//! ones (pinned by `tests/plan_equivalence.rs`); the win is one
//! prefix-sorted pass (and one pass of cache traffic) instead of S.
//!
//! The sweep also counts rows occurring in more than one slot of a class
//! (`FusedStats::cross_shared_rows`) — the dedup mass that makes fusion
//! worthwhile on a workload.

use crate::access::plan::{BagLayout, TtPlan};
use crate::tt::shapes::TtShapes;

/// Counters from the fused sweep of one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct FusedStats {
    /// TT slots planned through a fused (multi-slot) sweep.
    pub fused_slots: u64,
    /// Fused sweeps executed (one per same-shapes class with ≥ 2 slots).
    pub sweeps: u64,
    /// Distinct rows observed in more than one slot of a fused class —
    /// the cross-table sharing the fusion exploits.
    pub cross_shared_rows: u64,
}

/// Reusable scratch for the fused sweep (allocation-free steady state).
#[derive(Clone, Default)]
pub struct FusedSweep {
    /// concatenated (row, class-member, position) stream of one class.
    entries: Vec<(u64, u32, u32)>,
    /// per-class-member sorted (row, pos) pairs peeled off `entries`.
    per_slot: Vec<Vec<(u64, u32)>>,
    /// class grouping scratch: (shapes, member slot indices).
    classes: Vec<(TtShapes, Vec<usize>)>,
}

impl FusedSweep {
    /// Plan every compressed slot of the batch: slots sharing `TtShapes`
    /// (same padded vocabulary, dim and rank) are planned through one
    /// fused sorted sweep; singleton classes fall back to the private
    /// per-slot build (identical output, less bookkeeping).
    pub(crate) fn build_classes(
        &mut self,
        shapes: &[Option<TtShapes>],
        cols: &[Vec<u64>],
        tt: &mut [Option<TtPlan>],
        batch: usize,
        stats: &mut FusedStats,
    ) {
        // group slot indices by shapes, first-seen order (ns is small)
        for (_, members) in self.classes.iter_mut() {
            members.clear();
        }
        let mut n_classes = 0usize;
        for (t, sh) in shapes.iter().enumerate() {
            let Some(sh) = sh else { continue };
            let found = self.classes[..n_classes]
                .iter()
                .position(|(csh, _)| csh == sh);
            match found {
                Some(ci) => self.classes[ci].1.push(t),
                None => {
                    if n_classes == self.classes.len() {
                        self.classes.push((*sh, Vec::new()));
                    } else {
                        self.classes[n_classes].0 = *sh;
                    }
                    self.classes[n_classes].1.push(t);
                    n_classes += 1;
                }
            }
        }
        let classes = std::mem::take(&mut self.classes);
        for (sh, members) in classes[..n_classes].iter() {
            if members.len() == 1 {
                let t = members[0];
                let plan = tt[t].get_or_insert_with(TtPlan::default);
                plan.build(*sh, &cols[t], BagLayout::Unit(batch));
            } else {
                self.fuse_class(*sh, members, cols, tt, batch, stats);
            }
        }
        self.classes = classes;
    }

    /// One fused class: concatenate, sort once, peel per-slot plans.
    fn fuse_class(
        &mut self,
        sh: TtShapes,
        members: &[usize],
        cols: &[Vec<u64>],
        tt: &mut [Option<TtPlan>],
        batch: usize,
        stats: &mut FusedStats,
    ) {
        self.entries.clear();
        for (ci, &t) in members.iter().enumerate() {
            self.entries.extend(
                cols[t]
                    .iter()
                    .enumerate()
                    .map(|(pos, &row)| (row, ci as u32, pos as u32)),
            );
        }
        // THE single prefix-sorted pass: (row, member, pos) order means
        // each member's subsequence is (row, pos)-sorted — identical to
        // its private sort — while equal rows from different members sit
        // adjacent for the cross-sharing count below.
        self.entries.sort_unstable();
        self.per_slot.resize_with(members.len(), Vec::new);
        for v in self.per_slot.iter_mut() {
            v.clear();
        }
        let mut run_start = 0usize;
        let mut shared = 0u64;
        for (k, &(row, ci, pos)) in self.entries.iter().enumerate() {
            self.per_slot[ci as usize].push((row, pos));
            // close a row-run: count it as shared when it spans members
            let next_row = self.entries.get(k + 1).map(|e| e.0);
            if next_row != Some(row) {
                let first_ci = self.entries[run_start].1;
                if self.entries[run_start..=k].iter().any(|e| e.1 != first_ci) {
                    shared += 1;
                }
                run_start = k + 1;
            }
        }
        for (ci, &t) in members.iter().enumerate() {
            let plan = tt[t].get_or_insert_with(TtPlan::default);
            plan.build_forward_sorted(sh, &self.per_slot[ci], BagLayout::Unit(batch));
        }
        stats.sweeps += 1;
        stats.fused_slots += members.len() as u64;
        stats.cross_shared_rows += shared;
    }

    /// Member slot-lists of the classes that actually fused (≥ 2 slots)
    /// in the most recent [`build_classes`](Self::build_classes) sweep —
    /// the candidates for a class-wide ranked execution layout.  Stale
    /// class scratch from earlier sweeps has its member list cleared, so
    /// it never leaks through here.
    pub(crate) fn multi_classes(&self) -> impl Iterator<Item = &[usize]> + '_ {
        self.classes.iter().filter(|(_, m)| m.len() >= 2).map(|(_, m)| m.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(vals: &[u64]) -> Vec<u64> {
        vals.to_vec()
    }

    #[test]
    fn fused_class_plans_match_private_builds() {
        let sh = TtShapes::plan(4000, 8, 4);
        let shapes = vec![Some(sh), Some(sh), None];
        let cols = vec![col(&[5, 7, 7, 900, 5]), col(&[7, 11, 5, 2000, 2000]), col(&[0; 5])];
        let mut fused_tt: Vec<Option<TtPlan>> = vec![None, None, None];
        let mut sweep = FusedSweep::default();
        let mut stats = FusedStats::default();
        sweep.build_classes(&shapes, &cols, &mut fused_tt, 5, &mut stats);
        assert_eq!(stats.sweeps, 1);
        assert_eq!(stats.fused_slots, 2);
        // rows 5 and 7 occur in both slots
        assert_eq!(stats.cross_shared_rows, 2);

        for t in 0..2 {
            let mut private = TtPlan::default();
            private.build(sh, &cols[t], BagLayout::Unit(5));
            let f = fused_tt[t].as_ref().unwrap();
            assert_eq!(f.uniq_rows, private.uniq_rows, "slot {t} uniq");
            assert_eq!(f.index_slot, private.index_slot, "slot {t} scatter");
            assert_eq!(f.group_starts, private.group_starts, "slot {t} groups");
            assert_eq!(f.occ_sorted(), private.occ_sorted(), "slot {t} occ");
            assert!(f.forward_ready() && f.backward_ready());
        }
        assert!(fused_tt[2].is_none());
    }

    #[test]
    fn singleton_classes_take_private_path() {
        let a = TtShapes::plan(1000, 8, 4);
        let b = TtShapes::plan(50_000, 8, 4);
        let shapes = vec![Some(a), Some(b)];
        let cols = vec![col(&[1, 2, 3]), col(&[9, 9, 40_000])];
        let mut tt: Vec<Option<TtPlan>> = vec![None, None];
        let mut sweep = FusedSweep::default();
        let mut stats = FusedStats::default();
        sweep.build_classes(&shapes, &cols, &mut tt, 3, &mut stats);
        assert_eq!(stats.sweeps, 0);
        assert_eq!(stats.fused_slots, 0);
        assert_eq!(tt[0].as_ref().unwrap().distinct_rows(), 3);
        assert_eq!(tt[1].as_ref().unwrap().distinct_rows(), 2);
    }
}
