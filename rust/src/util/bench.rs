//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by every `cargo bench` target: warmup, timed iterations, outlier-
//! robust summary, and paper-style table rows on stdout.  Deliberately
//! small and deterministic — benches print the same rows the paper reports
//! so EXPERIMENTS.md can diff paper-vs-measured directly.

use std::time::{Duration, Instant};

use crate::util::stats::{summarize, Summary};

/// One benchmark measurement.
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub per_iter: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.per_iter.mean)
    }

    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.per_iter.mean
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 3,
            max_iters: 2_000,
        }
    }

    /// Run `f` repeatedly; each call is one iteration.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup until budget elapses (at least one call).
        let w0 = Instant::now();
        loop {
            f();
            if w0.elapsed() >= self.warmup {
                break;
            }
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters || t0.elapsed() < self.budget)
            && samples.len() < self.max_iters
        {
            let it = Instant::now();
            f();
            samples.push(it.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            per_iter: summarize(&samples),
        }
    }
}

/// Pretty paper-style table emitter.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{:<width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format seconds as an adaptive human string.
pub fn fmt_dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}µs", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.2}GB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.1}MB", b / (K * K))
    } else if b >= K {
        format!("{:.1}KB", b / K)
    } else {
        format!("{b:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100,
        };
        let mut acc = 0u64;
        let r = b.run("noop", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 3);
        assert!(r.per_iter.mean >= 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_dur(2.0), "2.00s");
        assert_eq!(fmt_dur(0.002), "2.00ms");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert!(fmt_bytes(59_200_000_000).starts_with("55."));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
    }
}
