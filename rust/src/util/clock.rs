//! Injectable monotonic time + exponentially weighted moving averages —
//! the two primitives every autotune controller (`runtime::autotune`)
//! is built from.  Controllers take a [`Clock`] instead of calling
//! `Instant::now` directly so their unit tests drive time by hand
//! ([`Clock::manual`] + [`Clock::advance`]) and stay wall-clock-free and
//! bit-reproducible.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonic clock reporting seconds since its creation.  Cloning a
/// manual clock shares its time source, so a controller and the test
/// driving it observe the same hand-advanced timeline.
#[derive(Clone, Debug)]
pub struct Clock {
    source: Source,
}

#[derive(Clone, Debug)]
enum Source {
    /// Wall time (production): seconds since the clock was built.
    Real(Instant),
    /// Hand-advanced time (tests): nanoseconds behind an `Arc`, shared
    /// by every clone.
    Manual(Arc<AtomicU64>),
}

impl Clock {
    /// Wall-clock-backed monotonic source (production default).
    pub fn real() -> Clock {
        Clock { source: Source::Real(Instant::now()) }
    }

    /// Deterministic test clock starting at t = 0; advance with
    /// [`Clock::advance`].
    pub fn manual() -> Clock {
        Clock { source: Source::Manual(Arc::new(AtomicU64::new(0))) }
    }

    /// Seconds since this clock (or the manual source it shares) began.
    pub fn now(&self) -> f64 {
        match &self.source {
            Source::Real(t0) => t0.elapsed().as_secs_f64(),
            Source::Manual(ns) => ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Advance a manual clock by `secs`.  Panics on a real clock — a
    /// test that advances wall time by hand is a bug, not a no-op.
    pub fn advance(&self, secs: f64) {
        assert!(secs >= 0.0, "clocks are monotonic; cannot advance by {secs}");
        match &self.source {
            Source::Real(_) => panic!("Clock::advance on a real clock"),
            Source::Manual(ns) => {
                ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
            }
        }
    }
}

/// Exponentially weighted moving average: `v ← (1-α)·v + α·x`.  The
/// first observation seeds the value directly (no zero-bias warmup).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` ∈ (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Ewma {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha {alpha} not in (0, 1]");
        Ewma { alpha, value: None }
    }

    /// Fold in one observation; returns the updated average.
    pub fn observe(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    /// Current average (`None` before the first observation).
    pub fn value(&self) -> Option<f64> {
        self.value
    }

    /// Current average, or `default` before the first observation.
    pub fn or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    /// Number of observations is not tracked; this resets the average so
    /// the next observation re-seeds it (used at controller phase
    /// boundaries, e.g. after a bijection refresh).
    pub fn reset(&mut self) {
        self.value = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic_and_shared() {
        let c = Clock::manual();
        assert_eq!(c.now(), 0.0);
        let c2 = c.clone();
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        assert!((c2.now() - 1.5).abs() < 1e-9, "clones must share the source");
        c2.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn real_clock_moves_forward() {
        let c = Clock::real();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    #[should_panic(expected = "real clock")]
    fn advancing_real_clock_panics() {
        Clock::real().advance(1.0);
    }

    #[test]
    fn ewma_seeds_then_smooths() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), None);
        assert_eq!(e.or(7.0), 7.0);
        assert_eq!(e.observe(10.0), 10.0, "first sample seeds directly");
        assert_eq!(e.observe(0.0), 5.0);
        assert_eq!(e.observe(5.0), 5.0);
        e.reset();
        assert_eq!(e.observe(3.0), 3.0, "reset re-seeds");
    }

    #[test]
    fn ewma_converges_to_constant_input() {
        let mut e = Ewma::new(0.2);
        for _ in 0..200 {
            e.observe(4.0);
        }
        assert!((e.or(0.0) - 4.0).abs() < 1e-9);
    }
}
