//! Deterministic PRNGs for data generation, init, and property tests.
//!
//! The offline crate closure has no `rand`, so we carry our own
//! xoshiro256++ (Blackman & Vigna) seeded through SplitMix64 — the standard
//! pairing, reproducible across platforms, good enough statistical quality
//! for workload synthesis and far faster than we need.

/// SplitMix64: seeds xoshiro and serves as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached pair dropped for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use rejection, else shuffle.
        if k * 4 < n {
            let mut picked = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.usize_below(n);
                if picked.insert(x) {
                    out.push(x);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(9);
        let s = r.sample_distinct(100, 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
    }
}
