//! Poison-recovering synchronization helpers.
//!
//! A `Mutex` poisons when a holder panics. On the serve/net request
//! paths that must *degrade*, not cascade: the data under our queue
//! and ring mutexes is a plain value that is valid at every step (no
//! multi-field invariants updated non-atomically), so recovering the
//! guard and continuing is sound — the alternative, `.unwrap()`, turns
//! one chaos-injected replica panic into an unwinding client and a
//! lost request. `recad lint` rule D3 bans the unwrap form on those
//! paths; these helpers are the sanctioned replacement.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` with the same poison recovery. Returns the
/// re-acquired guard and whether the wait timed out.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};
    use std::thread;

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let h = thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        });
        assert!(h.join().is_err());
        assert!(m.is_poisoned());
        let g = lock_recover(&m);
        assert_eq!(*g, 7);
    }

    #[test]
    fn wait_timeout_recover_times_out() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let (g, timed_out) = wait_timeout_recover(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
        assert!(!*g);
    }
}
