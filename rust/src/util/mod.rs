//! Shared infrastructure: PRNG, statistics, bench harness, property checks.
//!
//! These exist in-repo because the offline crate closure lacks `rand`,
//! `criterion`, and `proptest`; each submodule is a small, tested,
//! deterministic replacement scoped to what Rec-AD needs.

pub mod bench;
pub mod check;
pub mod clock;
pub mod hash;
pub mod json;
pub mod prng;
pub mod stats;
pub mod sync;
