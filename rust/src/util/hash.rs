//! FNV-1a hashing for stable keys, shared by the dataset generators
//! (feature hashing) and the plan-affinity router (prefix keys).

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one u64 into a running FNV-1a state, byte by byte.
#[inline]
pub fn fnv1a_step(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a of a u64 slice.
#[inline]
pub fn fnv1a(data: &[u64]) -> u64 {
    data.iter().fold(FNV_OFFSET, |h, &d| fnv1a_step(h, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_hash_is_the_step_fold() {
        let xs = [3u64, 0, u64::MAX, 42];
        let mut h = FNV_OFFSET;
        for &x in &xs {
            h = fnv1a_step(h, x);
        }
        assert_eq!(fnv1a(&xs), h);
        assert_eq!(fnv1a(&[]), FNV_OFFSET);
    }

    #[test]
    fn distinct_inputs_diverge() {
        assert_ne!(fnv1a(&[1]), fnv1a(&[2]));
        assert_ne!(fnv1a(&[1, 2]), fnv1a(&[2, 1]));
    }
}
