//! Lightweight property-testing driver (proptest is unavailable offline).
//!
//! `props!` runs a closure across many seeded random cases and reports the
//! first failing seed, so a failure reproduces with `CASE_SEED=<n>`.  Not a
//! shrinker — cases are kept small instead.

use crate::util::prng::Rng;

/// Run `cases` random property checks. The closure receives a per-case RNG
/// and the case index; it should panic (assert) on violation.
pub fn check_cases<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut f: F) {
    // Allow narrowing to one case for debugging: CASE_SEED=17 cargo test
    if let Ok(s) = std::env::var("CASE_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            f(&mut rng, seed as usize);
            return;
        }
    }
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (reproduce with CASE_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_cases_runs_all() {
        let mut n = 0;
        check_cases("count", 10, |_rng, _case| {
            n += 1;
        });
        assert_eq!(n, 10);
    }

    #[test]
    fn allclose_passes_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic]
    fn allclose_fails_far() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}
