//! Streaming statistics + latency histograms used by metrics and benches.

/// Welford's online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile summary over a recorded sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize on empty sample set");
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut w = Welford::new();
    for &x in &s {
        w.push(x);
    }
    Summary {
        count: s.len(),
        mean: w.mean(),
        std: w.std(),
        min: s[0],
        p50: percentile(&s, 0.50),
        p90: percentile(&s, 0.90),
        p99: percentile(&s, 0.99),
        max: *s.last().unwrap(),
    }
}

/// Linear-interpolated percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-bucket log-scale latency histogram (ns) — O(1) record, compact.
#[derive(Clone)]
pub struct LatencyHist {
    buckets: Vec<u64>, // bucket i covers [2^i, 2^(i+1)) ns
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { buckets: vec![0; 64], count: 0, sum_ns: 0, max_ns: 0 }
    }

    pub fn record(&mut self, dur: std::time::Duration) {
        self.record_ns(dur.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&mut self, ns: u64) {
        let b = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile from bucket midpoints.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return 1.5 * (1u64 << i) as f64; // bucket midpoint
            }
        }
        self.max_ns as f64
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_ordered() {
        let s = summarize(&[5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0]);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99);
        assert!(s.p99 <= s.max);
        assert_eq!(s.count, 7);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn hist_quantiles_monotone() {
        let mut h = LatencyHist::new();
        for i in 1..1000u64 {
            h.record_ns(i * 1000);
        }
        assert!(h.quantile_ns(0.5) <= h.quantile_ns(0.99));
        assert_eq!(h.count(), 999);
        assert!(h.mean_ns() > 0.0);
    }

    #[test]
    fn hist_merge_adds_counts() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record_ns(100);
        b.record_ns(200);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
