//! Minimal JSON parser (serde is unavailable offline).
//!
//! Supports the complete JSON grammar minus exotic escapes; ample for
//! `artifacts/meta.json` and the benches' report files.  Recursive
//! descent, zero dependencies, strict enough to reject malformed input.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (for bench reports).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'u') => {
                            // \uXXXX, with UTF-16 surrogate pairs decoded
                            // (😀 => U+1F600); a lone surrogate
                            // degrades to U+FFFD instead of corrupting
                            let hex4 = |b: &[u8], at: usize| -> Option<u32> {
                                let h = b.get(at..at + 4)?;
                                u32::from_str_radix(std::str::from_utf8(h).ok()?, 16).ok()
                            };
                            let code = hex4(self.b, self.i + 1)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            if (0xD800..0xDC00).contains(&code) {
                                // high surrogate: pair with \uDC00..\uDFFF
                                let lo = if self.b.get(self.i + 5) == Some(&b'\\')
                                    && self.b.get(self.i + 6) == Some(&b'u')
                                {
                                    hex4(self.b, self.i + 7)
                                        .filter(|c| (0xDC00..0xE000).contains(c))
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo) => {
                                        let c = 0x10000
                                            + ((code - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                        self.i += 10; // XXXX + \uYYYY
                                    }
                                    None => {
                                        s.push('\u{FFFD}'); // lone high
                                        self.i += 4;
                                    }
                                }
                            } else if (0xDC00..0xE000).contains(&code) {
                                s.push('\u{FFFD}'); // lone low surrogate
                                self.i += 4;
                            } else {
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                self.i += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_doc() {
        let doc = r#"{"model": {"dense_dim": 6, "lr": 0.05},
                      "params": [{"name": "bot/0/0", "shape": [6, 16],
                                  "dtype": "float32"}],
                      "ok": true, "none": null}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().get("dense_dim").unwrap().as_u64(), Some(6));
        assert_eq!(j.get("model").unwrap().get("lr").unwrap().as_f64(), Some(0.05));
        let p0 = j.get("params").unwrap().idx(0).unwrap();
        assert_eq!(p0.get("name").unwrap().as_str(), Some("bot/0/0"));
        assert_eq!(p0.get("shape").unwrap().idx(1).unwrap().as_usize(), Some(16));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":false}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn surrogate_pairs_roundtrip() {
        // non-BMP char via a UTF-16 surrogate pair escape
        let j = Json::parse(r#""smile \uD83D\uDE00 end""#).unwrap();
        assert_eq!(j.as_str(), Some("smile \u{1F600} end"));
        // the writer emits raw UTF-8, which must re-parse identically
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        // a bench-report-shaped doc with a non-BMP name survives the trip
        let doc = r#"{"arms": [{"name": "tt_\uD83D\uDE80_fwd", "n": 1}]}"#;
        let d = Json::parse(doc).unwrap();
        let name = d.get("arms").unwrap().idx(0).unwrap().get("name").unwrap();
        assert_eq!(name.as_str(), Some("tt_\u{1F680}_fwd"));
        assert_eq!(Json::parse(&d.to_string()).unwrap(), d);
        // lone surrogates degrade to U+FFFD instead of corrupting
        assert_eq!(Json::parse(r#""\uD83D x""#).unwrap().as_str(), Some("\u{FFFD} x"));
        assert_eq!(Json::parse(r#""\uDE00""#).unwrap().as_str(), Some("\u{FFFD}"));
        // BMP escapes still decode as before
        assert_eq!(Json::parse(r#""\u0041\u00e9""#).unwrap().as_str(), Some("A\u{e9}"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }
}
