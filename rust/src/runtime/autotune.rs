//! Self-tuning runtime: feedback controllers that fold the static
//! performance knobs into measurement-driven loops.
//!
//! Three controllers, one per knob family, all **off by default** and
//! provably inert when disabled (the hook sites consult them only when
//! installed; `tests/autotune_equivalence.rs` pins autotune=off
//! bit-identical to the static paths):
//!
//! * [`CacheBudgetTuner`] — picks `[access] cache_kb` by probing a small
//!   ladder of budgets during the first batches: each planned batch is
//!   built under one rung, the trainer reports the measured step time
//!   through a [`CacheFeedback`] bus, and the tuner normalizes it to
//!   seconds per distinct TT row (so batch-composition noise cancels).
//!   Once every rung has `probe_batches` samples it commits the argmin
//!   and stops probing; a table-shape change or a >2× drift in distinct
//!   rows per batch re-opens the probe.
//! * [`ReorderCadenceTuner`] — adapts `refresh_every` from the observed
//!   `TtPlan::reuse_rate()`: a fresh bijection re-baselines the peak;
//!   when the smoothed reuse decays `reuse_decay_tol` below that peak
//!   the interval halves (drift: refresh sooner), and after a long
//!   decay-free stretch it doubles (stable: rebuild less).
//! * [`ServeBatchTuner`] — nudges a replica's `max_batch`/`deadline_us`
//!   from the queue-delay vs service-time split each `Reply` already
//!   carries, bounded by a p99 attack-window target: over target it
//!   stops waiting for fill (deadline → 0) and, when queueing dominates,
//!   widens batches to drain the queue; under target it grows batches
//!   under queue pressure or allows a bounded fill wait otherwise.
//!
//! All three are built on the injectable [`Clock`] + [`Ewma`] from
//! `util::clock`, so their unit tests run wall-clock-free.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::clock::{Clock, Ewma};
use crate::util::stats::percentile;

/// `[autotune]` config section (also `--autotune` on the CLI).  The
/// master `enabled` switch gates all three loops; the per-loop flags
/// select which knob families participate once enabled.
#[derive(Clone, Debug, PartialEq)]
pub struct AutotuneCfg {
    /// Master switch; `false` (the default) leaves every static path
    /// untouched.
    pub enabled: bool,
    /// Tune `[access] cache_kb` from measured step times.
    pub cache: bool,
    /// Tune `[access] refresh_every` from reuse-rate decay.
    pub reorder: bool,
    /// Tune `[serve] max_batch`/`deadline_us` per replica.
    pub serve: bool,
    /// Cache budgets (KiB) probed before committing.
    pub cache_ladder: Vec<usize>,
    /// Feedback samples required per rung before the ladder commits.
    pub probe_batches: usize,
    /// Cadence bounds: `refresh_every` is clamped to this range.
    pub min_refresh: usize,
    pub max_refresh: usize,
    /// Fractional reuse-rate decay below the post-refresh peak that
    /// triggers a cadence shorten (0.1 = 10% below peak).
    pub reuse_decay_tol: f64,
    /// Serve-loop p99 attack-window target (µs).
    pub target_p99_us: u64,
    /// Upper bound on autotuned `max_batch`.
    pub max_batch_cap: usize,
}

impl Default for AutotuneCfg {
    fn default() -> Self {
        AutotuneCfg {
            enabled: false,
            cache: true,
            reorder: true,
            serve: true,
            cache_ladder: vec![64, 128, 256, 512],
            probe_batches: 3,
            min_refresh: 2,
            max_refresh: 512,
            reuse_decay_tol: 0.1,
            target_p99_us: 20_000,
            max_batch_cap: 32,
        }
    }
}

impl AutotuneCfg {
    /// Cache-budget loop active?
    pub fn cache_on(&self) -> bool {
        self.enabled && self.cache && !self.cache_ladder.is_empty()
    }

    /// Reorder-cadence loop active?
    pub fn reorder_on(&self) -> bool {
        self.enabled && self.reorder
    }

    /// Serve-batching loop active?
    pub fn serve_on(&self) -> bool {
        self.enabled && self.serve
    }

    /// The serve-loop parameters the server threads consume.
    pub fn serve_tune(&self) -> ServeTuneCfg {
        ServeTuneCfg {
            target_p99: Duration::from_micros(self.target_p99_us.max(1)),
            max_batch_cap: self.max_batch_cap.max(1),
            adjust_every: 64,
            min_interval: Duration::from_millis(1),
        }
    }
}

// ---------------------------------------------------------------------------
// Cache-budget tuning
// ---------------------------------------------------------------------------

/// Producer handle of the step-time feedback bus: the trainer's consume
/// closure times `train_step_planned` and pushes the seconds here; the
/// planner-side [`CacheBudgetTuner`] drains them in batch order.
#[derive(Clone)]
pub struct CacheFeedback(Arc<Mutex<VecDeque<f64>>>);

impl CacheFeedback {
    /// Report one measured step time (seconds) for the oldest
    /// not-yet-scored planned batch.
    pub fn push(&self, secs: f64) {
        self.0.lock().unwrap().push_back(secs);
    }
}

/// One planned-but-not-yet-scored batch: which rung sized its layout,
/// and (once the plan is built) how many distinct TT rows it walked.
#[derive(Clone, Debug)]
struct IssuedProbe {
    rung: usize,
    rows: Option<usize>,
}

/// Ladder-probing controller for the per-batch cache budget.  Drive it
/// from the planning loop:
///
/// 1. [`CacheBudgetTuner::budget_now`] BEFORE the layout policy is set —
///    returns the cache budget (KiB) this batch should be built under;
/// 2. [`CacheBudgetTuner::note_rows`] AFTER the plan is built — reports
///    the shape signature + distinct-row count that normalize feedback;
/// 3. the trainer pushes measured step seconds via [`CacheFeedback`].
///
/// Feedback arrives in batch order (the trainer consumes batches in the
/// order they were planned), so attribution is a FIFO walk of the
/// issued-probe queue — no timestamps needed.
#[derive(Clone)]
pub struct CacheBudgetTuner {
    ladder: Vec<usize>,
    probe_batches: usize,
    /// Seconds per distinct row, smoothed per rung.
    cost: Vec<Ewma>,
    /// Scored feedback samples per rung.
    seen: Vec<usize>,
    issued: VecDeque<IssuedProbe>,
    feedback: CacheFeedback,
    /// Committed rung index once the ladder has settled.
    committed: Option<usize>,
    /// Distinct-rows-per-batch level at commit time (drift detector).
    committed_rows: Option<usize>,
    committed_at: Option<f64>,
    shape_sig: Option<u64>,
    last_rows: usize,
    clock: Clock,
    /// Times the probe re-opened (shape change or row drift).
    pub reprobes: u64,
}

impl CacheBudgetTuner {
    pub fn new(cfg: &AutotuneCfg, clock: Clock) -> Self {
        let ladder = if cfg.cache_ladder.is_empty() {
            AutotuneCfg::default().cache_ladder
        } else {
            cfg.cache_ladder.clone()
        };
        let n = ladder.len();
        CacheBudgetTuner {
            ladder,
            probe_batches: cfg.probe_batches.max(1),
            cost: vec![Ewma::new(0.5); n],
            seen: vec![0; n],
            issued: VecDeque::new(),
            feedback: CacheFeedback(Arc::new(Mutex::new(VecDeque::new()))),
            committed: None,
            committed_rows: None,
            committed_at: None,
            shape_sig: None,
            last_rows: 0,
            clock,
            reprobes: 0,
        }
    }

    /// The feedback bus producer handle (hand it to the timing site).
    pub fn feedback(&self) -> CacheFeedback {
        self.feedback.clone()
    }

    /// Budget (KiB) for the batch about to be planned.  Drains pending
    /// feedback, commits the ladder argmin once every rung has
    /// `probe_batches` scored samples, and records the issued probe.
    pub fn budget_now(&mut self) -> usize {
        self.drain_feedback();
        let rung = match self.committed {
            Some(r) => r,
            None => self.least_probed_rung(),
        };
        self.issued.push_back(IssuedProbe { rung, rows: None });
        self.ladder[rung]
    }

    /// Report the built plan's shape signature + distinct TT rows.  A
    /// signature change or a >2× distinct-row drift from the committed
    /// level re-opens the probe.
    pub fn note_rows(&mut self, shape_sig: u64, rows: usize) {
        if self.shape_sig != Some(shape_sig) {
            if self.shape_sig.is_some() {
                self.reprobe();
            }
            self.shape_sig = Some(shape_sig);
        }
        self.last_rows = rows;
        if let Some(p) = self.issued.iter_mut().find(|p| p.rows.is_none()) {
            p.rows = Some(rows);
        }
        if self.committed.is_some() {
            let base = self.committed_rows.unwrap_or(rows).max(1);
            if rows > base * 2 || rows * 2 < base {
                self.reprobe();
            }
        }
    }

    /// Committed budget (KiB), once the ladder has settled.
    pub fn committed_kb(&self) -> Option<usize> {
        self.committed.map(|r| self.ladder[r])
    }

    /// Seconds-since-start at which the current commit landed.
    pub fn committed_at(&self) -> Option<f64> {
        self.committed_at
    }

    fn least_probed_rung(&self) -> usize {
        // count in-flight issues so consecutive prefetched batches spread
        // across rungs instead of piling onto one
        let mut load = self.seen.clone();
        for p in &self.issued {
            load[p.rung] += 1;
        }
        let mut best = 0;
        for (i, &n) in load.iter().enumerate() {
            if n < load[best] {
                best = i;
            }
        }
        best
    }

    fn drain_feedback(&mut self) {
        loop {
            let secs = {
                let mut q = self.feedback.0.lock().unwrap();
                // the front probe must already know its row count (its
                // plan was built before its step could be timed); if not,
                // the sample belongs to a future batch — leave it queued
                if self.issued.front().map_or(true, |p| p.rows.is_none()) {
                    break;
                }
                match q.pop_front() {
                    Some(s) => s,
                    None => break,
                }
            };
            let p = self.issued.pop_front().expect("checked above");
            let rows = p.rows.expect("checked above").max(1);
            self.seen[p.rung] += 1;
            self.cost[p.rung].observe(secs / rows as f64);
        }
        if self.committed.is_none() && self.seen.iter().all(|&n| n >= self.probe_batches) {
            let mut best = 0;
            for i in 1..self.ladder.len() {
                if self.cost[i].or(f64::INFINITY) < self.cost[best].or(f64::INFINITY) {
                    best = i;
                }
            }
            self.committed = Some(best);
            self.committed_rows = Some(self.last_rows);
            self.committed_at = Some(self.clock.now());
        }
    }

    fn reprobe(&mut self) {
        self.committed = None;
        self.committed_rows = None;
        self.committed_at = None;
        for c in &mut self.cost {
            c.reset();
        }
        for s in &mut self.seen {
            *s = 0;
        }
        self.reprobes += 1;
    }
}

impl std::fmt::Debug for CacheBudgetTuner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheBudgetTuner")
            .field("ladder", &self.ladder)
            .field("seen", &self.seen)
            .field("committed_kb", &self.committed_kb())
            .field("reprobes", &self.reprobes)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Reorder-cadence tuning
// ---------------------------------------------------------------------------

/// Peak-decay controller for `refresh_every`.  Feed it once per planned
/// batch per table; it returns `Some(new_interval)` when the cadence
/// should change (apply via `set_refresh_every` on the reorder engine).
#[derive(Clone, Debug)]
pub struct ReorderCadenceTuner {
    every: usize,
    min: usize,
    max: usize,
    decay_tol: f64,
    reuse: Ewma,
    /// Post-refresh peak of the smoothed reuse rate.
    peak: f64,
    /// Batches since the last decay signal or cadence change.
    stable: usize,
    /// Times the interval halved (drift detected).
    pub shortens: u64,
    /// Times the interval doubled (reuse stable).
    pub relaxes: u64,
}

impl ReorderCadenceTuner {
    pub fn new(initial_every: usize, cfg: &AutotuneCfg) -> Self {
        let min = cfg.min_refresh.max(1);
        let max = cfg.max_refresh.max(min);
        ReorderCadenceTuner {
            every: initial_every.clamp(min, max),
            min,
            max,
            decay_tol: cfg.reuse_decay_tol.clamp(0.0, 1.0),
            reuse: Ewma::new(0.3),
            peak: 0.0,
            stable: 0,
            shortens: 0,
            relaxes: 0,
        }
    }

    /// Current interval (the engine may have started from a different
    /// clamp; callers apply returned changes, this mirrors them).
    pub fn every(&self) -> usize {
        self.every
    }

    /// Observe one batch's reuse rate; `adopted` marks the batch where a
    /// refreshed bijection landed (it re-baselines the peak — reuse
    /// legitimately jumps there).  Returns the new interval when the
    /// cadence changes.
    pub fn observe(&mut self, reuse_rate: f64, adopted: bool) -> Option<usize> {
        let smoothed = self.reuse.observe(reuse_rate);
        if adopted {
            self.peak = smoothed;
        } else {
            self.peak = self.peak.max(smoothed);
            if smoothed < self.peak * (1.0 - self.decay_tol) && self.every > self.min {
                // reuse decayed below the post-refresh peak: drift —
                // refresh more often
                self.every = (self.every / 2).max(self.min);
                self.shortens += 1;
                self.peak = smoothed;
                self.stable = 0;
                return Some(self.every);
            }
        }
        self.stable += 1;
        if self.stable >= self.every * 2 && self.every < self.max {
            // a full double interval with no decay: stable — rebuild less
            self.every = (self.every * 2).min(self.max);
            self.relaxes += 1;
            self.stable = 0;
            return Some(self.every);
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Serve-batching tuning
// ---------------------------------------------------------------------------

/// Serve-loop parameters consumed by the server worker threads.
#[derive(Clone, Copy, Debug)]
pub struct ServeTuneCfg {
    /// p99 attack-window bound the knobs must respect.
    pub target_p99: Duration,
    /// Upper bound on autotuned `max_batch`.
    pub max_batch_cap: usize,
    /// Replies between knob adjustments.
    pub adjust_every: usize,
    /// Minimum wall time between adjustments (debounce under bursts).
    pub min_interval: Duration,
}

/// The fill deadline never exceeds `target_p99 * DEADLINE_FRAC`: waiting
/// longer than a quarter of the latency budget for batch fill can never
/// pay for itself at p99.
pub const DEADLINE_FRAC: f64 = 0.25;

/// The live `max_batch`/`deadline` pair a worker loop reads each
/// iteration — atomics behind an `Arc` so the tuner (same thread) and
/// any observer (stats thread, tests) see consistent values without
/// locking the hot path.
#[derive(Debug)]
pub struct BatchKnobs {
    max_batch: AtomicUsize,
    deadline_ns: AtomicU64,
}

impl BatchKnobs {
    pub fn new(max_batch: usize, deadline: Duration) -> Arc<BatchKnobs> {
        Arc::new(BatchKnobs {
            max_batch: AtomicUsize::new(max_batch.max(1)),
            deadline_ns: AtomicU64::new(deadline.as_nanos().min(u64::MAX as u128) as u64),
        })
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    pub fn deadline(&self) -> Duration {
        Duration::from_nanos(self.deadline_ns.load(Ordering::Relaxed))
    }

    fn set(&self, max_batch: usize, deadline: Duration) {
        self.max_batch.store(max_batch.max(1), Ordering::Relaxed);
        self.deadline_ns
            .store(deadline.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }
}

/// Per-replica micro-batching controller.  Feed every reply's
/// end-to-end window + its queue/service split; every `adjust_every`
/// replies (debounced by `min_interval`) it recomputes the window p99
/// and nudges the knobs:
///
/// * p99 over target → deadline halves toward 0 (stop waiting for
///   fill), and if queue delay dominates service time, `max_batch`
///   doubles (drain the queue in fewer dispatches);
/// * p99 under target, queue-dominated → `max_batch` doubles (up to the
///   cap);
/// * p99 under target, service-dominated → the fill deadline may grow,
///   but never beyond `min(headroom/4, target * DEADLINE_FRAC)`.
///
/// Invariants (pinned in tests): `max_batch ∈ [1, cap]`; `deadline ≤
/// target_p99 * DEADLINE_FRAC` always; an over-target adjustment never
/// raises the deadline.
pub struct ServeBatchTuner {
    cfg: ServeTuneCfg,
    knobs: Arc<BatchKnobs>,
    clock: Clock,
    window: Vec<f64>,
    queue: Ewma,
    service: Ewma,
    last_adjust: Option<f64>,
    /// Number of knob adjustments applied.
    pub adjustments: u64,
}

impl ServeBatchTuner {
    pub fn new(
        cfg: ServeTuneCfg,
        initial_batch: usize,
        initial_deadline: Duration,
        clock: Clock,
    ) -> Self {
        let bound = cfg.target_p99.mul_f64(DEADLINE_FRAC);
        let knobs = BatchKnobs::new(
            initial_batch.clamp(1, cfg.max_batch_cap.max(1)),
            initial_deadline.min(bound),
        );
        ServeBatchTuner {
            cfg,
            knobs,
            clock,
            window: Vec::new(),
            queue: Ewma::new(0.2),
            service: Ewma::new(0.2),
            last_adjust: None,
            adjustments: 0,
        }
    }

    /// The shared knob pair the worker loop reads.
    pub fn knobs(&self) -> Arc<BatchKnobs> {
        Arc::clone(&self.knobs)
    }

    /// Feed one reply: end-to-end attack window, its queue-delay part,
    /// and its service-time part.
    pub fn observe(&mut self, window: Duration, queue_delay: Duration, service: Duration) {
        self.window.push(window.as_secs_f64());
        self.queue.observe(queue_delay.as_secs_f64());
        self.service.observe(service.as_secs_f64());
        if self.window.len() < self.cfg.adjust_every.max(1) {
            return;
        }
        let now = self.clock.now();
        if let Some(last) = self.last_adjust {
            if now - last < self.cfg.min_interval.as_secs_f64() {
                return; // debounce: keep accumulating
            }
        }
        self.adjust(now);
    }

    fn adjust(&mut self, now: f64) {
        let mut w = std::mem::take(&mut self.window);
        w.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = percentile(&w, 0.99);
        let target = self.cfg.target_p99.as_secs_f64();
        let bound = target * DEADLINE_FRAC;
        let queue_dominated = self.queue.or(0.0) > self.service.or(0.0);
        let b = self.knobs.max_batch();
        let d = self.knobs.deadline().as_secs_f64();
        let (nb, nd) = if p99 > target {
            // over budget: stop waiting for fill; widen batches only if
            // the time is going to queueing rather than compute
            let nd = if d / 2.0 < target * 0.05 { 0.0 } else { d / 2.0 };
            let nb = if queue_dominated { (b * 2).min(self.cfg.max_batch_cap) } else { b };
            (nb, nd)
        } else if queue_dominated {
            // under budget but queueing: bigger dispatches, same wait
            ((b * 2).min(self.cfg.max_batch_cap), d)
        } else {
            // under budget, compute-bound: allow a bounded fill wait so
            // batching amortizes dispatch overhead
            let headroom = ((target - p99) / 4.0).max(0.0);
            let grown = (d.max(target * 0.01) * 2.0).min(headroom);
            (b, grown.max(d).min(bound))
        };
        let changed = nb != b || (nd - d).abs() > 1e-12;
        self.knobs.set(nb, Duration::from_secs_f64(nd.clamp(0.0, bound)));
        if changed {
            self.adjustments += 1;
        }
        self.last_adjust = Some(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn on() -> AutotuneCfg {
        AutotuneCfg { enabled: true, ..AutotuneCfg::default() }
    }

    #[test]
    fn disabled_cfg_gates_every_loop() {
        let off = AutotuneCfg::default();
        assert!(!off.enabled && !off.cache_on() && !off.reorder_on() && !off.serve_on());
        let on = on();
        assert!(on.cache_on() && on.reorder_on() && on.serve_on());
        let partial = AutotuneCfg { serve: false, ..on };
        assert!(partial.cache_on() && !partial.serve_on());
    }

    /// Synthetic cost model: rung 1 (128 KiB) is cheapest.  The ladder
    /// must probe every rung, commit 128, and stay committed.
    #[test]
    fn cache_ladder_settles_on_cheapest_rung() {
        let cfg = on();
        let mut t = CacheBudgetTuner::new(&cfg, Clock::manual());
        let fb = t.feedback();
        let cost_of = |kb: usize| match kb {
            64 => 4.0e-3,
            128 => 1.0e-3,
            256 => 2.0e-3,
            _ => 3.0e-3,
        };
        let mut history = Vec::new();
        for _ in 0..40 {
            let kb = t.budget_now();
            history.push(kb);
            t.note_rows(0xABCD, 1000);
            fb.push(cost_of(kb));
        }
        assert_eq!(t.committed_kb(), Some(128), "ladder must commit the cheapest rung");
        assert_eq!(t.reprobes, 0);
        // every rung was probed at least probe_batches times
        for &kb in &cfg.cache_ladder {
            assert!(
                history.iter().filter(|&&h| h == kb).count() >= cfg.probe_batches,
                "rung {kb} under-probed"
            );
        }
        // and the tail is pure committed budget
        assert!(history[history.len() - 8..].iter().all(|&h| h == 128));
    }

    #[test]
    fn cache_ladder_reprobes_on_shape_change_and_row_drift() {
        let cfg = on();
        let mut t = CacheBudgetTuner::new(&cfg, Clock::manual());
        let fb = t.feedback();
        for _ in 0..20 {
            let kb = t.budget_now();
            t.note_rows(1, 1000);
            fb.push(if kb == 512 { 1.0e-3 } else { 5.0e-3 });
        }
        assert_eq!(t.committed_kb(), Some(512));
        // shape change: probe re-opens
        t.budget_now();
        t.note_rows(2, 1000);
        assert_eq!(t.committed_kb(), None, "shape change must re-open the probe");
        assert_eq!(t.reprobes, 1);
        fb.push(1.0e-3);
        for _ in 0..20 {
            let kb = t.budget_now();
            t.note_rows(2, 1000);
            fb.push(if kb == 64 { 1.0e-3 } else { 5.0e-3 });
        }
        assert_eq!(t.committed_kb(), Some(64), "re-probe must re-commit on new costs");
        // row drift beyond 2x: probe re-opens again
        t.budget_now();
        t.note_rows(2, 2500);
        assert_eq!(t.committed_kb(), None, "row drift must re-open the probe");
        assert_eq!(t.reprobes, 2);
    }

    #[test]
    fn cadence_shortens_under_decay_and_relaxes_when_stable() {
        let cfg = on();
        let mut t = ReorderCadenceTuner::new(64, &cfg);
        assert_eq!(t.every(), 64);
        // drift: reuse decays steadily from a high post-refresh peak
        let mut reuse = 0.9;
        let mut changed = Vec::new();
        t.observe(reuse, true); // fresh bijection baselines the peak
        for _ in 0..14 {
            reuse *= 0.95;
            if let Some(e) = t.observe(reuse, false) {
                changed.push(e);
            }
        }
        assert!(t.shortens >= 2, "steady decay must shorten the cadence");
        assert!(t.every() < 64);
        assert!(changed.windows(2).all(|w| w[1] <= w[0]), "shortens must be monotone");
        assert!(t.every() >= cfg.min_refresh, "cadence must respect the floor");
        // stability: constant reuse relaxes the cadence back out
        let short = t.every();
        let mut relaxed = false;
        for _ in 0..(short * 8) {
            if t.observe(0.5, false).is_some() {
                relaxed = true;
            }
        }
        assert!(relaxed && t.every() > short, "stable reuse must relax the cadence");
        assert!(t.relaxes >= 1);
        assert!(t.every() <= cfg.max_refresh);
    }

    #[test]
    fn cadence_never_leaves_bounds() {
        let cfg = AutotuneCfg { min_refresh: 4, max_refresh: 16, ..on() };
        let mut t = ReorderCadenceTuner::new(1000, &cfg);
        assert_eq!(t.every(), 16, "initial interval clamps into range");
        // hammer decay: must stop at the floor
        for i in 0..200 {
            t.observe(if i % 2 == 0 { 0.9 } else { 0.1 }, false);
            assert!(t.every() >= 4 && t.every() <= 16);
        }
    }

    fn serve_cfg() -> ServeTuneCfg {
        ServeTuneCfg {
            target_p99: Duration::from_micros(10_000),
            max_batch_cap: 16,
            adjust_every: 8,
            min_interval: Duration::ZERO,
        }
    }

    #[test]
    fn over_target_drives_deadline_to_zero_and_respects_cap() {
        let cfg = serve_cfg();
        let mut t =
            ServeBatchTuner::new(cfg, 4, Duration::from_micros(2_000), Clock::manual());
        let knobs = t.knobs();
        let mut deadlines = vec![knobs.deadline()];
        // queue-dominated overload: window 20ms, 15ms of it queueing
        for _ in 0..200 {
            t.observe(
                Duration::from_millis(20),
                Duration::from_millis(15),
                Duration::from_millis(5),
            );
            deadlines.push(knobs.deadline());
        }
        assert!(t.adjustments >= 1);
        assert_eq!(knobs.deadline(), Duration::ZERO, "over target must stop fill waits");
        assert!(deadlines.windows(2).all(|w| w[1] <= w[0]), "deadline never grows over target");
        assert_eq!(knobs.max_batch(), cfg.max_batch_cap, "queue pressure widens to the cap");
    }

    #[test]
    fn under_target_grows_batch_under_queue_pressure_only() {
        let cfg = serve_cfg();
        let mut t = ServeBatchTuner::new(cfg, 2, Duration::ZERO, Clock::manual());
        let knobs = t.knobs();
        // fast replies, but queue delay dominates service
        for _ in 0..40 {
            t.observe(
                Duration::from_micros(500),
                Duration::from_micros(400),
                Duration::from_micros(100),
            );
        }
        assert!(knobs.max_batch() > 2, "queue-dominated must widen batches");
        assert!(knobs.max_batch() <= cfg.max_batch_cap);
    }

    #[test]
    fn deadline_never_exceeds_p99_bound() {
        let cfg = serve_cfg();
        let bound = cfg.target_p99.mul_f64(DEADLINE_FRAC);
        // an initial deadline beyond the bound is clamped at construction
        let t = ServeBatchTuner::new(cfg, 1, Duration::from_secs(1), Clock::manual());
        assert!(t.knobs().deadline() <= bound);
        // light compute-bound load: deadline may grow but never past the bound
        let mut t = ServeBatchTuner::new(cfg, 1, Duration::ZERO, Clock::manual());
        let knobs = t.knobs();
        for _ in 0..400 {
            t.observe(
                Duration::from_micros(300),
                Duration::from_micros(20),
                Duration::from_micros(280),
            );
            assert!(knobs.deadline() <= bound, "deadline exceeded the p99 bound");
        }
        assert!(knobs.deadline() > Duration::ZERO, "light load should allow some fill wait");
        assert_eq!(knobs.max_batch(), 1, "service-dominated load must not widen batches");
    }

    #[test]
    fn min_interval_debounces_adjustments() {
        let cfg = ServeTuneCfg { min_interval: Duration::from_secs(1), ..serve_cfg() };
        let clock = Clock::manual();
        let mut t = ServeBatchTuner::new(cfg, 1, Duration::ZERO, clock.clone());
        for _ in 0..100 {
            t.observe(
                Duration::from_micros(500),
                Duration::from_micros(400),
                Duration::from_micros(100),
            );
        }
        assert_eq!(t.adjustments, 1, "only the first adjustment fits in the debounce window");
        clock.advance(2.0);
        for _ in 0..cfg.adjust_every {
            t.observe(
                Duration::from_micros(500),
                Duration::from_micros(400),
                Duration::from_micros(100),
            );
        }
        assert!(t.adjustments >= 2, "adjustments resume after the debounce window");
    }
}
