//! PJRT runtime: load the L2-lowered HLO text artifacts and execute them
//! from the coordinator's hot path.  Python never runs here — the rust
//! binary is self-contained once `make artifacts` has produced
//! `artifacts/{*.hlo.txt, meta.json, init_params.bin}`.
//!
//! The PJRT executors need the `xla` bindings, which are not available in
//! offline builds; they are gated behind the off-by-default `pjrt`
//! feature.  Without it, artifact *metadata* loading still works and the
//! executor types are API-compatible stubs whose constructors return a
//! descriptive error — so the CLI, tests and benches compile and degrade
//! gracefully instead of failing the whole build.
//!
//! `autotune` is the runtime's self-tuning layer: feedback controllers
//! that fold the static cache/reorder/serve knobs into measurement-driven
//! loops (off by default; see `runtime::autotune`).

pub mod artifact;
pub mod autotune;
pub mod fault;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::{ArtifactMeta, Artifacts, ParamMeta};
pub use autotune::{
    AutotuneCfg, BatchKnobs, CacheBudgetTuner, CacheFeedback, ReorderCadenceTuner,
    ServeBatchTuner, ServeTuneCfg,
};
pub use fault::{FaultCfg, FaultEvent, FaultPlan};
#[cfg(feature = "pjrt")]
pub use client::client;
pub use executor::{DlrmFwd, DlrmTrainStep, TtLookupExe};
