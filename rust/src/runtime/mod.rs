//! PJRT runtime: load the L2-lowered HLO text artifacts and execute them
//! from the coordinator's hot path.  Python never runs here — the rust
//! binary is self-contained once `make artifacts` has produced
//! `artifacts/{*.hlo.txt, meta.json, init_params.bin}`.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactMeta, Artifacts, ParamMeta};
pub use client::client;
pub use executor::{DlrmFwd, DlrmTrainStep, TtLookupExe};
