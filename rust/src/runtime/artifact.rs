//! Artifact registry: discovers `artifacts/`, parses `meta.json`, compiles
//! HLO text modules on the PJRT client, and loads the initial parameter
//! blob exported by `python/compile/aot.py`.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[cfg(feature = "pjrt")]
use crate::runtime::client;
use crate::util::json::Json;

/// One flat parameter leaf of the L2 model (order matters: it is the
/// positional argument order of every artifact).
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamMeta {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed `meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub dense_dim: usize,
    pub emb_dim: usize,
    pub num_tables: usize,
    pub table_rows: Vec<u64>,
    pub table_compressed: Vec<bool>,
    pub lr: f64,
    pub fwd_batch: usize,
    pub train_batch: usize,
    pub lookup_batch: usize,
    pub lookup_bag: usize,
    pub lookup_rows: u64,
    pub lookup_m: [u64; 3],
    pub lookup_rank: usize,
    pub params: Vec<ParamMeta>,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let j = Json::parse(text).context("meta.json parse")?;
        let model = j.get("model").context("missing model")?;
        let batches = j.get("batches").context("missing batches")?;
        let spec = j.get("tt_lookup_spec").context("missing tt_lookup_spec")?;
        let need_u = |v: &Json, k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).with_context(|| format!("missing {k}"))
        };
        let tables = model.get("tables").and_then(Json::as_arr).context("tables")?;
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .context("params")?
            .iter()
            .map(|p| -> Result<ParamMeta> {
                Ok(ParamMeta {
                    name: p.get("name").and_then(Json::as_str).context("name")?.to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    dtype: p.get("dtype").and_then(Json::as_str).context("dtype")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let lookup = batches.get("lookup").and_then(Json::as_arr).context("lookup")?;
        let m = spec.get("m").and_then(Json::as_arr).context("m")?;
        Ok(ArtifactMeta {
            dense_dim: need_u(model, "dense_dim")?,
            emb_dim: need_u(model, "emb_dim")?,
            num_tables: need_u(model, "num_tables")?,
            table_rows: tables
                .iter()
                .map(|t| t.get("rows").and_then(Json::as_u64).context("rows"))
                .collect::<Result<_>>()?,
            table_compressed: tables
                .iter()
                .map(|t| t.get("compressed").and_then(Json::as_bool).context("compressed"))
                .collect::<Result<_>>()?,
            lr: model.get("lr").and_then(Json::as_f64).context("lr")?,
            fwd_batch: need_u(batches, "fwd")?,
            train_batch: need_u(batches, "train")?,
            lookup_batch: lookup[0].as_usize().context("lookup[0]")?,
            lookup_bag: lookup[1].as_usize().context("lookup[1]")?,
            lookup_rows: spec.get("rows").and_then(Json::as_u64).context("rows")?,
            lookup_m: [
                m[0].as_u64().context("m0")?,
                m[1].as_u64().context("m1")?,
                m[2].as_u64().context("m2")?,
            ],
            lookup_rank: need_u(spec, "rank")?,
            params,
        })
    }

    /// Total f32 element count across all parameter leaves.
    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

/// Compiled artifact registry.  Without the `pjrt` feature only the
/// metadata + init-param side is populated (no executables are compiled).
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: ArtifactMeta,
    #[cfg(feature = "pjrt")]
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Initial parameter leaves (f32, little-endian blob from aot.py).
    pub init_params: Vec<Vec<f32>>,
}

impl Artifacts {
    /// Load + compile everything under `dir`.  Compilation happens once;
    /// executables are reused across the training/serving run.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json (run `make artifacts`)", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;

        #[cfg(feature = "pjrt")]
        let executables = {
            let mut executables = HashMap::new();
            for name in ["tt_lookup", "dlrm_fwd", "dlrm_train_step"] {
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client()
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
                executables.insert(name.to_string(), exe);
            }
            executables
        };

        let blob = std::fs::read(dir.join("init_params.bin")).context("init_params.bin")?;
        let expect = meta.total_param_elems() * 4;
        if blob.len() != expect {
            bail!("init_params.bin is {} bytes, expected {expect}", blob.len());
        }
        let mut init_params = Vec::with_capacity(meta.params.len());
        let mut off = 0usize;
        for p in &meta.params {
            let n = p.len();
            let mut v = vec![0f32; n];
            for (i, x) in v.iter_mut().enumerate() {
                let b = &blob[off + i * 4..off + i * 4 + 4];
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += n * 4;
            init_params.push(v);
        }

        #[cfg(feature = "pjrt")]
        let arts = Artifacts { dir, meta, executables, init_params };
        #[cfg(not(feature = "pjrt"))]
        let arts = Artifacts { dir, meta, init_params };
        Ok(arts)
    }

    #[cfg(feature = "pjrt")]
    pub fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_fixture() {
        let doc = r#"{
          "model": {"dense_dim": 6, "emb_dim": 16, "num_tables": 2,
                    "tables": [{"rows": 100, "compressed": true, "rank": 8},
                               {"rows": 50, "compressed": false, "rank": 8}],
                    "lr": 0.05},
          "batches": {"fwd": 128, "train": 64, "lookup": [256, 4]},
          "tt_lookup_spec": {"rows": 6000, "dim": 16, "m": [18, 18, 19],
                             "n": [2, 2, 4], "rank": 8},
          "params": [{"name": "bot/0/0", "shape": [6, 64], "dtype": "float32"},
                     {"name": "bot/0/1", "shape": [64], "dtype": "float32"}]
        }"#;
        let m = ArtifactMeta::parse(doc).unwrap();
        assert_eq!(m.dense_dim, 6);
        assert_eq!(m.num_tables, 2);
        assert_eq!(m.table_rows, vec![100, 50]);
        assert_eq!(m.table_compressed, vec![true, false]);
        assert_eq!(m.fwd_batch, 128);
        assert_eq!(m.lookup_bag, 4);
        assert_eq!(m.lookup_m, [18, 18, 19]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.total_param_elems(), 6 * 64 + 64);
    }
}
