//! Typed executors over the compiled artifacts.
//!
//! Parameters live as PJRT device buffers between steps (`execute_b`), so
//! a training step costs: upload batch (3 small buffers) → execute →
//! download loss + refresh param buffers from the returned tuple.  All
//! artifacts were lowered with `return_tuple=True`, so outputs arrive as a
//! single tuple literal that we decompose.

use anyhow::{bail, Result};

use crate::runtime::artifact::Artifacts;
use crate::runtime::client;

/// Upload a host f32 slice as a device buffer.
fn upload_f32(data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client()
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow::anyhow!("upload f32: {e}"))
}

/// Upload a host i32 slice.
fn upload_i32(data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    client()
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow::anyhow!("upload i32: {e}"))
}

/// Fused train-step executor: `(params…, dense, idx, labels) → (loss,
/// params…)`.  Owns the resident parameter buffers.
pub struct DlrmTrainStep<'a> {
    arts: &'a Artifacts,
    params: Vec<xla::PjRtBuffer>,
    pub steps: u64,
}

impl<'a> DlrmTrainStep<'a> {
    pub fn new(arts: &'a Artifacts) -> Result<Self> {
        let params = arts
            .meta
            .params
            .iter()
            .zip(&arts.init_params)
            .map(|(m, v)| upload_f32(v, &m.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(DlrmTrainStep { arts, params, steps: 0 })
    }

    /// Run one SGD step; returns the batch loss.
    ///
    /// `dense` is [train_batch × dense_dim] f32 row-major, `idx` is
    /// [train_batch × num_tables] i32, `labels` is [train_batch].
    pub fn step(&mut self, dense: &[f32], idx: &[i32], labels: &[f32]) -> Result<f32> {
        let m = &self.arts.meta;
        let b = m.train_batch;
        if dense.len() != b * m.dense_dim || idx.len() != b * m.num_tables || labels.len() != b {
            bail!(
                "batch shape mismatch: dense {} idx {} labels {} (want b={b})",
                dense.len(),
                idx.len(),
                labels.len()
            );
        }
        let exe = self.arts.exe("dlrm_train_step")?;
        let d = upload_f32(dense, &[b, m.dense_dim])?;
        let i = upload_i32(idx, &[b, m.num_tables])?;
        let l = upload_f32(labels, &[b])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&d);
        args.push(&i);
        args.push(&l);
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("train_step execute: {e}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple decompose: {e}"))?;
        if parts.len() != 1 + self.params.len() {
            bail!("train_step returned {} outputs, want {}", parts.len(), 1 + self.params.len());
        }
        let loss: f32 = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss literal: {e}"))?[0];
        // refresh resident params from the returned leaves
        for (k, lit) in parts.drain(..).skip(1).enumerate() {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("param {k} download: {e}"))?;
            self.params[k] = upload_f32(&v, &m.params[k].shape)?;
        }
        self.steps += 1;
        Ok(loss)
    }

    /// Download the current parameter leaves.
    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .map(|b| {
                b.to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("param download: {e}"))?
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("param to_vec: {e}"))
            })
            .collect()
    }
}

/// Serving-path forward executor: `(params…, dense, idx) → probs`.
pub struct DlrmFwd<'a> {
    arts: &'a Artifacts,
    params: Vec<xla::PjRtBuffer>,
}

impl<'a> DlrmFwd<'a> {
    /// Build with specific parameter leaves (e.g. the output of training).
    pub fn with_params(arts: &'a Artifacts, leaves: &[Vec<f32>]) -> Result<Self> {
        if leaves.len() != arts.meta.params.len() {
            bail!("expected {} leaves, got {}", arts.meta.params.len(), leaves.len());
        }
        let params = arts
            .meta
            .params
            .iter()
            .zip(leaves)
            .map(|(m, v)| upload_f32(v, &m.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(DlrmFwd { arts, params })
    }

    pub fn new(arts: &'a Artifacts) -> Result<Self> {
        let leaves = arts.init_params.clone();
        Self::with_params(arts, &leaves)
    }

    /// Predict attack probabilities for a full `fwd_batch`-sized batch.
    pub fn predict(&self, dense: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        let m = &self.arts.meta;
        let b = m.fwd_batch;
        if dense.len() != b * m.dense_dim || idx.len() != b * m.num_tables {
            bail!("fwd batch shape mismatch");
        }
        let exe = self.arts.exe("dlrm_fwd")?;
        let d = upload_f32(dense, &[b, m.dense_dim])?;
        let i = upload_i32(idx, &[b, m.num_tables])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&d);
        args.push(&i);
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("fwd execute: {e}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        let probs = tuple
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("tuple: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        Ok(probs)
    }

    /// Predict for fewer than `fwd_batch` samples by padding (serving
    /// router path; Table VI uses batch 1).
    pub fn predict_padded(&self, dense: &[f32], idx: &[i32], n: usize) -> Result<Vec<f32>> {
        let m = &self.arts.meta;
        let b = m.fwd_batch;
        if n == 0 || n > b {
            bail!("predict_padded: n={n} out of range 1..={b}");
        }
        let mut dfull = vec![0f32; b * m.dense_dim];
        let mut ifull = vec![0i32; b * m.num_tables];
        dfull[..n * m.dense_dim].copy_from_slice(dense);
        ifull[..n * m.num_tables].copy_from_slice(idx);
        let mut probs = self.predict(&dfull, &ifull)?;
        probs.truncate(n);
        Ok(probs)
    }
}

/// Standalone Eff-TT pooled-lookup executor (runtime validation +
/// microbench): `(d1, d2, d3, idx) → pooled [lookup_batch, emb_dim]`.
pub struct TtLookupExe<'a> {
    arts: &'a Artifacts,
}

impl<'a> TtLookupExe<'a> {
    pub fn new(arts: &'a Artifacts) -> Self {
        TtLookupExe { arts }
    }

    pub fn run(
        &self,
        d1: (&[f32], &[usize]),
        d2: (&[f32], &[usize]),
        d3: (&[f32], &[usize]),
        idx: &[i32],
    ) -> Result<Vec<f32>> {
        let m = &self.arts.meta;
        if idx.len() != m.lookup_batch * m.lookup_bag {
            bail!("lookup idx len {} != {}", idx.len(), m.lookup_batch * m.lookup_bag);
        }
        let exe = self.arts.exe("tt_lookup")?;
        let b1 = upload_f32(d1.0, d1.1)?;
        let b2 = upload_f32(d2.0, d2.1)?;
        let b3 = upload_f32(d3.0, d3.1)?;
        let bi = upload_i32(idx, &[m.lookup_batch, m.lookup_bag])?;
        let out = exe
            .execute_b(&[&b1, &b2, &b3, &bi])
            .map_err(|e| anyhow::anyhow!("tt_lookup execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }
}
