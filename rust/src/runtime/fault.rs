//! Deterministic chaos-injection harness (`[fault]` config section /
//! `--fault-*` CLI; off by default).
//!
//! A [`FaultPlan`] schedules four serving faults — replica panic, worker
//! stall, reply-channel sever, queue flood — two training faults —
//! per-round stragglers and a permanently dead worker — and one
//! network-tier fault — a whole-node kill (`kill_node`) that stops a
//! `net::NodeServer` mid-stream so the remote router's eviction +
//! requeue path gets chaos coverage.  Every decision
//! is a **stateless hash** of `(seed, fault kind, actor, sequence)`
//! rather than a draw from a shared sequential PRNG, so fault schedules
//! are reproducible regardless of thread interleaving: the same seed
//! injects the same faults at the same logical points, which is what
//! lets `tests/fault_equivalence.rs` pin deterministic replay and lets
//! every recovery path be exercised from a bench arm.
//!
//! The plan also keeps a **recovery event log**: injection sites and the
//! supervisor record `(kind, actor, seq)` tuples, and [`FaultPlan::events`]
//! returns them canonically sorted so two runs under one seed can be
//! compared verbatim even though threads interleave differently.
//!
//! Consumers hold an `Option<Arc<FaultPlan>>`; `None` (the
//! [`FaultCfg::plan`] result for a disabled config) means the fault
//! branches are never entered and the hot paths execute the exact
//! fault-free code.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::prng::splitmix64;

// Per-kind hash domains so e.g. stall and panic decisions for the same
// (actor, seq) are independent draws.
const K_PANIC: u64 = 0x01;
const K_STALL: u64 = 0x02;
const K_SEVER: u64 = 0x03;
const K_FLOOD: u64 = 0x04;
const K_STRAGGLE: u64 = 0x05;
const K_NODEKILL: u64 = 0x06;

/// `[fault]` section of the run config (+ the matching `--fault-*`
/// flags).  Everything defaults to off: rates 0, no deterministic kill,
/// no dead worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultCfg {
    /// Master switch (`[fault] enabled` / `--fault`).  When false,
    /// [`FaultCfg::plan`] returns `None` and no fault code runs at all.
    pub enabled: bool,
    /// Seed of the stateless fault schedule (`[fault] seed` /
    /// `--fault-seed`).
    pub seed: u64,
    /// Deterministic replica kill: panic replica `kill_replica` once it
    /// has served `kill_after` requests (first incarnation only — the
    /// respawned replica is not re-killed, so one config = one kill).
    pub kill_replica: Option<usize>,
    pub kill_after: u64,
    /// Probabilistic replica panic per batch pickup (any incarnation).
    pub panic_rate: f64,
    /// Worker stall: probability per batch pickup, stall length in ms.
    pub stall_rate: f64,
    pub stall_ms: u64,
    /// Reply-channel sever: probability per request that the replica
    /// drops the reply sender instead of answering (the client sees the
    /// request as `dropped`).
    pub sever_rate: f64,
    /// Queue flood: probability per submitted request that an attacker
    /// burst of `flood_burst` junk requests is stuffed behind it.
    pub flood_rate: f64,
    pub flood_burst: usize,
    /// Training: probability per (worker, round) that the worker misses
    /// the all-reduce deadline and is excluded from that round's
    /// weighted mean; `straggle_ms` is how late it arrives (simulated
    /// stall charged to the straggler).
    pub straggle_rate: f64,
    pub straggle_ms: u64,
    /// Training: worker that dies permanently at round `dead_round`
    /// (its shard re-routes to the surviving workers from then on).
    pub dead_worker: Option<usize>,
    pub dead_round: u64,
    /// Serving-tier node kill (`net::NodeServer`): node `kill_node`'s
    /// first generation stops dead — without replying — once it has
    /// accepted `node_kill_after` requests AND the seeded
    /// `(seed, NodeKill, node, served)` verdict lands under
    /// `node_kill_rate`.  Rate 1.0 makes the threshold deterministic;
    /// lower rates let the kill point wander (reproducibly) with the
    /// seed.  A respawned node (generation ≥ 1) is spared.
    pub kill_node: Option<usize>,
    pub node_kill_after: u64,
    pub node_kill_rate: f64,
}

impl Default for FaultCfg {
    fn default() -> Self {
        FaultCfg {
            enabled: false,
            seed: 1,
            kill_replica: None,
            kill_after: 8,
            panic_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 20,
            sever_rate: 0.0,
            flood_rate: 0.0,
            flood_burst: 4,
            straggle_rate: 0.0,
            straggle_ms: 5,
            dead_worker: None,
            dead_round: 1,
            kill_node: None,
            node_kill_after: 8,
            node_kill_rate: 1.0,
        }
    }
}

impl FaultCfg {
    /// Build the injectable plan — `None` unless `enabled`, so consumers
    /// holding `Option<Arc<FaultPlan>>` skip every fault branch on the
    /// disabled path.
    pub fn plan(&self) -> Option<Arc<FaultPlan>> {
        self.enabled.then(|| FaultPlan::new(*self))
    }

    /// The CI chaos arm: `RECAD_FAULT_SEED=<n>` selects a mild mixed
    /// fault load (one deterministic replica kill + low-rate sever /
    /// flood / stall / straggle) so the equivalence tests exercise live
    /// injection instead of only the disabled path.
    pub fn from_env() -> Option<FaultCfg> {
        let seed: u64 = std::env::var("RECAD_FAULT_SEED").ok()?.trim().parse().ok()?;
        Some(FaultCfg {
            enabled: true,
            seed,
            kill_replica: Some(0),
            kill_after: 4,
            stall_rate: 0.02,
            stall_ms: 2,
            sever_rate: 0.02,
            flood_rate: 0.02,
            flood_burst: 2,
            straggle_rate: 0.2,
            straggle_ms: 1,
            ..FaultCfg::default()
        })
    }
}

/// One entry of the recovery event log.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct FaultEvent {
    /// "panic" | "stall" | "sever" | "flood" | "respawn" | "straggle" |
    /// "dead" | "node_kill".
    pub kind: &'static str,
    /// Replica / worker index the event happened on.
    pub actor: usize,
    /// Kind-specific sequence: request seq, pickup round, served count,
    /// or respawn epoch.
    pub seq: u64,
}

/// The seeded fault schedule + recovery event log.  Shared as
/// `Arc<FaultPlan>` between the server, the supervisor, and the
/// training workers; all decision methods are `&self` and stateless.
pub struct FaultPlan {
    cfg: FaultCfg,
    log: Mutex<Vec<FaultEvent>>,
}

impl FaultPlan {
    pub fn new(cfg: FaultCfg) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { cfg, log: Mutex::new(Vec::new()) })
    }

    pub fn cfg(&self) -> &FaultCfg {
        &self.cfg
    }

    /// Uniform draw in [0, 1) fully determined by (seed, kind, actor,
    /// seq) — thread interleaving cannot perturb it.
    fn roll(&self, kind: u64, actor: u64, seq: u64) -> f64 {
        let mut s = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ kind.wrapping_mul(0xD1B5_4A32_D192_ED03)
            ^ actor.wrapping_mul(0xA24B_AED4_963E_E407)
            ^ seq.wrapping_mul(0x9FB2_1C65_1E98_DF25);
        let z = splitmix64(&mut s);
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Deterministic kill: fires once the target replica's FIRST
    /// incarnation (`epoch == 0`) has served `kill_after` requests.
    pub fn kill_now(&self, replica: usize, epoch: u64, served: u64) -> bool {
        epoch == 0
            && self.cfg.kill_replica == Some(replica)
            && served >= self.cfg.kill_after
    }

    /// Probabilistic panic per (replica, pickup round).
    pub fn panic_now(&self, replica: usize, round: u64) -> bool {
        self.cfg.panic_rate > 0.0
            && self.roll(K_PANIC, replica as u64, round) < self.cfg.panic_rate
    }

    /// Worker stall of `stall_ms` at this (replica, pickup round)?
    pub fn stall(&self, replica: usize, round: u64) -> Option<Duration> {
        (self.cfg.stall_rate > 0.0
            && self.roll(K_STALL, replica as u64, round) < self.cfg.stall_rate)
            .then(|| Duration::from_millis(self.cfg.stall_ms))
    }

    /// Sever the reply channel of request `seq`?
    pub fn sever_reply(&self, seq: u64) -> bool {
        self.cfg.sever_rate > 0.0 && self.roll(K_SEVER, 0, seq) < self.cfg.sever_rate
    }

    /// Junk-request burst to stuff behind request `seq` (0 = none).
    pub fn flood_burst(&self, seq: u64) -> usize {
        if self.cfg.flood_rate > 0.0 && self.roll(K_FLOOD, 0, seq) < self.cfg.flood_rate {
            self.cfg.flood_burst
        } else {
            0
        }
    }

    /// Does training worker `worker` miss round `round`'s all-reduce
    /// deadline?  (Exclusion from the weighted mean; its delta carries
    /// over as error feedback.)
    pub fn straggle(&self, worker: usize, round: u64) -> bool {
        self.cfg.straggle_rate > 0.0
            && self.roll(K_STRAGGLE, worker as u64, round) < self.cfg.straggle_rate
    }

    /// How late a straggler arrives (the simulated stall it pays).
    pub fn straggle_delay(&self) -> Duration {
        Duration::from_millis(self.cfg.straggle_ms)
    }

    /// Is training worker `worker` permanently dead at `round`?
    pub fn worker_dead(&self, worker: usize, round: u64) -> bool {
        self.cfg.dead_worker == Some(worker) && round >= self.cfg.dead_round
    }

    /// Serving-node kill verdict: checked by `net::NodeServer` before
    /// accepting request number `served`.  First generation only (a
    /// respawned node passes `generation == 1` and is spared), gated by
    /// the accept-count threshold, then decided by the same stateless
    /// `(seed, kind, actor, seq)` hash as every other fault — so the
    /// kill point replays bit-identically under one seed.
    pub fn node_kill_now(&self, node: u64, generation: u64, served: u64) -> bool {
        generation == 0
            && self.cfg.kill_node == Some(node as usize)
            && served >= self.cfg.node_kill_after
            && self.roll(K_NODEKILL, node, served) < self.cfg.node_kill_rate
    }

    /// Append to the recovery event log (injection sites + supervisor).
    pub fn record(&self, kind: &'static str, actor: usize, seq: u64) {
        self.log.lock().unwrap().push(FaultEvent { kind, actor, seq });
    }

    /// The recovery event log, canonically sorted — two runs under one
    /// seed must produce equal logs (deterministic replay) even though
    /// threads append in wall-clock order.
    pub fn events(&self) -> Vec<FaultEvent> {
        let mut v = self.log.lock().unwrap().clone();
        v.sort();
        v
    }

    /// Count of logged events of one kind.
    pub fn event_count(&self, kind: &str) -> usize {
        self.log.lock().unwrap().iter().filter(|e| e.kind == kind).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic() -> FaultCfg {
        FaultCfg {
            enabled: true,
            seed: 7,
            kill_replica: Some(1),
            kill_after: 3,
            panic_rate: 0.1,
            stall_rate: 0.3,
            stall_ms: 4,
            sever_rate: 0.25,
            flood_rate: 0.2,
            flood_burst: 3,
            straggle_rate: 0.5,
            straggle_ms: 2,
            dead_worker: Some(2),
            dead_round: 5,
            kill_node: Some(1),
            node_kill_after: 6,
            node_kill_rate: 1.0,
        }
    }

    #[test]
    fn disabled_cfg_builds_no_plan() {
        assert!(FaultCfg::default().plan().is_none());
        let mut c = chaotic();
        c.enabled = false;
        assert!(c.plan().is_none());
        assert!(chaotic().plan().is_some());
    }

    #[test]
    fn decisions_are_deterministic_per_seed() {
        let a = FaultPlan::new(chaotic());
        let b = FaultPlan::new(chaotic());
        for seq in 0..200u64 {
            assert_eq!(a.sever_reply(seq), b.sever_reply(seq));
            assert_eq!(a.flood_burst(seq), b.flood_burst(seq));
            for w in 0..4 {
                assert_eq!(a.straggle(w, seq), b.straggle(w, seq));
                assert_eq!(a.stall(w, seq), b.stall(w, seq));
                assert_eq!(a.panic_now(w, seq), b.panic_now(w, seq));
            }
        }
        // a different seed disagrees somewhere
        let mut other = chaotic();
        other.seed = 8;
        let c = FaultPlan::new(other);
        let diverged = (0..200u64).any(|s| a.sever_reply(s) != c.sever_reply(s));
        assert!(diverged, "seed must change the schedule");
    }

    #[test]
    fn zero_rates_never_fire_and_rates_hit_roughly_proportionally() {
        let quiet = FaultPlan::new(FaultCfg { enabled: true, ..FaultCfg::default() });
        for seq in 0..500u64 {
            assert!(!quiet.sever_reply(seq));
            assert_eq!(quiet.flood_burst(seq), 0);
            assert!(!quiet.straggle(0, seq));
            assert!(quiet.stall(0, seq).is_none());
            assert!(!quiet.panic_now(0, seq));
        }
        let p = FaultPlan::new(chaotic());
        let hits = (0..2000u64).filter(|&s| p.sever_reply(s)).count();
        // sever_rate 0.25 over 2000 draws: a very loose band
        assert!((300..700).contains(&hits), "sever hits {hits} off-rate");
    }

    #[test]
    fn kill_and_dead_worker_are_threshold_deterministic() {
        let p = FaultPlan::new(chaotic());
        assert!(!p.kill_now(1, 0, 2));
        assert!(p.kill_now(1, 0, 3));
        assert!(p.kill_now(1, 0, 99));
        assert!(!p.kill_now(0, 0, 99), "only the configured replica dies");
        assert!(!p.kill_now(1, 1, 99), "respawned incarnation is spared");
        assert!(!p.worker_dead(2, 4));
        assert!(p.worker_dead(2, 5));
        assert!(p.worker_dead(2, 100));
        assert!(!p.worker_dead(0, 100));
    }

    #[test]
    fn node_kill_is_seeded_threshold_deterministic_and_spares_respawns() {
        let p = FaultPlan::new(chaotic()); // kill_node 1, after 6, rate 1.0
        assert!(!p.node_kill_now(1, 0, 5), "fired below the accept threshold");
        assert!(p.node_kill_now(1, 0, 6), "rate-1.0 kill must fire at the threshold");
        assert!(!p.node_kill_now(0, 0, 99), "only the configured node dies");
        assert!(!p.node_kill_now(1, 1, 99), "respawned generation is spared");
        // sub-1.0 rates replay identically per seed and diverge across seeds
        let mk = |seed| {
            FaultPlan::new(FaultCfg { seed, node_kill_rate: 0.3, ..chaotic() })
        };
        let (a, b, c) = (mk(7), mk(7), mk(8));
        let fire = |p: &Arc<FaultPlan>| {
            (6..200u64).map(|s| p.node_kill_now(1, 0, s)).collect::<Vec<bool>>()
        };
        assert_eq!(fire(&a), fire(&b), "same seed, different node-kill schedule");
        assert_ne!(fire(&a), fire(&c), "seed did not perturb the node-kill schedule");
        // the new kind domain leaves existing schedules unperturbed
        let base = FaultPlan::new(FaultCfg { kill_node: None, ..chaotic() });
        for seq in 0..200u64 {
            assert_eq!(a.sever_reply(seq), base.sever_reply(seq));
            assert_eq!(a.flood_burst(seq), base.flood_burst(seq));
        }
        // defaults keep the fault off entirely
        let quiet = FaultPlan::new(FaultCfg { enabled: true, ..FaultCfg::default() });
        assert!(!quiet.node_kill_now(0, 0, 1_000_000));
    }

    #[test]
    fn event_log_sorts_canonically() {
        let p = FaultPlan::new(chaotic());
        p.record("sever", 2, 40);
        p.record("panic", 1, 3);
        p.record("respawn", 1, 1);
        p.record("sever", 0, 12);
        let ev = p.events();
        let mut sorted = ev.clone();
        sorted.sort();
        assert_eq!(ev, sorted);
        assert_eq!(p.event_count("sever"), 2);
        assert_eq!(p.event_count("respawn"), 1);
        assert_eq!(p.event_count("flood"), 0);
    }

    #[test]
    fn env_cfg_round_trips() {
        // from_env reads the process env; only assert the parse contract
        // indirectly through an explicit seed config
        let c = FaultCfg { enabled: true, seed: 99, ..FaultCfg::default() };
        let p = c.plan().unwrap();
        assert_eq!(p.cfg().seed, 99);
    }
}
