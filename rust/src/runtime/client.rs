//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`/`Sync`), so
//! the client — and everything compiled on it — is confined to the thread
//! that created it.  We expose a thread-local singleton: each coordinator
//! thread that touches PJRT lazily builds its own client, which also maps
//! naturally onto the simulated-device model (one client per worker
//! thread ≙ one device context per GPU).  Artifacts/executables must be
//! loaded on the thread that executes them.

use std::cell::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// This thread's PJRT CPU client (lazily initialized).
///
/// # Panics
/// Panics if PJRT initialization fails — there is no degraded mode.
pub fn client() -> xla::PjRtClient {
    CLIENT.with(|c| {
        c.get_or_init(|| {
            xla::PjRtClient::cpu().expect("PJRT CPU client initialization failed")
        })
        .clone()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_initializes_and_is_cpu() {
        let c = client();
        assert!(c.device_count() >= 1);
        let name = c.platform_name().to_lowercase();
        assert!(name.contains("cpu") || name.contains("host"), "{name}");
    }

    #[test]
    fn separate_threads_get_separate_clients() {
        let _a = client();
        std::thread::spawn(|| {
            let _b = client(); // must not panic or deadlock
        })
        .join()
        .unwrap();
    }
}
