//! API-compatible stand-ins for the PJRT executors, compiled when the
//! `pjrt` feature is off (the default for offline builds).  Constructors
//! and entry points return a descriptive error instead of touching PJRT,
//! so callers keep one code path and fail at runtime only if they
//! actually try to execute a compiled artifact.

use anyhow::{bail, Result};

use crate::runtime::artifact::Artifacts;

const NO_PJRT: &str = "recad was built without the `pjrt` feature; \
executing compiled artifacts requires vendoring the xla/PJRT bindings \
(add the `xla` crate as a dependency) and rebuilding with \
`--features pjrt`";

/// Stub of the fused train-step executor.
pub struct DlrmTrainStep<'a> {
    _arts: &'a Artifacts,
    pub steps: u64,
}

impl<'a> DlrmTrainStep<'a> {
    pub fn new(arts: &'a Artifacts) -> Result<Self> {
        let _ = arts;
        bail!(NO_PJRT)
    }

    pub fn step(&mut self, _dense: &[f32], _idx: &[i32], _labels: &[f32]) -> Result<f32> {
        bail!(NO_PJRT)
    }

    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        bail!(NO_PJRT)
    }
}

/// Stub of the serving-path forward executor.
pub struct DlrmFwd<'a> {
    _arts: &'a Artifacts,
}

impl<'a> DlrmFwd<'a> {
    pub fn with_params(arts: &'a Artifacts, _leaves: &[Vec<f32>]) -> Result<Self> {
        let _ = arts;
        bail!(NO_PJRT)
    }

    pub fn new(arts: &'a Artifacts) -> Result<Self> {
        let _ = arts;
        bail!(NO_PJRT)
    }

    pub fn predict(&self, _dense: &[f32], _idx: &[i32]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    pub fn predict_padded(&self, _dense: &[f32], _idx: &[i32], _n: usize) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

/// Stub of the standalone Eff-TT pooled-lookup executor.
pub struct TtLookupExe<'a> {
    _arts: &'a Artifacts,
}

impl<'a> TtLookupExe<'a> {
    pub fn new(arts: &'a Artifacts) -> Self {
        TtLookupExe { _arts: arts }
    }

    pub fn run(
        &self,
        _d1: (&[f32], &[usize]),
        _d2: (&[f32], &[usize]),
        _d3: (&[f32], &[usize]),
        _idx: &[i32],
    ) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}
