//! Evaluation metrics: classification quality (Table III/V) and the
//! throughput/latency trackers shared by the serving path and benches.

pub mod auc;
pub mod classify;

pub use auc::auc;
pub use classify::{evaluate, ClassifyReport, Confusion};
