//! ROC-AUC — the CTR-standard ranking metric backing Table V's accuracy
//! parity claims (threshold-free, robust to class imbalance).

/// Exact AUC by the rank-sum (Mann–Whitney U) formulation, with proper
/// tie handling via midranks.  O(n log n).
pub fn auc(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let n = probs.len();
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp: NaN scores sort greatest instead of panicking (a NaN
    // logit would otherwise kill a whole eval run) and ties stay exact.
    order.sort_by(|&a, &b| probs[a].total_cmp(&probs[b]));
    // midranks over tie groups
    let mut rank = vec![0.0f64; n];
    let mut i = 0usize;
    while i < n {
        let mut j = i;
        while j + 1 < n && probs[order[j + 1]] == probs[order[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            rank[order[k]] = mid;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l > 0.5).count() as f64;
    let n_neg = n as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return 0.5; // degenerate: no ranking information
    }
    let rank_sum_pos: f64 = (0..n).filter(|&k| labels[k] > 0.5).map(|k| rank[k]).sum();
    (rank_sum_pos - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking() {
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &[0.0, 0.0, 1.0, 1.0]), 1.0);
    }

    #[test]
    fn inverted_ranking() {
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &[0.0, 0.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn random_is_half() {
        // all-equal scores: every pair is a tie -> 0.5 by midranks
        assert!((auc(&[0.5; 10], &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(auc(&[0.3, 0.7], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn nan_scores_do_not_panic() {
        // regression: partial_cmp().unwrap() used to panic here
        let probs = [0.2f32, f32::NAN, 0.8, 0.4, f32::NAN];
        let labels = [0.0f32, 0.0, 1.0, 1.0, 1.0];
        let a = auc(&probs, &labels);
        assert!(a.is_finite(), "{a}");
        assert!((0.0..=1.0).contains(&a), "{a}");
        // all-NaN input also stays finite and in range
        let a = auc(&[f32::NAN; 4], &[1.0, 0.0, 1.0, 0.0]);
        assert!((0.0..=1.0).contains(&a), "{a}");
    }

    #[test]
    fn matches_brute_force() {
        let probs = [0.1f32, 0.4, 0.35, 0.8, 0.65, 0.9, 0.5, 0.2];
        let labels = [0.0f32, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        // brute force pair counting
        let mut wins = 0.0;
        let mut total = 0.0;
        for i in 0..8 {
            for j in 0..8 {
                if labels[i] > 0.5 && labels[j] < 0.5 {
                    total += 1.0;
                    if probs[i] > probs[j] {
                        wins += 1.0;
                    } else if probs[i] == probs[j] {
                        wins += 0.5;
                    }
                }
            }
        }
        assert!((auc(&probs, &labels) - wins / total).abs() < 1e-12);
    }
}
