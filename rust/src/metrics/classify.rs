//! Detection-quality metrics (paper §V-F): Accuracy, Recall, Precision,
//! F1 from a probability/label stream at a decision threshold.

/// Confusion-matrix counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn observe(&mut self, prob: f32, label: f32, threshold: f32) {
        let pred = prob > threshold;
        let truth = label > 0.5;
        match (pred, truth) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

/// Full evaluation report (one Table III row).
#[derive(Clone, Debug)]
pub struct ClassifyReport {
    pub confusion: Confusion,
    pub accuracy: f64,
    pub recall: f64,
    pub precision: f64,
    pub f1: f64,
}

/// Evaluate probabilities against labels at `threshold`.
pub fn evaluate(probs: &[f32], labels: &[f32], threshold: f32) -> ClassifyReport {
    assert_eq!(probs.len(), labels.len());
    let mut c = Confusion::default();
    for (&p, &l) in probs.iter().zip(labels) {
        c.observe(p, l, threshold);
    }
    ClassifyReport {
        confusion: c,
        accuracy: c.accuracy(),
        recall: c.recall(),
        precision: c.precision(),
        f1: c.f1(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_classifier() {
        let r = evaluate(&[0.9, 0.1, 0.8, 0.2], &[1.0, 0.0, 1.0, 0.0], 0.5);
        assert_eq!(r.accuracy, 1.0);
        assert_eq!(r.recall, 1.0);
        assert_eq!(r.f1, 1.0);
    }

    #[test]
    fn all_wrong() {
        let r = evaluate(&[0.1, 0.9], &[1.0, 0.0], 0.5);
        assert_eq!(r.accuracy, 0.0);
        assert_eq!(r.recall, 0.0);
    }

    #[test]
    fn known_confusion() {
        // tp=1 fp=1 tn=1 fn=1
        let r = evaluate(&[0.9, 0.9, 0.1, 0.1], &[1.0, 0.0, 0.0, 1.0], 0.5);
        assert_eq!(r.confusion, Confusion { tp: 1, fp: 1, tn: 1, fn_: 1 });
        assert!((r.accuracy - 0.5).abs() < 1e-12);
        assert!((r.recall - 0.5).abs() < 1e-12);
        assert!((r.precision - 0.5).abs() < 1e-12);
        assert!((r.f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn threshold_moves_tradeoff() {
        let probs = [0.3, 0.6, 0.7, 0.9];
        let labels = [0.0, 1.0, 0.0, 1.0];
        let loose = evaluate(&probs, &labels, 0.2);
        let tight = evaluate(&probs, &labels, 0.8);
        assert!(loose.recall >= tight.recall);
        assert!(tight.precision >= loose.precision);
    }
}
