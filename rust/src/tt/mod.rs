//! Native Tensor-Train embedding engine (the paper's §III, in rust).
//!
//! The PJRT runtime executes the L2-lowered model artifacts; this module is
//! the coordinator-side mirror used for (a) host-memory parameter serving,
//! (b) system-scale benches where per-op HLO dispatch would dominate, and
//! (c) the Fig. 12 ablations.  `table::EffTtTable` is validated against
//! both the python oracle (fixtures) and the PJRT `tt_lookup` artifact
//! (integration tests).

pub mod decompose;
pub mod linalg;
pub mod plain;
pub mod shapes;
pub mod table;

pub use plain::PlainTable;
pub use decompose::{tt_svd, TtSvd};
pub use shapes::TtShapes;
pub use table::{EffTtOptions, EffTtTable, TtScratch, TtStats};
