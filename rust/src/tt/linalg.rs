//! Small-matrix kernels for the TT hot path.
//!
//! TT contractions are many *tiny* GEMMs (n≈2–4, R≈8–32), so a cache-
//! blocked microkernel with the k-loop innermost-unrolled beats any
//! generic BLAS call overhead at these sizes.  All matrices are row-major
//! contiguous f32.

/// C[m,n] = A[m,k] · B[k,n]  (overwrite).
#[inline]
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    gemm_acc(a, b, c, m, k, n);
}

/// C[m,n] += A[m,k] · B[k,n].
///
/// i-k-j loop order: the innermost j-loop is a contiguous AXPY over rows of
/// B and C, which LLVM auto-vectorizes; `a[i*k+p]` is hoisted per k-step.
#[inline]
pub fn gemm_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ[k,m]ᵀ · B[k,n], i.e. A is stored [k, m] and used transposed.
#[inline]
pub fn gemm_at_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += A[m,k] · Bᵀ where B is stored [n, k] and used transposed.
#[inline]
pub fn gemm_bt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            crow[j] += dot(arow, brow);
        }
    }
}

/// C[m,n] += A[m,k] · B[k,n], k-loop unrolled ×4 — the tile inner-loop
/// microkernel of the cache-resident (hottest-first tiled) plan walk.
///
/// **Bit-identical** to [`gemm_acc`]: each output element accumulates its
/// k-terms in the same ascending order, one `+=` per term (no FMA
/// contraction, no reassociation); the unroll only widens the instruction
/// window so 4 rows of B stream per pass.  A rare zero in the unrolled
/// A-quad falls back to the guarded serial step so the `av == 0.0` skip
/// semantics match exactly.
#[inline]
pub fn gemm_acc_ku(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for j in 0..n {
                    let mut cv = crow[j];
                    cv += a0 * b0[j];
                    cv += a1 * b1[j];
                    cv += a2 * b2[j];
                    cv += a3 * b3[j];
                    crow[j] = cv;
                }
            } else {
                for q in 0..4 {
                    let av = arow[p + q];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[(p + q) * n..(p + q + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            p += 4;
        }
        for pp in k4..k {
            let av = arow[pp];
            if av == 0.0 {
                continue;
            }
            let brow = &b[pp * n..(pp + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ·B (A stored [k,m]), k-loop unrolled ×4 — the tiled
/// backward's chain-product microkernel (dD3 / dD2 hops).
///
/// **Bit-identical** to [`gemm_at_acc`]: per output element the k-terms
/// accumulate in the same ascending order with one `+=` per term; a zero
/// in the unrolled quad falls back to the guarded serial step so the skip
/// semantics match exactly.
#[inline]
pub fn gemm_at_tiled(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let mut p = 0;
    while p < k4 {
        let a0 = &a[p * m..(p + 1) * m];
        let a1 = &a[(p + 1) * m..(p + 2) * m];
        let a2 = &a[(p + 2) * m..(p + 3) * m];
        let a3 = &a[(p + 3) * m..(p + 4) * m];
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                for j in 0..n {
                    let mut cv = crow[j];
                    cv += x0 * b0[j];
                    cv += x1 * b1[j];
                    cv += x2 * b2[j];
                    cv += x3 * b3[j];
                    crow[j] = cv;
                }
            } else {
                for (xv, brow) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if xv == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += xv * bv;
                    }
                }
            }
        }
        p += 4;
    }
    for pp in k4..k {
        let arow = &a[pp * m..(pp + 1) * m];
        let brow = &b[pp * n..(pp + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Output-column lane width of the portable wide kernels: 8 f32s = one
/// AVX register (two NEON registers).  Fixed-trip loops over local arrays
/// of this width give LLVM full-width vector ops without `std::arch`.
pub const LANES: usize = 8;

/// Wide-lane [`gemm_acc_ku`]: the same k-unrolled tile microkernel with
/// the j-loop advanced `LANES` output columns at a time.  Each output
/// element still accumulates its four k-terms in ascending order with one
/// `+=` per term (no FMA, no reassociation) — lanes only change *which
/// elements step together*, never any element's op sequence — so the
/// result is **bit-identical** to [`gemm_acc_ku`] (hence to [`gemm_acc`]).
/// With the off-by-default `simd` cargo feature on x86_64, an AVX
/// `std::arch` path is selected at runtime; it uses mul-then-add (never
/// FMA), which is IEEE-identical per lane to the scalar sequence.
#[inline]
pub fn gemm_acc_kuw(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::is_x86_feature_detected!("avx") {
        // SAFETY: AVX availability checked on the line above.
        // lint:allow(D6) AVX dispatch guarded by is_x86_feature_detected
        unsafe { gemm_acc_ku_avx(a, b, c, m, k, n) };
        return;
    }
    gemm_acc_ku_wide(a, b, c, m, k, n);
}

fn gemm_acc_ku_wide(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let nw = n / LANES * LANES;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                let mut j = 0;
                while j < nw {
                    let mut cv = [0.0f32; LANES];
                    cv.copy_from_slice(&crow[j..j + LANES]);
                    for l in 0..LANES {
                        cv[l] += a0 * b0[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += a1 * b1[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += a2 * b2[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += a3 * b3[j + l];
                    }
                    crow[j..j + LANES].copy_from_slice(&cv);
                    j += LANES;
                }
                for jj in nw..n {
                    let mut cv = crow[jj];
                    cv += a0 * b0[jj];
                    cv += a1 * b1[jj];
                    cv += a2 * b2[jj];
                    cv += a3 * b3[jj];
                    crow[jj] = cv;
                }
            } else {
                for q in 0..4 {
                    let av = arow[p + q];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[(p + q) * n..(p + q + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            p += 4;
        }
        for pp in k4..k {
            let av = arow[pp];
            if av == 0.0 {
                continue;
            }
            let brow = &b[pp * n..(pp + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx")]
// lint:allow(D6) target_feature fn: callers prove AVX before entry
unsafe fn gemm_acc_ku_avx(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let nw = n / 8 * 8;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                let (v0, v1, v2, v3) = (
                    _mm256_set1_ps(a0),
                    _mm256_set1_ps(a1),
                    _mm256_set1_ps(a2),
                    _mm256_set1_ps(a3),
                );
                let mut j = 0;
                while j < nw {
                    let mut cv = _mm256_loadu_ps(crow.as_ptr().add(j));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v0, _mm256_loadu_ps(b0.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v1, _mm256_loadu_ps(b1.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v2, _mm256_loadu_ps(b2.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v3, _mm256_loadu_ps(b3.as_ptr().add(j))));
                    _mm256_storeu_ps(crow.as_mut_ptr().add(j), cv);
                    j += 8;
                }
                for jj in nw..n {
                    let mut cv = crow[jj];
                    cv += a0 * b0[jj];
                    cv += a1 * b1[jj];
                    cv += a2 * b2[jj];
                    cv += a3 * b3[jj];
                    crow[jj] = cv;
                }
            } else {
                for q in 0..4 {
                    let av = arow[p + q];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[(p + q) * n..(p + q + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            p += 4;
        }
        for pp in k4..k {
            let av = arow[pp];
            if av == 0.0 {
                continue;
            }
            let brow = &b[pp * n..(pp + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Wide-lane [`gemm_at_tiled`]: see [`gemm_acc_kuw`] for the lane/bit-
/// identity argument; this is the Aᵀ-layout twin used by the tiled
/// backward's chain hops.  **Bit-identical** to [`gemm_at_tiled`] (hence
/// to [`gemm_at_acc`]).
#[inline]
pub fn gemm_at_tiledw(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::is_x86_feature_detected!("avx") {
        // SAFETY: AVX availability checked on the line above.
        // lint:allow(D6) AVX dispatch guarded by is_x86_feature_detected
        unsafe { gemm_at_tiled_avx(a, b, c, m, k, n) };
        return;
    }
    gemm_at_tiled_wide(a, b, c, m, k, n);
}

fn gemm_at_tiled_wide(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let nw = n / LANES * LANES;
    let mut p = 0;
    while p < k4 {
        let a0 = &a[p * m..(p + 1) * m];
        let a1 = &a[(p + 1) * m..(p + 2) * m];
        let a2 = &a[(p + 2) * m..(p + 3) * m];
        let a3 = &a[(p + 3) * m..(p + 4) * m];
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                let mut j = 0;
                while j < nw {
                    let mut cv = [0.0f32; LANES];
                    cv.copy_from_slice(&crow[j..j + LANES]);
                    for l in 0..LANES {
                        cv[l] += x0 * b0[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += x1 * b1[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += x2 * b2[j + l];
                    }
                    for l in 0..LANES {
                        cv[l] += x3 * b3[j + l];
                    }
                    crow[j..j + LANES].copy_from_slice(&cv);
                    j += LANES;
                }
                for jj in nw..n {
                    let mut cv = crow[jj];
                    cv += x0 * b0[jj];
                    cv += x1 * b1[jj];
                    cv += x2 * b2[jj];
                    cv += x3 * b3[jj];
                    crow[jj] = cv;
                }
            } else {
                for (xv, brow) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if xv == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += xv * bv;
                    }
                }
            }
        }
        p += 4;
    }
    for pp in k4..k {
        let arow = &a[pp * m..(pp + 1) * m];
        let brow = &b[pp * n..(pp + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx")]
// lint:allow(D6) target_feature fn: callers prove AVX before entry
unsafe fn gemm_at_tiled_avx(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let nw = n / 8 * 8;
    let mut p = 0;
    while p < k4 {
        let a0 = &a[p * m..(p + 1) * m];
        let a1 = &a[(p + 1) * m..(p + 2) * m];
        let a2 = &a[(p + 2) * m..(p + 3) * m];
        let a3 = &a[(p + 3) * m..(p + 4) * m];
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                let (v0, v1, v2, v3) = (
                    _mm256_set1_ps(x0),
                    _mm256_set1_ps(x1),
                    _mm256_set1_ps(x2),
                    _mm256_set1_ps(x3),
                );
                let mut j = 0;
                while j < nw {
                    let mut cv = _mm256_loadu_ps(crow.as_ptr().add(j));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v0, _mm256_loadu_ps(b0.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v1, _mm256_loadu_ps(b1.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v2, _mm256_loadu_ps(b2.as_ptr().add(j))));
                    cv = _mm256_add_ps(cv, _mm256_mul_ps(v3, _mm256_loadu_ps(b3.as_ptr().add(j))));
                    _mm256_storeu_ps(crow.as_mut_ptr().add(j), cv);
                    j += 8;
                }
                for jj in nw..n {
                    let mut cv = crow[jj];
                    cv += x0 * b0[jj];
                    cv += x1 * b1[jj];
                    cv += x2 * b2[jj];
                    cv += x3 * b3[jj];
                    crow[jj] = cv;
                }
            } else {
                for (xv, brow) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if xv == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += xv * bv;
                    }
                }
            }
        }
        p += 4;
    }
    for pp in k4..k {
        let arow = &a[pp * m..(pp + 1) * m];
        let brow = &b[pp * n..(pp + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Column-restricted Aᵀ·B: `block[m, j1-j0] += Aᵀ[k,m]ᵀ · B[k, j0..j1]`,
/// where A is stored [k, m] and `block` is a private dense buffer for the
/// column range.  The k-loop is outermost and ascending — exactly
/// [`gemm_at_acc`]'s per-element accumulation order — so stitching column
/// blocks back together reproduces the serial result bit-for-bit.  This is
/// the worker kernel behind `exec::par_gemm_at_overwrite`.
#[inline]
pub fn gemm_at_block(
    a: &[f32],
    b: &[f32],
    block: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    j0: usize,
    j1: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert!(j0 < j1 && j1 <= n);
    let bw = j1 - j0;
    debug_assert_eq!(block.len(), m * bw);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n + j0..p * n + j1];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut block[i * bw..(i + 1) * bw];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Dense dot product with 4-way unrolling.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += x (AXPY with alpha=1).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, &b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, &b) in y.iter_mut().zip(x) {
        *a += alpha * b;
    }
}

// ---------------------------------------------------------------------------
// Quantized operands: int8 (symmetric per-block scale) and f16 (bit-cast
// u16) views, dequantized element-by-element *inside* the kernel loops —
// the quantized TT serving path never materializes an f32 copy of a core
// slice larger than the [n1, R] first-hop seed.
// ---------------------------------------------------------------------------

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (hand-rolled; no
/// `half` crate in offline builds).  Handles subnormals, ±inf and NaN.
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // inf / NaN: keep NaN-ness via a quiet payload bit
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m;
    }
    let e = exp - 127 + 15; // re-biased f16 exponent
    if e >= 31 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal: shift the implicit-1 mantissa down, round to even
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let mut v = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        if rem > half || (rem == half && (v & 1) == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    // normal: round the 23-bit mantissa to 10 bits, nearest-even; a
    // rounding carry into the exponent field is correct (may hit inf)
    let mut v = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (v & 1) == 1) {
        v += 1;
    }
    sign | v as u16
}

/// IEEE 754 binary16 bits → f32 (exact; every f16 value is representable).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0 {
        if mant == 0 {
            return f32::from_bits(sign); // ±0
        }
        // subnormal: renormalize into the f32 exponent range
        let mut e = 113u32; // 127 - 15 + 1
        let mut m = mant;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        return f32::from_bits(sign | (e << 23) | ((m & 0x03ff) << 13));
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13)); // inf/NaN
    }
    f32::from_bits(sign | ((exp + 127 - 15) << 23) | (mant << 13))
}

/// Symmetric per-block int8 scale: `max|v| / 127`, or 1.0 for an all-zero
/// block so zeros round-trip to exact zeros.
#[inline]
pub fn i8_scale(block: &[f32]) -> f32 {
    let max = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max > 0.0 {
        max / 127.0
    } else {
        1.0
    }
}

/// Quantize `block` into `out` with a symmetric scale (see [`i8_scale`]).
#[inline]
pub fn quantize_i8(block: &[f32], scale: f32, out: &mut [i8]) {
    debug_assert_eq!(block.len(), out.len());
    for (o, &v) in out.iter_mut().zip(block) {
        *o = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Read-only quantized operand, dequantized per element at the point of
/// use inside a kernel loop.
pub trait Dequant: Copy {
    /// Dequantized element at flat index `i`.
    fn at(&self, i: usize) -> f32;
    /// Number of elements in the view.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Expand the whole view into `out` — reserved for *tiny* operands
    /// (e.g. the [n1, R] slice seeding a TT prefix product).
    fn dequant_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len(), "dequant_into: length mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.at(i);
        }
    }
}

/// int8 block with one symmetric scale.
#[derive(Clone, Copy)]
pub struct QI8<'a> {
    pub q: &'a [i8],
    pub scale: f32,
}

impl Dequant for QI8<'_> {
    #[inline(always)]
    fn at(&self, i: usize) -> f32 {
        self.q[i] as f32 * self.scale
    }
    #[inline(always)]
    fn len(&self) -> usize {
        self.q.len()
    }
}

/// f16 block stored as raw bits.
#[derive(Clone, Copy)]
pub struct QF16<'a> {
    pub h: &'a [u16],
}

impl Dequant for QF16<'_> {
    #[inline(always)]
    fn at(&self, i: usize) -> f32 {
        f16_bits_to_f32(self.h[i])
    }
    #[inline(always)]
    fn len(&self) -> usize {
        self.h.len()
    }
}

/// [`gemm_acc`] with a quantized B, dequantized inside the j-loop.  Same
/// i-k-j order and `av == 0.0` skip as the f32 kernel.
#[inline]
pub fn gemm_acc_q<B: Dequant>(a: &[f32], b: B, c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let boff = p * n;
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += av * b.at(boff + j);
            }
        }
    }
}

/// [`gemm_acc_ku`] with a quantized B — the hop-2 tile microkernel of the
/// quantized serving walk.  Same quad structure, zero-skip fallback and
/// per-element accumulation order as the f32 kernel; B values are
/// dequantized at the point of use inside the j-loop.
#[inline]
pub fn gemm_acc_ku_q<B: Dequant>(a: &[f32], b: B, c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                let (o0, o1, o2, o3) = (p * n, (p + 1) * n, (p + 2) * n, (p + 3) * n);
                for (j, cv) in crow.iter_mut().enumerate() {
                    let mut v = *cv;
                    v += a0 * b.at(o0 + j);
                    v += a1 * b.at(o1 + j);
                    v += a2 * b.at(o2 + j);
                    v += a3 * b.at(o3 + j);
                    *cv = v;
                }
            } else {
                for q in 0..4 {
                    let av = arow[p + q];
                    if av == 0.0 {
                        continue;
                    }
                    let boff = (p + q) * n;
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv += av * b.at(boff + j);
                    }
                }
            }
            p += 4;
        }
        for pp in k4..k {
            let av = arow[pp];
            if av == 0.0 {
                continue;
            }
            let boff = pp * n;
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += av * b.at(boff + j);
            }
        }
    }
}

/// [`gemm_at_tiled`] with a quantized A (the [k,m]-stored core operand of
/// the chain hops), dequantized at the point of use.  The zero-skip guard
/// tests the *dequantized* value, matching the f32 kernel's semantics on
/// the same numbers.
#[inline]
pub fn gemm_at_tiled_q<A: Dequant>(a: A, b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let mut p = 0;
    while p < k4 {
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let (x0, x1, x2, x3) = (
                a.at(p * m + i),
                a.at((p + 1) * m + i),
                a.at((p + 2) * m + i),
                a.at((p + 3) * m + i),
            );
            let crow = &mut c[i * n..(i + 1) * n];
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                for j in 0..n {
                    let mut cv = crow[j];
                    cv += x0 * b0[j];
                    cv += x1 * b1[j];
                    cv += x2 * b2[j];
                    cv += x3 * b3[j];
                    crow[j] = cv;
                }
            } else {
                for (xv, brow) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if xv == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += xv * bv;
                    }
                }
            }
        }
        p += 4;
    }
    for pp in k4..k {
        let brow = &b[pp * n..(pp + 1) * n];
        for i in 0..m {
            let av = a.at(pp * m + i);
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check_cases};
    use crate::util::prng::Rng;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        check_cases("gemm", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(8) + 1,
                rng.usize_below(8) + 1,
                rng.usize_below(8) + 1,
            );
            let a = rand_vec(rng, m * k);
            let b = rand_vec(rng, k * n);
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n);
            assert_allclose(&c, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_at_matches() {
        check_cases("gemm_at", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
            );
            let at = rand_vec(rng, k * m); // stored [k, m]
            let b = rand_vec(rng, k * n);
            // materialize A = atᵀ  [m, k]
            let mut a = vec![0.0; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = at[p * m + i];
                }
            }
            let mut c1 = vec![0.0; m * n];
            gemm_at_acc(&at, &b, &mut c1, m, k, n);
            assert_allclose(&c1, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_bt_matches() {
        check_cases("gemm_bt", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
            );
            let a = rand_vec(rng, m * k);
            let bt = rand_vec(rng, n * k); // stored [n, k]
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut c1 = vec![0.0; m * n];
            gemm_bt_acc(&a, &bt, &mut c1, m, k, n);
            assert_allclose(&c1, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_at_block_stitches_to_full() {
        check_cases("gemm_at_block", 20, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 2,
                rng.usize_below(12) + 1,
                rng.usize_below(8) + 2,
            );
            let at = rand_vec(rng, k * m);
            let b = rand_vec(rng, k * n);
            let mut full = vec![0.0; m * n];
            gemm_at_acc(&at, &b, &mut full, m, k, n);
            // compute in two column blocks and stitch
            let split = n / 2 + 1;
            let mut stitched = vec![0.0; m * n];
            for (j0, j1) in [(0, split), (split, n)] {
                if j0 >= j1 {
                    continue;
                }
                let bw = j1 - j0;
                let mut block = vec![0.0; m * bw];
                gemm_at_block(&at, &b, &mut block, m, k, n, j0, j1);
                for i in 0..m {
                    stitched[i * n + j0..i * n + j1]
                        .copy_from_slice(&block[i * bw..(i + 1) * bw]);
                }
            }
            // bit-identical, not just close
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&full), bits(&stitched));
        });
    }

    #[test]
    fn gemm_acc_ku_bit_identical_to_gemm_acc() {
        check_cases("gemm_ku", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(10) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(10) + 1,
            );
            let mut a = rand_vec(rng, m * k);
            if case % 3 == 0 && !a.is_empty() {
                // exercise the zero-skip fallback inside an unrolled quad
                let z = rng.usize_below(a.len());
                a[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_ku = c_ref.clone();
            gemm_acc(&a, &b, &mut c_ref, m, k, n);
            gemm_acc_ku(&a, &b, &mut c_ku, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_ku), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn gemm_at_tiled_bit_identical_to_gemm_at_acc() {
        check_cases("gemm_at_tiled", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(10) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(10) + 1,
            );
            let mut at = rand_vec(rng, k * m);
            if case % 3 == 0 && !at.is_empty() {
                let z = rng.usize_below(at.len());
                at[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_t = c_ref.clone();
            gemm_at_acc(&at, &b, &mut c_ref, m, k, n);
            gemm_at_tiled(&at, &b, &mut c_t, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_t), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn dot_unrolled() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..13).map(|i| (i * i * 2) as f32).sum();
        assert!((dot(&a, &b) - expect).abs() < 1e-3);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![2.0, 4.0]);
    }

    #[test]
    fn gemm_acc_kuw_bit_identical_to_gemm_acc_ku() {
        // n up to 2.5×LANES so both the lane body and the scalar column
        // tail run; zero injection exercises the quad fallback.
        check_cases("gemm_kuw", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(8) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(2 * LANES + 5) + 1,
            );
            let mut a = rand_vec(rng, m * k);
            if case % 3 == 0 && !a.is_empty() {
                let z = rng.usize_below(a.len());
                a[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_w = c_ref.clone();
            gemm_acc_ku(&a, &b, &mut c_ref, m, k, n);
            gemm_acc_kuw(&a, &b, &mut c_w, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_w), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn gemm_at_tiledw_bit_identical_to_gemm_at_tiled() {
        check_cases("gemm_at_tiledw", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(8) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(2 * LANES + 5) + 1,
            );
            let mut at = rand_vec(rng, k * m);
            if case % 3 == 0 && !at.is_empty() {
                let z = rng.usize_below(at.len());
                at[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_w = c_ref.clone();
            gemm_at_tiled(&at, &b, &mut c_ref, m, k, n);
            gemm_at_tiledw(&at, &b, &mut c_w, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_w), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn f16_roundtrip_and_specials() {
        // every exactly-representable value survives the round trip
        for v in [0.0f32, -0.0, 1.0, -1.5, 0.09997559, 65504.0, -65504.0] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let err = (back - v).abs();
            assert!(err <= v.abs() * 1e-3, "{v} -> {back}");
        }
        assert_eq!(f32_to_f16_bits(0.0).to_be_bytes(), [0, 0]);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow saturates to inf; tiny values flush to signed zero
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e-9)).to_bits(), (-0.0f32).to_bits());
        // subnormal range stays close in relative terms
        let v = 3.1e-5f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(v));
        assert!((back - v).abs() < 1e-6, "{v} -> {back}");
    }

    #[test]
    fn f16_random_roundtrip_relative_error() {
        check_cases("f16_roundtrip", 200, |rng, _| {
            let v = rng.normal_f32(0.0, 10.0);
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            if v.abs() >= 6.2e-5 {
                // binary16 has 11 significand bits -> rel err <= 2^-11
                assert!((back - v).abs() <= v.abs() * 4.9e-4, "{v} -> {back}");
            } else {
                // subnormal range: half an ulp of 2^-24 absolute
                assert!((back - v).abs() <= 6.2e-8, "{v} -> {back}");
            }
        });
    }

    /// Quantized kernels must equal their f32 twin run on the *dequantized*
    /// operand bit-for-bit: dequant-at-point-of-use may not reorder or
    /// contract any arithmetic.
    #[test]
    fn quantized_kernels_match_dequantized_reference() {
        check_cases("gemm_q", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(8) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(10) + 1,
            );
            let mut a = rand_vec(rng, m * k);
            if case % 3 == 0 && !a.is_empty() {
                let z = rng.usize_below(a.len());
                a[z] = 0.0;
            }
            let bf = rand_vec(rng, k * n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

            // int8 view vs f32 kernels on the dequantized block
            let scale = i8_scale(&bf);
            let mut q = vec![0i8; bf.len()];
            quantize_i8(&bf, scale, &mut q);
            let qv = QI8 { q: &q, scale };
            let deq: Vec<f32> = (0..bf.len()).map(|i| qv.at(i)).collect();
            let c0 = rand_vec(rng, m * n);
            let (mut c_ref, mut c_q) = (c0.clone(), c0.clone());
            gemm_acc(&a, &deq, &mut c_ref, m, k, n);
            gemm_acc_q(&a, qv, &mut c_q, m, k, n);
            assert_eq!(bits(&c_ref), bits(&c_q), "acc_q i8 m={m} k={k} n={n}");
            let (mut c_ref, mut c_q) = (c0.clone(), c0.clone());
            gemm_acc_ku(&a, &deq, &mut c_ref, m, k, n);
            gemm_acc_ku_q(&a, qv, &mut c_q, m, k, n);
            assert_eq!(bits(&c_ref), bits(&c_q), "ku_q i8 m={m} k={k} n={n}");

            // f16 view, same contract
            let h: Vec<u16> = bf.iter().map(|&v| f32_to_f16_bits(v)).collect();
            let hv = QF16 { h: &h };
            let deq: Vec<f32> = (0..bf.len()).map(|i| hv.at(i)).collect();
            let (mut c_ref, mut c_q) = (c0.clone(), c0.clone());
            gemm_acc_ku(&a, &deq, &mut c_ref, m, k, n);
            gemm_acc_ku_q(&a, hv, &mut c_q, m, k, n);
            assert_eq!(bits(&c_ref), bits(&c_q), "ku_q f16 m={m} k={k} n={n}");

            // Aᵀ chain kernel with the quantized operand on the A side
            let atf = rand_vec(rng, k * m);
            let scale = i8_scale(&atf);
            let mut qa = vec![0i8; atf.len()];
            quantize_i8(&atf, scale, &mut qa);
            let qav = QI8 { q: &qa, scale };
            let deq_a: Vec<f32> = (0..atf.len()).map(|i| qav.at(i)).collect();
            let (mut c_ref, mut c_q) = (c0.clone(), c0);
            gemm_at_tiled(&deq_a, &bf, &mut c_ref, m, k, n);
            gemm_at_tiled_q(qav, &bf, &mut c_q, m, k, n);
            assert_eq!(bits(&c_ref), bits(&c_q), "at_tiled_q m={m} k={k} n={n}");
        });
    }

    #[test]
    fn i8_scale_zero_block_roundtrips_zeros() {
        let z = vec![0.0f32; 9];
        let s = i8_scale(&z);
        assert_eq!(s, 1.0);
        let mut q = vec![0i8; 9];
        quantize_i8(&z, s, &mut q);
        let v = QI8 { q: &q, scale: s };
        for i in 0..9 {
            assert_eq!(v.at(i).to_bits(), 0.0f32.to_bits());
        }
    }
}
