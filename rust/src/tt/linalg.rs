//! Small-matrix kernels for the TT hot path.
//!
//! TT contractions are many *tiny* GEMMs (n≈2–4, R≈8–32), so a cache-
//! blocked microkernel with the k-loop innermost-unrolled beats any
//! generic BLAS call overhead at these sizes.  All matrices are row-major
//! contiguous f32.

/// C[m,n] = A[m,k] · B[k,n]  (overwrite).
#[inline]
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    gemm_acc(a, b, c, m, k, n);
}

/// C[m,n] += A[m,k] · B[k,n].
///
/// i-k-j loop order: the innermost j-loop is a contiguous AXPY over rows of
/// B and C, which LLVM auto-vectorizes; `a[i*k+p]` is hoisted per k-step.
#[inline]
pub fn gemm_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ[k,m]ᵀ · B[k,n], i.e. A is stored [k, m] and used transposed.
#[inline]
pub fn gemm_at_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += A[m,k] · Bᵀ where B is stored [n, k] and used transposed.
#[inline]
pub fn gemm_bt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            crow[j] += dot(arow, brow);
        }
    }
}

/// C[m,n] += A[m,k] · B[k,n], k-loop unrolled ×4 — the tile inner-loop
/// microkernel of the cache-resident (hottest-first tiled) plan walk.
///
/// **Bit-identical** to [`gemm_acc`]: each output element accumulates its
/// k-terms in the same ascending order, one `+=` per term (no FMA
/// contraction, no reassociation); the unroll only widens the instruction
/// window so 4 rows of B stream per pass.  A rare zero in the unrolled
/// A-quad falls back to the guarded serial step so the `av == 0.0` skip
/// semantics match exactly.
#[inline]
pub fn gemm_acc_ku(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                let b0 = &b[p * n..(p + 1) * n];
                let b1 = &b[(p + 1) * n..(p + 2) * n];
                let b2 = &b[(p + 2) * n..(p + 3) * n];
                let b3 = &b[(p + 3) * n..(p + 4) * n];
                for j in 0..n {
                    let mut cv = crow[j];
                    cv += a0 * b0[j];
                    cv += a1 * b1[j];
                    cv += a2 * b2[j];
                    cv += a3 * b3[j];
                    crow[j] = cv;
                }
            } else {
                for q in 0..4 {
                    let av = arow[p + q];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[(p + q) * n..(p + q + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            p += 4;
        }
        for pp in k4..k {
            let av = arow[pp];
            if av == 0.0 {
                continue;
            }
            let brow = &b[pp * n..(pp + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += Aᵀ·B (A stored [k,m]), k-loop unrolled ×4 — the tiled
/// backward's chain-product microkernel (dD3 / dD2 hops).
///
/// **Bit-identical** to [`gemm_at_acc`]: per output element the k-terms
/// accumulate in the same ascending order with one `+=` per term; a zero
/// in the unrolled quad falls back to the guarded serial step so the skip
/// semantics match exactly.
#[inline]
pub fn gemm_at_tiled(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    let mut p = 0;
    while p < k4 {
        let a0 = &a[p * m..(p + 1) * m];
        let a1 = &a[(p + 1) * m..(p + 2) * m];
        let a2 = &a[(p + 2) * m..(p + 3) * m];
        let a3 = &a[(p + 3) * m..(p + 4) * m];
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for i in 0..m {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = &mut c[i * n..(i + 1) * n];
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                for j in 0..n {
                    let mut cv = crow[j];
                    cv += x0 * b0[j];
                    cv += x1 * b1[j];
                    cv += x2 * b2[j];
                    cv += x3 * b3[j];
                    crow[j] = cv;
                }
            } else {
                for (xv, brow) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if xv == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += xv * bv;
                    }
                }
            }
        }
        p += 4;
    }
    for pp in k4..k {
        let arow = &a[pp * m..(pp + 1) * m];
        let brow = &b[pp * n..(pp + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Column-restricted Aᵀ·B: `block[m, j1-j0] += Aᵀ[k,m]ᵀ · B[k, j0..j1]`,
/// where A is stored [k, m] and `block` is a private dense buffer for the
/// column range.  The k-loop is outermost and ascending — exactly
/// [`gemm_at_acc`]'s per-element accumulation order — so stitching column
/// blocks back together reproduces the serial result bit-for-bit.  This is
/// the worker kernel behind `exec::par_gemm_at_overwrite`.
#[inline]
pub fn gemm_at_block(
    a: &[f32],
    b: &[f32],
    block: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    j0: usize,
    j1: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert!(j0 < j1 && j1 <= n);
    let bw = j1 - j0;
    debug_assert_eq!(block.len(), m * bw);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n + j0..p * n + j1];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut block[i * bw..(i + 1) * bw];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Dense dot product with 4-way unrolling.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += x (AXPY with alpha=1).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, &b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, &b) in y.iter_mut().zip(x) {
        *a += alpha * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check_cases};
    use crate::util::prng::Rng;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        check_cases("gemm", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(8) + 1,
                rng.usize_below(8) + 1,
                rng.usize_below(8) + 1,
            );
            let a = rand_vec(rng, m * k);
            let b = rand_vec(rng, k * n);
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n);
            assert_allclose(&c, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_at_matches() {
        check_cases("gemm_at", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
            );
            let at = rand_vec(rng, k * m); // stored [k, m]
            let b = rand_vec(rng, k * n);
            // materialize A = atᵀ  [m, k]
            let mut a = vec![0.0; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = at[p * m + i];
                }
            }
            let mut c1 = vec![0.0; m * n];
            gemm_at_acc(&at, &b, &mut c1, m, k, n);
            assert_allclose(&c1, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_bt_matches() {
        check_cases("gemm_bt", 50, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
                rng.usize_below(6) + 1,
            );
            let a = rand_vec(rng, m * k);
            let bt = rand_vec(rng, n * k); // stored [n, k]
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut c1 = vec![0.0; m * n];
            gemm_bt_acc(&a, &bt, &mut c1, m, k, n);
            assert_allclose(&c1, &naive_gemm(&a, &b, m, k, n), 1e-5, 1e-5);
        });
    }

    #[test]
    fn gemm_at_block_stitches_to_full() {
        check_cases("gemm_at_block", 20, |rng, _| {
            let (m, k, n) = (
                rng.usize_below(6) + 2,
                rng.usize_below(12) + 1,
                rng.usize_below(8) + 2,
            );
            let at = rand_vec(rng, k * m);
            let b = rand_vec(rng, k * n);
            let mut full = vec![0.0; m * n];
            gemm_at_acc(&at, &b, &mut full, m, k, n);
            // compute in two column blocks and stitch
            let split = n / 2 + 1;
            let mut stitched = vec![0.0; m * n];
            for (j0, j1) in [(0, split), (split, n)] {
                if j0 >= j1 {
                    continue;
                }
                let bw = j1 - j0;
                let mut block = vec![0.0; m * bw];
                gemm_at_block(&at, &b, &mut block, m, k, n, j0, j1);
                for i in 0..m {
                    stitched[i * n + j0..i * n + j1]
                        .copy_from_slice(&block[i * bw..(i + 1) * bw]);
                }
            }
            // bit-identical, not just close
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&full), bits(&stitched));
        });
    }

    #[test]
    fn gemm_acc_ku_bit_identical_to_gemm_acc() {
        check_cases("gemm_ku", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(10) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(10) + 1,
            );
            let mut a = rand_vec(rng, m * k);
            if case % 3 == 0 && !a.is_empty() {
                // exercise the zero-skip fallback inside an unrolled quad
                let z = rng.usize_below(a.len());
                a[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_ku = c_ref.clone();
            gemm_acc(&a, &b, &mut c_ref, m, k, n);
            gemm_acc_ku(&a, &b, &mut c_ku, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_ku), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn gemm_at_tiled_bit_identical_to_gemm_at_acc() {
        check_cases("gemm_at_tiled", 40, |rng, case| {
            let (m, k, n) = (
                rng.usize_below(10) + 1,
                rng.usize_below(13) + 1,
                rng.usize_below(10) + 1,
            );
            let mut at = rand_vec(rng, k * m);
            if case % 3 == 0 && !at.is_empty() {
                let z = rng.usize_below(at.len());
                at[z] = 0.0;
            }
            let b = rand_vec(rng, k * n);
            let mut c_ref = rand_vec(rng, m * n);
            let mut c_t = c_ref.clone();
            gemm_at_acc(&at, &b, &mut c_ref, m, k, n);
            gemm_at_tiled(&at, &b, &mut c_t, m, k, n);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_ref), bits(&c_t), "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn dot_unrolled() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..13).map(|i| (i * i * 2) as f32).sum();
        assert!((dot(&a, &b) - expect).abs() < 1e-3);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![2.0, 4.0]);
    }
}
