//! TT shape planning — MUST mirror `python/compile/tt_spec.py` exactly so
//! artifacts lowered by L2 and the native engine index cores identically.
//!
//! A plain table `W ∈ R^{M×N}` factors into three cores (paper Eq. 2):
//!
//! ```text
//!   D1 [m1, n1, R]      D2 [R, m2, n2, R]      D3 [R, m3, n3]
//! ```
//!
//! with row index split (Eq. 5, row-major): `i1 = i/(m2·m3)`,
//! `i2 = (i/m3)%m2`, `i3 = i%m3`, and the Algorithm-1 reuse prefix
//! `p = i / m3` (shared ⇒ the partial product D1[i1]·D2[:,i2] is shared).

/// Split `x` into three factors as close to cube-root as possible
/// (ascending). Mirrors `tt_spec.factorize3`.
pub fn factorize3(x: u64) -> [u64; 3] {
    assert!(x > 0, "cannot factorize 0");
    let mut best = [1, 1, x];
    let mut best_cost = spread(&best);
    let cbrt = (x as f64).powf(1.0 / 3.0).round() as u64 + 2;
    for a in 1..=cbrt {
        if x % a != 0 {
            continue;
        }
        let rem = x / a;
        let sq = (rem as f64).sqrt() as u64 + 1;
        for b in a..=sq {
            if rem % b != 0 {
                continue;
            }
            let mut cand = [a, b, rem / b];
            cand.sort_unstable();
            let cost = spread(&cand);
            if cost < best_cost {
                best = cand;
                best_cost = cost;
            }
        }
    }
    best
}

fn spread(f: &[u64; 3]) -> u64 {
    f[2] - f[0]
}

/// Smallest `M >= rows` factoring into three balanced terms.
/// Mirrors `tt_spec.padded_rows`.
pub fn padded_rows(rows: u64) -> u64 {
    let mut m = rows;
    loop {
        let f = factorize3(m);
        if f[2] <= 4 * f[0] || f[2] <= 64 {
            return m;
        }
        m += 1;
    }
}

/// Complete shape plan for one Eff-TT table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TtShapes {
    /// Logical (pre-padding) row count.
    pub rows: u64,
    /// Embedding dimension N = n1·n2·n3.
    pub dim: usize,
    pub m: [u64; 3],
    pub n: [usize; 3],
    /// Internal ranks R1 = R2 = R (boundary ranks are 1).
    pub rank: usize,
}

impl TtShapes {
    /// Plan shapes for a `rows × dim` table (same algorithm as
    /// `TtSpec.plan` on the python side).
    pub fn plan(rows: u64, dim: usize, rank: usize) -> TtShapes {
        let m = factorize3(padded_rows(rows));
        let n64 = factorize3(dim as u64);
        let n = [n64[0] as usize, n64[1] as usize, n64[2] as usize];
        assert_eq!(
            n[0] * n[1] * n[2],
            dim,
            "dim {dim} not factorable into 3 terms"
        );
        TtShapes { rows, dim, m, n, rank }
    }

    /// Core element counts: `[m1·n1·R, R·m2·n2·R, R·m3·n3]`.
    pub fn core_lens(&self) -> [usize; 3] {
        let r = self.rank;
        [
            self.m[0] as usize * self.n[0] * r,
            r * self.m[1] as usize * self.n[1] * r,
            r * self.m[2] as usize * self.n[2],
        ]
    }

    pub fn padded_m(&self) -> u64 {
        self.m[0] * self.m[1] * self.m[2]
    }

    /// Row index → (i1, i2, i3).
    #[inline]
    pub fn tt_indices(&self, i: u64) -> (u64, u64, u64) {
        let (m2, m3) = (self.m[1], self.m[2]);
        (i / (m2 * m3), (i / m3) % m2, i % m3)
    }

    /// Reuse-buffer key (Algorithm 1): rows sharing it share D1·D2 slices.
    #[inline]
    pub fn prefix_of(&self, i: u64) -> u64 {
        i / self.m[2]
    }

    /// Number of distinct prefixes (`m1·m2`).
    pub fn num_prefixes(&self) -> u64 {
        self.m[0] * self.m[1]
    }

    /// Trainable parameter count in TT form.
    pub fn tt_params(&self) -> u64 {
        let l = self.core_lens();
        (l[0] + l[1] + l[2]) as u64
    }

    /// Parameter count of the uncompressed table.
    pub fn plain_params(&self) -> u64 {
        self.rows * self.dim as u64
    }

    /// Table IV's headline metric.
    pub fn compression_ratio(&self) -> f64 {
        self.plain_params() as f64 / self.tt_params() as f64
    }

    /// Bytes of f32 storage in TT form.
    pub fn tt_bytes(&self) -> u64 {
        self.tt_params() * 4
    }

    /// Number of core slices (`m1 + m2 + m3`) — the tile unit of the plan
    /// walk; int8 storage carries one f32 scale per slice.
    pub fn num_slices(&self) -> u64 {
        self.m[0] + self.m[1] + self.m[2]
    }

    /// Bytes of f16 storage in TT form (2 bytes per parameter).
    pub fn tt_bytes_f16(&self) -> u64 {
        self.tt_params() * 2
    }

    /// Bytes of int8 storage in TT form (1 byte per parameter plus one
    /// f32 scale per core slice).
    pub fn tt_bytes_int8(&self) -> u64 {
        self.tt_params() + self.num_slices() * 4
    }

    pub fn plain_bytes(&self) -> u64 {
        self.plain_params() * 4
    }

    /// FLOPs for one row lookup without reuse: two GEMM hops.
    pub fn lookup_flops(&self) -> u64 {
        let (n1, n2, n3) = (self.n[0] as u64, self.n[1] as u64, self.n[2] as u64);
        let r = self.rank as u64;
        // D1[i1] (n1×R) · D2[:,i2] (R×n2R) + P (n1n2×R) · D3[:,i3] (R×n3)
        2 * n1 * r * n2 * r + 2 * n1 * n2 * r * n3
    }

    /// FLOPs of just the second hop (paid even on reuse-buffer hits).
    pub fn hop2_flops(&self) -> u64 {
        let (n1, n2, n3) = (self.n[0] as u64, self.n[1] as u64, self.n[2] as u64);
        2 * n1 * n2 * self.rank as u64 * n3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check_cases;

    #[test]
    fn factorize3_products() {
        check_cases("factorize3", 200, |rng, _| {
            let x = rng.below(1_000_000) + 1;
            let f = factorize3(x);
            assert_eq!(f[0] * f[1] * f[2], x);
            assert!(f[0] <= f[1] && f[1] <= f[2]);
        });
    }

    #[test]
    fn padded_rows_balanced() {
        check_cases("padded", 100, |rng, _| {
            let rows = rng.below(3_000_000) + 32;
            let m = padded_rows(rows);
            assert!(m >= rows);
            let f = factorize3(m);
            assert!(f[2] <= 4 * f[0] || f[2] <= 64);
        });
    }

    #[test]
    fn index_roundtrip() {
        check_cases("roundtrip", 100, |rng, _| {
            let rows = rng.below(500_000) + 100;
            let s = TtShapes::plan(rows, 16, 8);
            let i = rng.below(rows);
            let (i1, i2, i3) = s.tt_indices(i);
            assert!(i1 < s.m[0] && i2 < s.m[1] && i3 < s.m[2]);
            assert_eq!(i1 * s.m[1] * s.m[2] + i2 * s.m[2] + i3, i);
            assert_eq!(s.prefix_of(i), i1 * s.m[1] + i2);
        });
    }

    #[test]
    fn known_factorizations() {
        assert_eq!(factorize3(1000), [10, 10, 10]);
        assert_eq!(factorize3(8), [2, 2, 2]);
        assert_eq!(factorize3(7), [1, 1, 7]);
    }

    #[test]
    fn table4_terabyte_ratio_direction() {
        // Criteo Terabyte row: 242.5M × 64 must compress by orders of
        // magnitude (paper reports 74× at their rank config; ratio grows
        // as rank shrinks).
        let s = TtShapes::plan(242_500_000, 64, 32);
        assert!(s.compression_ratio() > 1_000.0);
    }

    #[test]
    fn quantized_bytes_strictly_ordered() {
        // paper-scale shapes: int8 < f16 < f32, and the per-slice scale
        // overhead never erases the win
        for (rows, dim, rank) in [(1000u64, 16usize, 8usize), (242_500_000, 64, 32)] {
            let s = TtShapes::plan(rows, dim, rank);
            assert!(s.tt_bytes_int8() < s.tt_bytes_f16());
            assert!(s.tt_bytes_f16() < s.tt_bytes());
            assert_eq!(s.tt_bytes_f16() * 2, s.tt_bytes());
        }
    }

    #[test]
    fn python_parity_fixtures() {
        // Fixed cross-language fixtures (values printed by tt_spec.py).
        let s = TtShapes::plan(1000, 16, 8);
        assert_eq!(s.m, [10, 10, 10]);
        assert_eq!(s.n, [2, 2, 4]);
        let s = TtShapes::plan(6000, 16, 8);
        assert_eq!(s.padded_m() % 6000, 0);
    }
}
