//! `EffTtTable` — the paper's Eff-TT embedding table (native engine).
//!
//! Drop-in for the `nn.EmbeddingBag(mode="sum")` contract: flat `indices`
//! plus `offsets` (bag b covers `indices[offsets[b]..offsets[b+1]]`), sum-
//! pooled output rows.  Three optimizations from §III are first-class and
//! individually switchable (Fig. 12 ablation):
//!
//! * **intermediate reuse** — the D1·D2 partial product is computed once
//!   per *distinct prefix* in the batch and kept in the Reuse Buffer;
//! * **gradient aggregation** — backward first merges gradients of
//!   repeated rows, then pays the Eq. 8 chain products once per distinct
//!   row;
//! * **fused update** — aggregated core gradients are applied in the same
//!   pass (SGD), no separate grad materialization or optimizer copy.
//!
//! Core memory layouts are chosen for contiguous slice GEMMs (they differ
//! from the jax artifact layout; see [`EffTtTable::from_jax_cores`]):
//!
//! ```text
//!   D1 [m1][n1·R]      slice(i1) = [n1, R]
//!   D2 [m2][R·n2·R]    slice(i2) = [R, n2·R]
//!   D3 [m3][R·n3]      slice(i3) = [R, n3]
//! ```


use std::ops::Range;

use anyhow::{bail, Result};

use crate::access::plan::{BagLayout, TtPlan};
use crate::exec::par::{par_row_blocks, split_at_cuts, PAR_MIN_WORK};
use crate::exec::{split_ranges, ExecPool};
use crate::tt::linalg::{
    add_assign, axpy, f32_to_f16_bits, gemm_acc, gemm_acc_ku_q, gemm_acc_kuw, gemm_acc_q,
    gemm_at_acc, gemm_at_tiledw, gemm_bt_acc, i8_scale, quantize_i8, Dequant, QF16, QI8,
};
use crate::tt::shapes::TtShapes;
use crate::util::prng::Rng;

/// Serving-mode numeric format for frozen TT cores (`[tt] quantize` /
/// `--quantize`).  `Off` keeps the training-grade f32 path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantizeMode {
    #[default]
    Off,
    Int8,
    F16,
}

impl QuantizeMode {
    pub fn parse(s: &str) -> Result<QuantizeMode> {
        match s {
            "off" => Ok(QuantizeMode::Off),
            "int8" => Ok(QuantizeMode::Int8),
            "f16" => Ok(QuantizeMode::F16),
            other => bail!("unknown quantize mode '{other}' (expected off|int8|f16)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            QuantizeMode::Off => "off",
            QuantizeMode::Int8 => "int8",
            QuantizeMode::F16 => "f16",
        }
    }
}

/// One TT core in quantized storage, sliced exactly like its f32 twin
/// (slice i covers `[i·slice_len, (i+1)·slice_len)`), so the hottest-first
/// layout schedule walks the quantized tiles in the same order as the f32
/// ones.  int8 carries one symmetric scale per slice — the slice IS the
/// tile unit of the plan walk.
#[derive(Clone, Default)]
pub struct QCore {
    slice_len: usize,
    q8: Vec<i8>,
    scales: Vec<f32>,
    f16: Vec<u16>,
}

impl QCore {
    fn quantize(core: &[f32], slice_len: usize, mode: QuantizeMode) -> QCore {
        debug_assert_eq!(core.len() % slice_len, 0);
        let mut qc = QCore { slice_len, ..QCore::default() };
        match mode {
            QuantizeMode::Off => unreachable!("QCore::quantize called with mode=off"),
            QuantizeMode::Int8 => {
                qc.q8.resize(core.len(), 0);
                for (blk, qblk) in core.chunks(slice_len).zip(qc.q8.chunks_mut(slice_len)) {
                    let sc = i8_scale(blk);
                    quantize_i8(blk, sc, qblk);
                    qc.scales.push(sc);
                }
            }
            QuantizeMode::F16 => {
                qc.f16 = core.iter().map(|&v| f32_to_f16_bits(v)).collect();
            }
        }
        qc
    }

    #[inline]
    fn i8_slice(&self, i: usize) -> QI8<'_> {
        let l = self.slice_len;
        QI8 { q: &self.q8[i * l..(i + 1) * l], scale: self.scales[i] }
    }

    #[inline]
    fn f16_slice(&self, i: usize) -> QF16<'_> {
        let l = self.slice_len;
        QF16 { h: &self.f16[i * l..(i + 1) * l] }
    }

    fn bytes(&self) -> u64 {
        (self.q8.len() + self.scales.len() * 4 + self.f16.len() * 2) as u64
    }
}

/// Frozen quantized TT cores (see [`EffTtTable::freeze_quantized`]).
#[derive(Clone)]
pub struct QuantCores {
    pub mode: QuantizeMode,
    q1: QCore,
    q2: QCore,
    q3: QCore,
}

/// Which §III optimizations are active (Fig. 12 ablation switches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EffTtOptions {
    pub reuse: bool,
    pub grad_aggregation: bool,
    pub fused_update: bool,
}

impl Default for EffTtOptions {
    fn default() -> Self {
        EffTtOptions { reuse: true, grad_aggregation: true, fused_update: true }
    }
}

impl EffTtOptions {
    /// TT-Rec baseline behaviour: TT compression without the Eff-TT
    /// compute optimizations.
    pub fn ttrec_baseline() -> Self {
        EffTtOptions { reuse: false, grad_aggregation: false, fused_update: false }
    }
}

/// Lookup/backward instrumentation for the ablation benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct TtStats {
    /// First-hop GEMMs actually executed (== distinct prefixes when reuse
    /// is on, == total indices when off).
    pub prefix_gemms: u64,
    /// Reuse-buffer hits (first-hop GEMMs avoided).
    pub reuse_hits: u64,
    /// Second-hop GEMMs (always == total indices).
    pub hop2_gemms: u64,
    /// Backward chain products executed (× distinct rows when aggregation
    /// is on, × occurrences when off).
    pub backward_chains: u64,
    /// Occurrence gradients merged away by aggregation.
    pub grads_aggregated: u64,
}

impl TtStats {
    pub fn add(&mut self, o: &TtStats) {
        self.prefix_gemms += o.prefix_gemms;
        self.reuse_hits += o.reuse_hits;
        self.hop2_gemms += o.hop2_gemms;
        self.backward_chains += o.backward_chains;
        self.grads_aggregated += o.grads_aggregated;
    }
}

/// Reusable per-batch scratch so the hot path is allocation-free after
/// warmup (perf pass: §Perf L3).
#[derive(Clone, Default)]
pub struct TtScratch {
    /// Reuse Buffer: one [n1·n2, R] partial product per distinct prefix.
    buf: Vec<f32>,
    /// sort-based dedup workspace: (prefix, original position) pairs.
    /// (§Perf: sorting beats a HashMap here — the dedup runs per batch on
    /// the hot path and hashing 4k u64s cost more than the saved GEMMs.)
    order: Vec<(u64, u32)>,
    /// per-index slot assignment (parallel to the flat indices).
    index_slot: Vec<u32>,
    /// distinct-row materialization buffer [uniq_rows, dim].
    row: Vec<f32>,
    /// inline access plan for the unplanned-API wrappers (dedup, prefix
    /// groups, scatter map, aggregation order — see `access::plan`).
    plan: TtPlan,
    /// backward non-aggregated work list ((row, bag) pairs in bag order).
    occ: Vec<(u64, u32)>,
    agg_rows: Vec<u64>,
    agg_grads: Vec<f32>,
    /// backward phase-2 work list: (row, gradient slot).
    work: Vec<(u64, u32)>,
    /// backward phase-2 outputs: per-item core-slice gradients (chunked).
    g1: Vec<f32>,
    g2: Vec<f32>,
    g3: Vec<f32>,
    /// backward chain workspaces for the serial path.
    chain_p: Vec<f32>,
    chain_dp: Vec<f32>,
    /// per-worker partial-product workspaces for the parallel forward and
    /// backward shards — handed out per spawn so steady state is
    /// allocation-free (the shards used to `Vec::new()` per call).
    wp: Vec<Vec<f32>>,
    wdp: Vec<Vec<f32>>,
    /// tiled backward: per-chunk hottest-first compute order (absolute
    /// work indices, grouped by chunk) and its inverse (work index →
    /// gradient slot within the chunk), plus the bucketing cursors.
    chunk_order: Vec<u32>,
    chunk_slot: Vec<u32>,
    chunk_cursors: Vec<usize>,
}

#[derive(Clone)]
pub struct EffTtTable {
    pub shapes: TtShapes,
    pub opts: EffTtOptions,
    /// Cores in slice-contiguous layout (see module docs).
    pub core1: Vec<f32>,
    pub core2: Vec<f32>,
    pub core3: Vec<f32>,
    pub stats: TtStats,
    /// Shared parallel execution layer; serial by default.  All parallel
    /// paths are bit-identical to `workers = 1` (see `exec` module docs).
    pub pool: ExecPool,
    /// Frozen quantized cores (serving mode); `None` = f32 path.
    pub quant: Option<QuantCores>,
}

impl EffTtTable {
    /// TT-Rec-style random init: σ chosen so materialized rows have
    /// variance ≈ 1/dim (matches `kernels.tt_lookup.init_cores`).
    pub fn new(shapes: TtShapes, opts: EffTtOptions, rng: &mut Rng) -> Self {
        let r = shapes.rank;
        let (m1, m2, m3) = (shapes.m[0] as usize, shapes.m[1] as usize, shapes.m[2] as usize);
        let (n1, n2, n3) = (shapes.n[0], shapes.n[1], shapes.n[2]);
        let sigma = (1.0 / (shapes.dim as f64 * (r * r) as f64)).powf(1.0 / 6.0) as f32;
        let mut core1 = vec![0.0; m1 * n1 * r];
        let mut core2 = vec![0.0; m2 * r * n2 * r];
        let mut core3 = vec![0.0; m3 * r * n3];
        rng.fill_normal(&mut core1, 0.0, sigma);
        rng.fill_normal(&mut core2, 0.0, sigma);
        rng.fill_normal(&mut core3, 0.0, sigma);
        EffTtTable {
            shapes,
            opts,
            core1,
            core2,
            core3,
            stats: TtStats::default(),
            pool: ExecPool::serial(),
            quant: None,
        }
    }

    /// Attach a worker pool (threaded down from the engine's `ExecCfg`).
    pub fn set_pool(&mut self, pool: ExecPool) {
        self.pool = pool;
    }

    /// Build from cores in the jax artifact layout:
    /// D1 [m1, n1, R], D2 [R, m2, n2, R], D3 [R, m3, n3]
    /// (used by integration tests comparing native vs PJRT numerics).
    pub fn from_jax_cores(
        shapes: TtShapes,
        opts: EffTtOptions,
        d1: &[f32],
        d2: &[f32],
        d3: &[f32],
    ) -> Self {
        let r = shapes.rank;
        let (m1, m2, m3) = (shapes.m[0] as usize, shapes.m[1] as usize, shapes.m[2] as usize);
        let (n1, n2, n3) = (shapes.n[0], shapes.n[1], shapes.n[2]);
        assert_eq!(d1.len(), m1 * n1 * r);
        assert_eq!(d2.len(), r * m2 * n2 * r);
        assert_eq!(d3.len(), r * m3 * n3);
        // D1 layout is identical.
        let core1 = d1.to_vec();
        // D2: [r1, i2, j2, r2] -> [i2][r1, j2, r2]
        let mut core2 = vec![0.0; m2 * r * n2 * r];
        for r1 in 0..r {
            for i2 in 0..m2 {
                for x in 0..n2 * r {
                    core2[i2 * (r * n2 * r) + r1 * (n2 * r) + x] =
                        d2[r1 * (m2 * n2 * r) + i2 * (n2 * r) + x];
                }
            }
        }
        // D3: [r2, i3, j3] -> [i3][r2, j3]
        let mut core3 = vec![0.0; m3 * r * n3];
        for r2 in 0..r {
            for i3 in 0..m3 {
                for j3 in 0..n3 {
                    core3[i3 * (r * n3) + r2 * n3 + j3] =
                        d3[r2 * (m3 * n3) + i3 * n3 + j3];
                }
            }
        }
        EffTtTable {
            shapes,
            opts,
            core1,
            core2,
            core3,
            stats: TtStats::default(),
            pool: ExecPool::serial(),
            quant: None,
        }
    }

    /// Export cores back to the jax layout (inverse of `from_jax_cores`).
    pub fn to_jax_cores(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let r = self.shapes.rank;
        let (m2, m3) = (self.shapes.m[1] as usize, self.shapes.m[2] as usize);
        let (n2, n3) = (self.shapes.n[1], self.shapes.n[2]);
        let d1 = self.core1.clone();
        let mut d2 = vec![0.0; r * m2 * n2 * r];
        for i2 in 0..m2 {
            for r1 in 0..r {
                for x in 0..n2 * r {
                    d2[r1 * (m2 * n2 * r) + i2 * (n2 * r) + x] =
                        self.core2[i2 * (r * n2 * r) + r1 * (n2 * r) + x];
                }
            }
        }
        let mut d3 = vec![0.0; r * m3 * n3];
        for i3 in 0..m3 {
            for r2 in 0..r {
                for j3 in 0..n3 {
                    d3[r2 * (m3 * n3) + i3 * n3 + j3] =
                        self.core3[i3 * (r * n3) + r2 * n3 + j3];
                }
            }
        }
        (d1, d2, d3)
    }

    #[inline]
    fn slice1(&self, i1: usize) -> &[f32] {
        let l = self.shapes.n[0] * self.shapes.rank;
        &self.core1[i1 * l..(i1 + 1) * l]
    }

    #[inline]
    fn slice2(&self, i2: usize) -> &[f32] {
        let l = self.shapes.rank * self.shapes.n[1] * self.shapes.rank;
        &self.core2[i2 * l..(i2 + 1) * l]
    }

    #[inline]
    fn slice3(&self, i3: usize) -> &[f32] {
        let l = self.shapes.rank * self.shapes.n[2];
        &self.core3[i3 * l..(i3 + 1) * l]
    }

    /// Bytes held by the TT cores.  A frozen table reports the quantized
    /// footprint — the storage the serving hot path actually walks.
    pub fn bytes(&self) -> u64 {
        match &self.quant {
            Some(q) => q.q1.bytes() + q.q2.bytes() + q.q3.bytes(),
            None => ((self.core1.len() + self.core2.len() + self.core3.len()) * 4) as u64,
        }
    }

    /// Freeze the table into a reduced-precision serving format: each core
    /// is re-stored slice-by-slice as int8 (one symmetric scale per slice)
    /// or f16, and the planned forward dequantizes inside the tile walk
    /// (never as a separate materialization pass).  Forward-only —
    /// `backward_sgd*` panics on a frozen table; pass `Off` to thaw back
    /// to the f32 path.  Opt-in via `[tt] quantize` / `--quantize`.
    pub fn freeze_quantized(&mut self, mode: QuantizeMode) {
        if mode == QuantizeMode::Off {
            self.quant = None;
            return;
        }
        let s = &self.shapes;
        let r = s.rank;
        let (l1, l2, l3) = (s.n[0] * r, r * s.n[1] * r, r * s.n[2]);
        self.quant = Some(QuantCores {
            mode,
            q1: QCore::quantize(&self.core1, l1, mode),
            q2: QCore::quantize(&self.core2, l2, mode),
            q3: QCore::quantize(&self.core3, l3, mode),
        });
    }

    /// Compute the partial product P(prefix) = D1[i1] · D2[:,i2]
    /// into `out` ([n1·n2, R] == [n1, n2·R] row-major).
    fn prefix_product(&self, prefix: u64, out: &mut [f32]) {
        let s = &self.shapes;
        let (n1, n2) = (s.n[0], s.n[1]);
        let r = s.rank;
        let i1 = (prefix / s.m[1]) as usize;
        let i2 = (prefix % s.m[1]) as usize;
        out.fill(0.0);
        // [n1, R] · [R, n2·R] -> [n1, n2·R]
        gemm_acc(self.slice1(i1), self.slice2(i2), out, n1, r, n2 * r);
    }

    /// Stage 1 of a batch lookup: populate the Reuse Buffer, assigning one
    /// slot per distinct prefix (or per occurrence when reuse is off).
    fn prepare_prefixes(&mut self, indices: &[u64], scratch: &mut TtScratch) {
        let s = self.shapes;
        let plen = s.n[0] * s.n[1] * s.rank;
        scratch.index_slot.clear();
        if self.opts.reuse {
            // one slot per *distinct* prefix — Algorithm 1 dedup.
            // Sort-based: sorting (prefix, pos) pairs is ~3x faster than a
            // HashMap at batch sizes that matter, and index reordering
            // (§III-G) pre-clusters the stream so pdqsort hits its
            // near-sorted fast path (§Perf L3 iteration 1).
            scratch.order.clear();
            scratch
                .order
                .extend(indices.iter().enumerate().map(|(k, &i)| (s.prefix_of(i), k as u32)));
            scratch.order.sort_unstable();
            scratch.index_slot.resize(indices.len(), 0);
            let mut uniq = 0usize;
            let mut last = u64::MAX;
            // first pass: assign slots (buf not yet sized)
            for &(p, pos) in scratch.order.iter() {
                if p != last {
                    last = p;
                    uniq += 1;
                }
                scratch.index_slot[pos as usize] = (uniq - 1) as u32;
            }
            scratch.buf.resize(uniq * plen, 0.0);
            // second pass: one GEMM per distinct prefix
            last = u64::MAX;
            let mut slot = 0usize;
            for &(p, _) in scratch.order.iter() {
                if p != last {
                    let buf = &mut scratch.buf[slot * plen..(slot + 1) * plen];
                    self.prefix_product(p, buf);
                    last = p;
                    slot += 1;
                }
            }
            self.stats.prefix_gemms += uniq as u64;
            self.stats.reuse_hits += (indices.len() - uniq) as u64;
        } else {
            // TT-Rec path: recompute P per occurrence
            scratch.buf.resize(indices.len() * plen, 0.0);
            for (k, &idx) in indices.iter().enumerate() {
                let p = s.prefix_of(idx);
                let buf = &mut scratch.buf[k * plen..(k + 1) * plen];
                self.prefix_product(p, buf);
                scratch.index_slot.push(k as u32);
            }
            self.stats.prefix_gemms += indices.len() as u64;
        }
    }

    /// Materialize a single row into `out` [dim] (+= semantics).
    fn row_into(&self, slot_p: &[f32], i3: usize, out: &mut [f32], scratch_row: &mut [f32]) {
        let s = &self.shapes;
        let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
        let r = s.rank;
        scratch_row.fill(0.0);
        // [n1·n2, R] · [R, n3] -> [n1·n2, n3] == row-major [dim]
        gemm_acc(slot_p, self.slice3(i3), scratch_row, n1 * n2, r, n3);
        add_assign(out, scratch_row);
    }

    /// EmbeddingBag(sum) forward: `out` is [num_bags, dim] row-major.
    ///
    /// `offsets` has `num_bags + 1` entries; bag b pools
    /// `indices[offsets[b]..offsets[b+1]]`.
    ///
    /// Thin wrapper over [`EffTtTable::embedding_bag_planned`]: builds the
    /// access plan inline (into `scratch.plan`, reused across calls) with
    /// the exact sweeps the pre-refactor code ran, so results are
    /// bit-identical.  Callers with a plan from the ingest stage skip the
    /// inline build entirely.
    pub fn embedding_bag(
        &mut self,
        indices: &[u64],
        offsets: &[usize],
        out: &mut [f32],
        scratch: &mut TtScratch,
    ) {
        let mut plan = std::mem::take(&mut scratch.plan);
        if self.opts.reuse {
            plan.build_forward(self.shapes, indices, BagLayout::Offsets(offsets));
        }
        self.embedding_bag_planned(indices, BagLayout::Offsets(offsets), &plan, out, scratch);
        scratch.plan = plan;
    }

    /// Plan-accepting EmbeddingBag(sum) forward.  `plan` must have been
    /// built (`build_forward`/`build`) over exactly these `indices` and
    /// this table's shapes when `opts.reuse` is on; the TT-Rec
    /// (no-reuse) arm recomputes per occurrence and ignores the plan.
    pub fn embedding_bag_planned(
        &mut self,
        indices: &[u64],
        bags: BagLayout,
        plan: &TtPlan,
        out: &mut [f32],
        scratch: &mut TtScratch,
    ) {
        let s = self.shapes;
        let dim = s.dim;
        let n_bags = bags.num_bags();
        assert_eq!(out.len(), n_bags * dim);
        assert_eq!(bags.total(), indices.len());
        for &i in indices {
            assert!(i < s.rows, "index {i} out of range {}", s.rows);
        }
        let plen = s.n[0] * s.n[1] * s.rank;
        if self.opts.reuse {
            // §Perf L3 iteration 4 + exec refactor + access layer:
            // sample-level reuse (paper §III-B "intermediate results from
            // each embedding ROW can be recycled") over the shared
            // parallel layer, driven by the precomputed plan (distinct
            // rows, prefix-group boundaries, scatter map).  Distinct rows
            // are materialized in parallel, sharded ONLY at group
            // boundaries so each distinct prefix product is still
            // computed exactly once (TtStats counts identical to serial);
            // then rows are scatter-added into bags, sharded by bag.
            // Every parallel stage is bit-identical to workers=1.
            assert!(plan.forward_ready(), "plan missing forward section");
            debug_assert_eq!(plan.shapes(), Some(s), "plan built for different shapes");
            assert_eq!(plan.n_indices(), indices.len(), "plan/indices length mismatch");
            let uniq_rows = plan.uniq_rows.len();
            let uniq_pref = plan.group_starts.len();
            self.stats.prefix_gemms += uniq_pref as u64;
            self.stats.hop2_gemms += uniq_rows as u64;
            self.stats.reuse_hits += (indices.len() - uniq_pref) as u64;

            // materialize each distinct row once.  When the plan carries a
            // cache-resident layout the walk is tiled hottest-first: rows
            // land at their *scheduled* positions (big prefix groups
            // first, L2-sized tiles) and the scatter reads back through
            // `slot_pos`.  Shards cut at tile (resp. group) boundaries so
            // every distinct prefix product is still computed exactly
            // once across workers — TtStats are worker- AND layout-
            // invariant, and per-bag accumulation order is untouched, so
            // tiled execution is bit-identical to untiled (pinned by
            // `tests/plan_equivalence.rs`).
            scratch.row.resize(uniq_rows * dim, 0.0);
            let tiled = plan.tiled();
            debug_assert!(!tiled || plan.sched().len() == uniq_rows);
            let par_workers = if uniq_rows * dim * s.rank < PAR_MIN_WORK {
                1
            } else {
                self.pool.workers()
            };
            // shard cuts: tile boundaries when there are enough tiles to
            // feed every worker (shards then align to cache-coherent
            // units), else scheduled-group boundaries (same granularity
            // as the untiled path; any group boundary preserves the
            // compute-each-prefix-once invariant)
            let cuts: &[u32] = if tiled {
                if plan.tile_starts().len() > par_workers {
                    plan.tile_starts()
                } else {
                    plan.sched_group_starts()
                }
            } else {
                &plan.group_starts
            };
            let shards = split_at_cuts(uniq_rows, cuts, par_workers, 64);
            let table = &*self;
            let rows_list = &plan.uniq_rows[..];
            let sched: Option<&[u32]> = if tiled { Some(plan.sched()) } else { None };
            let quant = table.quant.as_ref();
            let fill = |rg: Range<usize>, block: &mut [f32], p: &mut Vec<f32>| match (quant, sched)
            {
                (Some(q), _) => {
                    fill_rows_quant(table, q, rows_list, sched, rg, block, plen, dim, p)
                }
                (None, Some(order)) => {
                    fill_rows_sched(table, rows_list, order, rg, block, plen, dim, p)
                }
                (None, None) => fill_rows(table, rows_list, rg, block, plen, dim, p),
            };
            if shards.len() <= 1 {
                fill(0..uniq_rows, &mut scratch.row[..], &mut scratch.buf);
            } else {
                // per-worker prefix buffers come from scratch (no
                // per-call allocations in the spawned shards)
                if scratch.wp.len() < shards.len() {
                    scratch.wp.resize_with(shards.len(), Vec::new);
                }
                std::thread::scope(|sc| {
                    let fill = &fill;
                    let mut rest = &mut scratch.row[..];
                    let mut pbufs = scratch.wp.iter_mut();
                    let last = shards.len() - 1;
                    let mut own: Option<(Range<usize>, &mut [f32], &mut Vec<f32>)> = None;
                    for (i, r) in shards.into_iter().enumerate() {
                        let take = (r.end - r.start) * dim;
                        let (block, tail) = std::mem::take(&mut rest).split_at_mut(take);
                        rest = tail;
                        let p = pbufs.next().unwrap();
                        if i == last {
                            own = Some((r, block, p));
                        } else {
                            sc.spawn(move || fill(r, block, p));
                        }
                    }
                    if let Some((r, block, p)) = own {
                        fill(r, block, p);
                    }
                });
            }

            // scatter-add distinct rows into bags (bag-sharded; each
            // bag's accumulation order is exactly the serial one).  The
            // unit-bag case skips the offsets indirection entirely; the
            // tiled layout adds only a position lookup per read.
            let rowbuf = &scratch.row[..];
            let slots = &plan.index_slot[..];
            let pos_map: Option<&[u32]> = if tiled { Some(&plan.slot_pos[..]) } else { None };
            let scatter_pool = if indices.len() * dim < PAR_MIN_WORK {
                ExecPool::serial()
            } else {
                self.pool
            };
            match bags {
                BagLayout::Unit(_) => {
                    par_row_blocks(&scatter_pool, out, dim, |b0, oblock| {
                        for (bi, dst) in oblock.chunks_mut(dim).enumerate() {
                            let slot = slots[b0 + bi] as usize;
                            let pos = match pos_map {
                                Some(m) => m[slot] as usize,
                                None => slot,
                            };
                            dst.fill(0.0);
                            add_assign(dst, &rowbuf[pos * dim..(pos + 1) * dim]);
                        }
                    });
                }
                BagLayout::Offsets(offsets) => {
                    par_row_blocks(&scatter_pool, out, dim, |b0, oblock| {
                        for (bi, dst) in oblock.chunks_mut(dim).enumerate() {
                            let b = b0 + bi;
                            dst.fill(0.0);
                            for k in offsets[b]..offsets[b + 1] {
                                let slot = slots[k] as usize;
                                let pos = match pos_map {
                                    Some(m) => m[slot] as usize,
                                    None => slot,
                                };
                                add_assign(dst, &rowbuf[pos * dim..(pos + 1) * dim]);
                            }
                        }
                    });
                }
            }
        } else {
            // TT-Rec path: recompute everything per occurrence; bags are
            // independent, so the pooling loop shards across bags.
            assert!(
                self.quant.is_none(),
                "quantized serving requires the reuse-planned forward"
            );
            self.prepare_prefixes(indices, scratch);
            self.stats.hop2_gemms += indices.len() as u64;
            let m3 = s.m[2];
            if self.pool.is_serial() || indices.len() * dim * s.rank < PAR_MIN_WORK {
                // allocation-free steady state: reuse the scratch row
                scratch.row.resize(dim, 0.0);
                let mut row_tmp = std::mem::take(&mut scratch.row);
                out.fill(0.0);
                for b in 0..n_bags {
                    let dst = &mut out[b * dim..(b + 1) * dim];
                    for k in bags.range(b) {
                        let idx = indices[k];
                        let slot = scratch.index_slot[k] as usize;
                        let p = &scratch.buf[slot * plen..(slot + 1) * plen];
                        self.row_into(p, (idx % m3) as usize, dst, &mut row_tmp);
                    }
                }
                scratch.row = row_tmp;
            } else {
                let table = &*self;
                let buf = &scratch.buf[..];
                let slots = &scratch.index_slot[..];
                par_row_blocks(&self.pool, out, dim, |b0, oblock| {
                    // one row buffer per block, amortized across its bags
                    let mut row_tmp = vec![0.0f32; dim];
                    for (bi, dst) in oblock.chunks_mut(dim).enumerate() {
                        let b = b0 + bi;
                        dst.fill(0.0);
                        for k in bags.range(b) {
                            let idx = indices[k];
                            let slot = slots[k] as usize;
                            let p = &buf[slot * plen..(slot + 1) * plen];
                            table.row_into(p, (idx % m3) as usize, dst, &mut row_tmp);
                        }
                    }
                });
            }
        }
    }

    /// Convenience single-row lookup (serving path).
    pub fn lookup_row(&mut self, index: u64, out: &mut [f32], scratch: &mut TtScratch) {
        let offsets = [0usize, 1usize];
        self.embedding_bag(&[index], &offsets, out, scratch);
    }

    /// Backward + (optionally fused) SGD update.
    ///
    /// `grad_out` is ∂L/∂(pooled bags) [num_bags, dim]: occurrence (b, k)
    /// receives grad_out[b] (sum pooling).  Returns nothing — cores are
    /// updated in place with learning rate `lr` (the paper's fused update);
    /// when `fused_update` is off the grads are first fully materialized
    /// per-core and then applied (extra traffic, as in TT-Rec).
    ///
    /// Thin wrapper over [`EffTtTable::backward_sgd_planned`]: builds the
    /// plan's backward section inline (same occurrence sort as the
    /// pre-refactor code → bit-identical results).
    pub fn backward_sgd(
        &mut self,
        indices: &[u64],
        offsets: &[usize],
        grad_out: &[f32],
        lr: f32,
        scratch: &mut TtScratch,
    ) {
        let mut plan = std::mem::take(&mut scratch.plan);
        if self.opts.grad_aggregation {
            plan.build_backward(self.shapes, indices, BagLayout::Offsets(offsets));
        }
        self.backward_sgd_planned(
            indices,
            BagLayout::Offsets(offsets),
            &plan,
            grad_out,
            lr,
            scratch,
        );
        scratch.plan = plan;
    }

    /// Plan-accepting backward + (optionally fused) SGD update.  With
    /// gradient aggregation on, `plan` supplies the sorted occurrence
    /// list (its backward section must cover exactly these `indices`);
    /// without aggregation the occurrence list is the natural bag order
    /// and the plan is not consulted.
    pub fn backward_sgd_planned(
        &mut self,
        indices: &[u64],
        bags: BagLayout,
        plan: &TtPlan,
        grad_out: &[f32],
        lr: f32,
        scratch: &mut TtScratch,
    ) {
        let s = self.shapes;
        let dim = s.dim;
        let n_bags = bags.num_bags();
        assert!(
            self.quant.is_none(),
            "frozen quantized table is forward-only (serving mode); \
             freeze_quantized(Off) thaws it for training"
        );
        assert_eq!(grad_out.len(), n_bags * dim);
        debug_assert_eq!(bags.total(), indices.len());

        // ---- step 1: advance gradient aggregation (Fig. 5b) -------------
        // Sort-based segmented accumulation (§Perf L3 iteration 2), with
        // the sort hoisted into the access plan: gradients of repeated
        // rows are summed into ONE flat reusable buffer by sweeping the
        // plan's sorted (row, bag) occurrence list — no HashMap, no
        // per-row Vec allocations, and no per-call sort when the plan
        // comes from the ingest stage.  Sorted order also keeps fused
        // updates to shared core slices bit-for-bit reproducible across
        // runs (the pipeline == sequential guarantee relies on it).
        if self.opts.grad_aggregation {
            assert!(plan.backward_ready(), "plan missing backward section");
            let occ = plan.occ_sorted();
            assert_eq!(occ.len(), indices.len(), "plan/indices length mismatch");
            scratch.agg_rows.clear();
            scratch.agg_grads.clear();
            let mut last = u64::MAX;
            for &(row, b) in occ.iter() {
                if row != last {
                    scratch.agg_rows.push(row);
                    let start = scratch.agg_grads.len();
                    scratch.agg_grads.resize(start + dim, 0.0);
                    last = row;
                }
                let slot = scratch.agg_rows.len() - 1;
                add_assign(
                    &mut scratch.agg_grads[slot * dim..(slot + 1) * dim],
                    &grad_out[b as usize * dim..(b as usize + 1) * dim],
                );
            }
            self.stats.grads_aggregated += (occ.len() - scratch.agg_rows.len()) as u64;
        } else {
            // no aggregation: one chain per occurrence, natural bag order
            scratch.occ.clear();
            for b in 0..n_bags {
                for k in bags.range(b) {
                    scratch.occ.push((indices[k], b as u32));
                }
            }
        }

        // ---- step 2: Eq. 8 chain products per work item (exec-sharded) --
        // §Perf L3 iteration 3 + exec refactor: the aggregated work list
        // is sorted by row, so rows sharing a TT prefix are adjacent and
        // each worker recomputes P only on prefix change within its shard.
        // Chains are evaluated against the cores as of their CHUNK's start
        // for every worker count — the compute phase is read-only, the
        // apply phase runs serially in work order, and chunk boundaries
        // are a worker-independent constant — so `workers = N` is
        // bit-identical to `workers = 1`, preserving the
        // pipeline==sequential guarantee.
        let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
        let r = s.rank;
        let (l1, l2, l3) = (n1 * r, r * n2 * r, r * n3);

        if self.opts.grad_aggregation {
            scratch.work.clear();
            scratch
                .work
                .extend(scratch.agg_rows.iter().enumerate().map(|(w, &row)| (row, w as u32)));
        }
        let n_work = if self.opts.grad_aggregation {
            scratch.work.len()
        } else {
            scratch.occ.len()
        };
        // Gradients are staged per CHUNK (not per batch), so the staging
        // buffers stay bounded regardless of batch size — the fused path
        // keeps its no-full-materialization property.  The chunk size is a
        // constant (worker-count independent), so chunk boundaries — and
        // therefore results — are identical for every worker count.
        const BACKWARD_CHUNK: usize = 1024;
        let chunk_cap = n_work.min(BACKWARD_CHUNK);
        scratch.g1.resize(chunk_cap * l1, 0.0);
        scratch.g2.resize(chunk_cap * l2, 0.0);
        scratch.g3.resize(chunk_cap * l3, 0.0);

        // Non-fused arm: full-core shadow grads (TT-Rec's extra traffic).
        let mut shadow: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = if !self.opts.fused_update {
            Some((
                vec![0.0; self.core1.len()],
                vec![0.0; self.core2.len()],
                vec![0.0; self.core3.len()],
            ))
        } else {
            None
        };

        // Tiled backward: with aggregation on, the work list IS the
        // plan's ascending distinct-row list, so the forward layout's
        // hottest-first schedule drives the chunk's (read-only) chain
        // computation too — core slices of the big prefix groups stay
        // hot, and the tile microkernels take the inner hops.  Gradient
        // slots follow the schedule; the serial APPLY phase still walks
        // the original ascending work order through the inverse map, so
        // updates land in exactly the untiled order ⇒ bit-identical.
        let tiled_bwd = self.opts.grad_aggregation && plan.tiled()
            && plan.sched().len() == n_work;
        debug_assert!(
            !tiled_bwd
                || scratch
                    .work
                    .iter()
                    .zip(plan.uniq_rows.iter())
                    .all(|(&(row, _), &u)| row == u),
            "plan layout does not cover this work list"
        );

        if tiled_bwd {
            // One O(n_work) bucketing pass builds EVERY chunk's scheduled
            // compute order: work indices land in their chunk's region of
            // `chunk_order` (chunk c owns [c·CHUNK, ...)) in schedule
            // order, and `chunk_slot[w]` records each item's gradient
            // slot within its chunk.
            let n_chunks = n_work.div_ceil(BACKWARD_CHUNK);
            scratch.chunk_order.resize(n_work, 0);
            scratch.chunk_slot.resize(n_work, 0);
            scratch.chunk_cursors.clear();
            scratch
                .chunk_cursors
                .extend((0..n_chunks).map(|c| c * BACKWARD_CHUNK));
            for &slot in plan.sched() {
                let w = slot as usize;
                let c = w / BACKWARD_CHUNK;
                let at = scratch.chunk_cursors[c];
                scratch.chunk_cursors[c] = at + 1;
                scratch.chunk_order[at] = w as u32;
                scratch.chunk_slot[w] = (at - c * BACKWARD_CHUNK) as u32;
            }
        }

        let mut cs = 0usize;
        while cs < n_work {
            let ce = (cs + BACKWARD_CHUNK).min(n_work);
            let clen = ce - cs;
            // ---- compute the chunk's chains (read-only, exec-sharded) ---
            {
                let table = &*self;
                let (work, grads): (&[(u64, u32)], &[f32]) = if table.opts.grad_aggregation {
                    (&scratch.work[..], &scratch.agg_grads[..])
                } else {
                    (&scratch.occ[..], grad_out)
                };
                // ~3 slice GEMMs per item (~dim*rank madds each)
                let serial = table.pool.is_serial() || clen * dim * r < PAR_MIN_WORK;
                let shards: Vec<Range<usize>> = if serial {
                    vec![0..clen]
                } else {
                    split_ranges(clen, table.pool.workers())
                };
                if shards.len() > 1 {
                    if scratch.wp.len() < shards.len() {
                        scratch.wp.resize_with(shards.len(), Vec::new);
                    }
                    if scratch.wdp.len() < shards.len() {
                        scratch.wdp.resize_with(shards.len(), Vec::new);
                    }
                }
                let order: Option<&[u32]> =
                    if tiled_bwd { Some(&scratch.chunk_order[cs..ce]) } else { None };
                let run = |rg: Range<usize>,
                           b1: &mut [f32],
                           b2: &mut [f32],
                           b3: &mut [f32],
                           p: &mut Vec<f32>,
                           dp: &mut Vec<f32>| match order {
                    Some(ord) => compute_chains_order(
                        table, work, ord, grads, dim, rg, b1, b2, b3, p, dp,
                    ),
                    None => compute_chains(
                        table,
                        work,
                        grads,
                        dim,
                        cs + rg.start..cs + rg.end,
                        b1,
                        b2,
                        b3,
                        p,
                        dp,
                    ),
                };
                if shards.len() <= 1 {
                    run(
                        0..clen,
                        &mut scratch.g1[..clen * l1],
                        &mut scratch.g2[..clen * l2],
                        &mut scratch.g3[..clen * l3],
                        &mut scratch.chain_p,
                        &mut scratch.chain_dp,
                    );
                } else {
                    std::thread::scope(|sc| {
                        let run = &run;
                        let mut r1 = &mut scratch.g1[..clen * l1];
                        let mut r2 = &mut scratch.g2[..clen * l2];
                        let mut r3 = &mut scratch.g3[..clen * l3];
                        let mut pbufs = scratch.wp.iter_mut();
                        let mut dpbufs = scratch.wdp.iter_mut();
                        let last = shards.len() - 1;
                        let mut own = None;
                        for (i, rg) in shards.into_iter().enumerate() {
                            let len = rg.end - rg.start;
                            let (b1, t1) = std::mem::take(&mut r1).split_at_mut(len * l1);
                            r1 = t1;
                            let (b2, t2) = std::mem::take(&mut r2).split_at_mut(len * l2);
                            r2 = t2;
                            let (b3, t3) = std::mem::take(&mut r3).split_at_mut(len * l3);
                            r3 = t3;
                            let p = pbufs.next().unwrap();
                            let dp = dpbufs.next().unwrap();
                            if i == last {
                                // calling thread works the final shard
                                own = Some((rg, b1, b2, b3, p, dp));
                            } else {
                                sc.spawn(move || run(rg, b1, b2, b3, p, dp));
                            }
                        }
                        if let Some((rg, b1, b2, b3, p, dp)) = own {
                            run(rg, b1, b2, b3, p, dp);
                        }
                    });
                }
            }

            // ---- apply the chunk serially, in work order ----------------
            for w in cs..ce {
                let row = if self.opts.grad_aggregation {
                    scratch.work[w].0
                } else {
                    scratch.occ[w].0
                };
                let (i1u, i2u, i3u) = s.tt_indices(row);
                let (i1, i2, i3) = (i1u as usize, i2u as usize, i3u as usize);
                let wi = if tiled_bwd { scratch.chunk_slot[w] as usize } else { w - cs };
                let g1 = &scratch.g1[wi * l1..(wi + 1) * l1];
                let g2 = &scratch.g2[wi * l2..(wi + 1) * l2];
                let g3 = &scratch.g3[wi * l3..(wi + 1) * l3];
                match &mut shadow {
                    None => {
                        // fused: straight into the cores (paper §III-D)
                        axpy(&mut self.core1[i1 * l1..(i1 + 1) * l1], -lr, g1);
                        axpy(&mut self.core2[i2 * l2..(i2 + 1) * l2], -lr, g2);
                        axpy(&mut self.core3[i3 * l3..(i3 + 1) * l3], -lr, g3);
                    }
                    Some((sh1, sh2, sh3)) => {
                        add_assign(&mut sh1[i1 * l1..(i1 + 1) * l1], g1);
                        add_assign(&mut sh2[i2 * l2..(i2 + 1) * l2], g2);
                        add_assign(&mut sh3[i3 * l3..(i3 + 1) * l3], g3);
                    }
                }
            }
            cs = ce;
        }
        self.stats.backward_chains += n_work as u64;

        if let Some((sh1, sh2, sh3)) = shadow {
            // TT-Rec-style deferred apply: the extra full-core pass the
            // paper's fused update removes.
            axpy(&mut self.core1, -lr, &sh1);
            axpy(&mut self.core2, -lr, &sh2);
            axpy(&mut self.core3, -lr, &sh3);
        }
    }

    /// Materialize the full padded table (test-only; O(M·N)).
    pub fn materialize(&self) -> Vec<f32> {
        let s = self.shapes;
        let m = s.padded_m();
        let mut out = vec![0.0; m as usize * s.dim];
        let plen = s.n[0] * s.n[1] * s.rank;
        let mut p = vec![0.0; plen];
        let mut row = vec![0.0; s.dim];
        for i in 0..m {
            self.prefix_product(s.prefix_of(i), &mut p);
            let dst = &mut out[i as usize * s.dim..(i as usize + 1) * s.dim];
            self.row_into(&p, (i % s.m[2]) as usize, dst, &mut row);
        }
        out
    }
}

/// Forward hop-2 worker: materialize the distinct rows `range` (indices
/// into `rows`) into `out_block`, recomputing the shared prefix product
/// only on prefix change.  Shard boundaries are prefix-group starts, so
/// across all workers every distinct prefix is computed exactly once —
/// the Reuse-Buffer accounting is independent of the worker count.
fn fill_rows(
    t: &EffTtTable,
    rows: &[u64],
    range: Range<usize>,
    out_block: &mut [f32],
    plen: usize,
    dim: usize,
    p: &mut Vec<f32>,
) {
    let s = &t.shapes;
    debug_assert_eq!(out_block.len(), (range.end - range.start) * dim);
    // `p` is caller-provided so the serial path can reuse TtScratch
    // storage (allocation-free steady state); parallel workers hand in
    // their own empty vec.
    p.resize(plen, 0.0);
    let mut last_pref = u64::MAX;
    for (bi, ri) in range.enumerate() {
        let idx = rows[ri];
        let pf = s.prefix_of(idx);
        if pf != last_pref {
            t.prefix_product(pf, &mut p[..plen]);
            last_pref = pf;
        }
        let dst = &mut out_block[bi * dim..(bi + 1) * dim];
        dst.fill(0.0);
        // [n1·n2, R] · [R, n3] -> row-major [dim]
        gemm_acc(
            &p[..plen],
            t.slice3((idx % s.m[2]) as usize),
            dst,
            s.n[0] * s.n[1],
            s.rank,
            s.n[2],
        );
    }
}

/// Backward phase-2 worker: Eq. 8 chain products for work items `range`,
/// writing per-item core-slice gradients into `g1/g2/g3` (blocks indexed
/// from the start of `range`).  Reads the cores only; the caller applies
/// updates afterwards, serially, so results are worker-count-invariant.
#[allow(clippy::too_many_arguments)]
fn compute_chains(
    t: &EffTtTable,
    work: &[(u64, u32)],
    grads: &[f32],
    dim: usize,
    range: Range<usize>,
    g1: &mut [f32],
    g2: &mut [f32],
    g3: &mut [f32],
    p: &mut Vec<f32>,
    dp: &mut Vec<f32>,
) {
    let s = &t.shapes;
    let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
    let r = s.rank;
    let plen = n1 * n2 * r;
    let (l1, l2, l3) = (n1 * r, r * n2 * r, r * n3);
    // workspaces are caller-provided so the serial path reuses TtScratch
    // storage (allocation-free steady state)
    p.resize(plen, 0.0);
    dp.resize(plen, 0.0);
    let mut cached_prefix = u64::MAX;
    for (wi, w) in range.enumerate() {
        let (row, gslot) = work[w];
        let ge = &grads[gslot as usize * dim..(gslot as usize + 1) * dim];
        let (i1u, i2u, i3u) = s.tt_indices(row);
        let (i1, i2, i3) = (i1u as usize, i2u as usize, i3u as usize);
        let prefix = s.prefix_of(row);
        if prefix != cached_prefix {
            t.prefix_product(prefix, &mut p[..plen]);
            cached_prefix = prefix;
        }
        // dD3[:,i3] = Pᵀ [R, n1n2] · gE [n1n2, n3]
        let d3 = &mut g3[wi * l3..(wi + 1) * l3];
        d3.fill(0.0);
        gemm_at_acc(&p[..plen], ge, d3, r, n1 * n2, n3);
        // dP = gE [n1n2, n3] · D3-sliceᵀ [n3, R]
        dp[..plen].fill(0.0);
        gemm_bt_acc(ge, t.slice3(i3), &mut dp[..plen], n1 * n2, n3, r);
        // dD2[:,i2] = D1-sliceᵀ [R, n1] · dP(view [n1, n2R])
        let d2 = &mut g2[wi * l2..(wi + 1) * l2];
        d2.fill(0.0);
        gemm_at_acc(t.slice1(i1), &dp[..plen], d2, r, n1, n2 * r);
        // dD1[i1] = dP [n1, n2R] · D2-sliceᵀ [n2R, R]
        let d1 = &mut g1[wi * l1..(wi + 1) * l1];
        d1.fill(0.0);
        gemm_bt_acc(&dp[..plen], t.slice2(i2), d1, n1, n2 * r, r);
    }
}

/// Tiled backward worker: Eq. 8 chain products for scheduled positions
/// `range` of the chunk's hottest-first `order` (absolute work indices),
/// writing per-item gradients at their SCHEDULED slots (the apply phase
/// reads them back through the inverse map, in original work order).
/// Chains are pure reads of the cores, so walking them in schedule order
/// cannot change any value; the dD3/dD2 hops run the wide-lane k-unrolled
/// tile microkernel ([`gemm_at_tiledw`], bit-identical to
/// [`gemm_at_acc`]).
///
/// MIRROR of [`compute_chains`] (indirection + kernels are the ONLY
/// differences).  The untiled original is kept byte-identical to PR-2
/// execution so the `train_planned_pr2` bench arm stays an honest
/// baseline — any change to the chain sequence here must be applied
/// there too (and vice versa), or the equivalence tests will catch the
/// divergence.
#[allow(clippy::too_many_arguments)]
fn compute_chains_order(
    t: &EffTtTable,
    work: &[(u64, u32)],
    order: &[u32],
    grads: &[f32],
    dim: usize,
    range: Range<usize>,
    g1: &mut [f32],
    g2: &mut [f32],
    g3: &mut [f32],
    p: &mut Vec<f32>,
    dp: &mut Vec<f32>,
) {
    let s = &t.shapes;
    let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
    let r = s.rank;
    let plen = n1 * n2 * r;
    let (l1, l2, l3) = (n1 * r, r * n2 * r, r * n3);
    p.resize(plen, 0.0);
    dp.resize(plen, 0.0);
    let mut cached_prefix = u64::MAX;
    for (wi, oi) in range.enumerate() {
        let (row, gslot) = work[order[oi] as usize];
        let ge = &grads[gslot as usize * dim..(gslot as usize + 1) * dim];
        let (i1u, i2u, i3u) = s.tt_indices(row);
        let (i1, i2, i3) = (i1u as usize, i2u as usize, i3u as usize);
        let prefix = s.prefix_of(row);
        if prefix != cached_prefix {
            t.prefix_product(prefix, &mut p[..plen]);
            cached_prefix = prefix;
        }
        // dD3[:,i3] = Pᵀ [R, n1n2] · gE [n1n2, n3]
        let d3 = &mut g3[wi * l3..(wi + 1) * l3];
        d3.fill(0.0);
        gemm_at_tiledw(&p[..plen], ge, d3, r, n1 * n2, n3);
        // dP = gE [n1n2, n3] · D3-sliceᵀ [n3, R]
        dp[..plen].fill(0.0);
        gemm_bt_acc(ge, t.slice3(i3), &mut dp[..plen], n1 * n2, n3, r);
        // dD2[:,i2] = D1-sliceᵀ [R, n1] · dP(view [n1, n2R])
        let d2 = &mut g2[wi * l2..(wi + 1) * l2];
        d2.fill(0.0);
        gemm_at_tiledw(t.slice1(i1), &dp[..plen], d2, r, n1, n2 * r);
        // dD1[i1] = dP [n1, n2R] · D2-sliceᵀ [n2R, R]
        let d1 = &mut g1[wi * l1..(wi + 1) * l1];
        d1.fill(0.0);
        gemm_bt_acc(&dp[..plen], t.slice2(i2), d1, n1, n2 * r, r);
    }
}

/// Tiled forward hop-2 worker: like [`fill_rows`], but walks scheduled
/// positions `range` of the plan's hottest-first `order` (slots into
/// `rows`), writing each row at its SCHEDULED position in `out_block`.
/// Scheduled groups are contiguous runs with distinct prefixes, so the
/// prefix product still recomputes exactly on group change; the hop-2
/// contraction runs the wide-lane k-unrolled tile microkernel
/// ([`gemm_acc_kuw`], bit-identical to [`gemm_acc`]).
///
/// MIRROR of [`fill_rows`] (indirection + kernel are the ONLY
/// differences); see the mirror note on [`compute_chains_order`] for why
/// the untiled original is intentionally left byte-identical to PR-2.
#[allow(clippy::too_many_arguments)]
fn fill_rows_sched(
    t: &EffTtTable,
    rows: &[u64],
    order: &[u32],
    range: Range<usize>,
    out_block: &mut [f32],
    plen: usize,
    dim: usize,
    p: &mut Vec<f32>,
) {
    let s = &t.shapes;
    debug_assert_eq!(out_block.len(), (range.end - range.start) * dim);
    p.resize(plen, 0.0);
    let mut last_pref = u64::MAX;
    for (bi, pos) in range.enumerate() {
        let idx = rows[order[pos] as usize];
        let pf = s.prefix_of(idx);
        if pf != last_pref {
            t.prefix_product(pf, &mut p[..plen]);
            last_pref = pf;
        }
        let dst = &mut out_block[bi * dim..(bi + 1) * dim];
        dst.fill(0.0);
        // [n1·n2, R] · [R, n3] -> row-major [dim] (tile microkernel)
        gemm_acc_kuw(
            &p[..plen],
            t.slice3((idx % s.m[2]) as usize),
            dst,
            s.n[0] * s.n[1],
            s.rank,
            s.n[2],
        );
    }
}

/// Quantized forward hop-2 worker: the [`fill_rows_sched`] /
/// [`fill_rows`] walk against frozen cores, dispatching on the frozen
/// format.  Dequantization happens per element inside the microkernels
/// ([`gemm_acc_q`] / [`gemm_acc_ku_q`]) as the tile walk streams the
/// slices; the only materialized f32 operand is the tiny [n1, R]
/// first-hop slice seeding each prefix product.
#[allow(clippy::too_many_arguments)]
fn fill_rows_quant(
    t: &EffTtTable,
    q: &QuantCores,
    rows: &[u64],
    order: Option<&[u32]>,
    range: Range<usize>,
    out_block: &mut [f32],
    plen: usize,
    dim: usize,
    p: &mut Vec<f32>,
) {
    match q.mode {
        QuantizeMode::Off => unreachable!("frozen cores with mode=off"),
        QuantizeMode::Int8 => fill_rows_q_impl(
            t,
            rows,
            order,
            range,
            out_block,
            plen,
            dim,
            p,
            |i| q.q1.i8_slice(i),
            |i| q.q2.i8_slice(i),
            |i| q.q3.i8_slice(i),
        ),
        QuantizeMode::F16 => fill_rows_q_impl(
            t,
            rows,
            order,
            range,
            out_block,
            plen,
            dim,
            p,
            |i| q.q1.f16_slice(i),
            |i| q.q2.f16_slice(i),
            |i| q.q3.f16_slice(i),
        ),
    }
}

/// Monomorphized body of [`fill_rows_quant`]: same prefix-change /
/// hop-2 structure as the f32 walkers, with an `Option` order indirection
/// merging the tiled and untiled variants (new code — not bound by the
/// PR-2 mirror-byte-identity constraint on the f32 originals).
#[allow(clippy::too_many_arguments)]
fn fill_rows_q_impl<B1, B2, B3>(
    t: &EffTtTable,
    rows: &[u64],
    order: Option<&[u32]>,
    range: Range<usize>,
    out_block: &mut [f32],
    plen: usize,
    dim: usize,
    p: &mut Vec<f32>,
    s1: impl Fn(usize) -> B1,
    s2: impl Fn(usize) -> B2,
    s3: impl Fn(usize) -> B3,
) where
    B1: Dequant,
    B2: Dequant,
    B3: Dequant,
{
    let s = &t.shapes;
    debug_assert_eq!(out_block.len(), (range.end - range.start) * dim);
    let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
    let r = s.rank;
    let l1 = n1 * r;
    // `p` holds the prefix product plus the dequant staging area for the
    // first-hop slice (the one materialized operand).
    p.resize(plen + l1, 0.0);
    let (pbuf, a1) = p.split_at_mut(plen);
    let mut last_pref = u64::MAX;
    for (bi, pos) in range.enumerate() {
        let ri = match order {
            Some(o) => o[pos] as usize,
            None => pos,
        };
        let idx = rows[ri];
        let pf = s.prefix_of(idx);
        if pf != last_pref {
            let i1 = (pf / s.m[1]) as usize;
            let i2 = (pf % s.m[1]) as usize;
            s1(i1).dequant_into(a1);
            pbuf.fill(0.0);
            // [n1, R] · [R, n2·R] -> [n1, n2·R], B dequantized in-kernel
            gemm_acc_q(a1, s2(i2), pbuf, n1, r, n2 * r);
            last_pref = pf;
        }
        let dst = &mut out_block[bi * dim..(bi + 1) * dim];
        dst.fill(0.0);
        // [n1·n2, R] · [R, n3] -> row-major [dim] (quantized tile kernel)
        gemm_acc_ku_q(&pbuf[..plen], s3((idx % s.m[2]) as usize), dst, n1 * n2, r, n3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check_cases};

    fn table(rows: u64, dim: usize, rank: usize, opts: EffTtOptions, seed: u64) -> EffTtTable {
        let shapes = TtShapes::plan(rows, dim, rank);
        EffTtTable::new(shapes, opts, &mut Rng::new(seed))
    }

    fn bag_of(indices: &[u64]) -> (Vec<u64>, Vec<usize>) {
        (indices.to_vec(), vec![0, indices.len()])
    }

    #[test]
    fn lookup_matches_materialized() {
        check_cases("lookup", 20, |rng, _| {
            let rows = rng.below(3000) + 50;
            let mut t = table(rows, 16, 4, EffTtOptions::default(), rng.next_u64());
            let w = t.materialize();
            let idx: Vec<u64> = (0..8).map(|_| rng.below(rows)).collect();
            let (ind, off) = bag_of(&idx);
            let mut out = vec![0.0; 16];
            let mut scr = TtScratch::default();
            t.embedding_bag(&ind, &off, &mut out, &mut scr);
            let mut expect = vec![0.0f32; 16];
            for &i in &idx {
                for d in 0..16 {
                    expect[d] += w[i as usize * 16 + d];
                }
            }
            assert_allclose(&out, &expect, 1e-4, 1e-5);
        });
    }

    #[test]
    fn reuse_and_noreuse_identical_values() {
        check_cases("reuse-equiv", 20, |rng, _| {
            let rows = rng.below(2000) + 100;
            let seed = rng.next_u64();
            let mut a = table(rows, 8, 4, EffTtOptions::default(), seed);
            let mut b = table(rows, 8, 4, EffTtOptions::ttrec_baseline(), seed);
            // skewed: low indices overrepresented => shared prefixes
            let idx: Vec<u64> = (0..16).map(|_| rng.below(rows.min(40))).collect();
            let (ind, off) = bag_of(&idx);
            let (mut oa, mut ob) = (vec![0.0; 8], vec![0.0; 8]);
            let mut scr = TtScratch::default();
            a.embedding_bag(&ind, &off, &mut oa, &mut scr);
            b.embedding_bag(&ind, &off, &mut ob, &mut scr);
            assert_allclose(&oa, &ob, 1e-4, 1e-5);
            // and reuse must actually have saved work on a skewed batch
            assert!(a.stats.prefix_gemms <= b.stats.prefix_gemms);
        });
    }

    #[test]
    fn reuse_buffer_dedups_exactly() {
        let mut t = table(1000, 8, 4, EffTtOptions::default(), 3);
        let m3 = t.shapes.m[2];
        // 4 indices, 2 distinct prefixes
        let idx = vec![5 * m3, 5 * m3 + 1, 7 * m3 + 2, 7 * m3 + 2];
        let (ind, off) = bag_of(&idx);
        let mut out = vec![0.0; 8];
        let mut scr = TtScratch::default();
        t.embedding_bag(&ind, &off, &mut out, &mut scr);
        assert_eq!(t.stats.prefix_gemms, 2);
        assert_eq!(t.stats.reuse_hits, 2);
        // row-level reuse: the duplicated full index is computed once
        assert_eq!(t.stats.hop2_gemms, 3);
    }

    #[test]
    fn multi_bag_offsets() {
        let mut t = table(500, 16, 4, EffTtOptions::default(), 9);
        let w = t.materialize();
        let indices = vec![3u64, 7, 7, 100, 42];
        let offsets = vec![0usize, 3, 3, 5]; // bag1 = {3,7,7}, bag2 = {}, bag3 = {100,42}
        let mut out = vec![0.0; 3 * 16];
        let mut scr = TtScratch::default();
        t.embedding_bag(&indices, &offsets, &mut out, &mut scr);
        let mut expect = vec![0.0f32; 3 * 16];
        for d in 0..16 {
            expect[d] = w[3 * 16 + d] + 2.0 * w[7 * 16 + d];
            expect[32 + d] = w[100 * 16 + d] + w[42 * 16 + d];
        }
        assert_allclose(&out, &expect, 1e-4, 1e-5);
    }

    /// Numerical-gradient check of backward_sgd through a quadratic loss.
    #[test]
    fn backward_matches_numerical_gradient() {
        let shapes = TtShapes::plan(300, 8, 4);
        let mut rng = Rng::new(17);
        let t0 = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let idx = vec![5u64, 99, 5, 200];
        let offsets = vec![0usize, 2, 4];
        let target: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1).collect();

        let loss = |t: &mut EffTtTable| -> f32 {
            let mut out = vec![0.0; 16];
            let mut scr = TtScratch::default();
            t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
            out.iter().zip(&target).map(|(o, t)| (o - t) * (o - t)).sum()
        };

        // analytic: dL/dout = 2(out - target)
        let mut t = EffTtTable {
            shapes,
            opts: EffTtOptions::default(),
            core1: t0.core1.clone(),
            core2: t0.core2.clone(),
            core3: t0.core3.clone(),
            stats: TtStats::default(),
            pool: ExecPool::serial(),
            quant: None,
        };
        let mut out = vec![0.0; 16];
        let mut scr = TtScratch::default();
        t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
        let g: Vec<f32> = out.iter().zip(&target).map(|(o, t)| 2.0 * (o - t)).collect();

        // Probe a few core-1 entries by finite differences.
        for probe in [0usize, 3, 7] {
            let eps = 1e-3;
            let mut tp = EffTtTable {
                shapes,
                opts: EffTtOptions::default(),
                core1: t0.core1.clone(),
                core2: t0.core2.clone(),
                core3: t0.core3.clone(),
                stats: TtStats::default(),
                pool: ExecPool::serial(),
                quant: None,
            };
            tp.core1[probe] += eps;
            let fp = loss(&mut tp);
            tp.core1[probe] -= 2.0 * eps;
            let fm = loss(&mut tp);
            let numeric = (fp - fm) / (2.0 * eps);

            // analytic grad via backward with lr=1 on a fresh copy, fused off
            let mut ta = EffTtTable {
                shapes,
                opts: EffTtOptions { fused_update: false, ..Default::default() },
                core1: t0.core1.clone(),
                core2: t0.core2.clone(),
                core3: t0.core3.clone(),
                stats: TtStats::default(),
                pool: ExecPool::serial(),
                quant: None,
            };
            ta.backward_sgd(&idx, &offsets, &g, 1.0, &mut scr);
            let analytic = t0.core1[probe] - ta.core1[probe]; // lr=1 ⇒ grad
            assert!(
                (numeric - analytic).abs() < 2e-2 * numeric.abs().max(1.0),
                "probe {probe}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn aggregation_on_off_same_update() {
        // gradient aggregation must change cost, not semantics
        check_cases("agg-equiv", 10, |rng, _| {
            let shapes = TtShapes::plan(400, 8, 4);
            let seed = rng.next_u64();
            let mk = |agg: bool| {
                let mut t = EffTtTable::new(
                    shapes,
                    EffTtOptions {
                        grad_aggregation: agg,
                        fused_update: false,
                        ..Default::default()
                    },
                    &mut Rng::new(seed),
                );
                t
            };
            let mut a = mk(true);
            let mut b = mk(false);
            let idx = vec![7u64, 7, 7, 30, 30, 99];
            let offsets = vec![0usize, 3, 6];
            let g: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut scr = TtScratch::default();
            a.backward_sgd(&idx, &offsets, &g, 0.1, &mut scr);
            b.backward_sgd(&idx, &offsets, &g, 0.1, &mut scr);
            assert_allclose(&a.core1, &b.core1, 1e-4, 1e-6);
            assert_allclose(&a.core2, &b.core2, 1e-4, 1e-6);
            assert_allclose(&a.core3, &b.core3, 1e-4, 1e-6);
            assert!(a.stats.backward_chains < b.stats.backward_chains);
        });
    }

    #[test]
    fn sgd_descends() {
        let mut t = table(300, 8, 4, EffTtOptions::default(), 5);
        let idx = vec![5u64, 99, 5, 200];
        let offsets = vec![0usize, 2, 4];
        let target: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1).collect();
        let mut scr = TtScratch::default();
        let mut first = None;
        let mut last = f32::INFINITY;
        for _ in 0..120 {
            let mut out = vec![0.0; 16];
            t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
            let loss: f32 = out.iter().zip(&target).map(|(o, t)| (o - t) * (o - t)).sum();
            let g: Vec<f32> = out.iter().zip(&target).map(|(o, t)| 2.0 * (o - t)).collect();
            t.backward_sgd(&idx, &offsets, &g, 0.02, &mut scr);
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < 0.1 * first.unwrap(), "loss did not descend: {} -> {last}", first.unwrap());
    }

    #[test]
    fn quantized_forward_close_to_f32_and_smaller() {
        check_cases("quant-fwd", 10, |rng, _| {
            let rows = rng.below(2000) + 100;
            let seed = rng.next_u64();
            let mut t = table(rows, 16, 4, EffTtOptions::default(), seed);
            let idx: Vec<u64> = (0..24).map(|_| rng.below(rows)).collect();
            let (ind, off) = bag_of(&idx);
            let mut scr = TtScratch::default();
            let mut f32_out = vec![0.0; 16];
            t.embedding_bag(&ind, &off, &mut f32_out, &mut scr);
            let f32_bytes = t.bytes();
            for mode in [QuantizeMode::F16, QuantizeMode::Int8] {
                let mut q = t.clone();
                q.freeze_quantized(mode);
                assert!(q.bytes() < f32_bytes, "{mode:?} footprint not below f32");
                let mut out = vec![0.0; 16];
                let mut qscr = TtScratch::default();
                q.embedding_bag(&ind, &off, &mut out, &mut qscr);
                let (rtol, atol) = match mode {
                    QuantizeMode::F16 => (1e-2, 1e-2),
                    _ => (0.2, 0.2),
                };
                assert_allclose(&out, &f32_out, rtol, atol);
            }
        });
    }

    #[test]
    fn thawed_table_bit_identical_to_never_frozen() {
        let mut t = table(800, 16, 4, EffTtOptions::default(), 21);
        let idx: Vec<u64> = vec![3, 700, 3, 41, 98, 41];
        let (ind, off) = bag_of(&idx);
        let mut scr = TtScratch::default();
        let mut before = vec![0.0; 16];
        t.embedding_bag(&ind, &off, &mut before, &mut scr);
        t.freeze_quantized(QuantizeMode::Int8);
        t.freeze_quantized(QuantizeMode::Off);
        let mut after = vec![0.0; 16];
        t.embedding_bag(&ind, &off, &mut after, &mut scr);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&before), bits(&after));
    }

    #[test]
    #[should_panic(expected = "forward-only")]
    fn frozen_table_rejects_backward() {
        let mut t = table(400, 8, 4, EffTtOptions::default(), 7);
        t.freeze_quantized(QuantizeMode::Int8);
        let g = vec![0.0f32; 8];
        let mut scr = TtScratch::default();
        t.backward_sgd(&[5], &[0, 1], &g, 0.1, &mut scr);
    }

    #[test]
    fn quantize_mode_parses_and_rejects() {
        assert_eq!(QuantizeMode::parse("off").unwrap(), QuantizeMode::Off);
        assert_eq!(QuantizeMode::parse("int8").unwrap(), QuantizeMode::Int8);
        assert_eq!(QuantizeMode::parse("f16").unwrap(), QuantizeMode::F16);
        assert!(QuantizeMode::parse("fp8").is_err());
        assert_eq!(QuantizeMode::Int8.as_str(), "int8");
    }

    #[test]
    fn jax_layout_roundtrip() {
        let shapes = TtShapes::plan(600, 16, 4);
        let mut rng = Rng::new(123);
        let t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let (d1, d2, d3) = t.to_jax_cores();
        let t2 = EffTtTable::from_jax_cores(shapes, EffTtOptions::default(), &d1, &d2, &d3);
        assert_allclose(&t.core1, &t2.core1, 0.0, 0.0);
        assert_allclose(&t.core2, &t2.core2, 0.0, 0.0);
        assert_allclose(&t.core3, &t2.core3, 0.0, 0.0);
    }
}
