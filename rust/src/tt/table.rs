//! `EffTtTable` — the paper's Eff-TT embedding table (native engine).
//!
//! Drop-in for the `nn.EmbeddingBag(mode="sum")` contract: flat `indices`
//! plus `offsets` (bag b covers `indices[offsets[b]..offsets[b+1]]`), sum-
//! pooled output rows.  Three optimizations from §III are first-class and
//! individually switchable (Fig. 12 ablation):
//!
//! * **intermediate reuse** — the D1·D2 partial product is computed once
//!   per *distinct prefix* in the batch and kept in the Reuse Buffer;
//! * **gradient aggregation** — backward first merges gradients of
//!   repeated rows, then pays the Eq. 8 chain products once per distinct
//!   row;
//! * **fused update** — aggregated core gradients are applied in the same
//!   pass (SGD), no separate grad materialization or optimizer copy.
//!
//! Core memory layouts are chosen for contiguous slice GEMMs (they differ
//! from the jax artifact layout; see [`EffTtTable::from_jax_cores`]):
//!
//! ```text
//!   D1 [m1][n1·R]      slice(i1) = [n1, R]
//!   D2 [m2][R·n2·R]    slice(i2) = [R, n2·R]
//!   D3 [m3][R·n3]      slice(i3) = [R, n3]
//! ```


use crate::tt::linalg::{add_assign, axpy, gemm_acc, gemm_at_acc, gemm_bt_acc};
use crate::tt::shapes::TtShapes;
use crate::util::prng::Rng;

/// Which §III optimizations are active (Fig. 12 ablation switches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EffTtOptions {
    pub reuse: bool,
    pub grad_aggregation: bool,
    pub fused_update: bool,
}

impl Default for EffTtOptions {
    fn default() -> Self {
        EffTtOptions { reuse: true, grad_aggregation: true, fused_update: true }
    }
}

impl EffTtOptions {
    /// TT-Rec baseline behaviour: TT compression without the Eff-TT
    /// compute optimizations.
    pub fn ttrec_baseline() -> Self {
        EffTtOptions { reuse: false, grad_aggregation: false, fused_update: false }
    }
}

/// Lookup/backward instrumentation for the ablation benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct TtStats {
    /// First-hop GEMMs actually executed (== distinct prefixes when reuse
    /// is on, == total indices when off).
    pub prefix_gemms: u64,
    /// Reuse-buffer hits (first-hop GEMMs avoided).
    pub reuse_hits: u64,
    /// Second-hop GEMMs (always == total indices).
    pub hop2_gemms: u64,
    /// Backward chain products executed (× distinct rows when aggregation
    /// is on, × occurrences when off).
    pub backward_chains: u64,
    /// Occurrence gradients merged away by aggregation.
    pub grads_aggregated: u64,
}

impl TtStats {
    pub fn add(&mut self, o: &TtStats) {
        self.prefix_gemms += o.prefix_gemms;
        self.reuse_hits += o.reuse_hits;
        self.hop2_gemms += o.hop2_gemms;
        self.backward_chains += o.backward_chains;
        self.grads_aggregated += o.grads_aggregated;
    }
}

/// Reusable per-batch scratch so the hot path is allocation-free after
/// warmup (perf pass: §Perf L3).
#[derive(Default)]
pub struct TtScratch {
    /// Reuse Buffer: one [n1·n2, R] partial product per distinct prefix.
    buf: Vec<f32>,
    /// sort-based dedup workspace: (prefix, original position) pairs.
    /// (§Perf: sorting beats a HashMap here — the dedup runs per batch on
    /// the hot path and hashing 4k u64s cost more than the saved GEMMs.)
    order: Vec<(u64, u32)>,
    /// per-index slot assignment (parallel to the flat indices).
    index_slot: Vec<u32>,
    /// row scratch [n1·n2, n3] for hop-2 output.
    row: Vec<f32>,
    /// backward: sort-based aggregation workspace ((row, bag) pairs) and
    /// the aggregated per-distinct-row gradient buffer.
    occ: Vec<(u64, u32)>,
    agg_rows: Vec<u64>,
    agg_grads: Vec<f32>,
}

pub struct EffTtTable {
    pub shapes: TtShapes,
    pub opts: EffTtOptions,
    /// Cores in slice-contiguous layout (see module docs).
    pub core1: Vec<f32>,
    pub core2: Vec<f32>,
    pub core3: Vec<f32>,
    pub stats: TtStats,
}

impl EffTtTable {
    /// TT-Rec-style random init: σ chosen so materialized rows have
    /// variance ≈ 1/dim (matches `kernels.tt_lookup.init_cores`).
    pub fn new(shapes: TtShapes, opts: EffTtOptions, rng: &mut Rng) -> Self {
        let r = shapes.rank;
        let (m1, m2, m3) = (shapes.m[0] as usize, shapes.m[1] as usize, shapes.m[2] as usize);
        let (n1, n2, n3) = (shapes.n[0], shapes.n[1], shapes.n[2]);
        let sigma = (1.0 / (shapes.dim as f64 * (r * r) as f64)).powf(1.0 / 6.0) as f32;
        let mut core1 = vec![0.0; m1 * n1 * r];
        let mut core2 = vec![0.0; m2 * r * n2 * r];
        let mut core3 = vec![0.0; m3 * r * n3];
        rng.fill_normal(&mut core1, 0.0, sigma);
        rng.fill_normal(&mut core2, 0.0, sigma);
        rng.fill_normal(&mut core3, 0.0, sigma);
        EffTtTable { shapes, opts, core1, core2, core3, stats: TtStats::default() }
    }

    /// Build from cores in the jax artifact layout:
    /// D1 [m1, n1, R], D2 [R, m2, n2, R], D3 [R, m3, n3]
    /// (used by integration tests comparing native vs PJRT numerics).
    pub fn from_jax_cores(
        shapes: TtShapes,
        opts: EffTtOptions,
        d1: &[f32],
        d2: &[f32],
        d3: &[f32],
    ) -> Self {
        let r = shapes.rank;
        let (m1, m2, m3) = (shapes.m[0] as usize, shapes.m[1] as usize, shapes.m[2] as usize);
        let (n1, n2, n3) = (shapes.n[0], shapes.n[1], shapes.n[2]);
        assert_eq!(d1.len(), m1 * n1 * r);
        assert_eq!(d2.len(), r * m2 * n2 * r);
        assert_eq!(d3.len(), r * m3 * n3);
        // D1 layout is identical.
        let core1 = d1.to_vec();
        // D2: [r1, i2, j2, r2] -> [i2][r1, j2, r2]
        let mut core2 = vec![0.0; m2 * r * n2 * r];
        for r1 in 0..r {
            for i2 in 0..m2 {
                for x in 0..n2 * r {
                    core2[i2 * (r * n2 * r) + r1 * (n2 * r) + x] =
                        d2[r1 * (m2 * n2 * r) + i2 * (n2 * r) + x];
                }
            }
        }
        // D3: [r2, i3, j3] -> [i3][r2, j3]
        let mut core3 = vec![0.0; m3 * r * n3];
        for r2 in 0..r {
            for i3 in 0..m3 {
                for j3 in 0..n3 {
                    core3[i3 * (r * n3) + r2 * n3 + j3] =
                        d3[r2 * (m3 * n3) + i3 * n3 + j3];
                }
            }
        }
        EffTtTable { shapes, opts, core1, core2, core3, stats: TtStats::default() }
    }

    /// Export cores back to the jax layout (inverse of `from_jax_cores`).
    pub fn to_jax_cores(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let r = self.shapes.rank;
        let (m2, m3) = (self.shapes.m[1] as usize, self.shapes.m[2] as usize);
        let (n2, n3) = (self.shapes.n[1], self.shapes.n[2]);
        let d1 = self.core1.clone();
        let mut d2 = vec![0.0; r * m2 * n2 * r];
        for i2 in 0..m2 {
            for r1 in 0..r {
                for x in 0..n2 * r {
                    d2[r1 * (m2 * n2 * r) + i2 * (n2 * r) + x] =
                        self.core2[i2 * (r * n2 * r) + r1 * (n2 * r) + x];
                }
            }
        }
        let mut d3 = vec![0.0; r * m3 * n3];
        for i3 in 0..m3 {
            for r2 in 0..r {
                for j3 in 0..n3 {
                    d3[r2 * (m3 * n3) + i3 * n3 + j3] =
                        self.core3[i3 * (r * n3) + r2 * n3 + j3];
                }
            }
        }
        (d1, d2, d3)
    }

    #[inline]
    fn slice1(&self, i1: usize) -> &[f32] {
        let l = self.shapes.n[0] * self.shapes.rank;
        &self.core1[i1 * l..(i1 + 1) * l]
    }

    #[inline]
    fn slice2(&self, i2: usize) -> &[f32] {
        let l = self.shapes.rank * self.shapes.n[1] * self.shapes.rank;
        &self.core2[i2 * l..(i2 + 1) * l]
    }

    #[inline]
    fn slice3(&self, i3: usize) -> &[f32] {
        let l = self.shapes.rank * self.shapes.n[2];
        &self.core3[i3 * l..(i3 + 1) * l]
    }

    /// Bytes held by the TT cores.
    pub fn bytes(&self) -> u64 {
        ((self.core1.len() + self.core2.len() + self.core3.len()) * 4) as u64
    }

    /// Compute the partial product P(prefix) = D1[i1] · D2[:,i2]
    /// into `out` ([n1·n2, R] == [n1, n2·R] row-major).
    fn prefix_product(&self, prefix: u64, out: &mut [f32]) {
        let s = &self.shapes;
        let (n1, n2) = (s.n[0], s.n[1]);
        let r = s.rank;
        let i1 = (prefix / s.m[1]) as usize;
        let i2 = (prefix % s.m[1]) as usize;
        out.fill(0.0);
        // [n1, R] · [R, n2·R] -> [n1, n2·R]
        gemm_acc(self.slice1(i1), self.slice2(i2), out, n1, r, n2 * r);
    }

    /// Stage 1 of a batch lookup: populate the Reuse Buffer, assigning one
    /// slot per distinct prefix (or per occurrence when reuse is off).
    fn prepare_prefixes(&mut self, indices: &[u64], scratch: &mut TtScratch) {
        let s = self.shapes;
        let plen = s.n[0] * s.n[1] * s.rank;
        scratch.index_slot.clear();
        if self.opts.reuse {
            // one slot per *distinct* prefix — Algorithm 1 dedup.
            // Sort-based: sorting (prefix, pos) pairs is ~3x faster than a
            // HashMap at batch sizes that matter, and index reordering
            // (§III-G) pre-clusters the stream so pdqsort hits its
            // near-sorted fast path (§Perf L3 iteration 1).
            scratch.order.clear();
            scratch
                .order
                .extend(indices.iter().enumerate().map(|(k, &i)| (s.prefix_of(i), k as u32)));
            scratch.order.sort_unstable();
            scratch.index_slot.resize(indices.len(), 0);
            let mut uniq = 0usize;
            let mut last = u64::MAX;
            // first pass: assign slots (buf not yet sized)
            for &(p, pos) in scratch.order.iter() {
                if p != last {
                    last = p;
                    uniq += 1;
                }
                scratch.index_slot[pos as usize] = (uniq - 1) as u32;
            }
            scratch.buf.resize(uniq * plen, 0.0);
            // second pass: one GEMM per distinct prefix
            last = u64::MAX;
            let mut slot = 0usize;
            for &(p, _) in scratch.order.iter() {
                if p != last {
                    let buf = &mut scratch.buf[slot * plen..(slot + 1) * plen];
                    self.prefix_product(p, buf);
                    last = p;
                    slot += 1;
                }
            }
            self.stats.prefix_gemms += uniq as u64;
            self.stats.reuse_hits += (indices.len() - uniq) as u64;
        } else {
            // TT-Rec path: recompute P per occurrence
            scratch.buf.resize(indices.len() * plen, 0.0);
            for (k, &idx) in indices.iter().enumerate() {
                let p = s.prefix_of(idx);
                let buf = &mut scratch.buf[k * plen..(k + 1) * plen];
                self.prefix_product(p, buf);
                scratch.index_slot.push(k as u32);
            }
            self.stats.prefix_gemms += indices.len() as u64;
        }
    }

    /// Materialize a single row into `out` [dim] (+= semantics).
    fn row_into(&self, slot_p: &[f32], i3: usize, out: &mut [f32], scratch_row: &mut [f32]) {
        let s = &self.shapes;
        let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
        let r = s.rank;
        scratch_row.fill(0.0);
        // [n1·n2, R] · [R, n3] -> [n1·n2, n3] == row-major [dim]
        gemm_acc(slot_p, self.slice3(i3), scratch_row, n1 * n2, r, n3);
        add_assign(out, scratch_row);
    }

    /// EmbeddingBag(sum) forward: `out` is [num_bags, dim] row-major.
    ///
    /// `offsets` has `num_bags + 1` entries; bag b pools
    /// `indices[offsets[b]..offsets[b+1]]`.
    pub fn embedding_bag(
        &mut self,
        indices: &[u64],
        offsets: &[usize],
        out: &mut [f32],
        scratch: &mut TtScratch,
    ) {
        let s = self.shapes;
        let dim = s.dim;
        let bags = offsets.len() - 1;
        assert_eq!(out.len(), bags * dim);
        assert_eq!(*offsets.last().unwrap(), indices.len());
        for &i in indices {
            assert!(i < s.rows, "index {i} out of range {}", s.rows);
        }
        let plen = s.n[0] * s.n[1] * s.rank;
        if self.opts.reuse {
            // §Perf L3 iteration 4: sample-level reuse taken to its
            // conclusion (paper §III-B "intermediate results from each
            // embedding ROW can be recycled"): sort (index, pos) once,
            // compute each distinct PREFIX product once (first hop) and
            // each distinct ROW once (second hop), then scatter-add into
            // the bags.  Prefix runs are contiguous in sorted order, so
            // both levels fall out of one sweep.
            scratch.order.clear();
            scratch
                .order
                .extend(indices.iter().enumerate().map(|(k, &i)| (i, k as u32)));
            scratch.order.sort_unstable();
            scratch.index_slot.resize(indices.len(), 0);
            // count uniques for buffer sizing
            let mut uniq_rows = 0usize;
            let mut uniq_pref = 0usize;
            let mut last_row = u64::MAX;
            let mut last_pref = u64::MAX;
            for &(idx, _) in scratch.order.iter() {
                if idx != last_row {
                    uniq_rows += 1;
                    last_row = idx;
                    let pf = s.prefix_of(idx);
                    if pf != last_pref {
                        uniq_pref += 1;
                        last_pref = pf;
                    }
                }
            }
            scratch.buf.resize(plen.max(1), 0.0); // single P (runs are contiguous)
            scratch.row.resize(uniq_rows * dim, 0.0);
            let mut row_slot = usize::MAX;
            last_row = u64::MAX;
            last_pref = u64::MAX;
            for oi in 0..scratch.order.len() {
                let (idx, pos) = scratch.order[oi];
                if idx != last_row {
                    let pf = s.prefix_of(idx);
                    if pf != last_pref {
                        // split-borrow: buf is scratch.buf, cores are self
                        let buf = &mut scratch.buf[..plen];
                        self.prefix_product(pf, buf);
                        last_pref = pf;
                        self.stats.prefix_gemms += 1;
                    }
                    row_slot = row_slot.wrapping_add(1);
                    let dst = &mut scratch.row[row_slot * dim..(row_slot + 1) * dim];
                    dst.fill(0.0);
                    let i3 = (idx % s.m[2]) as usize;
                    // [n1·n2, R] · [R, n3] -> row-major [dim]
                    gemm_acc(
                        &scratch.buf[..plen],
                        self.slice3(i3),
                        dst,
                        s.n[0] * s.n[1],
                        s.rank,
                        s.n[2],
                    );
                    self.stats.hop2_gemms += 1;
                    last_row = idx;
                }
                scratch.index_slot[pos as usize] = row_slot as u32;
            }
            self.stats.reuse_hits += (indices.len() - uniq_pref) as u64;
            let _ = uniq_rows;
            // scatter-add rows into bags
            out.fill(0.0);
            for b in 0..bags {
                let (head, tail) = out.split_at_mut(b * dim);
                let _ = head;
                let dst = &mut tail[..dim];
                for k in offsets[b]..offsets[b + 1] {
                    let slot = scratch.index_slot[k] as usize;
                    add_assign(dst, &scratch.row[slot * dim..(slot + 1) * dim]);
                }
            }
        } else {
            // TT-Rec path: recompute everything per occurrence
            self.prepare_prefixes(indices, scratch);
            scratch.row.resize(dim, 0.0);
            let mut row_tmp = std::mem::take(&mut scratch.row);
            out.fill(0.0);
            for b in 0..bags {
                let dst = &mut out[b * dim..(b + 1) * dim];
                for k in offsets[b]..offsets[b + 1] {
                    let idx = indices[k];
                    let slot = scratch.index_slot[k] as usize;
                    let p = &scratch.buf[slot * plen..(slot + 1) * plen];
                    let i3 = (idx % s.m[2]) as usize;
                    self.row_into(p, i3, dst, &mut row_tmp);
                    self.stats.hop2_gemms += 1;
                }
            }
            scratch.row = row_tmp;
        }
    }

    /// Convenience single-row lookup (serving path).
    pub fn lookup_row(&mut self, index: u64, out: &mut [f32], scratch: &mut TtScratch) {
        let offsets = [0usize, 1usize];
        self.embedding_bag(&[index], &offsets, out, scratch);
    }

    /// Backward + (optionally fused) SGD update.
    ///
    /// `grad_out` is ∂L/∂(pooled bags) [num_bags, dim]: occurrence (b, k)
    /// receives grad_out[b] (sum pooling).  Returns nothing — cores are
    /// updated in place with learning rate `lr` (the paper's fused update);
    /// when `fused_update` is off the grads are first fully materialized
    /// per-core and then applied (extra traffic, as in TT-Rec).
    pub fn backward_sgd(
        &mut self,
        indices: &[u64],
        offsets: &[usize],
        grad_out: &[f32],
        lr: f32,
        scratch: &mut TtScratch,
    ) {
        let s = self.shapes;
        let dim = s.dim;
        let bags = offsets.len() - 1;
        assert_eq!(grad_out.len(), bags * dim);

        // ---- step 1: advance gradient aggregation (Fig. 5b) -------------
        // Sort-based segmented accumulation (§Perf L3 iteration 2): the
        // occurrence list (row, bag) is sorted by row and gradients are
        // summed into ONE flat reusable buffer — no HashMap, no per-row
        // Vec allocations.  Sorted order also keeps fused updates to
        // shared core slices bit-for-bit reproducible across runs (the
        // pipeline == sequential guarantee relies on it).
        scratch.occ.clear();
        for b in 0..bags {
            for k in offsets[b]..offsets[b + 1] {
                scratch.occ.push((indices[k], b as u32));
            }
        }
        if self.opts.grad_aggregation {
            scratch.occ.sort_unstable();
            scratch.agg_rows.clear();
            scratch.agg_grads.clear();
            let mut last = u64::MAX;
            for &(row, b) in scratch.occ.iter() {
                if row != last {
                    scratch.agg_rows.push(row);
                    let start = scratch.agg_grads.len();
                    scratch.agg_grads.resize(start + dim, 0.0);
                    last = row;
                }
                let slot = scratch.agg_rows.len() - 1;
                add_assign(
                    &mut scratch.agg_grads[slot * dim..(slot + 1) * dim],
                    &grad_out[b as usize * dim..(b as usize + 1) * dim],
                );
            }
            self.stats.grads_aggregated +=
                (scratch.occ.len() - scratch.agg_rows.len()) as u64;
        }

        // ---- step 2: Eq. 8 chain products per work item ------------------
        let (n1, n2, n3) = (s.n[0], s.n[1], s.n[2]);
        let r = s.rank;
        let plen = n1 * n2 * r;

        // When the fused update is off, accumulate into shadow grads first.
        let mut shadow: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = if !self.opts.fused_update {
            Some((
                vec![0.0; self.core1.len()],
                vec![0.0; self.core2.len()],
                vec![0.0; self.core3.len()],
            ))
        } else {
            None
        };

        let mut p = vec![0.0; plen];
        let mut dslice3 = vec![0.0; r * n3];
        let mut dp = vec![0.0; plen];
        let mut dslice2 = vec![0.0; r * n2 * r];
        let mut dslice1 = vec![0.0; n1 * r];
        // work items: aggregated slots, or raw occurrences (TT-Rec arm)
        let n_work = if self.opts.grad_aggregation {
            scratch.agg_rows.len()
        } else {
            scratch.occ.len()
        };
        // Â§Perf L3 iteration 3: the aggregated work list is sorted by row,
        // so rows sharing a TT prefix are adjacent â the Reuse-Buffer idea
        // applied to BACKWARD: recompute P only on prefix change.  (In the
        // fused path this also means every grad in a same-prefix run is
        // evaluated at the same parameter point â closer to textbook SGD
        // than per-item recomputation.)
        let mut cached_prefix = u64::MAX;
        for w in 0..n_work {
            let (row, ge): (u64, &[f32]) = if self.opts.grad_aggregation {
                (
                    scratch.agg_rows[w],
                    &scratch.agg_grads[w * dim..(w + 1) * dim],
                )
            } else {
                let (row, b) = scratch.occ[w];
                (row, &grad_out[b as usize * dim..(b as usize + 1) * dim])
            };
            let (i1u, i2u, i3u) = s.tt_indices(row);
            let (i1, i2, i3) = (i1u as usize, i2u as usize, i3u as usize);
            let prefix = s.prefix_of(row);
            if prefix != cached_prefix {
                self.prefix_product(prefix, &mut p);
                cached_prefix = prefix;
            }

            // dD3[:,i3] += Pᵀ [R, n1n2] · gE [n1n2, n3]
            dslice3.fill(0.0);
            gemm_at_acc(&p, ge, &mut dslice3, r, n1 * n2, n3);

            // dP = gE [n1n2, n3] · D3-sliceᵀ [n3, R]
            dp.fill(0.0);
            gemm_bt_acc(ge, self.slice3(i3), &mut dp, n1 * n2, n3, r);

            // dD2[:,i2] += D1-sliceᵀ [R, n1] · dP(view [n1, n2R])
            dslice2.fill(0.0);
            gemm_at_acc(self.slice1(i1), &dp, &mut dslice2, r, n1, n2 * r);

            // dD1[i1] += dP [n1, n2R] · D2-sliceᵀ [n2R, R]
            dslice1.fill(0.0);
            gemm_bt_acc(&dp, self.slice2(i2), &mut dslice1, n1, n2 * r, r);

            self.stats.backward_chains += 1;

            match &mut shadow {
                Some((g1, g2, g3)) => {
                    let l1 = n1 * r;
                    add_assign(&mut g1[i1 * l1..(i1 + 1) * l1], &dslice1);
                    let l2 = r * n2 * r;
                    add_assign(&mut g2[i2 * l2..(i2 + 1) * l2], &dslice2);
                    let l3 = r * n3;
                    add_assign(&mut g3[i3 * l3..(i3 + 1) * l3], &dslice3);
                }
                None => {
                    // fused: apply immediately
                    let l1 = n1 * r;
                    axpy(&mut self.core1[i1 * l1..(i1 + 1) * l1], -lr, &dslice1);
                    let l2 = r * n2 * r;
                    axpy(&mut self.core2[i2 * l2..(i2 + 1) * l2], -lr, &dslice2);
                    let l3 = r * n3;
                    axpy(&mut self.core3[i3 * l3..(i3 + 1) * l3], -lr, &dslice3);
                }
            }
        }
        if let Some((g1, g2, g3)) = shadow {
            // TT-Rec-style deferred apply: an extra full-core pass.
            axpy(&mut self.core1, -lr, &g1);
            axpy(&mut self.core2, -lr, &g2);
            axpy(&mut self.core3, -lr, &g3);
        }
        // IMPORTANT (fused path): applying a slice update can affect later
        // chain products only if the same core slice is revisited; the
        // paper accepts this Hogwild-style race within a batch (grads are
        // already aggregated per-row, so each (i1,i2,i3) triple is visited
        // once — only *shared* slices between different rows see it).
    }

    /// Materialize the full padded table (test-only; O(M·N)).
    pub fn materialize(&self) -> Vec<f32> {
        let s = self.shapes;
        let m = s.padded_m();
        let mut out = vec![0.0; m as usize * s.dim];
        let plen = s.n[0] * s.n[1] * s.rank;
        let mut p = vec![0.0; plen];
        let mut row = vec![0.0; s.dim];
        for i in 0..m {
            self.prefix_product(s.prefix_of(i), &mut p);
            let dst = &mut out[i as usize * s.dim..(i as usize + 1) * s.dim];
            self.row_into(&p, (i % s.m[2]) as usize, dst, &mut row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, check_cases};

    fn table(rows: u64, dim: usize, rank: usize, opts: EffTtOptions, seed: u64) -> EffTtTable {
        let shapes = TtShapes::plan(rows, dim, rank);
        EffTtTable::new(shapes, opts, &mut Rng::new(seed))
    }

    fn bag_of(indices: &[u64]) -> (Vec<u64>, Vec<usize>) {
        (indices.to_vec(), vec![0, indices.len()])
    }

    #[test]
    fn lookup_matches_materialized() {
        check_cases("lookup", 20, |rng, _| {
            let rows = rng.below(3000) + 50;
            let mut t = table(rows, 16, 4, EffTtOptions::default(), rng.next_u64());
            let w = t.materialize();
            let idx: Vec<u64> = (0..8).map(|_| rng.below(rows)).collect();
            let (ind, off) = bag_of(&idx);
            let mut out = vec![0.0; 16];
            let mut scr = TtScratch::default();
            t.embedding_bag(&ind, &off, &mut out, &mut scr);
            let mut expect = vec![0.0f32; 16];
            for &i in &idx {
                for d in 0..16 {
                    expect[d] += w[i as usize * 16 + d];
                }
            }
            assert_allclose(&out, &expect, 1e-4, 1e-5);
        });
    }

    #[test]
    fn reuse_and_noreuse_identical_values() {
        check_cases("reuse-equiv", 20, |rng, _| {
            let rows = rng.below(2000) + 100;
            let seed = rng.next_u64();
            let mut a = table(rows, 8, 4, EffTtOptions::default(), seed);
            let mut b = table(rows, 8, 4, EffTtOptions::ttrec_baseline(), seed);
            // skewed: low indices overrepresented => shared prefixes
            let idx: Vec<u64> = (0..16).map(|_| rng.below(rows.min(40))).collect();
            let (ind, off) = bag_of(&idx);
            let (mut oa, mut ob) = (vec![0.0; 8], vec![0.0; 8]);
            let mut scr = TtScratch::default();
            a.embedding_bag(&ind, &off, &mut oa, &mut scr);
            b.embedding_bag(&ind, &off, &mut ob, &mut scr);
            assert_allclose(&oa, &ob, 1e-4, 1e-5);
            // and reuse must actually have saved work on a skewed batch
            assert!(a.stats.prefix_gemms <= b.stats.prefix_gemms);
        });
    }

    #[test]
    fn reuse_buffer_dedups_exactly() {
        let mut t = table(1000, 8, 4, EffTtOptions::default(), 3);
        let m3 = t.shapes.m[2];
        // 4 indices, 2 distinct prefixes
        let idx = vec![5 * m3, 5 * m3 + 1, 7 * m3 + 2, 7 * m3 + 2];
        let (ind, off) = bag_of(&idx);
        let mut out = vec![0.0; 8];
        let mut scr = TtScratch::default();
        t.embedding_bag(&ind, &off, &mut out, &mut scr);
        assert_eq!(t.stats.prefix_gemms, 2);
        assert_eq!(t.stats.reuse_hits, 2);
        // row-level reuse: the duplicated full index is computed once
        assert_eq!(t.stats.hop2_gemms, 3);
    }

    #[test]
    fn multi_bag_offsets() {
        let mut t = table(500, 16, 4, EffTtOptions::default(), 9);
        let w = t.materialize();
        let indices = vec![3u64, 7, 7, 100, 42];
        let offsets = vec![0usize, 3, 3, 5]; // bag1 = {3,7,7}, bag2 = {}, bag3 = {100,42}
        let mut out = vec![0.0; 3 * 16];
        let mut scr = TtScratch::default();
        t.embedding_bag(&indices, &offsets, &mut out, &mut scr);
        let mut expect = vec![0.0f32; 3 * 16];
        for d in 0..16 {
            expect[d] = w[3 * 16 + d] + 2.0 * w[7 * 16 + d];
            expect[32 + d] = w[100 * 16 + d] + w[42 * 16 + d];
        }
        assert_allclose(&out, &expect, 1e-4, 1e-5);
    }

    /// Numerical-gradient check of backward_sgd through a quadratic loss.
    #[test]
    fn backward_matches_numerical_gradient() {
        let shapes = TtShapes::plan(300, 8, 4);
        let mut rng = Rng::new(17);
        let t0 = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let idx = vec![5u64, 99, 5, 200];
        let offsets = vec![0usize, 2, 4];
        let target: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1).collect();

        let loss = |t: &mut EffTtTable| -> f32 {
            let mut out = vec![0.0; 16];
            let mut scr = TtScratch::default();
            t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
            out.iter().zip(&target).map(|(o, t)| (o - t) * (o - t)).sum()
        };

        // analytic: dL/dout = 2(out - target)
        let mut t = EffTtTable {
            shapes,
            opts: EffTtOptions::default(),
            core1: t0.core1.clone(),
            core2: t0.core2.clone(),
            core3: t0.core3.clone(),
            stats: TtStats::default(),
        };
        let mut out = vec![0.0; 16];
        let mut scr = TtScratch::default();
        t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
        let g: Vec<f32> = out.iter().zip(&target).map(|(o, t)| 2.0 * (o - t)).collect();

        // Probe a few core-1 entries by finite differences.
        for probe in [0usize, 3, 7] {
            let eps = 1e-3;
            let mut tp = EffTtTable {
                shapes,
                opts: EffTtOptions::default(),
                core1: t0.core1.clone(),
                core2: t0.core2.clone(),
                core3: t0.core3.clone(),
                stats: TtStats::default(),
            };
            tp.core1[probe] += eps;
            let fp = loss(&mut tp);
            tp.core1[probe] -= 2.0 * eps;
            let fm = loss(&mut tp);
            let numeric = (fp - fm) / (2.0 * eps);

            // analytic grad via backward with lr=1 on a fresh copy, fused off
            let mut ta = EffTtTable {
                shapes,
                opts: EffTtOptions { fused_update: false, ..Default::default() },
                core1: t0.core1.clone(),
                core2: t0.core2.clone(),
                core3: t0.core3.clone(),
                stats: TtStats::default(),
            };
            ta.backward_sgd(&idx, &offsets, &g, 1.0, &mut scr);
            let analytic = t0.core1[probe] - ta.core1[probe]; // lr=1 ⇒ grad
            assert!(
                (numeric - analytic).abs() < 2e-2 * numeric.abs().max(1.0),
                "probe {probe}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn aggregation_on_off_same_update() {
        // gradient aggregation must change cost, not semantics
        check_cases("agg-equiv", 10, |rng, _| {
            let shapes = TtShapes::plan(400, 8, 4);
            let seed = rng.next_u64();
            let mk = |agg: bool| {
                let mut t = EffTtTable::new(
                    shapes,
                    EffTtOptions {
                        grad_aggregation: agg,
                        fused_update: false,
                        ..Default::default()
                    },
                    &mut Rng::new(seed),
                );
                t
            };
            let mut a = mk(true);
            let mut b = mk(false);
            let idx = vec![7u64, 7, 7, 30, 30, 99];
            let offsets = vec![0usize, 3, 6];
            let g: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut scr = TtScratch::default();
            a.backward_sgd(&idx, &offsets, &g, 0.1, &mut scr);
            b.backward_sgd(&idx, &offsets, &g, 0.1, &mut scr);
            assert_allclose(&a.core1, &b.core1, 1e-4, 1e-6);
            assert_allclose(&a.core2, &b.core2, 1e-4, 1e-6);
            assert_allclose(&a.core3, &b.core3, 1e-4, 1e-6);
            assert!(a.stats.backward_chains < b.stats.backward_chains);
        });
    }

    #[test]
    fn sgd_descends() {
        let mut t = table(300, 8, 4, EffTtOptions::default(), 5);
        let idx = vec![5u64, 99, 5, 200];
        let offsets = vec![0usize, 2, 4];
        let target: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1).collect();
        let mut scr = TtScratch::default();
        let mut first = None;
        let mut last = f32::INFINITY;
        for _ in 0..120 {
            let mut out = vec![0.0; 16];
            t.embedding_bag(&idx, &offsets, &mut out, &mut scr);
            let loss: f32 = out.iter().zip(&target).map(|(o, t)| (o - t) * (o - t)).sum();
            let g: Vec<f32> = out.iter().zip(&target).map(|(o, t)| 2.0 * (o - t)).collect();
            t.backward_sgd(&idx, &offsets, &g, 0.02, &mut scr);
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < 0.1 * first.unwrap(), "loss did not descend: {} -> {last}", first.unwrap());
    }

    #[test]
    fn jax_layout_roundtrip() {
        let shapes = TtShapes::plan(600, 16, 4);
        let mut rng = Rng::new(123);
        let t = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let (d1, d2, d3) = t.to_jax_cores();
        let t2 = EffTtTable::from_jax_cores(shapes, EffTtOptions::default(), &d1, &d2, &d3);
        assert_allclose(&t.core1, &t2.core1, 0.0, 0.0);
        assert_allclose(&t.core2, &t2.core2, 0.0, 0.0);
        assert_allclose(&t.core3, &t2.core3, 0.0, 0.0);
    }
}
