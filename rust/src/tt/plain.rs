//! `PlainTable` — the uncompressed `nn.EmbeddingBag` baseline (DLRM/FAE
//! store these in host memory; Table IV compares their footprint against
//! Eff-TT).  Same `embedding_bag` contract as [`EffTtTable`].

use crate::tt::linalg::{add_assign, axpy};
use crate::util::prng::Rng;

#[derive(Clone)]
pub struct PlainTable {
    pub rows: u64,
    pub dim: usize,
    pub weights: Vec<f32>,
}

impl PlainTable {
    pub fn new(rows: u64, dim: usize, rng: &mut Rng) -> Self {
        let mut weights = vec![0.0; rows as usize * dim];
        let sigma = (1.0 / dim as f64).sqrt() as f32;
        rng.fill_normal(&mut weights, 0.0, sigma);
        PlainTable { rows, dim, weights }
    }

    /// Zero-initialized table (for gradient accumulators).
    pub fn zeros(rows: u64, dim: usize) -> Self {
        PlainTable { rows, dim, weights: vec![0.0; rows as usize * dim] }
    }

    #[inline]
    pub fn row(&self, i: u64) -> &[f32] {
        let d = self.dim;
        &self.weights[i as usize * d..(i as usize + 1) * d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: u64) -> &mut [f32] {
        let d = self.dim;
        &mut self.weights[i as usize * d..(i as usize + 1) * d]
    }

    pub fn bytes(&self) -> u64 {
        (self.weights.len() * 4) as u64
    }

    /// EmbeddingBag(sum) forward — same contract as `EffTtTable`.
    pub fn embedding_bag(&self, indices: &[u64], offsets: &[usize], out: &mut [f32]) {
        let d = self.dim;
        let bags = offsets.len() - 1;
        assert_eq!(out.len(), bags * d);
        out.fill(0.0);
        for b in 0..bags {
            let dst = &mut out[b * d..(b + 1) * d];
            for k in offsets[b]..offsets[b + 1] {
                let i = indices[k];
                debug_assert!(i < self.rows);
                let row = &self.weights[i as usize * d..(i as usize + 1) * d];
                add_assign(dst, row);
            }
        }
    }

    /// SGD on the touched rows (sparse update).
    pub fn backward_sgd(
        &mut self,
        indices: &[u64],
        offsets: &[usize],
        grad_out: &[f32],
        lr: f32,
    ) {
        let d = self.dim;
        let bags = offsets.len() - 1;
        assert_eq!(grad_out.len(), bags * d);
        for b in 0..bags {
            let g = &grad_out[b * d..(b + 1) * d];
            for k in offsets[b]..offsets[b + 1] {
                let i = indices[k] as usize;
                axpy(&mut self.weights[i * d..(i + 1) * d], -lr, g);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;

    #[test]
    fn bag_sums_rows() {
        let mut rng = Rng::new(1);
        let t = PlainTable::new(10, 4, &mut rng);
        let mut out = vec![0.0; 4];
        t.embedding_bag(&[2, 2, 5], &[0, 3], &mut out);
        let expect: Vec<f32> = (0..4)
            .map(|d| 2.0 * t.weights[2 * 4 + d] + t.weights[5 * 4 + d])
            .collect();
        assert_allclose(&out, &expect, 1e-6, 1e-7);
    }

    #[test]
    fn sgd_moves_only_touched_rows() {
        let mut rng = Rng::new(2);
        let mut t = PlainTable::new(10, 4, &mut rng);
        let before = t.weights.clone();
        let g = vec![1.0; 4];
        t.backward_sgd(&[3], &[0, 1], &g, 0.5);
        for i in 0..10 {
            for d in 0..4 {
                let idx = i * 4 + d;
                if i == 3 {
                    assert!((t.weights[idx] - (before[idx] - 0.5)).abs() < 1e-6);
                } else {
                    assert_eq!(t.weights[idx], before[idx]);
                }
            }
        }
    }

    #[test]
    fn duplicate_in_bag_gets_double_grad() {
        let mut t = PlainTable::zeros(5, 2);
        t.backward_sgd(&[1, 1], &[0, 2], &[1.0, 2.0], 1.0);
        assert_allclose(t.row(1), &[-2.0, -4.0], 1e-6, 1e-7);
    }
}
