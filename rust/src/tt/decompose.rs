//! TT-SVD: decompose an EXISTING embedding table into TT cores
//! (Oseledets 2011, the algorithm TT-Rec uses to initialize from
//! pretrained weights — §II-B "trainable TT embedding table").
//!
//! The paper trains cores from random init, but production migration
//! (the nn.EmbeddingBag drop-in story) needs to import pretrained
//! tables: W [M×N] is reshaped to the (m1·n1)×(m2·n2)×(m3·n3) tensor of
//! Eq. 2 and factored by two successive truncated SVDs.  Jacobi one-sided
//! SVD keeps us dependency-free; tables are decomposed in f64 for
//! stability and stored back as f32 cores.

use crate::tt::shapes::TtShapes;
use crate::tt::table::{EffTtOptions, EffTtTable};

/// Dense column-major-free matrix helper for the decomposition path.
struct Mat {
    r: usize,
    c: usize,
    a: Vec<f64>,
}

impl Mat {
    fn zeros(r: usize, c: usize) -> Mat {
        Mat { r, c, a: vec![0.0; r * c] }
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.c + j]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.c + j] = v;
    }
}

/// One-sided Jacobi SVD: A [r×c] = U Σ Vᵀ with r ≥ 1, returns
/// (U [r×k], σ [k], V [c×k]) for k = min(r, c), singular values
/// descending.  O(r·c²·sweeps) — fine for the slim matrices TT-SVD
/// produces (c ≤ m·n ≤ a few hundred at embedding shapes).
fn jacobi_svd(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (r, c) = (a.r, a.c);
    // work on columns of A; accumulate V as rotations applied to identity
    let mut u = Mat { r, c, a: a.a.clone() };
    let mut v = Mat::zeros(c, c);
    for i in 0..c {
        v.set(i, i, 1.0);
    }
    let col_dot = |m: &Mat, i: usize, j: usize| -> f64 {
        (0..m.r).map(|t| m.at(t, i) * m.at(t, j)).sum()
    };
    for _sweep in 0..30 {
        let mut off = 0.0f64;
        for i in 0..c {
            for j in i + 1..c {
                let aii = col_dot(&u, i, i);
                let ajj = col_dot(&u, j, j);
                let aij = col_dot(&u, i, j);
                off += aij * aij;
                if aij.abs() < 1e-14 * (aii * ajj).sqrt().max(1e-300) {
                    continue;
                }
                // Jacobi rotation zeroing the (i,j) gram entry
                let tau = (ajj - aii) / (2.0 * aij);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let cs = 1.0 / (1.0 + t * t).sqrt();
                let sn = cs * t;
                for m in [&mut u, &mut v] {
                    for row in 0..m.r {
                        let (xi, xj) = (m.at(row, i), m.at(row, j));
                        m.set(row, i, cs * xi - sn * xj);
                        m.set(row, j, sn * xi + cs * xj);
                    }
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }
    // singular values = column norms; normalize U's columns
    let mut order: Vec<usize> = (0..c).collect();
    let mut sig: Vec<f64> = (0..c).map(|i| col_dot(&u, i, i).sqrt()).collect();
    order.sort_by(|&x, &y| sig[y].partial_cmp(&sig[x]).unwrap());
    let k = r.min(c);
    let mut uu = Mat::zeros(r, k);
    let mut vv = Mat::zeros(c, k);
    let mut s = vec![0.0; k];
    for (slot, &i) in order.iter().take(k).enumerate() {
        s[slot] = sig[i];
        let inv = if sig[i] > 1e-300 { 1.0 / sig[i] } else { 0.0 };
        for t in 0..r {
            uu.set(t, slot, u.at(t, i) * inv);
        }
        for t in 0..c {
            vv.set(t, slot, v.at(t, i));
        }
    }
    sig = s;
    (uu, sig, vv)
}

/// Result of a TT-SVD decomposition.
pub struct TtSvd {
    pub table: EffTtTable,
    /// Relative Frobenius reconstruction error ‖W − Ŵ‖/‖W‖.
    pub rel_error: f64,
}

/// Decompose `weights` [rows × dim] into an `EffTtTable` at `shapes`
/// (rank-truncated; padding rows are treated as zero).
pub fn tt_svd(weights: &[f32], shapes: TtShapes, opts: EffTtOptions) -> TtSvd {
    let rows = shapes.rows as usize;
    let dim = shapes.dim;
    assert_eq!(weights.len(), rows * dim);
    let (m1, m2, m3) = (shapes.m[0] as usize, shapes.m[1] as usize, shapes.m[2] as usize);
    let (n1, n2, n3) = (shapes.n[0], shapes.n[1], shapes.n[2]);
    let r = shapes.rank;

    // Eq. 2 tensorization: entry ((i1 j1),(i2 j2),(i3 j3));
    // unfold as A1 [(m1 n1) × (m2 n2 m3 n3)]
    let c1 = m2 * n2 * m3 * n3;
    let mut a1 = Mat::zeros(m1 * n1, c1);
    for i in 0..rows {
        let (i1, i2, i3) = {
            let i = i as u64;
            let t = shapes.tt_indices(i);
            (t.0 as usize, t.1 as usize, t.2 as usize)
        };
        for j in 0..dim {
            let (j1, rem) = (j / (n2 * n3), j % (n2 * n3));
            let (j2, j3) = (rem / n3, rem % n3);
            let row = i1 * n1 + j1;
            let col = ((i2 * n2 + j2) * m3 + i3) * n3 + j3;
            a1.set(row, col, weights[i * dim + j] as f64);
        }
    }

    // SVD 1: A1 = U1 Σ1 V1ᵀ, truncate to rank r  →  D1 = U1 [m1 n1 × r]
    let (u1, s1, v1) = jacobi_svd(&a1);
    let r1 = r.min(s1.len());
    // carry Σ into the remainder: B = Σ1 V1ᵀ  [r1 × c1]
    let mut b = Mat::zeros(r1, c1);
    for k in 0..r1 {
        for col in 0..c1 {
            b.set(k, col, s1[k] * v1.at(col, k));
        }
    }
    // reshape B to A2 [(r1 m2 n2) × (m3 n3)]
    let c2 = m3 * n3;
    let mut a2 = Mat::zeros(r1 * m2 * n2, c2);
    for k in 0..r1 {
        for i2 in 0..m2 {
            for j2 in 0..n2 {
                for i3 in 0..m3 {
                    for j3 in 0..n3 {
                        let col1 = ((i2 * n2 + j2) * m3 + i3) * n3 + j3;
                        a2.set((k * m2 + i2) * n2 + j2, i3 * n3 + j3, b.at(k, col1));
                    }
                }
            }
        }
    }
    // SVD 2: A2 = U2 Σ2 V2ᵀ truncate to r  →  D2 = U2, D3 = Σ2 V2ᵀ
    let (u2, s2, v2) = jacobi_svd(&a2);
    let r2 = r.min(s2.len());

    // Pack cores in the jax layout then convert (reusing the tested path)
    // D1 [m1, n1, r]: U1 columns (zero-pad if r1 < r)
    let mut d1 = vec![0.0f32; m1 * n1 * r];
    for row in 0..m1 * n1 {
        for k in 0..r1 {
            d1[row * r + k] = u1.at(row, k) as f32;
        }
    }
    // D2 [r, m2, n2, r]: U2[(k1 m2 n2), k2]
    let mut d2 = vec![0.0f32; r * m2 * n2 * r];
    for k1 in 0..r1 {
        for i2 in 0..m2 {
            for j2 in 0..n2 {
                for k2 in 0..r2 {
                    d2[((k1 * m2 + i2) * n2 + j2) * r + k2] =
                        u2.at((k1 * m2 + i2) * n2 + j2, k2) as f32;
                }
            }
        }
    }
    // D3 [r, m3, n3]: Σ2 V2ᵀ
    let mut d3 = vec![0.0f32; r * m3 * n3];
    for k2 in 0..r2 {
        for col in 0..c2 {
            d3[k2 * c2 + col] = (s2[k2] * v2.at(col, k2)) as f32;
        }
    }

    let table = EffTtTable::from_jax_cores(shapes, opts, &d1, &d2, &d3);
    // reconstruction error over the real (non-padding) rows
    let w2 = table.materialize();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..rows {
        for j in 0..dim {
            let w = weights[i * dim + j] as f64;
            let e = w - w2[i * dim + j] as f64;
            num += e * e;
            den += w * w;
        }
    }
    TtSvd { table, rel_error: (num / den.max(1e-300)).sqrt() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_allclose;
    use crate::util::prng::Rng;

    /// A table that IS low-rank must reconstruct near-exactly.
    #[test]
    fn exact_recovery_of_tt_structured_table() {
        let shapes = TtShapes::plan(216, 8, 4);
        let mut rng = Rng::new(3);
        let src = EffTtTable::new(shapes, EffTtOptions::default(), &mut rng);
        let w = src.materialize();
        let w_rows: Vec<f32> = w[..216 * 8].to_vec();
        let dec = tt_svd(&w_rows, shapes, EffTtOptions::default());
        assert!(
            dec.rel_error < 1e-3,
            "low-rank table should round-trip: err {}",
            dec.rel_error
        );
        // spot-check lookups agree
        let mut scratch = crate::tt::table::TtScratch::default();
        let mut a = vec![0.0; 8];
        let mut b = dec.table;
        b.embedding_bag(&[7, 100, 215], &[0, 3], &mut a, &mut scratch);
        let mut expect = vec![0.0f32; 8];
        for &i in &[7usize, 100, 215] {
            for d in 0..8 {
                expect[d] += w_rows[i * 8 + d];
            }
        }
        assert_allclose(&a, &expect, 1e-2, 1e-3);
    }

    /// Random (full-rank) tables: error decreases with rank — the
    /// accuracy-vs-compression dial of Table IV/V.
    #[test]
    fn error_monotone_in_rank() {
        let rows = 216usize;
        let dim = 8usize;
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; rows * dim];
        rng.fill_normal(&mut w, 0.0, 1.0);
        let mut last = f64::INFINITY;
        for rank in [2usize, 4, 8] {
            let shapes = TtShapes::plan(rows as u64, dim, rank);
            let dec = tt_svd(&w, shapes, EffTtOptions::default());
            assert!(
                dec.rel_error <= last + 1e-9,
                "rank {rank}: error went up ({last} -> {})",
                dec.rel_error
            );
            last = dec.rel_error;
        }
        assert!(last < 1.0, "even truncated TT must capture something");
    }

    #[test]
    fn jacobi_svd_reconstructs() {
        let mut rng = Rng::new(9);
        let (r, c) = (12usize, 5usize);
        let mut a = Mat::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                a.set(i, j, rng.normal());
            }
        }
        let (u, s, v) = jacobi_svd(&a);
        // A ≈ U Σ Vᵀ
        for i in 0..r {
            for j in 0..c {
                let mut x = 0.0;
                for k in 0..s.len() {
                    x += u.at(i, k) * s[k] * v.at(j, k);
                }
                assert!((x - a.at(i, j)).abs() < 1e-8, "({i},{j}): {x} vs {}", a.at(i, j));
            }
        }
        // descending singular values
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }
}
