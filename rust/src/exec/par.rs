//! Parallel tensor primitives over [`ExecPool`](crate::exec::ExecPool).
//!
//! All primitives are **bit-identical** to their serial counterparts in
//! `tt::linalg` for any worker count: output is sharded into disjoint
//! contiguous blocks and each element's reduction order is exactly the
//! serial loop's.  See the determinism rules in the module docs of
//! [`crate::exec`].

use std::ops::Range;

use crate::exec::{split_ranges, ExecPool};
use crate::tt::linalg::{gemm_acc, gemm_at_acc, gemm_at_block, gemm_bt_acc};

/// Below this many multiply-adds a parallel region costs more in thread
/// spawns than it saves; primitives fall back to the serial kernel.
pub const PAR_MIN_WORK: usize = 32 * 1024;

/// Shard a `[rows, width]` row-major buffer into at most `workers`
/// contiguous row blocks and run `f(first_row, block)` on each block in
/// parallel.  `f` must treat rows independently: the serial pool calls it
/// once over the whole buffer, parallel pools call it once per block.
pub fn par_row_blocks<T, F>(pool: &ExecPool, data: &mut [T], width: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(width > 0, "row width must be positive");
    debug_assert_eq!(data.len() % width, 0);
    let rows = data.len() / width;
    if pool.is_serial() || rows < 2 {
        f(0, data);
        return;
    }
    let ranges = split_ranges(rows, pool.workers());
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = data;
        let last = ranges.len() - 1;
        let mut own: Option<(usize, &mut [T])> = None;
        let mut spawned = Vec::with_capacity(last);
        for (i, r) in ranges.into_iter().enumerate() {
            let take = (r.end - r.start) * width;
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let first_row = r.start;
            if i == last {
                // the calling thread works the final shard instead of
                // idling at the scope join (no spare-thread oversubscribe)
                own = Some((first_row, block));
            } else {
                spawned.push((i, r, s.spawn(move || f(first_row, block))));
            }
        }
        if let Some((first_row, block)) = own {
            f(first_row, block);
        }
        // join explicitly so a dead worker is named (shard + row span +
        // original payload) instead of the scope's anonymous re-panic
        for (i, r, h) in spawned {
            if let Err(p) = h.join() {
                panic!(
                    "exec shard worker {i} (rows {}..{}) panicked: {}",
                    r.start,
                    r.end,
                    panic_message(&p)
                );
            }
        }
    });
}

/// Best-effort extraction of a panic payload's message (panics carry
/// `&str` or `String` in practice).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Split `0..n` into at most `parts` balanced contiguous shards whose
/// boundaries are drawn from `cuts` (ascending positions, first element
/// 0) — the generalized boundary-constrained sharding behind the TT plan
/// walks.  Cutting anywhere else would split a prefix group (recomputing
/// a shared partial product and perturbing the `TtStats` accounting) or
/// an L2 tile (evicting its working set mid-walk), so shard edges snap to
/// the next cut at or after each balanced target.  Below `min_n` elements
/// the whole range stays on one worker (thread spawns would dominate).
pub fn split_at_cuts(n: usize, cuts: &[u32], parts: usize, min_n: usize) -> Vec<Range<usize>> {
    if parts <= 1 || cuts.len() <= 1 || n < min_n {
        return vec![0..n];
    }
    let mut edges: Vec<usize> = vec![0];
    for w in 1..parts {
        let target = n * w / parts;
        let gi = cuts.partition_point(|&g| (g as usize) < target);
        let cut = cuts.get(gi).map(|&g| g as usize).unwrap_or(n);
        let last = *edges.last().unwrap();
        if cut > last && cut < n {
            edges.push(cut);
        }
    }
    edges.push(n);
    edges.windows(2).map(|w| w[0]..w[1]).collect()
}

/// C[m,n] += A[m,k] · B[k,n], rows of A/C sharded across workers.
/// Bit-identical to [`gemm_acc`] (each output row runs the same serial
/// i-k-j kernel on exactly one worker).
pub fn par_gemm_acc(
    pool: &ExecPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if pool.is_serial() || m < 2 || m * k * n < PAR_MIN_WORK {
        gemm_acc(a, b, c, m, k, n);
        return;
    }
    par_row_blocks(pool, c, n, |row0, cblock| {
        let rows = cblock.len() / n;
        gemm_acc(&a[row0 * k..(row0 + rows) * k], b, cblock, rows, k, n);
    });
}

/// C[m,n] += A[m,k] · Bᵀ (B stored [n,k]), rows of A/C sharded across
/// workers.  Bit-identical to [`gemm_bt_acc`].
pub fn par_gemm_bt_acc(
    pool: &ExecPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if pool.is_serial() || m < 2 || m * k * n < PAR_MIN_WORK {
        gemm_bt_acc(a, b, c, m, k, n);
        return;
    }
    par_row_blocks(pool, c, n, |row0, cblock| {
        let rows = cblock.len() / n;
        gemm_bt_acc(&a[row0 * k..(row0 + rows) * k], b, cblock, rows, k, n);
    });
}

/// C[m,n] = Aᵀ·B (A stored [k,m]; overwrite), **columns** of C sharded
/// across workers — the batch dimension `k` is the long one in the
/// `dW = xᵀ·dout` use case, and column sharding keeps each element's
/// k-accumulation order identical to the serial kernel, so the result is
/// bit-identical to `c.fill(0); gemm_at_acc(a, b, c, m, k, n)`.
///
/// Workers accumulate into private column-block buffers (C's columns
/// interleave in row-major memory, so they cannot be handed out as
/// disjoint `&mut` slices); the main thread stitches the blocks back —
/// a pure copy, which cannot perturb values.
pub fn par_gemm_at_overwrite(
    pool: &ExecPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    if pool.is_serial() || n < 2 || m * k * n < PAR_MIN_WORK {
        gemm_at_acc(a, b, c, m, k, n);
        return;
    }
    let ranges: Vec<Range<usize>> = split_ranges(n, pool.workers());
    let mut blocks: Vec<Vec<f32>> =
        ranges.iter().map(|r| vec![0.0f32; m * (r.end - r.start)]).collect();
    std::thread::scope(|s| {
        let last = ranges.len() - 1;
        let mut own: Option<(usize, usize, &mut Vec<f32>)> = None;
        let mut spawned = Vec::with_capacity(last);
        for (i, (r, block)) in ranges.iter().zip(blocks.iter_mut()).enumerate() {
            let (j0, j1) = (r.start, r.end);
            if i == last {
                own = Some((j0, j1, block));
            } else {
                spawned.push((i, j0, j1, s.spawn(move || gemm_at_block(a, b, block, m, k, n, j0, j1))));
            }
        }
        if let Some((j0, j1, block)) = own {
            gemm_at_block(a, b, block, m, k, n, j0, j1);
        }
        for (i, j0, j1, h) in spawned {
            if let Err(p) = h.join() {
                panic!(
                    "exec shard worker {i} (cols {j0}..{j1}) panicked: {}",
                    panic_message(&p)
                );
            }
        }
    });
    for (r, block) in ranges.iter().zip(blocks.iter()) {
        let bw = r.end - r.start;
        for i in 0..m {
            c[i * n + r.start..i * n + r.end].copy_from_slice(&block[i * bw..(i + 1) * bw]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;
    use crate::util::prng::Rng;

    fn pool(w: usize) -> ExecPool {
        ExecPool::new(ExecCfg::with_workers(w))
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn par_row_blocks_visits_every_row_once() {
        let mut data = vec![0u32; 37 * 3];
        par_row_blocks(&pool(4), &mut data, 3, |row0, block| {
            for (i, row) in block.chunks_mut(3).enumerate() {
                for v in row.iter_mut() {
                    *v += (row0 + i + 1) as u32;
                }
            }
        });
        for (r, row) in data.chunks(3).enumerate() {
            assert!(row.iter().all(|&v| v == (r + 1) as u32), "row {r}: {row:?}");
        }
    }

    #[test]
    fn split_at_cuts_respects_boundaries() {
        // cuts at 0, 10, 50, 90 over 100 elements
        let cuts = [0u32, 10, 50, 90];
        for parts in [1usize, 2, 3, 8] {
            let shards = split_at_cuts(100, &cuts, parts, 64);
            let mut at = 0usize;
            for s in &shards {
                assert_eq!(s.start, at, "gap at parts={parts}");
                assert!(s.end > s.start);
                at = s.end;
            }
            assert_eq!(at, 100);
            assert!(shards.len() <= parts.max(1));
            // every interior edge is a declared cut
            for s in &shards[1..] {
                assert!(cuts.contains(&(s.start as u32)), "edge {} not a cut", s.start);
            }
        }
        // below min_n: single shard regardless of parts
        assert_eq!(split_at_cuts(40, &cuts, 4, 64), vec![0..40]);
        // degenerate cut list: single shard
        assert_eq!(split_at_cuts(100, &[0], 4, 64), vec![0..100]);
    }

    #[test]
    fn par_gemm_acc_bit_identical_to_serial() {
        let mut rng = Rng::new(11);
        // sizes above PAR_MIN_WORK so the parallel path actually runs
        for (m, k, n) in [(64, 32, 32), (65, 17, 40), (128, 8, 64)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_serial = rand_vec(&mut rng, m * n);
            let mut c_par = c_serial.clone();
            gemm_acc(&a, &b, &mut c_serial, m, k, n);
            par_gemm_acc(&pool(3), &a, &b, &mut c_par, m, k, n);
            assert_eq!(bits(&c_serial), bits(&c_par), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn par_gemm_bt_acc_bit_identical_to_serial() {
        let mut rng = Rng::new(12);
        for (m, k, n) in [(64, 32, 32), (70, 30, 33)] {
            let a = rand_vec(&mut rng, m * k);
            let bt = rand_vec(&mut rng, n * k);
            let mut c_serial = vec![0.0f32; m * n];
            let mut c_par = vec![0.0f32; m * n];
            gemm_bt_acc(&a, &bt, &mut c_serial, m, k, n);
            par_gemm_bt_acc(&pool(4), &a, &bt, &mut c_par, m, k, n);
            assert_eq!(bits(&c_serial), bits(&c_par), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn par_gemm_at_overwrite_bit_identical_to_serial() {
        let mut rng = Rng::new(13);
        for (m, k, n) in [(32, 64, 32), (10, 333, 48), (64, 64, 17)] {
            let at = rand_vec(&mut rng, k * m);
            let b = rand_vec(&mut rng, k * n);
            let mut c_serial = vec![0.0f32; m * n];
            gemm_at_acc(&at, &b, &mut c_serial, m, k, n);
            let mut c_par = rand_vec(&mut rng, m * n); // junk: must be overwritten
            par_gemm_at_overwrite(&pool(3), &at, &b, &mut c_par, m, k, n);
            assert_eq!(bits(&c_serial), bits(&c_par), "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn panicking_shard_worker_is_resurfaced_with_its_shard_label() {
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut data = vec![0u32; 64 * 2];
            par_row_blocks(&pool(4), &mut data, 2, |row0, _block| {
                if row0 == 0 {
                    panic!("injected shard fault");
                }
            });
        }))
        .expect_err("worker panic must propagate to the caller");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string".to_string());
        assert!(
            msg.contains("exec shard worker 0 (rows 0..16)"),
            "panic not labeled with the shard: {msg}"
        );
        assert!(msg.contains("injected shard fault"), "original payload lost: {msg}");
    }

    #[test]
    fn serial_pool_matches_parallel_pool() {
        let mut rng = Rng::new(14);
        let (m, k, n) = (48, 48, 48);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        let mut c4 = vec![0.0f32; m * n];
        par_gemm_acc(&pool(1), &a, &b, &mut c1, m, k, n);
        par_gemm_acc(&pool(4), &a, &b, &mut c4, m, k, n);
        assert_eq!(bits(&c1), bits(&c4));
    }
}
