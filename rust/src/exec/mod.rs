//! `exec` — the shared parallel execution layer.
//!
//! Every hot path in the system (TT contractions, the engine MLPs, the
//! streaming server, the baseline arms) used to hand-roll its own serial
//! loops.  This module centralizes intra-step parallelism behind one tiny
//! abstraction: a work-stealing-free worker pool ([`ExecPool`]) built on
//! scoped `std::thread` tasks (no dependencies), plus parallel tensor
//! primitives in [`par`].
//!
//! Design rules, in priority order:
//!
//! 1. **Determinism** — every primitive produces results *bit-identical*
//!    to its serial counterpart, for any worker count.  Sharding is always
//!    by disjoint output blocks whose per-element reduction order matches
//!    the serial loop; cross-worker reductions, where unavoidable, happen
//!    serially in worker-id order.  The pipeline's pipeline==sequential
//!    guarantee and the `workers=N == workers=1` property tests both rest
//!    on this.
//! 2. **`workers = 1` is cheap** — the serial configuration never spawns
//!    a thread, and hot paths reuse caller-provided scratch instead of
//!    allocating per call.
//! 3. **Static sharding** — contiguous balanced ranges, no work stealing:
//!    the workloads here (row-blocked GEMMs, per-distinct-row chains) are
//!    uniform enough that stealing buys nothing and costs determinism.

pub mod par;

pub use par::{par_gemm_acc, par_gemm_at_overwrite, par_gemm_bt_acc, par_row_blocks, split_at_cuts};

use std::ops::Range;

/// Parallelism configuration, threaded through `RecAdConfig` → `EngineCfg`
/// → `NativeDlrm`/`EffTtTable` and the benches' CLI/env arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecCfg {
    /// Worker count, >= 1.  1 means fully serial (no threads spawned).
    pub workers: usize,
}

impl Default for ExecCfg {
    fn default() -> Self {
        ExecCfg { workers: 1 }
    }
}

impl ExecCfg {
    pub fn serial() -> ExecCfg {
        ExecCfg { workers: 1 }
    }

    pub fn with_workers(workers: usize) -> ExecCfg {
        ExecCfg { workers: workers.max(1) }
    }

    /// One worker per available hardware thread.
    pub fn available() -> ExecCfg {
        let w = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ExecCfg { workers: w }
    }

    /// Read a worker count from an environment variable (benches use
    /// `RECAD_WORKERS`); unset/invalid falls back to serial.
    pub fn from_env(var: &str) -> ExecCfg {
        match std::env::var(var).ok().and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(w) if w >= 1 => ExecCfg { workers: w },
            _ => ExecCfg::serial(),
        }
    }
}

/// A work-stealing-free worker pool.  The pool itself is just a target
/// width; parallel regions are realized as scoped threads per call, so
/// borrowing inputs/outputs from the caller's stack is safe and there is
/// no channel/queue machinery to keep consistent.  `Copy` on purpose:
/// threading it through structs costs nothing.
#[derive(Clone, Copy, Debug)]
pub struct ExecPool {
    workers: usize,
}

impl Default for ExecPool {
    fn default() -> Self {
        ExecPool::serial()
    }
}

impl ExecPool {
    pub fn new(cfg: ExecCfg) -> ExecPool {
        ExecPool { workers: cfg.workers.max(1) }
    }

    pub fn serial() -> ExecPool {
        ExecPool { workers: 1 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn is_serial(&self) -> bool {
        self.workers <= 1
    }

}

/// Split `0..n` into at most `parts` balanced contiguous ranges (the
/// first `n % parts` ranges get one extra element).  Never returns empty
/// ranges; returns a single `0..n` range when `n <= 1` or `parts <= 1`.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            for parts in [1usize, 2, 3, 8, 1000] {
                let rs = split_ranges(n, parts);
                let mut at = 0usize;
                for r in &rs {
                    assert_eq!(r.start, at, "gap at n={n} parts={parts}");
                    assert!(r.end > r.start, "empty range at n={n} parts={parts}");
                    at = r.end;
                }
                assert_eq!(at, n);
                assert!(rs.len() <= parts.max(1));
                // balanced: lengths differ by at most one
                if let (Some(min), Some(max)) = (
                    rs.iter().map(|r| r.end - r.start).min(),
                    rs.iter().map(|r| r.end - r.start).max(),
                ) {
                    assert!(max - min <= 1);
                }
            }
        }
    }


    #[test]
    fn cfg_constructors_clamp() {
        assert_eq!(ExecCfg::with_workers(0).workers, 1);
        assert!(ExecCfg::available().workers >= 1);
        assert_eq!(ExecCfg::from_env("RECAD_NO_SUCH_VAR").workers, 1);
        assert!(ExecPool::new(ExecCfg::with_workers(0)).is_serial());
    }
}
