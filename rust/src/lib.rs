//! Rec-AD: Tensor-Train-compressed DLRM for FDIA detection.
#![allow(clippy::needless_range_loop)]

pub mod access;
pub mod analysis;
pub mod baselines;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod metrics;
pub mod net;
pub mod powersys;
pub mod reorder;
pub mod runtime;
pub mod serve;
pub mod tt;
pub mod util;
