//! Configuration system: typed config + a TOML-subset parser (tables,
//! key = value with strings / numbers / booleans / arrays of numbers;
//! comments).  serde/toml are unavailable offline; this subset covers the
//! launcher's needs and rejects anything outside it loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::access::AccessCfg;
use crate::analysis::LintCfg;
use crate::coordinator::data_parallel::Placement;
use crate::coordinator::engine::EngineCfg;
use crate::exec::ExecCfg;
use crate::runtime::autotune::AutotuneCfg;
use crate::runtime::fault::FaultCfg;
use crate::serve::{Policy, ServeCfg};
use crate::tt::table::{EffTtOptions, QuantizeMode};

/// Parsed TOML-subset document: `section.key -> value`.
#[derive(Debug, Default)]
pub struct Toml {
    values: BTreeMap<String, TomlValue>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    NumArray(Vec<f64>),
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim(), ln + 1)?);
        }
        Ok(Toml { values })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.get(key) {
            Some(TomlValue::Str(s)) => s,
            _ => default,
        }
    }

    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        match self.get(key) {
            Some(TomlValue::Num(n)) => *n,
            _ => default,
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.num_or(key, default as f64) as usize
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(TomlValue::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn nums(&self, key: &str) -> Option<Vec<f64>> {
        match self.get(key) {
            Some(TomlValue::NumArray(v)) => Some(v.clone()),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: the subset forbids '#' inside strings
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(v: &str, ln: usize) -> Result<TomlValue> {
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let nums: Result<Vec<f64>> = inner
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .with_context(|| format!("line {ln}: bad number '{s}'"))
            })
            .collect();
        return Ok(TomlValue::NumArray(nums?));
    }
    if let Ok(n) = v.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Num(n));
    }
    bail!("line {ln}: cannot parse value '{v}' (supported: string, number, bool, [numbers])")
}

/// `[net]` section: the multi-node serving tier (`recad node` /
/// `recad route`).  The TOML subset has no string arrays, so `nodes` is
/// a single comma-separated `host:port` list.
#[derive(Clone, Debug, PartialEq)]
pub struct NetCfg {
    /// `recad node` bind address (`host:port`; port 0 picks a free port).
    pub listen: String,
    /// comma-separated node addresses the router dials (`recad route`).
    pub nodes: String,
    /// virtual nodes per physical node on the consistent-hash ring.
    pub vnodes: usize,
    /// router heartbeat cadence toward idle-suspect nodes (ms).
    pub heartbeat_ms: u64,
    /// per-node in-flight request cap before router backpressure.
    pub max_outstanding: usize,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            listen: "127.0.0.1:7070".into(),
            nodes: String::new(),
            vnodes: 64,
            heartbeat_ms: 50,
            max_outstanding: 256,
        }
    }
}

impl NetCfg {
    /// The `nodes` list split on commas (empty entries dropped).
    pub fn node_list(&self) -> Vec<String> {
        self.nodes
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

/// Top-level launcher configuration.
#[derive(Clone, Debug)]
pub struct RecAdConfig {
    /// "ieee118" | "avazu" | "kaggle" | "terabyte"
    pub dataset: String,
    pub scale: f64,
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub tt_rank: usize,
    pub reorder: bool,
    pub reuse: bool,
    pub grad_aggregation: bool,
    pub fused_update: bool,
    /// `[tt] quantize = "off"|"int8"|"f16"` / `--quantize`: serving-mode
    /// TT-core storage.  Serve freezes the trained cores into the chosen
    /// format (dequantize-in-microkernel fast path); train uses it to
    /// pick int8 gradient exchange (`int8` => quantized sparse
    /// all-reduce under plan placement).
    pub quantize: QuantizeMode,
    pub pipeline_lc: usize,
    /// exec-layer worker count (1 = serial; N-way intra-step parallelism
    /// is bit-identical to serial by construction).
    pub workers: usize,
    /// access-layer ingest lookahead (`[access] plan_ahead` /
    /// `--plan-ahead N`): batches assembled + planned ahead of training
    /// on the ingest worker; 0 plans inline.  Bit-identical either way.
    pub plan_ahead: usize,
    /// refresh the index bijection online every `reorder_refresh` batches
    /// (`[access] online_reorder` / `--online-reorder`).
    pub online_reorder: bool,
    /// batches between online bijection rebuilds.
    pub reorder_refresh: usize,
    /// L2 budget in KiB for hottest-first tiled plan layouts
    /// (`[access] cache_kb` / `--cache-kb N`); 0 disables tiling.
    pub cache_kb: usize,
    /// plan same-vocabulary TT slots through one fused sorted sweep
    /// (`[access] fuse_tables` / `--fuse-tables`).
    pub fuse_tables: bool,
    /// run online bijection rebuilds on a background worker
    /// (`[access] background_reorder` / `--background-reorder`).
    pub background_reorder: bool,
    /// `[train]` section: data-parallel replica workers (devices).  1 =
    /// single-engine training (`--devices N`).
    pub devices: usize,
    /// `[train] placement = "replicated"|"plan"` / `--placement`: how
    /// multi-device shards and the parameter exchange map onto workers.
    /// `replicated` is bit-identical to the historical data-parallel
    /// path; `plan` routes prefix groups to their owning worker and
    /// ships TT-core gradients sparsely.
    pub placement: Placement,
    /// `[serve]` section: replica count, micro-batching, route policy,
    /// dispatch charge, and the load shape (closed-loop `clients` /
    /// open-loop `arrival_rate`).
    pub serve: ServeCfg,
    /// `[autotune]` section / `--autotune`: feedback controllers folding
    /// `cache_kb`, `refresh_every`, and serve `max_batch`/`deadline_us`
    /// into measurement-driven loops.  Off by default; disabled is
    /// bit-identical to the static paths.
    pub autotune: AutotuneCfg,
    /// `[fault]` section / `--fault-*`: the seeded chaos-injection plan
    /// (replica kills/panics/stalls, reply severs, queue floods, training
    /// stragglers, a dead worker).  Off by default; disabled is
    /// bit-identical to the fault-free paths.
    pub fault: FaultCfg,
    /// `[net]` section: node bind address, router node list, ring vnodes,
    /// heartbeat cadence and per-node backpressure cap for the
    /// `node`/`route` multi-node serving subcommands.
    pub net: NetCfg,
    /// `[lint]` section: extra allowlist roots for `recad lint`.  The
    /// baked-in defaults (see `analysis::LintCfg`) are always active —
    /// config can only *extend* them, never drop a rule's scope.
    pub lint: LintCfg,
    pub seed: u64,
    pub artifacts_dir: String,
}

impl Default for RecAdConfig {
    fn default() -> Self {
        RecAdConfig {
            dataset: "ieee118".into(),
            scale: 1.0 / 2000.0,
            epochs: 2,
            batch_size: 128,
            lr: 0.05,
            tt_rank: 8,
            reorder: true,
            reuse: true,
            grad_aggregation: true,
            fused_update: true,
            quantize: QuantizeMode::Off,
            pipeline_lc: 4,
            workers: 1,
            plan_ahead: AccessCfg::default().plan_ahead,
            online_reorder: false,
            reorder_refresh: AccessCfg::default().refresh_every,
            cache_kb: AccessCfg::default().cache_kb,
            fuse_tables: false,
            background_reorder: false,
            devices: 1,
            placement: Placement::Replicated,
            serve: ServeCfg::default(),
            autotune: AutotuneCfg::default(),
            fault: FaultCfg::default(),
            net: NetCfg::default(),
            lint: LintCfg::default(),
            seed: 42,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// Extend a lint allowlist with a comma-separated path list from
/// config, skipping blanks and duplicates.
fn extend_paths(dst: &mut Vec<String>, csv: &str) {
    for p in csv.split(',') {
        let p = p.trim();
        if !p.is_empty() && !dst.iter().any(|d| d == p) {
            dst.push(p.to_string());
        }
    }
}

/// A key that, when present, must be a positive integer (`>= 1`, no
/// fraction).  The parser's `usize_or` would silently truncate `0.7` to
/// 0 or wrap a negative through `as usize` — these checks run on the RAW
/// value so bad numerics fail loudly, naming the offending key.
fn expect_positive_int(t: &Toml, key: &str) -> Result<()> {
    if let Some(TomlValue::Num(n)) = t.get(key) {
        if *n < 1.0 || n.fract() != 0.0 {
            bail!("config key '{key}' must be a positive integer, got {n}");
        }
    }
    Ok(())
}

/// A key that, when present, must be a non-negative integer.
fn expect_unsigned_int(t: &Toml, key: &str) -> Result<()> {
    if let Some(TomlValue::Num(n)) = t.get(key) {
        if *n < 0.0 || n.fract() != 0.0 {
            bail!("config key '{key}' must be a non-negative integer, got {n}");
        }
    }
    Ok(())
}

/// A key that, when present, must be a probability in `[0, 1]`.
fn expect_rate(t: &Toml, key: &str) -> Result<()> {
    if let Some(TomlValue::Num(n)) = t.get(key) {
        if !(0.0..=1.0).contains(n) {
            bail!("config key '{key}' must be a rate in [0, 1], got {n}");
        }
    }
    Ok(())
}

/// A key that, when present, must be a non-negative number.
fn expect_non_negative(t: &Toml, key: &str) -> Result<()> {
    if let Some(TomlValue::Num(n)) = t.get(key) {
        if *n < 0.0 {
            bail!("config key '{key}' must be non-negative, got {n}");
        }
    }
    Ok(())
}

/// Validate the `[serve]` / `[train]` / `[fault]` numerics before any
/// `as usize` narrowing can hide them.  Only EXPLICIT keys are checked —
/// absent keys keep their (valid) defaults.
fn validate_numerics(t: &Toml) -> Result<()> {
    for key in [
        "serve.replicas",
        "serve.max_batch",
        "serve.deadline_us",
        "train.devices",
        "net.vnodes",
        "net.max_outstanding",
    ] {
        expect_positive_int(t, key)?;
    }
    for key in [
        "serve.dispatch_us",
        "serve.clients",
        "serve.shed_budget_us",
        "serve.heartbeat_ms",
        "serve.hang_ms",
        "fault.seed",
        "fault.kill_replica",
        "fault.kill_after",
        "fault.stall_ms",
        "fault.flood_burst",
        "fault.straggle_ms",
        "fault.dead_worker",
        "fault.dead_round",
        "fault.kill_node",
        "fault.node_kill_after",
        "net.heartbeat_ms",
    ] {
        expect_unsigned_int(t, key)?;
    }
    for key in [
        "fault.panic_rate",
        "fault.stall_rate",
        "fault.sever_rate",
        "fault.flood_rate",
        "fault.straggle_rate",
        "fault.node_kill_rate",
    ] {
        expect_rate(t, key)?;
    }
    expect_non_negative(t, "serve.arrival_rate")?;
    Ok(())
}

impl RecAdConfig {
    pub fn from_toml(t: &Toml) -> Result<RecAdConfig> {
        validate_numerics(t)?;
        let d = RecAdConfig::default();
        Ok(RecAdConfig {
            dataset: t.str_or("run.dataset", &d.dataset).to_string(),
            scale: t.num_or("run.scale", d.scale),
            epochs: t.usize_or("run.epochs", d.epochs),
            batch_size: t.usize_or("run.batch_size", d.batch_size),
            lr: t.num_or("run.lr", d.lr),
            tt_rank: t.usize_or("tt.rank", d.tt_rank),
            reorder: t.bool_or("tt.reorder", d.reorder),
            reuse: t.bool_or("tt.reuse", d.reuse),
            grad_aggregation: t.bool_or("tt.grad_aggregation", d.grad_aggregation),
            fused_update: t.bool_or("tt.fused_update", d.fused_update),
            quantize: QuantizeMode::parse(t.str_or("tt.quantize", d.quantize.as_str()))
                .context("[tt] quantize")?,
            pipeline_lc: t.usize_or("pipeline.lc", d.pipeline_lc),
            workers: t.usize_or("exec.workers", d.workers).max(1),
            plan_ahead: t.usize_or("access.plan_ahead", d.plan_ahead),
            online_reorder: t.bool_or("access.online_reorder", d.online_reorder),
            reorder_refresh: t.usize_or("access.refresh_every", d.reorder_refresh).max(1),
            cache_kb: t.usize_or("access.cache_kb", d.cache_kb),
            fuse_tables: t.bool_or("access.fuse_tables", d.fuse_tables),
            background_reorder: t.bool_or("access.background_reorder", d.background_reorder),
            devices: t.usize_or("train.devices", d.devices).max(1),
            placement: Placement::parse(t.str_or("train.placement", d.placement.as_str()))
                .context("[train] placement")?,
            serve: ServeCfg {
                replicas: t.usize_or("serve.replicas", d.serve.replicas).max(1),
                max_batch: t.usize_or("serve.max_batch", d.serve.max_batch).max(1),
                deadline_us: t.usize_or("serve.deadline_us", d.serve.deadline_us as usize)
                    as u64,
                policy: Policy::parse(t.str_or("serve.policy", d.serve.policy.as_str()))
                    .context("[serve] policy")?,
                dispatch_us: t.usize_or("serve.dispatch_us", d.serve.dispatch_us as usize)
                    as u64,
                clients: t.usize_or("serve.clients", d.serve.clients),
                arrival_rate: t.num_or("serve.arrival_rate", d.serve.arrival_rate),
                shed_budget_us: t
                    .usize_or("serve.shed_budget_us", d.serve.shed_budget_us as usize)
                    as u64,
                heartbeat_ms: t
                    .usize_or("serve.heartbeat_ms", d.serve.heartbeat_ms as usize)
                    as u64,
                hang_ms: t.usize_or("serve.hang_ms", d.serve.hang_ms as usize) as u64,
            },
            autotune: AutotuneCfg {
                enabled: t.bool_or("autotune.enabled", d.autotune.enabled),
                cache: t.bool_or("autotune.cache", d.autotune.cache),
                reorder: t.bool_or("autotune.reorder", d.autotune.reorder),
                serve: t.bool_or("autotune.serve", d.autotune.serve),
                cache_ladder: t
                    .nums("autotune.cache_ladder")
                    .map(|v| v.into_iter().map(|n| n.max(0.0) as usize).collect())
                    .unwrap_or(d.autotune.cache_ladder),
                probe_batches: t
                    .usize_or("autotune.probe_batches", d.autotune.probe_batches)
                    .max(1),
                min_refresh: t.usize_or("autotune.min_refresh", d.autotune.min_refresh).max(1),
                max_refresh: t.usize_or("autotune.max_refresh", d.autotune.max_refresh).max(1),
                reuse_decay_tol: t.num_or("autotune.reuse_decay_tol", d.autotune.reuse_decay_tol),
                target_p99_us: t
                    .usize_or("autotune.target_p99_us", d.autotune.target_p99_us as usize)
                    as u64,
                max_batch_cap: t
                    .usize_or("autotune.max_batch_cap", d.autotune.max_batch_cap)
                    .max(1),
            },
            fault: FaultCfg {
                enabled: t.bool_or("fault.enabled", d.fault.enabled),
                seed: t.usize_or("fault.seed", d.fault.seed as usize) as u64,
                kill_replica: match t.get("fault.kill_replica") {
                    Some(TomlValue::Num(n)) => Some(*n as usize),
                    _ => d.fault.kill_replica,
                },
                kill_after: t.usize_or("fault.kill_after", d.fault.kill_after as usize) as u64,
                panic_rate: t.num_or("fault.panic_rate", d.fault.panic_rate),
                stall_rate: t.num_or("fault.stall_rate", d.fault.stall_rate),
                stall_ms: t.usize_or("fault.stall_ms", d.fault.stall_ms as usize) as u64,
                sever_rate: t.num_or("fault.sever_rate", d.fault.sever_rate),
                flood_rate: t.num_or("fault.flood_rate", d.fault.flood_rate),
                flood_burst: t.usize_or("fault.flood_burst", d.fault.flood_burst),
                straggle_rate: t.num_or("fault.straggle_rate", d.fault.straggle_rate),
                straggle_ms: t.usize_or("fault.straggle_ms", d.fault.straggle_ms as usize)
                    as u64,
                dead_worker: match t.get("fault.dead_worker") {
                    Some(TomlValue::Num(n)) => Some(*n as usize),
                    _ => d.fault.dead_worker,
                },
                dead_round: t.usize_or("fault.dead_round", d.fault.dead_round as usize) as u64,
                kill_node: match t.get("fault.kill_node") {
                    Some(TomlValue::Num(n)) => Some(*n as usize),
                    _ => d.fault.kill_node,
                },
                node_kill_after: t
                    .usize_or("fault.node_kill_after", d.fault.node_kill_after as usize)
                    as u64,
                node_kill_rate: t.num_or("fault.node_kill_rate", d.fault.node_kill_rate),
            },
            net: NetCfg {
                listen: t.str_or("net.listen", &d.net.listen).to_string(),
                nodes: t.str_or("net.nodes", &d.net.nodes).to_string(),
                vnodes: t.usize_or("net.vnodes", d.net.vnodes).max(1),
                heartbeat_ms: t
                    .usize_or("net.heartbeat_ms", d.net.heartbeat_ms as usize)
                    as u64,
                max_outstanding: t
                    .usize_or("net.max_outstanding", d.net.max_outstanding)
                    .max(1),
            },
            lint: {
                let mut l = d.lint.clone();
                extend_paths(&mut l.allow_instant, t.str_or("lint.allow_instant", ""));
                extend_paths(&mut l.request_paths, t.str_or("lint.request_paths", ""));
                extend_paths(&mut l.allow_spawn, t.str_or("lint.allow_spawn", ""));
                l.strict_pragmas = t.bool_or("lint.strict_pragmas", l.strict_pragmas);
                l
            },
            seed: t.num_or("run.seed", d.seed as f64) as u64,
            artifacts_dir: t.str_or("run.artifacts_dir", &d.artifacts_dir).to_string(),
        })
    }

    pub fn load(path: &str) -> Result<RecAdConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn engine_cfg(&self) -> EngineCfg {
        let mut cfg = EngineCfg::ieee118(self.scale);
        cfg.lr = self.lr as f32;
        cfg.tt_rank = self.tt_rank;
        cfg.tt_opts = EffTtOptions {
            reuse: self.reuse,
            grad_aggregation: self.grad_aggregation,
            fused_update: self.fused_update,
        };
        cfg.exec = ExecCfg::with_workers(self.workers);
        cfg
    }

    /// The `[access]` section as an [`AccessCfg`] for the ingest stage.
    pub fn access_cfg(&self) -> AccessCfg {
        AccessCfg {
            plan_ahead: self.plan_ahead,
            online_reorder: self.online_reorder,
            refresh_every: self.reorder_refresh,
            cache_kb: self.cache_kb,
            fuse_tables: self.fuse_tables,
            background_reorder: self.background_reorder,
            ..AccessCfg::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = r#"
# Rec-AD run config
[run]
dataset = "ieee118"
epochs = 5
batch_size = 256
lr = 0.01
seed = 7

[tt]
rank = 16
reorder = false
quantize = "int8"

[pipeline]
lc = 8

[train]
devices = 4
placement = "plan"

[exec]
workers = 3

[access]
plan_ahead = 2
online_reorder = true
refresh_every = 16
cache_kb = 512
fuse_tables = true
background_reorder = true

[serve]
replicas = 4
max_batch = 8
deadline_us = 2000
policy = "plan_affinity"
dispatch_us = 50
clients = 6
arrival_rate = 1200.0
"#;
        let t = Toml::parse(doc).unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.dataset, "ieee118");
        assert_eq!(c.epochs, 5);
        assert_eq!(c.batch_size, 256);
        assert!((c.lr - 0.01).abs() < 1e-12);
        assert_eq!(c.tt_rank, 16);
        assert!(!c.reorder);
        assert!(c.reuse); // default preserved
        assert_eq!(c.quantize, QuantizeMode::Int8);
        assert_eq!(c.pipeline_lc, 8);
        assert_eq!(c.workers, 3);
        assert_eq!(c.devices, 4);
        assert_eq!(c.placement, Placement::Plan);
        assert_eq!(c.seed, 7);
        assert_eq!(c.plan_ahead, 2);
        assert!(c.online_reorder);
        assert_eq!(c.reorder_refresh, 16);
        let a = c.access_cfg();
        assert_eq!(a.plan_ahead, 2);
        assert!(a.online_reorder);
        assert_eq!(a.refresh_every, 16);
        assert_eq!(a.cache_kb, 512);
        assert!(a.fuse_tables);
        assert!(a.background_reorder);
        assert_eq!(c.serve.replicas, 4);
        assert_eq!(c.serve.max_batch, 8);
        assert_eq!(c.serve.deadline_us, 2000);
        assert_eq!(c.serve.policy, crate::serve::Policy::PlanAffinity);
        assert_eq!(c.serve.dispatch_us, 50);
        assert_eq!(c.serve.clients, 6);
        assert!((c.serve.arrival_rate - 1200.0).abs() < 1e-9);
    }

    #[test]
    fn access_defaults_without_section() {
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        let d = crate::access::AccessCfg::default();
        assert_eq!(c.plan_ahead, d.plan_ahead);
        assert!(!c.online_reorder);
        assert_eq!(c.reorder_refresh, d.refresh_every);
        // [serve] defaults: 1 replica, round robin, closed loop
        assert_eq!(c.serve.replicas, 1);
        assert_eq!(c.serve.policy, crate::serve::Policy::RoundRobin);
        assert_eq!(c.serve.arrival_rate, 0.0);
    }

    #[test]
    fn rejects_unknown_route_policy() {
        let t = Toml::parse("[serve]\npolicy = \"coin_flip\"\n").unwrap();
        assert!(RecAdConfig::from_toml(&t).is_err());
    }

    #[test]
    fn rejects_unknown_quantize_mode_and_defaults_off() {
        let t = Toml::parse("[tt]\nquantize = \"int4\"\n").unwrap();
        assert!(RecAdConfig::from_toml(&t).is_err());
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.quantize, QuantizeMode::Off);
    }

    #[test]
    fn rejects_unknown_placement_and_defaults_replicated() {
        let t = Toml::parse("[train]\nplacement = \"telepathy\"\n").unwrap();
        assert!(RecAdConfig::from_toml(&t).is_err());
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.devices, 1);
        assert_eq!(c.placement, Placement::Replicated);
    }

    #[test]
    fn parses_autotune_section_and_defaults_off() {
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.autotune, AutotuneCfg::default());
        assert!(!c.autotune.enabled, "autotune must default off");
        let doc = r#"
[autotune]
enabled = true
serve = false
cache_ladder = [32, 96]
probe_batches = 5
min_refresh = 4
max_refresh = 128
reuse_decay_tol = 0.2
target_p99_us = 5000
max_batch_cap = 8
"#;
        let c = RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).unwrap();
        assert!(c.autotune.enabled && c.autotune.cache_on() && c.autotune.reorder_on());
        assert!(!c.autotune.serve_on());
        assert_eq!(c.autotune.cache_ladder, vec![32, 96]);
        assert_eq!(c.autotune.probe_batches, 5);
        assert_eq!(c.autotune.min_refresh, 4);
        assert_eq!(c.autotune.max_refresh, 128);
        assert!((c.autotune.reuse_decay_tol - 0.2).abs() < 1e-12);
        assert_eq!(c.autotune.target_p99_us, 5000);
        assert_eq!(c.autotune.max_batch_cap, 8);
    }

    #[test]
    fn parses_fault_section_and_defaults_off() {
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.fault, FaultCfg::default());
        assert!(!c.fault.enabled, "fault injection must default off");
        assert!(c.fault.plan().is_none(), "disabled cfg must build no plan");
        let doc = r#"
[fault]
enabled = true
seed = 9
kill_replica = 0
kill_after = 3
panic_rate = 0.05
stall_rate = 0.1
stall_ms = 2
sever_rate = 0.02
flood_rate = 0.01
flood_burst = 2
straggle_rate = 0.25
straggle_ms = 1
dead_worker = 1
dead_round = 4
kill_node = 1
node_kill_after = 6
node_kill_rate = 0.5
"#;
        let c = RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).unwrap();
        assert!(c.fault.enabled);
        assert_eq!(c.fault.seed, 9);
        assert_eq!(c.fault.kill_replica, Some(0));
        assert_eq!(c.fault.kill_after, 3);
        assert!((c.fault.panic_rate - 0.05).abs() < 1e-12);
        assert!((c.fault.stall_rate - 0.1).abs() < 1e-12);
        assert_eq!(c.fault.stall_ms, 2);
        assert!((c.fault.sever_rate - 0.02).abs() < 1e-12);
        assert!((c.fault.flood_rate - 0.01).abs() < 1e-12);
        assert_eq!(c.fault.flood_burst, 2);
        assert!((c.fault.straggle_rate - 0.25).abs() < 1e-12);
        assert_eq!(c.fault.straggle_ms, 1);
        assert_eq!(c.fault.dead_worker, Some(1));
        assert_eq!(c.fault.dead_round, 4);
        assert_eq!(c.fault.kill_node, Some(1));
        assert_eq!(c.fault.node_kill_after, 6);
        assert!((c.fault.node_kill_rate - 0.5).abs() < 1e-12);
        assert!(c.fault.plan().is_some());
    }

    #[test]
    fn parses_net_section_and_splits_node_list() {
        let t = Toml::parse("[run]\nepochs = 1\n").unwrap();
        let c = RecAdConfig::from_toml(&t).unwrap();
        assert_eq!(c.net, NetCfg::default());
        assert_eq!(c.net.listen, "127.0.0.1:7070");
        assert!(c.net.node_list().is_empty(), "no nodes by default");
        let doc = r#"
[net]
listen = "0.0.0.0:7071"
nodes = "10.0.0.1:7070, 10.0.0.2:7070,10.0.0.3:7070"
vnodes = 128
heartbeat_ms = 25
max_outstanding = 64
"#;
        let c = RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.net.listen, "0.0.0.0:7071");
        assert_eq!(
            c.net.node_list(),
            vec!["10.0.0.1:7070", "10.0.0.2:7070", "10.0.0.3:7070"]
        );
        assert_eq!(c.net.vnodes, 128);
        assert_eq!(c.net.heartbeat_ms, 25);
        assert_eq!(c.net.max_outstanding, 64);
    }

    #[test]
    fn parses_lint_section_extending_defaults() {
        let doc = "[lint]\nallow_instant = \"src/custom/probe.rs, src/other/\"\nstrict_pragmas = true\n";
        let c = RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).unwrap();
        // defaults survive…
        assert!(c.lint.allow_instant.iter().any(|p| p == "src/util/clock.rs"));
        assert!(c.lint.request_paths.iter().any(|p| p == "src/serve/"));
        // …and the extensions land
        assert!(c.lint.allow_instant.iter().any(|p| p == "src/custom/probe.rs"));
        assert!(c.lint.allow_instant.iter().any(|p| p == "src/other/"));
        assert!(c.lint.strict_pragmas);
        // defaults without the section
        let c = RecAdConfig::from_toml(&Toml::parse("[run]\nepochs = 1\n").unwrap()).unwrap();
        assert!(!c.lint.strict_pragmas);
        assert_eq!(c.lint.allow_spawn.len(), 3);
    }

    #[test]
    fn parses_serve_guard_knobs() {
        let doc = "[serve]\nshed_budget_us = 500\nheartbeat_ms = 5\nhang_ms = 100\n";
        let c = RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).unwrap();
        assert_eq!(c.serve.shed_budget_us, 500);
        assert_eq!(c.serve.heartbeat_ms, 5);
        assert_eq!(c.serve.hang_ms, 100);
        // defaults: no shedding, no supervision
        let c = RecAdConfig::from_toml(&Toml::parse("[run]\nepochs = 1\n").unwrap()).unwrap();
        assert_eq!(c.serve.shed_budget_us, 0);
        assert_eq!(c.serve.heartbeat_ms, 0);
        assert_eq!(c.serve.hang_ms, 200);
    }

    #[test]
    fn rejects_invalid_numerics_naming_the_key() {
        let cases = [
            ("[serve]\nreplicas = 0\n", "serve.replicas"),
            ("[serve]\nmax_batch = 0\n", "serve.max_batch"),
            ("[serve]\ndeadline_us = 0\n", "serve.deadline_us"),
            ("[train]\ndevices = 0\n", "train.devices"),
            ("[serve]\nshed_budget_us = -5\n", "serve.shed_budget_us"),
            ("[serve]\nheartbeat_ms = -1\n", "serve.heartbeat_ms"),
            ("[serve]\nhang_ms = 1.5\n", "serve.hang_ms"),
            ("[serve]\narrival_rate = -10.0\n", "serve.arrival_rate"),
            ("[fault]\npanic_rate = 1.5\n", "fault.panic_rate"),
            ("[fault]\nstall_rate = -0.1\n", "fault.stall_rate"),
            ("[fault]\nsever_rate = 2\n", "fault.sever_rate"),
            ("[fault]\nflood_rate = -1\n", "fault.flood_rate"),
            ("[fault]\nstraggle_rate = 1.01\n", "fault.straggle_rate"),
            ("[fault]\nkill_replica = -2\n", "fault.kill_replica"),
            ("[fault]\nstall_ms = 2.5\n", "fault.stall_ms"),
            ("[fault]\ndead_worker = -1\n", "fault.dead_worker"),
            ("[fault]\nkill_node = -1\n", "fault.kill_node"),
            ("[fault]\nnode_kill_after = 1.5\n", "fault.node_kill_after"),
            ("[fault]\nnode_kill_rate = 1.5\n", "fault.node_kill_rate"),
            ("[net]\nvnodes = 0\n", "net.vnodes"),
            ("[net]\nmax_outstanding = 0.5\n", "net.max_outstanding"),
            ("[net]\nheartbeat_ms = -1\n", "net.heartbeat_ms"),
        ];
        for (doc, key) in cases {
            let t = Toml::parse(doc).unwrap();
            let err = RecAdConfig::from_toml(&t)
                .err()
                .unwrap_or_else(|| panic!("{doc:?} must be rejected"));
            let msg = format!("{err:#}");
            assert!(msg.contains(key), "error for {doc:?} does not name '{key}': {msg}");
        }
        // the valid boundary values still pass
        for doc in ["[serve]\nreplicas = 1\n", "[fault]\npanic_rate = 1.0\n",
                    "[fault]\nstall_rate = 0.0\n", "[serve]\narrival_rate = 0.0\n"] {
            assert!(RecAdConfig::from_toml(&Toml::parse(doc).unwrap()).is_ok(), "{doc:?}");
        }
    }

    #[test]
    fn arrays_and_underscored_numbers() {
        let t = Toml::parse("dims = [64, 32]\nrows = 12_000_000\n").unwrap();
        assert_eq!(t.nums("dims"), Some(vec![64.0, 32.0]));
        assert_eq!(t.num_or("rows", 0.0), 12_000_000.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Toml::parse("key value").is_err());
        assert!(Toml::parse("key = {inline}").is_err());
    }

    #[test]
    fn engine_cfg_reflects_ablations() {
        let mut c = RecAdConfig::default();
        c.reuse = false;
        c.workers = 4;
        let e = c.engine_cfg();
        assert!(!e.tt_opts.reuse);
        assert!(e.tt_opts.grad_aggregation);
        assert_eq!(e.exec.workers, 4);
    }
}
