//! Shared workload builders for the `cargo bench` targets (one per paper
//! table/figure).  Benches run on a CPU substrate, so dataset schemas are
//! instantiated at `BENCH_SCALE` of the paper's vocabulary sizes — the
//! index *distribution shape* (Zipf skew, co-occurrence) is preserved,
//! which is what every measured effect depends on (DESIGN.md §4).

use crate::coordinator::engine::EngineCfg;
use crate::data::ctr::{Batch, CtrGenerator};
use crate::data::schema::{self, DatasetSchema};
use crate::exec::ExecCfg;
use crate::tt::table::EffTtOptions;

/// Vocabulary scale for bench instantiations.
pub const BENCH_SCALE: f64 = 1.0 / 1000.0;

/// Env var every bench honors for its parallel arm.
pub const WORKERS_ENV: &str = "RECAD_WORKERS";

/// Worker count for the parallel arm of a bench: `RECAD_WORKERS` if set
/// (parsed by `ExecCfg::from_env`; invalid/zero values mean serial), else
/// all available hardware threads.
pub fn bench_workers() -> usize {
    match std::env::var(WORKERS_ENV) {
        Ok(raw) => {
            if raw.trim().parse::<usize>().ok().filter(|&w| w >= 1).is_none() {
                eprintln!(
                    "warning: {WORKERS_ENV}='{raw}' is not a positive integer; \
                     running serial (workers=1)"
                );
            }
            ExecCfg::from_env(WORKERS_ENV).workers
        }
        Err(_) => ExecCfg::available().workers,
    }
}

/// The workers arms a bench should run: always `[1]`, plus the parallel
/// arm when more than one hardware thread is usable.
pub fn exec_arms() -> Vec<usize> {
    let n = bench_workers();
    if n > 1 {
        vec![1, n]
    } else {
        vec![1]
    }
}

/// One measured bench arm, in `perf_probe`'s JSON schema: throughput +
/// p50/p99 per-iteration latency at a worker count.
pub struct BenchArm {
    pub name: String,
    pub workers: usize,
    /// items (lookups or samples) per second
    pub throughput: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// number of measured samples behind the percentiles (lets CI assert
    /// an arm — e.g. the ingest-stall arms — actually collected data)
    pub n: usize,
    /// extra per-arm scalars serialized as additional JSON keys (e.g.
    /// the device-placement arms report `payload_bytes`)
    pub extra: Vec<(String, f64)>,
}

impl BenchArm {
    /// Build an arm from repeated per-iteration wall times (seconds) and
    /// the items processed per iteration.
    pub fn from_iters(name: String, workers: usize, iters: &[f64], items: usize) -> BenchArm {
        let s = crate::util::stats::summarize(iters);
        BenchArm {
            name,
            workers,
            throughput: items as f64 / s.p50,
            p50_us: s.p50 * 1e6,
            p99_us: s.p99 * 1e6,
            n: iters.len(),
            extra: Vec::new(),
        }
    }

    /// Attach an extra scalar reported alongside the standard fields.
    pub fn with_extra(mut self, key: &str, value: f64) -> BenchArm {
        self.extra.push((key.to_string(), value));
        self
    }

    fn json(&self) -> String {
        let extra: String = self
            .extra
            .iter()
            .map(|(k, v)| format!(", \"{k}\": {v:.1}"))
            .collect();
        format!(
            "{{\"name\": \"{}\", \"workers\": {}, \"throughput_per_sec\": {:.1}, \
             \"p50_us\": {:.1}, \"p99_us\": {:.1}, \"n\": {}{extra}}}",
            self.name, self.workers, self.throughput, self.p50_us, self.p99_us, self.n
        )
    }
}

/// Look up an arm's extra scalar by arm name + key (e.g. the
/// `payload_bytes` the placement and quantized-path arms report) — the
/// helper benches use to assert cross-arm orderings before writing JSON.
pub fn arm_extra(arms: &[BenchArm], name: &str, key: &str) -> Option<f64> {
    arms.iter()
        .find(|a| a.name == name)?
        .extra
        .iter()
        .find(|(k, _)| k == key)
        .map(|&(_, v)| v)
}

/// Write `BENCH_<bench>.json` in `perf_probe`'s schema, then parse it
/// back with the crate's JSON parser as a self-check (the CI smoke job
/// relies on this failing loudly on malformed output).  Returns the path.
pub fn write_bench_json(bench: &str, parallel_workers: usize, arms: &[BenchArm]) -> String {
    let body: Vec<String> = arms.iter().map(|a| a.json()).collect();
    let json = format!(
        "{{\"bench\": \"{bench}\", \"parallel_workers\": {parallel_workers}, \
         \"arms\": [\n  {}\n]}}\n",
        body.join(",\n  ")
    );
    let path = format!("BENCH_{bench}.json");
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("write {path}: {e}"));
    let parsed = crate::util::json::Json::parse(&json)
        .unwrap_or_else(|e| panic!("{path} is not valid JSON: {e:?}"));
    let n = parsed
        .get("arms")
        .and_then(|a| a.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    assert_eq!(n, arms.len(), "{path}: arm count mismatch after round-trip");
    path
}

/// Scale a schema's vocabularies (min 16 rows each).
pub fn scaled(s: &DatasetSchema, scale: f64) -> DatasetSchema {
    DatasetSchema {
        name: s.name,
        n_dense: s.n_dense,
        vocabs: s
            .vocabs
            .iter()
            .map(|&v| ((v as f64 * scale) as u64).max(16))
            .collect(),
        emb_dim: s.emb_dim,
        zipf_s: s.zipf_s,
        ft_rank: s.ft_rank,
    }
}

/// Engine config for a (scaled) schema: tables above `threshold` rows are
/// TT-compressed — the paper's §V-C policy, scaled alongside the vocab.
pub fn engine_for(s: &DatasetSchema, scale: f64, rank: usize) -> EngineCfg {
    let threshold = (1_000_000.0 * scale) as u64;
    EngineCfg {
        dense_dim: s.n_dense,
        emb_dim: s.emb_dim.min(16), // bench dim capped for CPU wall time
        tables: s.vocabs.iter().map(|&v| (v, v > threshold)).collect(),
        tt_rank: rank,
        bot_hidden: vec![64, 32],
        top_hidden: vec![64, 32],
        lr: 0.05,
        tt_opts: EffTtOptions::default(),
        exec: ExecCfg::serial(),
    }
}

/// The three CTR datasets + IEEE118, scaled for benching.
pub fn bench_schemas() -> Vec<DatasetSchema> {
    vec![
        scaled(&schema::avazu(), BENCH_SCALE),
        scaled(&schema::criteo_kaggle(), BENCH_SCALE),
        scaled(&schema::ieee118(), BENCH_SCALE),
    ]
}

/// Profiling + eval batch streams for one schema.
pub fn workload(s: &DatasetSchema, seed: u64, n_batches: usize, batch: usize)
    -> (Vec<Batch>, Vec<Batch>) {
    let mut gen = CtrGenerator::new(s.clone(), seed);
    let profile = gen.batches(n_batches / 2, batch);
    let eval = gen.batches(n_batches, batch);
    (profile, eval)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_preserves_structure() {
        let s = scaled(&schema::avazu(), BENCH_SCALE);
        assert_eq!(s.n_sparse(), 20);
        assert!(s.vocabs[0] >= 16 && s.vocabs[0] < 10_000);
    }

    #[test]
    fn engine_compresses_scaled_big_tables() {
        let s = scaled(&schema::ieee118(), BENCH_SCALE);
        let cfg = engine_for(&s, BENCH_SCALE, 8);
        assert!(cfg.tables[0].1, "scaled 12k-row table should compress");
        assert!(!cfg.tables[2].1, "118-row table stays plain");
    }

    #[test]
    fn arm_extra_finds_named_scalars() {
        let arms = vec![
            BenchArm::from_iters("a".into(), 1, &[0.5], 10).with_extra("payload_bytes", 64.0),
            BenchArm::from_iters("b".into(), 2, &[0.5], 10),
        ];
        assert_eq!(arm_extra(&arms, "a", "payload_bytes"), Some(64.0));
        assert_eq!(arm_extra(&arms, "b", "payload_bytes"), None);
        assert_eq!(arm_extra(&arms, "c", "payload_bytes"), None);
    }

    #[test]
    fn workload_batches_have_schema_shape() {
        let s = scaled(&schema::avazu(), BENCH_SCALE);
        let (p, e) = workload(&s, 1, 8, 64);
        assert_eq!(p.len(), 4);
        assert_eq!(e.len(), 8);
        assert_eq!(e[0].sparse.len(), 64 * 20);
    }
}
