//! FAE baseline (paper [25]): hot embeddings live on the GPU, cold ones on
//! the host.  Batches containing only hot indices train entirely on
//! device; a batch touching any cold index falls back to the PS path.
//! The paper observes ~25% of batches stay cold-contaminated — the ceiling
//! FAE hits and Rec-AD removes (§V-H).

use std::collections::HashSet;
use std::time::Instant;

use crate::baselines::{StepCost, TrainArm};
use crate::coordinator::engine::{EngineCfg, NativeDlrm};
use crate::coordinator::platform::SimPlatform;
use crate::data::ctr::Batch;
use crate::reorder::freq::FreqCounter;
use crate::util::prng::Rng;

pub struct Fae {
    pub engine: NativeDlrm,
    pub platform: SimPlatform,
    /// Per-table hot sets (device-resident rows).
    hot: Vec<HashSet<u64>>,
    big_slots: Vec<usize>,
    pub hot_batches: u64,
    pub cold_batches: u64,
}

impl Fae {
    /// Profile `profile_batches` to pick hot sets covering `hot_mass` of
    /// accesses on the host-eligible (large) tables.
    pub fn new(
        mut cfg: EngineCfg,
        platform: SimPlatform,
        host_threshold_rows: u64,
        profile_batches: &[Batch],
        hot_mass: f64,
        rng: &mut Rng,
    ) -> Fae {
        for t in cfg.tables.iter_mut() {
            t.1 = false; // FAE keeps tables uncompressed
        }
        let ns = cfg.tables.len();
        let big_slots: Vec<usize> = cfg
            .tables
            .iter()
            .enumerate()
            .filter(|(_, t)| t.0 > host_threshold_rows)
            .map(|(i, _)| i)
            .collect();
        let mut hot = vec![HashSet::new(); ns];
        for &slot in &big_slots {
            let mut f = FreqCounter::new();
            for b in profile_batches {
                let col: Vec<u64> = b.sparse_col(slot, ns).collect();
                f.observe(&col);
            }
            hot[slot] = f.hot_set(hot_mass).into_iter().collect();
        }
        Fae {
            engine: NativeDlrm::new(cfg, rng),
            platform,
            hot,
            big_slots,
            hot_batches: 0,
            cold_batches: 0,
        }
    }

    fn cold_rows(&self, batch: &Batch) -> usize {
        let ns = self.engine.cfg.n_tables();
        let mut cold = HashSet::new();
        for &slot in &self.big_slots {
            for idx in batch.sparse_col(slot, ns) {
                if !self.hot[slot].contains(&idx) {
                    cold.insert((slot, idx));
                }
            }
        }
        cold.len()
    }
}

impl TrainArm for Fae {
    fn name(&self) -> String {
        "FAE".to_string()
    }

    fn step(&mut self, batch: &Batch) -> StepCost {
        let cold = self.cold_rows(batch);
        let c = &self.platform.cost;
        let comm = if cold == 0 {
            self.hot_batches += 1;
            c.dispatch
        } else {
            self.cold_batches += 1;
            let bytes = (cold * self.engine.cfg.emb_dim * 4) as u64;
            c.gather_time(cold) + c.h2d_time(bytes) * 2 + c.gather_time(cold) + c.dispatch * 2
        };
        // lint:allow(D2) baseline step timing is the Table III measurement itself
        let t = Instant::now();
        let loss = self.engine.train_step(batch);
        StepCost { loss, compute: t.elapsed(), comm }
    }

    fn device_embedding_bytes(&self) -> u64 {
        let dim = self.engine.cfg.emb_dim as u64;
        let hot_rows: u64 = self.hot.iter().map(|h| h.len() as u64).sum();
        let small: u64 = self
            .engine
            .tables
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.big_slots.contains(i))
            .map(|(_, t)| t.bytes())
            .sum();
        small + hot_rows * dim * 4
    }

    fn host_embedding_bytes(&self) -> u64 {
        self.engine
            .tables
            .iter()
            .enumerate()
            .filter(|(i, _)| self.big_slots.contains(i))
            .map(|(_, t)| t.bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::DatasetSchema;
    use crate::data::ctr::CtrGenerator;

    fn setup() -> (Fae, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(20_000, false), (50, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let schema = DatasetSchema {
            name: "fae-test",
            n_dense: 2,
            vocabs: vec![20_000, 50],
            emb_dim: 8,
            zipf_s: 1.3,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 3);
        let profile = gen.batches(20, 8);
        let mut rng = Rng::new(9);
        let arm = Fae::new(cfg, SimPlatform::v100(1), 1000, &profile, 0.97, &mut rng);
        let eval = gen.batches(20, 8);
        (arm, eval)
    }

    #[test]
    fn most_batches_hot_under_zipf() {
        let (mut arm, eval) = setup();
        for b in &eval {
            arm.step(b);
        }
        let total = arm.hot_batches + arm.cold_batches;
        assert_eq!(total, 20);
        assert!(
            arm.hot_batches > 0,
            "zipf-1.3 with 97% hot mass and batch 8 should give all-hot batches"
        );
    }

    #[test]
    fn cold_batches_cost_more() {
        let (mut arm, eval) = setup();
        let mut hot_comm = None;
        let mut cold_comm = None;
        for b in &eval {
            let before_cold = arm.cold_batches;
            let c = arm.step(b);
            if arm.cold_batches > before_cold {
                cold_comm.get_or_insert(c.comm);
            } else {
                hot_comm.get_or_insert(c.comm);
            }
        }
        if let (Some(h), Some(c)) = (hot_comm, cold_comm) {
            assert!(c > h, "cold {c:?} !> hot {h:?}");
        }
    }
}
