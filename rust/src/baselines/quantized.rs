//! Post-training int8 quantization baseline (the paper's related-work
//! alternative to TT compression, ref [22]): per-row symmetric int8
//! weights with an f32 scale.  4× compression (vs Eff-TT's 5–80×) and a
//! measurable accuracy cost — the trade-off Table I summarizes.

use crate::tt::linalg::{axpy, i8_scale, quantize_i8, Dequant, QI8};
use crate::tt::plain::PlainTable;

/// Per-row symmetric int8 embedding table.
pub struct QuantizedTable {
    pub rows: u64,
    pub dim: usize,
    q: Vec<i8>,
    scale: Vec<f32>,
}

impl QuantizedTable {
    /// Quantize an existing f32 table (the shared `tt::linalg` int8
    /// scheme: per-block symmetric scale, one block per row here).
    pub fn from_plain(t: &PlainTable) -> QuantizedTable {
        let (rows, dim) = (t.rows, t.dim);
        let mut q = vec![0i8; rows as usize * dim];
        let mut scale = vec![0.0f32; rows as usize];
        for r in 0..rows as usize {
            let row = &t.weights[r * dim..(r + 1) * dim];
            let s = i8_scale(row);
            scale[r] = s;
            quantize_i8(row, s, &mut q[r * dim..(r + 1) * dim]);
        }
        QuantizedTable { rows, dim, q, scale }
    }

    pub fn bytes(&self) -> u64 {
        (self.q.len() + self.scale.len() * 4) as u64
    }

    /// Dequantized row materialization (panics unless `out.len()` is
    /// exactly `dim` — a short buffer used to truncate silently).
    pub fn row(&self, i: u64, out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), d, "row buffer len {} != dim {d}", out.len());
        let i = i as usize;
        QI8 { q: &self.q[i * d..(i + 1) * d], scale: self.scale[i] }.dequant_into(out);
    }

    /// EmbeddingBag(sum) with on-the-fly dequantization.
    pub fn embedding_bag(&self, indices: &[u64], offsets: &[usize], out: &mut [f32]) {
        let d = self.dim;
        let bags = offsets.len() - 1;
        assert_eq!(out.len(), bags * d);
        out.fill(0.0);
        let mut row = vec![0.0f32; d];
        for b in 0..bags {
            let dst = &mut out[b * d..(b + 1) * d];
            for k in offsets[b]..offsets[b + 1] {
                self.row(indices[k], &mut row);
                axpy(dst, 1.0, &row);
            }
        }
    }

    /// Max absolute quantization error across the table.
    pub fn max_error(&self, original: &PlainTable) -> f32 {
        let d = self.dim;
        let mut row = vec![0.0f32; d];
        let mut err = 0.0f32;
        for r in 0..self.rows {
            self.row(r, &mut row);
            for (a, b) in row.iter().zip(original.row(r)) {
                err = err.max((a - b).abs());
            }
        }
        err
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tt::shapes::TtShapes;
    use crate::util::prng::Rng;

    #[test]
    fn four_x_compression() {
        let mut rng = Rng::new(1);
        let t = PlainTable::new(1000, 16, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        let ratio = t.bytes() as f64 / q.bytes() as f64;
        assert!(ratio > 3.0 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(2);
        let t = PlainTable::new(500, 16, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        // symmetric int8: error ≤ scale/2 = max|row|/254
        let worst_scale = (0..500u64)
            .map(|r| t.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .fold(0.0f32, f32::max);
        assert!(q.max_error(&t) <= worst_scale / 127.0 + 1e-6);
    }

    #[test]
    fn bag_close_to_plain() {
        let mut rng = Rng::new(3);
        let t = PlainTable::new(200, 8, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        let idx = [5u64, 9, 5, 77];
        let off = [0usize, 4];
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        t.embedding_bag(&idx, &off, &mut a);
        q.embedding_bag(&idx, &off, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
    }

    #[test]
    fn zero_max_row_round_trips_exact_zeros() {
        let mut rng = Rng::new(4);
        let mut t = PlainTable::new(8, 4, &mut rng);
        t.weights[2 * 4..3 * 4].fill(0.0); // all-zero row => max == 0.0
        let q = QuantizedTable::from_plain(&t);
        let mut out = vec![1.0f32; 4];
        q.row(2, &mut out);
        assert_eq!(out, vec![0.0; 4], "zero row must dequantize to exact zeros");
        // and a nonzero neighbor still round-trips within scale/2
        q.row(3, &mut out);
        let orig = t.row(3);
        let max = orig.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (a, b) in out.iter().zip(orig) {
            assert!((a - b).abs() <= max / 127.0 + 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "row buffer len")]
    fn short_row_buffer_panics_instead_of_truncating() {
        let mut rng = Rng::new(5);
        let t = PlainTable::new(4, 8, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        let mut short = vec![0.0f32; 4]; // != dim — used to truncate silently
        q.row(0, &mut short);
    }

    /// Table I context: int8 gives 4x, Eff-TT gives far more at scale.
    #[test]
    fn tt_beats_quantization_on_footprint() {
        let shapes = TtShapes::plan(1_000_000, 16, 16);
        let int8_bytes = 1_000_000u64 * (16 + 4); // q + scale
        assert!(shapes.tt_bytes() * 10 < int8_bytes);
    }
}
