//! Post-training int8 quantization baseline (the paper's related-work
//! alternative to TT compression, ref [22]): per-row symmetric int8
//! weights with an f32 scale.  4× compression (vs Eff-TT's 5–80×) and a
//! measurable accuracy cost — the trade-off Table I summarizes.

use crate::tt::linalg::axpy;
use crate::tt::plain::PlainTable;

/// Per-row symmetric int8 embedding table.
pub struct QuantizedTable {
    pub rows: u64,
    pub dim: usize,
    q: Vec<i8>,
    scale: Vec<f32>,
}

impl QuantizedTable {
    /// Quantize an existing f32 table.
    pub fn from_plain(t: &PlainTable) -> QuantizedTable {
        let (rows, dim) = (t.rows, t.dim);
        let mut q = vec![0i8; rows as usize * dim];
        let mut scale = vec![0.0f32; rows as usize];
        for r in 0..rows as usize {
            let row = &t.weights[r * dim..(r + 1) * dim];
            let max = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = if max > 0.0 { max / 127.0 } else { 1.0 };
            scale[r] = s;
            for d in 0..dim {
                q[r * dim + d] = (row[d] / s).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantizedTable { rows, dim, q, scale }
    }

    pub fn bytes(&self) -> u64 {
        (self.q.len() + self.scale.len() * 4) as u64
    }

    /// Dequantized row materialization.
    pub fn row(&self, i: u64, out: &mut [f32]) {
        let d = self.dim;
        let s = self.scale[i as usize];
        for (o, &qv) in out.iter_mut().zip(&self.q[i as usize * d..(i as usize + 1) * d]) {
            *o = qv as f32 * s;
        }
    }

    /// EmbeddingBag(sum) with on-the-fly dequantization.
    pub fn embedding_bag(&self, indices: &[u64], offsets: &[usize], out: &mut [f32]) {
        let d = self.dim;
        let bags = offsets.len() - 1;
        assert_eq!(out.len(), bags * d);
        out.fill(0.0);
        let mut row = vec![0.0f32; d];
        for b in 0..bags {
            let dst = &mut out[b * d..(b + 1) * d];
            for k in offsets[b]..offsets[b + 1] {
                self.row(indices[k], &mut row);
                axpy(dst, 1.0, &row);
            }
        }
    }

    /// Max absolute quantization error across the table.
    pub fn max_error(&self, original: &PlainTable) -> f32 {
        let d = self.dim;
        let mut row = vec![0.0f32; d];
        let mut err = 0.0f32;
        for r in 0..self.rows {
            self.row(r, &mut row);
            for (a, b) in row.iter().zip(original.row(r)) {
                err = err.max((a - b).abs());
            }
        }
        err
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tt::shapes::TtShapes;
    use crate::util::prng::Rng;

    #[test]
    fn four_x_compression() {
        let mut rng = Rng::new(1);
        let t = PlainTable::new(1000, 16, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        let ratio = t.bytes() as f64 / q.bytes() as f64;
        assert!(ratio > 3.0 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = Rng::new(2);
        let t = PlainTable::new(500, 16, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        // symmetric int8: error ≤ scale/2 = max|row|/254
        let worst_scale = (0..500u64)
            .map(|r| t.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .fold(0.0f32, f32::max);
        assert!(q.max_error(&t) <= worst_scale / 127.0 + 1e-6);
    }

    #[test]
    fn bag_close_to_plain() {
        let mut rng = Rng::new(3);
        let t = PlainTable::new(200, 8, &mut rng);
        let q = QuantizedTable::from_plain(&t);
        let idx = [5u64, 9, 5, 77];
        let off = [0usize, 4];
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        t.embedding_bag(&idx, &off, &mut a);
        q.embedding_bag(&idx, &off, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
    }

    /// Table I context: int8 gives 4x, Eff-TT gives far more at scale.
    #[test]
    fn tt_beats_quantization_on_footprint() {
        let shapes = TtShapes::plan(1_000_000, 16, 16);
        let int8_bytes = 1_000_000u64 * (16 + 4); // q + scale
        assert!(shapes.tt_bytes() * 10 < int8_bytes);
    }
}
