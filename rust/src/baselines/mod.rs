//! Baseline systems (paper §V-C): DLRM-PS, FAE, TT-Rec, HugeCTR-like and
//! TorchRec-like arms, plus a classical GBDT-flavor detector for Table I
//! context.
//!
//! Single-device arms implement [`TrainArm`]: every step runs **real**
//! compute through the native engine and reports a **modeled** link cost
//! from the platform cost model; benches compose the two (sequential arms:
//! `compute + comm`; the pipeline overlaps for real in
//! `coordinator::pipeline`).  Multi-device scaling (Figs. 11/13) is
//! composed analytically from measured compute + the cost model — see
//! `multi_gpu.rs`.

pub mod dlrm_ps;
pub mod fae;
pub mod gbdt;
pub mod multi_gpu;
pub mod quantized;
pub mod recad;
pub mod ttrec;

use std::time::Duration;

use crate::data::ctr::Batch;

/// The outcome of one training step under a given system arm.
pub struct StepCost {
    pub loss: f32,
    /// Measured on-device compute time.
    pub compute: Duration,
    /// Modeled communication/dispatch time (serialized with compute for
    /// non-pipelined systems).
    pub comm: Duration,
}

impl StepCost {
    pub fn total(&self) -> Duration {
        self.compute + self.comm
    }
}

/// A trainable system arm.
pub trait TrainArm {
    fn name(&self) -> String;
    fn step(&mut self, batch: &Batch) -> StepCost;
    /// Device-resident embedding bytes.
    fn device_embedding_bytes(&self) -> u64;
    /// Host-resident embedding bytes.
    fn host_embedding_bytes(&self) -> u64;
}

/// Throughput over a batch stream: samples / Σ step totals.
pub fn run_arm(arm: &mut dyn TrainArm, batches: &[Batch]) -> ArmReport {
    let mut compute = Duration::ZERO;
    let mut comm = Duration::ZERO;
    let mut losses = Vec::with_capacity(batches.len());
    for b in batches {
        let c = arm.step(b);
        compute += c.compute;
        comm += c.comm;
        losses.push(c.loss);
    }
    let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
    ArmReport {
        name: arm.name(),
        samples,
        compute,
        comm,
        losses,
    }
}

#[derive(Clone, Debug)]
pub struct ArmReport {
    pub name: String,
    pub samples: u64,
    pub compute: Duration,
    pub comm: Duration,
    pub losses: Vec<f32>,
}

impl ArmReport {
    pub fn total(&self) -> Duration {
        self.compute + self.comm
    }

    pub fn throughput(&self) -> f64 {
        self.samples as f64 / self.total().as_secs_f64()
    }

    pub fn mean_tail_loss(&self) -> f32 {
        let k = (self.losses.len() / 5).max(1);
        self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32
    }
}
