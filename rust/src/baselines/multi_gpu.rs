//! Multi-device scaling models (Figs. 11 & 13).
//!
//! Real data-parallel speedup cannot be measured on this single-core
//! substrate, so scaling composes **measured** single-device compute with
//! the platform's **modeled** interconnect costs — the same decomposition
//! the paper's analysis uses:
//!
//! * **Rec-AD (data parallel)** — Eff-TT tables are small enough to
//!   replicate; per step: compute/n + allreduce(MLP grads + TT core grads).
//! * **DLRM (model parallel embeddings)** — tables sharded; per step:
//!   compute/n + 2× all-to-all of the batch's embedding vectors (fwd
//!   gather + bwd scatter) + allreduce(MLP grads).
//! * **HugeCTR-like** — model-parallel embeddings with optimized fused
//!   collectives: same structure, lower per-transfer latency.
//! * **TorchRec-like** — column-wise sharding: every lookup touches all
//!   shards, all-to-all volume multiplies by the shard fan-out factor.

use std::time::Duration;

use crate::coordinator::platform::CostModel;

#[derive(Clone, Copy, Debug)]
pub struct MultiGpuWorkload {
    /// Measured single-device compute per batch.
    pub compute: Duration,
    pub batch_size: usize,
    pub n_sparse: usize,
    pub emb_dim: usize,
    /// Data-parallel gradient payload (MLP params + TT cores), bytes.
    pub dp_grad_bytes: u64,
}

impl MultiGpuWorkload {
    /// Bytes of embedding vectors a batch moves in one all-to-all.
    fn emb_bytes(&self) -> u64 {
        (self.batch_size * self.n_sparse * self.emb_dim * 4) as u64
    }
}

/// Per-step time for each system at `n` devices.
pub fn recad_step(w: &MultiGpuWorkload, c: &CostModel, n: usize) -> Duration {
    let compute = w.compute / n as u32;
    compute + c.allreduce_time(w.dp_grad_bytes, n)
}

pub fn dlrm_model_parallel_step(w: &MultiGpuWorkload, c: &CostModel, n: usize) -> Duration {
    let compute = w.compute / n as u32;
    // fwd all-to-all + bwd all-to-all of embedding activations/grads
    compute
        + c.alltoall_time(w.emb_bytes(), n) * 2
        + c.allreduce_time(w.dp_grad_bytes, n)
}

pub fn hugectr_step(w: &MultiGpuWorkload, c: &CostModel, n: usize) -> Duration {
    // production-grade collectives: fused launches halve the fixed
    // latency; volume is the same as model-parallel DLRM
    let mut cc = *c;
    cc.transfer_latency = c.transfer_latency / 2;
    let compute = w.compute / n as u32;
    compute
        + cc.alltoall_time(w.emb_bytes(), n) * 2
        + cc.allreduce_time(w.dp_grad_bytes, n)
}

pub fn torchrec_step(w: &MultiGpuWorkload, c: &CostModel, n: usize) -> Duration {
    // column-wise sharding: each embedding vector is split across all n
    // shards, so every lookup gathers from every device (higher volume +
    // per-shard latency)
    let compute = w.compute / n as u32;
    let vol = w.emb_bytes(); // same payload but touched by all shards
    compute
        + c.alltoall_time(vol, n) * 2
        + c.transfer_latency * (n as u32) / 2
        + c.allreduce_time(w.dp_grad_bytes, n)
}

/// Throughput (samples/s) from a per-step time.
pub fn throughput(w: &MultiGpuWorkload, step: Duration, n: usize) -> f64 {
    // n devices each process batch_size samples per step (weak scaling,
    // as in the paper's throughput plots)
    (w.batch_size * n) as f64 / step.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::platform::SimPlatform;

    fn workload() -> MultiGpuWorkload {
        MultiGpuWorkload {
            compute: Duration::from_millis(40),
            batch_size: 4096,
            n_sparse: 26,
            emb_dim: 16,
            dp_grad_bytes: 2 << 20,
        }
    }

    #[test]
    fn recad_scales_better_than_model_parallel() {
        let w = workload();
        let c = SimPlatform::v100(4).cost;
        let r4 = throughput(&w, recad_step(&w, &c, 4), 4);
        let d4 = throughput(&w, dlrm_model_parallel_step(&w, &c, 4), 4);
        assert!(r4 > d4, "Rec-AD {r4} !> DLRM-MP {d4}");
    }

    #[test]
    fn fig11_shape_scaling_gain() {
        // 4-GPU Rec-AD must beat 1-GPU by a healthy margin, and beat
        // 4-GPU DLRM by ≈1.4x (paper)
        let w = workload();
        let c = SimPlatform::v100(4).cost;
        let r1 = throughput(&w, recad_step(&w, &c, 1), 1);
        let r4 = throughput(&w, recad_step(&w, &c, 4), 4);
        let d4 = throughput(&w, dlrm_model_parallel_step(&w, &c, 4), 4);
        assert!(r4 > 2.0 * r1);
        let ratio = r4 / d4;
        assert!(ratio > 1.1 && ratio < 3.0, "ratio {ratio}");
    }

    #[test]
    fn fig13_shape_ordering() {
        // Rec-AD > HugeCTR > TorchRec at 4 devices (paper: 1.07x / 1.35x)
        let w = workload();
        let c = SimPlatform::v100(4).cost;
        let r = throughput(&w, recad_step(&w, &c, 4), 4);
        let h = throughput(&w, hugectr_step(&w, &c, 4), 4);
        let t = throughput(&w, torchrec_step(&w, &c, 4), 4);
        assert!(r > h && h > t, "r={r} h={h} t={t}");
    }
}
