//! Classical non-DLRM comparator (Table I's XGBoost row): gradient-boosted
//! decision stumps over the dense features + one-hot-hashed sparse
//! features.  Exists to contextualize detection accuracy — classical
//! learners can't exploit the sparse structure the way embeddings do.

use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};

/// One regression stump on feature `f` at threshold `t`.
#[derive(Clone, Debug)]
struct Stump {
    feature: usize,
    threshold: f32,
    left: f32,
    right: f32,
}

pub struct Gbdt {
    stumps: Vec<Stump>,
    pub learning_rate: f32,
    base: f32,
}

const HASH_BUCKETS: usize = 16;

/// Feature extraction: dense features + per-sparse-feature hash bucket
/// indicator means (cheap one-hot summary usable by stumps).
fn features(s: &Sample) -> Vec<f32> {
    let mut f = Vec::with_capacity(N_DENSE + N_SPARSE);
    f.extend_from_slice(&s.dense);
    for &idx in &s.sparse {
        f.push((idx % HASH_BUCKETS as u64) as f32 / HASH_BUCKETS as f32);
    }
    f
}

impl Gbdt {
    /// Fit `rounds` stumps on logistic gradients.
    pub fn fit(samples: &[Sample], rounds: usize, learning_rate: f32) -> Gbdt {
        let x: Vec<Vec<f32>> = samples.iter().map(features).collect();
        let y: Vec<f32> = samples.iter().map(|s| s.label).collect();
        let pos = y.iter().sum::<f32>() / y.len() as f32;
        let base = (pos / (1.0 - pos)).max(1e-6).ln();
        let mut pred = vec![base; y.len()];
        let mut stumps = Vec::with_capacity(rounds);
        let nf = x[0].len();
        for _ in 0..rounds {
            // pseudo-residuals of log-loss
            let resid: Vec<f32> = pred
                .iter()
                .zip(&y)
                .map(|(&p, &yy)| yy - sigmoid(p))
                .collect();
            // best stump over a coarse threshold grid
            let mut best: Option<(f32, Stump)> = None;
            for f in 0..nf {
                let mut vals: Vec<f32> = x.iter().map(|r| r[f]).collect();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for q in [0.25, 0.5, 0.75] {
                    let t = vals[(vals.len() as f32 * q) as usize];
                    let (mut ls, mut ln, mut rs, mut rn) = (0.0f32, 0, 0.0f32, 0);
                    for (r, row) in x.iter().enumerate() {
                        if row[f] <= t {
                            ls += resid[r];
                            ln += 1;
                        } else {
                            rs += resid[r];
                            rn += 1;
                        }
                    }
                    if ln == 0 || rn == 0 {
                        continue;
                    }
                    let (lv, rv) = (ls / ln as f32, rs / rn as f32);
                    let gain = ls * lv + rs * rv;
                    if best.as_ref().map(|(g, _)| gain > *g).unwrap_or(true) {
                        best = Some((
                            gain,
                            Stump { feature: f, threshold: t, left: lv, right: rv },
                        ));
                    }
                }
            }
            let stump = best.expect("non-degenerate data").1;
            for (r, row) in x.iter().enumerate() {
                let v = if row[stump.feature] <= stump.threshold {
                    stump.left
                } else {
                    stump.right
                };
                pred[r] += learning_rate * v;
            }
            stumps.push(stump);
        }
        Gbdt { stumps, learning_rate, base }
    }

    pub fn predict(&self, s: &Sample) -> f32 {
        let x = features(s);
        let mut p = self.base;
        for st in &self.stumps {
            p += self.learning_rate
                * if x[st.feature] <= st.threshold { st.left } else { st.right };
        }
        sigmoid(p)
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};

    #[test]
    fn learns_something_on_fdia_data() {
        let ds = generate(&DatasetCfg {
            n_normal: 300,
            n_attack: 100,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 20,
            noise_std: 0.005,
            seed: 3,
        });
        let (train, test) = ds.split(0.8);
        let model = Gbdt::fit(train, 30, 0.3);
        let correct = test
            .iter()
            .filter(|s| (model.predict(s) > 0.5) == (s.label > 0.5))
            .count();
        let acc = correct as f64 / test.len() as f64;
        // must beat the majority-class rate at least somewhat
        assert!(acc > 0.6, "gbdt acc {acc}");
    }
}
