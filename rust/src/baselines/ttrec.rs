//! TT-Rec baseline (paper [23]): TT-compressed embeddings on device, but
//! WITHOUT the Eff-TT compute optimizations — no intermediate reuse, no
//! advance gradient aggregation, no fused update, no index reordering.
//! Compression equals Rec-AD's; throughput should trail it by ≈1.4×
//! (paper §V-H).

use std::time::Instant;

use crate::baselines::{StepCost, TrainArm};
use crate::coordinator::engine::{EngineCfg, NativeDlrm};
use crate::coordinator::platform::SimPlatform;
use crate::data::ctr::Batch;
use crate::tt::table::EffTtOptions;
use crate::util::prng::Rng;

pub struct TtRec {
    pub engine: NativeDlrm,
    pub platform: SimPlatform,
}

impl TtRec {
    pub fn new(mut cfg: EngineCfg, platform: SimPlatform, rng: &mut Rng) -> TtRec {
        cfg.tt_opts = EffTtOptions::ttrec_baseline();
        TtRec { engine: NativeDlrm::new(cfg, rng), platform }
    }
}

impl TrainArm for TtRec {
    fn name(&self) -> String {
        "TT-Rec".to_string()
    }

    fn step(&mut self, batch: &Batch) -> StepCost {
        // lint:allow(D2) baseline step timing is the Table III measurement itself
        let t = Instant::now();
        let loss = self.engine.train_step(batch);
        StepCost { loss, compute: t.elapsed(), comm: self.platform.cost.dispatch }
    }

    fn device_embedding_bytes(&self) -> u64 {
        self.engine.embedding_bytes()
    }

    fn host_embedding_bytes(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttrec_disables_eff_tt_optimizations() {
        let cfg = EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(3000, true)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let mut rng = Rng::new(1);
        let mut arm = TtRec::new(cfg, SimPlatform::v100(1), &mut rng);
        let batch = Batch {
            dense: vec![0.1; 8],
            sparse: vec![1, 1, 2, 2], // duplicates: reuse would dedup
            labels: vec![1.0, 0.0, 1.0, 0.0],
            batch_size: 4,
        };
        arm.step(&batch);
        let s = arm.engine.tt_stats();
        assert_eq!(s.reuse_hits, 0, "TT-Rec must not reuse");
        assert_eq!(s.grads_aggregated, 0, "TT-Rec must not aggregate");
    }
}
