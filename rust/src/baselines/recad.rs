//! The Rec-AD arm: Eff-TT embeddings (reuse + aggregation + fused update)
//! plus the index bijection applied per batch (§III-G/H).  All compressed
//! tables are device-resident — no CPU↔GPU embedding traffic.
//!
//! Since the access refactor this arm is pure *configuration* over the
//! shared `access` layer: an [`AccessPlanner`] profiled offline owns the
//! bijections and the per-batch remap/dedup; the arm itself just feeds
//! plans to the engine.

use std::time::Instant;

use crate::access::{AccessPlanner, BatchPlan};
use crate::baselines::{StepCost, TrainArm};
use crate::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use crate::coordinator::platform::SimPlatform;
use crate::data::ctr::Batch;
use crate::util::prng::Rng;

pub struct RecAd {
    pub engine: NativeDlrm,
    pub platform: SimPlatform,
    /// Shared access-planning layer (bijections built offline from the
    /// profiling sample, paper §III-H; identity when `reorder=false`).
    pub planner: AccessPlanner,
    plan: BatchPlan,
}

impl RecAd {
    /// `profile` drives both the hot-set and the co-occurrence graph.
    /// `reorder=false` is the Fig. 12 "w/o index reordering" arm.
    pub fn new(
        cfg: EngineCfg,
        platform: SimPlatform,
        profile: &[Batch],
        reorder: bool,
        rng: &mut Rng,
    ) -> RecAd {
        let planner = if reorder {
            AccessPlanner::with_profile(&cfg, profile, 0.05)
        } else {
            AccessPlanner::for_engine_cfg(&cfg)
        };
        RecAd {
            engine: NativeDlrm::new(cfg, rng),
            platform,
            planner,
            plan: BatchPlan::default(),
        }
    }

    pub fn tt_stats(&self) -> crate::tt::table::TtStats {
        self.engine.tt_stats()
    }

    /// The plan of the most recent step (tests / instrumentation).
    pub fn last_plan(&self) -> &BatchPlan {
        &self.plan
    }
}

impl TrainArm for RecAd {
    fn name(&self) -> String {
        "Rec-AD".to_string()
    }

    fn step(&mut self, batch: &Batch) -> StepCost {
        let dispatch = self.platform.cost.dispatch;
        // lint:allow(D2) baseline step timing is the Table III measurement itself
        let t = Instant::now();
        // access planning (remap + dedup) is part of the input pipeline
        // (measured)
        self.planner.plan_into(batch, &mut self.plan);
        let loss = self.engine.train_step_planned(batch, &self.plan);
        StepCost { loss, compute: t.elapsed(), comm: dispatch }
    }

    fn device_embedding_bytes(&self) -> u64 {
        self.engine.embedding_bytes()
    }

    fn host_embedding_bytes(&self) -> u64 {
        0
    }
}

/// Footprint check used by Fig. 13: Rec-AD fits where plain tables spill.
pub fn fits_single_device(cfg: &EngineCfg, platform: &SimPlatform, rng: &mut Rng) -> bool {
    let engine = NativeDlrm::new(cfg.clone(), rng);
    let bytes: u64 = engine
        .tables
        .iter()
        .map(|t| match t {
            TableSlot::Tt(t) => t.bytes(),
            TableSlot::Plain(t) => t.bytes(),
        })
        .sum();
    platform.fits_hbm(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::DatasetSchema;
    use crate::data::ctr::CtrGenerator;

    fn setup(reorder: bool) -> (RecAd, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(4000, true), (40, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let schema = DatasetSchema {
            name: "recad-test",
            n_dense: 2,
            vocabs: vec![4000, 40],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 5);
        let profile = gen.batches(15, 32);
        let mut rng = Rng::new(4);
        let arm = RecAd::new(cfg, SimPlatform::v100(1), &profile, reorder, &mut rng);
        let eval = gen.batches(10, 32);
        (arm, eval)
    }

    #[test]
    fn steps_and_learns() {
        let (mut arm, eval) = setup(true);
        let first = arm.step(&eval[0]).loss;
        for b in &eval {
            for _ in 0..3 {
                arm.step(b);
            }
        }
        let last = arm.step(&eval[0]).loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn reordering_increases_reuse_hits() {
        let (mut with, eval) = setup(true);
        let (mut without, _) = setup(false);
        for b in &eval {
            with.step(b);
            without.step(b);
        }
        let (a, b) = (with.tt_stats(), without.tt_stats());
        // CtrGenerator draws iid per batch (no co-occurrence structure),
        // so the bijection cannot *gain* reuse here — it must merely not
        // lose materially.  The genuine improvement on structured batches
        // is proven in reorder::bijection::tests::
        // reordering_improves_prefix_sharing.
        assert!(
            a.reuse_hits as f64 >= 0.8 * b.reuse_hits as f64,
            "reordering lost too much reuse: {} vs {}",
            a.reuse_hits,
            b.reuse_hits
        );
    }

    #[test]
    fn remap_is_in_vocab_and_stable() {
        let (mut arm, eval) = setup(true);
        let ns = arm.engine.cfg.n_tables();
        let rows0 = arm.engine.cfg.tables[0].0;
        let before: Vec<u64> = eval[0].sparse.clone();
        arm.step(&eval[0]);
        let plan = arm.last_plan();
        // table-0 column remapped within vocab, table-1 untouched
        for r in 0..eval[0].batch_size {
            assert!(plan.col(0)[r] < rows0);
            assert_eq!(plan.col(1)[r], before[r * ns + 1]);
        }
        // remap is a function: same raw id -> same new id, every step
        let bij = arm.planner.bijection(0).expect("profiled bijection");
        for r in 0..eval[0].batch_size {
            assert_eq!(arm.last_plan().col(0)[r], bij.apply(before[r * ns]));
        }
    }
}
