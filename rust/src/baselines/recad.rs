//! The Rec-AD arm: Eff-TT embeddings (reuse + aggregation + fused update)
//! plus the offline index bijection applied per batch (§III-G/H).  All
//! compressed tables are device-resident — no CPU↔GPU embedding traffic.

use std::time::Instant;

use crate::baselines::{StepCost, TrainArm};
use crate::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use crate::coordinator::platform::SimPlatform;
use crate::data::ctr::Batch;
use crate::reorder::bijection::IndexBijection;
use crate::util::prng::Rng;

pub struct RecAd {
    pub engine: NativeDlrm,
    pub platform: SimPlatform,
    /// Per-table bijection (None = identity; built offline from a
    /// profiling sample, paper §III-H).
    bijections: Vec<Option<IndexBijection>>,
    scratch_batch: Batch,
}

impl RecAd {
    /// `profile` drives both the hot-set and the co-occurrence graph.
    /// `reorder=false` is the Fig. 12 "w/o index reordering" arm.
    pub fn new(
        cfg: EngineCfg,
        platform: SimPlatform,
        profile: &[Batch],
        reorder: bool,
        rng: &mut Rng,
    ) -> RecAd {
        let ns = cfg.tables.len();
        let mut bijections: Vec<Option<IndexBijection>> = (0..ns).map(|_| None).collect();
        if reorder {
            for (slot, &(rows, compressed)) in cfg.tables.iter().enumerate() {
                if !compressed {
                    continue; // reordering pays off on the TT tables
                }
                let cols: Vec<Vec<u64>> = profile
                    .iter()
                    .map(|b| b.sparse_col(slot, ns).collect())
                    .collect();
                let refs: Vec<&[u64]> = cols.iter().map(|c| c.as_slice()).collect();
                bijections[slot] = Some(IndexBijection::build(rows, &refs, 0.05));
            }
        }
        RecAd {
            engine: NativeDlrm::new(cfg, rng),
            platform,
            bijections,
            scratch_batch: Batch { dense: vec![], sparse: vec![], labels: vec![], batch_size: 0 },
        }
    }

    /// Apply the per-table bijections into the scratch batch (free-standing
    /// borrow shape so the engine can be borrowed mutably afterwards).
    fn remap_into(
        scratch: &mut Batch,
        bijections: &[Option<IndexBijection>],
        batch: &Batch,
        ns: usize,
    ) {
        scratch.dense.clear();
        scratch.dense.extend_from_slice(&batch.dense);
        scratch.labels.clear();
        scratch.labels.extend_from_slice(&batch.labels);
        scratch.sparse.clear();
        scratch.sparse.extend_from_slice(&batch.sparse);
        scratch.batch_size = batch.batch_size;
        for (slot, bij) in bijections.iter().enumerate() {
            if let Some(bij) = bij {
                for r in 0..scratch.batch_size {
                    let k = r * ns + slot;
                    scratch.sparse[k] = bij.apply(scratch.sparse[k]);
                }
            }
        }
    }

    pub fn tt_stats(&self) -> crate::tt::table::TtStats {
        self.engine.tt_stats()
    }
}

impl TrainArm for RecAd {
    fn name(&self) -> String {
        "Rec-AD".to_string()
    }

    fn step(&mut self, batch: &Batch) -> StepCost {
        let dispatch = self.platform.cost.dispatch;
        let t = Instant::now();
        // bijection application is part of the input pipeline (measured)
        Self::remap_into(
            &mut self.scratch_batch,
            &self.bijections,
            batch,
            self.engine.cfg.n_tables(),
        );
        let loss = self.engine.train_step(&self.scratch_batch);
        StepCost { loss, compute: t.elapsed(), comm: dispatch }
    }

    fn device_embedding_bytes(&self) -> u64 {
        self.engine.embedding_bytes()
    }

    fn host_embedding_bytes(&self) -> u64 {
        0
    }
}

/// Footprint check used by Fig. 13: Rec-AD fits where plain tables spill.
pub fn fits_single_device(cfg: &EngineCfg, platform: &SimPlatform, rng: &mut Rng) -> bool {
    let engine = NativeDlrm::new(cfg.clone(), rng);
    let bytes: u64 = engine
        .tables
        .iter()
        .map(|t| match t {
            TableSlot::Tt(t) => t.bytes(),
            TableSlot::Plain(t) => t.bytes(),
        })
        .sum();
    platform.fits_hbm(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::DatasetSchema;
    use crate::data::ctr::CtrGenerator;

    fn setup(reorder: bool) -> (RecAd, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 2,
            emb_dim: 8,
            tables: vec![(4000, true), (40, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let schema = DatasetSchema {
            name: "recad-test",
            n_dense: 2,
            vocabs: vec![4000, 40],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 5);
        let profile = gen.batches(15, 32);
        let mut rng = Rng::new(4);
        let arm = RecAd::new(cfg, SimPlatform::v100(1), &profile, reorder, &mut rng);
        let eval = gen.batches(10, 32);
        (arm, eval)
    }

    #[test]
    fn steps_and_learns() {
        let (mut arm, eval) = setup(true);
        let first = arm.step(&eval[0]).loss;
        for b in &eval {
            for _ in 0..3 {
                arm.step(b);
            }
        }
        let last = arm.step(&eval[0]).loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn reordering_increases_reuse_hits() {
        let (mut with, eval) = setup(true);
        let (mut without, _) = setup(false);
        for b in &eval {
            with.step(b);
            without.step(b);
        }
        let (a, b) = (with.tt_stats(), without.tt_stats());
        // CtrGenerator draws iid per batch (no co-occurrence structure),
        // so the bijection cannot *gain* reuse here — it must merely not
        // lose materially.  The genuine improvement on structured batches
        // is proven in reorder::bijection::tests::
        // reordering_improves_prefix_sharing.
        assert!(
            a.reuse_hits as f64 >= 0.8 * b.reuse_hits as f64,
            "reordering lost too much reuse: {} vs {}",
            a.reuse_hits,
            b.reuse_hits
        );
    }

    #[test]
    fn remap_is_in_vocab_and_stable() {
        let (mut arm, eval) = setup(true);
        let ns = arm.engine.cfg.n_tables();
        let rows0 = arm.engine.cfg.tables[0].0;
        let before: Vec<u64> = eval[0].sparse.clone();
        arm.step(&eval[0]);
        let remapped = arm.scratch_batch.sparse.clone();
        // table-0 entries remapped within vocab, table-1 untouched
        for r in 0..eval[0].batch_size {
            assert!(remapped[r * ns] < rows0);
            assert_eq!(remapped[r * ns + 1], before[r * ns + 1]);
        }
    }
}
