//! Vanilla DLRM with a parameter-server embedding layout (paper baseline
//! [24]): uncompressed tables too large for HBM live in host memory; every
//! batch pays gather + H2D for its rows and D2H for its gradients.

use std::time::Instant;

use crate::baselines::{StepCost, TrainArm};
use crate::coordinator::engine::{EngineCfg, NativeDlrm};
use crate::coordinator::platform::SimPlatform;
use crate::data::ctr::Batch;
use crate::util::prng::Rng;

pub struct DlrmPs {
    pub engine: NativeDlrm,
    pub platform: SimPlatform,
    /// Table slots that exceed the device budget and live on the host.
    host_slots: Vec<usize>,
}

impl DlrmPs {
    /// Build with every table uncompressed; tables bigger than
    /// `host_threshold_rows` are host-resident (PS mode).
    pub fn new(
        mut cfg: EngineCfg,
        platform: SimPlatform,
        host_threshold_rows: u64,
        rng: &mut Rng,
    ) -> DlrmPs {
        for t in cfg.tables.iter_mut() {
            t.1 = false; // uncompressed everywhere — the baseline
        }
        let host_slots = cfg
            .tables
            .iter()
            .enumerate()
            .filter(|(_, t)| t.0 > host_threshold_rows)
            .map(|(i, _)| i)
            .collect();
        DlrmPs { engine: NativeDlrm::new(cfg, rng), platform, host_slots }
    }

    fn distinct_host_rows(&self, batch: &Batch) -> usize {
        let ns = self.engine.cfg.n_tables();
        let mut seen = std::collections::HashSet::new();
        for &slot in &self.host_slots {
            for idx in batch.sparse_col(slot, ns) {
                seen.insert((slot, idx));
            }
        }
        seen.len()
    }
}

impl TrainArm for DlrmPs {
    fn name(&self) -> String {
        "DLRM".to_string()
    }

    fn step(&mut self, batch: &Batch) -> StepCost {
        let rows = self.distinct_host_rows(batch);
        let bytes = (rows * self.engine.cfg.emb_dim * 4) as u64;
        let c = &self.platform.cost;
        // gather + H2D (rows down) + D2H (grads back) + host apply
        let comm = c.gather_time(rows)
            + c.h2d_time(bytes)
            + c.h2d_time(bytes)
            + c.gather_time(rows)
            + c.dispatch * 2;
        // lint:allow(D2) baseline step timing is the Table III measurement itself
        let t = Instant::now();
        let loss = self.engine.train_step(batch);
        StepCost { loss, compute: t.elapsed(), comm }
    }

    fn device_embedding_bytes(&self) -> u64 {
        self.engine
            .tables
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.host_slots.contains(i))
            .map(|(_, t)| t.bytes())
            .sum()
    }

    fn host_embedding_bytes(&self) -> u64 {
        self.engine
            .tables
            .iter()
            .enumerate()
            .filter(|(i, _)| self.host_slots.contains(i))
            .map(|(_, t)| t.bytes())
            .sum()
    }
}

// expose for FAE which shares the table-placement logic
impl DlrmPs {
    pub fn host_slots(&self) -> &[usize] {
        &self.host_slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn small() -> (DlrmPs, Batch) {
        let cfg = EngineCfg {
            dense_dim: 4,
            emb_dim: 8,
            tables: vec![(5000, false), (100, false)],
            tt_rank: 4,
            bot_hidden: vec![8],
            top_hidden: vec![8],
            lr: 0.05,
            tt_opts: Default::default(),
            exec: Default::default(),
        };
        let mut rng = Rng::new(1);
        let arm = DlrmPs::new(cfg, SimPlatform::v100(1), 1000, &mut rng);
        let batch = Batch {
            dense: vec![0.1; 8 * 4],
            sparse: (0..16).map(|i| (i * 37 % 100) as u64).collect(),
            labels: vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0],
            batch_size: 8,
        };
        (arm, batch)
    }

    #[test]
    fn big_table_goes_to_host() {
        let (arm, _) = small();
        assert_eq!(arm.host_slots(), &[0]);
        assert!(arm.host_embedding_bytes() > arm.device_embedding_bytes());
    }

    #[test]
    fn step_charges_comm() {
        let (mut arm, batch) = small();
        let c = arm.step(&batch);
        assert!(c.comm > Duration::ZERO);
        assert!(c.compute > Duration::ZERO);
        assert!(c.loss.is_finite());
    }
}
