//! Human and machine-readable output for `recad lint`.
//!
//! The JSON schema (stable, asserted by CI's bench-smoke job via
//! `BENCH_lint.json` and consumable by editors):
//!
//! ```json
//! {
//!   "rules": [{"id": "D1", "invariant": "…"}, …],
//!   "files_scanned": 63,
//!   "findings_raw": 41,
//!   "suppressed": 38,
//!   "findings": [
//!     {"file": "src/foo.rs", "line": 12, "rule": "D1", "message": "…"}
//!   ]
//! }
//! ```
//!
//! `findings` lists only what survives pragma suppression (including
//! pragma-misuse findings under rule id "pragma"); `findings_raw`
//! counts rule hits before pragmas — the ratchet CI tracks is
//! `findings == []` while `findings_raw` stays honest about how many
//! sites are pragma-justified rather than clean.

use std::collections::BTreeMap;

use crate::analysis::rules::{Finding, RULES};
use crate::analysis::LintRun;
use crate::util::json::Json;

/// Render findings for a terminal: grouped by file, `file:line [rule]
/// message`, with a one-line summary.
pub fn human(run: &LintRun) -> String {
    let mut s = String::new();
    let mut last_file = "";
    for f in &run.findings {
        if f.file != last_file {
            s.push_str(&format!("{}\n", f.file));
            last_file = &f.file;
        }
        s.push_str(&format!("  {}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    s.push_str(&format!(
        "lint: {} file(s), {} finding(s) ({} raw, {} pragma-suppressed)\n",
        run.files,
        run.findings.len(),
        run.findings_raw,
        run.suppressed
    ));
    s
}

fn finding_json(f: &Finding) -> Json {
    let mut o = BTreeMap::new();
    o.insert("file".to_string(), Json::Str(f.file.clone()));
    o.insert("line".to_string(), Json::Num(f.line as f64));
    o.insert("rule".to_string(), Json::Str(f.rule.clone()));
    o.insert("message".to_string(), Json::Str(f.message.clone()));
    Json::Obj(o)
}

/// Serialize a run to the documented JSON schema.
pub fn to_json(run: &LintRun) -> String {
    let rules: Vec<Json> = RULES
        .iter()
        .map(|&(id, inv)| {
            let mut o = BTreeMap::new();
            o.insert("id".to_string(), Json::Str(id.to_string()));
            o.insert("invariant".to_string(), Json::Str(inv.to_string()));
            Json::Obj(o)
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("rules".to_string(), Json::Arr(rules));
    o.insert("files_scanned".to_string(), Json::Num(run.files as f64));
    o.insert("findings_raw".to_string(), Json::Num(run.findings_raw as f64));
    o.insert("suppressed".to_string(), Json::Num(run.suppressed as f64));
    o.insert(
        "findings".to_string(),
        Json::Arr(run.findings.iter().map(finding_json).collect()),
    );
    Json::Obj(o).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_run() -> LintRun {
        LintRun {
            files: 2,
            findings: vec![Finding {
                file: "src/a.rs".into(),
                line: 3,
                rule: "D1".into(),
                message: "iteration".into(),
            }],
            findings_raw: 4,
            suppressed: 3,
        }
    }

    #[test]
    fn json_round_trips_through_util_json() {
        let s = to_json(&sample_run());
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("files_scanned").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("findings_raw").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("rules").unwrap().as_arr().unwrap().len(), RULES.len());
        let f = j.get("findings").unwrap().idx(0).unwrap();
        assert_eq!(f.get("rule").unwrap().as_str().unwrap(), "D1");
        assert_eq!(f.get("line").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn human_output_names_every_finding() {
        let h = human(&sample_run());
        assert!(h.contains("src/a.rs:3 [D1]"));
        assert!(h.contains("1 finding(s) (4 raw, 3 pragma-suppressed)"));
    }
}
